"""Telemetry plane: tracing is bit-identical when detached AND when
attached (the tracer only records), exported Chrome traces are schema-
valid with non-overlapping per-lane spans, the idle attributor
decomposes a hand-built two-device timeline exactly, and the metrics
registry's instruments behave (percentiles, collisions, peaks)."""
import json

import numpy as np
import pytest

from repro.core.baselines import REGISTRY
from repro.core.simulation import (SimModel, heterogeneous_cluster,
                                   simulate_fedoptima)
from repro.fleet import diurnal_trace
from repro.obs import trace as trace_mod
from repro.obs.idle import attribute_idle
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import Tracer, emit_span, traced, validate_chrome_trace

MODEL = SimModel(dev_fwd_flops=1e9, dev_bwd_flops=2e9, full_fwd_flops=5e9,
                 srv_flops_per_batch=8e9, act_bytes=1e6, dev_model_bytes=4e6,
                 full_model_bytes=2e7, batch_size=32)


def _metric_tuple(m):
    return (tuple(np.asarray(m.dev_busy).tolist()), m.srv_busy,
            m.bytes_up, m.bytes_down, m.dev_samples, m.srv_batches,
            m.aggregations, m.max_buffered)


def _churn_trace(K, dur, seed=7):
    return diurnal_trace(K, horizon=dur, interval=dur / 24.0, day=dur / 2.0,
                         on_frac=0.6, bw=12.5e6, bw_jitter=0.3, seed=seed)


# ---------------------------------------------------------------------------
# bit-identity: the tracer only records
# ---------------------------------------------------------------------------

class TestBitIdentity:
    def test_detached_flag_off(self):
        assert trace_mod.TRACING is False
        assert trace_mod._STACK == []

    def test_fedoptima_traced_equals_plain(self):
        cluster = heterogeneous_cluster(6)
        fleet = _churn_trace(6, 120.0)
        kw = dict(duration=120.0, omega=4, fleet=fleet, seed=3)
        plain = simulate_fedoptima(MODEL, cluster, **kw)
        with traced(Tracer(domain="sim")) as tr:
            traced_m = simulate_fedoptima(MODEL, cluster, **kw)
        assert _metric_tuple(plain) == _metric_tuple(traced_m)
        assert len(tr.spans) > 0
        assert trace_mod.TRACING is False   # detached on exit

    @pytest.mark.parametrize("name", sorted(REGISTRY))
    def test_baselines_traced_equal_plain(self, name):
        cluster = heterogeneous_cluster(4)
        fn = REGISTRY[name]
        plain = fn(MODEL, cluster, duration=90.0)
        with traced(Tracer(domain="sim")):
            tm = fn(MODEL, cluster, duration=90.0)
        assert _metric_tuple(plain) == _metric_tuple(tm)


# ---------------------------------------------------------------------------
# Chrome export: schema validity + per-lane non-overlap
# ---------------------------------------------------------------------------

class TestChromeExport:
    def _trace_sim(self):
        cluster = heterogeneous_cluster(6)
        with traced(Tracer(domain="sim")) as tr:
            simulate_fedoptima(MODEL, cluster, duration=90.0, omega=4,
                               fleet=_churn_trace(6, 90.0), seed=5)
        return tr

    def test_valid_schema_and_lanes(self, tmp_path):
        tr = self._trace_sim()
        doc = tr.to_chrome()
        assert validate_chrome_trace(doc) == []
        lanes = tr.lanes()
        assert "srv" in lanes
        assert any(ln.startswith("dev/") for ln in lanes)
        assert any(ln.startswith("net/") for ln in lanes)
        # export round-trips through JSON
        path = tmp_path / "t.json"
        tr.export_chrome(str(path))
        with open(path) as f:
            assert validate_chrome_trace(json.load(f)) == []

    def test_pid_mapping(self):
        tr = self._trace_sim()
        doc = tr.to_chrome()
        by_tidname = {(e["pid"], e["args"]["name"])
                      for e in doc["traceEvents"]
                      if e["ph"] == "M" and e["name"] == "thread_name"}
        assert any(p == 1 and n == "srv" for p, n in by_tidname)
        assert any(p == 2 and n.startswith("device ")
                   for p, n in by_tidname)
        assert any(p == 3 and n.startswith("uplink ")
                   for p, n in by_tidname)

    def test_validator_flags_overlap(self):
        doc = {"traceEvents": [
            {"name": "a", "ph": "X", "ts": 0.0, "dur": 10.0,
             "pid": 1, "tid": 0},
            {"name": "b", "ph": "X", "ts": 5.0, "dur": 10.0,
             "pid": 1, "tid": 0}]}
        problems = validate_chrome_trace(doc)
        assert len(problems) == 1 and "overlap" in problems[0]

    def test_clip_spans_never_overlap(self):
        tr = Tracer(domain="sim")
        tr.add_span("srv", "a", 0.0, 10.0, clip=True)
        tr.add_span("srv", "b", 5.0, 15.0, clip=True)   # clips to [10, 15]
        tr.add_span("srv", "c", 6.0, 9.0, clip=True)    # fully shadowed
        assert [(s[2], s[3]) for s in tr.spans] == [(0.0, 10.0),
                                                    (10.0, 15.0)]
        assert validate_chrome_trace(tr.to_chrome()) == []


# ---------------------------------------------------------------------------
# idle attribution: synthetic two-device timeline, exact seconds
# ---------------------------------------------------------------------------

class TestIdleAttribution:
    def test_two_device_exact(self):
        tr = Tracer(domain="sim")
        tr.add_span("dev/0", "train", 0.0, 1.0)
        tr.add_span("dev/0", "train", 3.0, 4.0)
        tr.add_span("dev/1", "train", 0.0, 2.0)
        tr.add_span("srv", "aggregate", 2.0, 3.0)
        attr = attribute_idle(tr, duration=4.0)
        srv = attr["server"]
        # server: warmup [0,2) before its first busy; [3,4) a started+
        # online device (dev/1) idles while dev/0 runs -> straggler
        assert srv["busy_s"] == pytest.approx(1.0)
        assert srv["warmup_s"] == pytest.approx(2.0)
        assert srv["straggler_s"] == pytest.approx(1.0)
        assert srv["task_dependency_s"] == pytest.approx(0.0)
        dev = attr["devices"]
        # devices: [2,3) both wait on the server (task dependency, 2
        # device-seconds); [1,2) dev/0 waits on dev/1 and [3,4) dev/1
        # waits on dev/0 (straggler, 2 device-seconds)
        assert dev["busy_s"] == pytest.approx(4.0)
        assert dev["task_dependency_s"] == pytest.approx(2.0)
        assert dev["straggler_s"] == pytest.approx(2.0)
        assert dev["warmup_s"] == pytest.approx(0.0)
        # fractions normalize by total device-time (2 devices x 4 s)
        assert dev["task_dependency_frac"] == pytest.approx(0.25)

    def test_offline_device_counts_offline_not_idle(self):
        tr = Tracer(domain="sim")
        tr.add_span("dev/0", "train", 0.0, 2.0)
        tr.add_span("srv", "aggregate", 2.0, 4.0)
        tr.add_instant("dev/1", "leave", 0.0)
        tr.add_instant("dev/1", "join", 2.0)
        tr.add_span("dev/1", "train", 2.0, 4.0)
        attr = attribute_idle(tr, duration=4.0)
        assert attr["per_device"]["1"]["offline_s"] == pytest.approx(2.0)
        assert attr["devices"]["offline_s"] == pytest.approx(2.0)

    def test_rejects_bad_duration(self):
        with pytest.raises(ValueError):
            attribute_idle(Tracer(domain="sim"), duration=0.0)

    def test_sim_run_attribution_sums_to_one(self):
        cluster = heterogeneous_cluster(6)
        with traced(Tracer(domain="sim")) as tr:
            simulate_fedoptima(MODEL, cluster, duration=90.0, omega=4,
                               seed=5)
        attr = attribute_idle(tr, duration=90.0)
        srv = attr["server"]
        total = (srv["busy_s"] + srv["warmup_s"] +
                 srv["task_dependency_s"] + srv["straggler_s"])
        assert total == pytest.approx(90.0, rel=1e-6)
        assert 0.0 <= srv["idle_frac"] <= 1.0


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_counter_monotone(self):
        c = Counter()
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_gauge_peak(self):
        g = Gauge()
        g.set(5)
        g.add(-3)
        assert g.value == 2 and g.peak == 5

    def test_histogram_percentiles(self):
        h = Histogram()
        for v in (1.0, 2.0, 3.0, 4.0, 5.0):
            h.observe(v)
        snap = h.snapshot()
        assert snap["count"] == 5
        assert snap["min"] == 1.0 and snap["max"] == 5.0
        # bucket-quantized percentiles stay within the observed range
        assert 1.0 <= snap["p50"] <= 5.0
        assert snap["p50"] <= snap["p95"] <= snap["p99"] <= 5.0

    def test_histogram_empty(self):
        assert Histogram().snapshot() == {"count": 0}

    def test_registry_get_or_create_and_collision(self):
        reg = MetricsRegistry()
        assert reg.counter("x") is reg.counter("x")
        with pytest.raises(ValueError):
            reg.gauge("x")
        with pytest.raises(ValueError):
            reg.histogram("x")

    def test_dump_line_and_jsonl(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("a.n").inc(3)
        reg.gauge("a.level").set(7)
        line = reg.dump_line(prefix="[t]")
        assert line.startswith("[t]") and "a.n=3" in line
        path = tmp_path / "m.jsonl"
        reg.write_jsonl(str(path), extra={"tag": "x"})
        rec = json.loads(path.read_text().splitlines()[0])
        assert rec["tag"] == "x"
        assert rec["metrics"]["counters"]["a.n"] == 3

    def test_sim_metrics_to_registry_and_steady(self):
        cluster = heterogeneous_cluster(6)
        m = simulate_fedoptima(MODEL, cluster, duration=90.0, omega=4,
                               seed=5)
        reg = m.to_registry()
        snap = reg.snapshot()
        assert snap["counters"]["sim.aggregations"] == m.aggregations
        steady = m.steady_summary()
        assert steady and steady["warmup_s"] >= 0.0
        assert steady["steady_s"] == pytest.approx(
            90.0 - steady["warmup_s"])


# ---------------------------------------------------------------------------
# executor instrumentation (pod wall-domain lanes)
# ---------------------------------------------------------------------------

class _AsyncStub:
    """Future-backed device stand-in: dispatch returns immediately, the
    metrics block on a worker thread — the async contract RoundExecutor
    drains against (mirrors benchmarks.common.StubDevice)."""

    class _Lazy:
        def __init__(self, fut):
            self._fut = fut

        def __float__(self):
            return float(self._fut.result())

    def __init__(self, round_s):
        from concurrent.futures import ThreadPoolExecutor
        import time
        self._sleep = lambda: time.sleep(round_s) or 0.0
        self._pool = ThreadPoolExecutor(max_workers=1)

    def step(self, state, batch):
        fut = self._pool.submit(self._sleep)
        return state, {"d_loss": self._Lazy(fut), "s_loss": self._Lazy(fut)}

    def close(self):
        self._pool.shutdown(wait=True)


class TestExecutorTrace:
    def _run(self, window, tracer=None):
        from contextlib import ExitStack

        from repro.core.control_plane import ControlPlane
        from repro.core.executor import RoundExecutor

        G = 4
        cp = ControlPlane(G, 2, 4)
        dev = _AsyncStub(0.01)
        try:
            ex = RoundExecutor(dev.step, cp, window=window)
            with ExitStack() as stack:
                if tracer is not None:
                    stack.enter_context(traced(tracer))
                ex.run(0, 0, 6,
                       active_fn=lambda r: np.ones(G, bool),
                       batch_fn=lambda r, plan: {})
        finally:
            dev.close()
        return ex

    def test_window4_trace_has_mesh_and_device_lanes(self):
        tr = Tracer(domain="wall")
        ex = self._run(4, tracer=tr)
        lanes = tr.lanes()
        assert "mesh" in lanes
        assert any(ln.startswith("dev/") for ln in lanes)
        assert any(ln.startswith("host/") for ln in lanes)
        assert validate_chrome_trace(tr.to_chrome()) == []
        assert ex.peak_in_flight == 4

    def test_summary_registry_backed(self):
        ex = self._run(2)
        assert ex.metrics.counter("exec.host_s").value == ex.total_host_s
        assert ex.metrics.gauge("exec.in_flight").peak == ex.peak_in_flight
        s = ex.summary()
        assert s["peak_in_flight"] == ex.peak_in_flight


# ---------------------------------------------------------------------------
# lint RP002 extension (obs clock in hot paths)
# ---------------------------------------------------------------------------

class TestLintObsClock:
    def _lint(self, tmp_path, source, name="core/hot.py"):
        from repro.analysis.lint import lint_file
        p = tmp_path / name
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(source)
        return lint_file(p)

    def test_perf_counter_flagged_in_hot_path(self, tmp_path):
        errs = self._lint(tmp_path,
                          "import time\nt = time.perf_counter()\n")
        assert any(e.rule == "RP002" and "obs clock" in e.message
                   for e in errs)

    def test_monotonic_flagged(self, tmp_path):
        errs = self._lint(tmp_path, "import time\nt = time.monotonic()\n")
        assert any(e.rule == "RP002" for e in errs)

    def test_waiver_by_rule_id(self, tmp_path):
        errs = self._lint(
            tmp_path,
            "import time\n"
            "t = time.perf_counter()  # lint: allow-rp002\n")
        assert not any(e.rule == "RP002" for e in errs)

    def test_waiver_by_rule_name(self, tmp_path):
        errs = self._lint(
            tmp_path,
            "import time\n"
            "t = time.perf_counter()  # lint: allow-wallclock\n")
        assert not any(e.rule == "RP002" for e in errs)

    def test_obs_clock_itself_clean(self, tmp_path):
        # the sanctioned read is not in a hot segment and stays unflagged
        errs = self._lint(tmp_path,
                          "import time\nnow = time.perf_counter\n",
                          name="obs/clock.py")
        assert not errs

    def test_repo_is_lint_clean(self):
        from repro.analysis.lint import lint_paths
        import repro
        assert lint_paths([list(repro.__path__)[0]]) == []
