"""Async staleness-weighted aggregation (paper Alg. 4 lines 12-19)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregator import AsyncAggregator, fedasync_update


def _tree(v):
    return {"w": jnp.full((3,), v), "b": jnp.full((2,), v / 2)}


def test_fresh_update_alpha_one():
    agg = AsyncAggregator(theta_d=_tree(0.0), theta_aux=_tree(0.0))
    ok = agg.aggregate(_tree(1.0), _tree(1.0), t_k=0)   # staleness 0 -> α=1
    assert ok and agg.version == 1
    np.testing.assert_allclose(agg.theta_d["w"], 1.0)


def test_staleness_shrinks_alpha():
    agg = AsyncAggregator(theta_d=_tree(0.0), theta_aux=_tree(0.0))
    for _ in range(4):                       # advance global version to 4
        agg.aggregate(_tree(0.0), _tree(0.0), t_k=agg.version)
    agg.aggregate(_tree(1.0), _tree(1.0), t_k=0)   # staleness 4 -> α=1/5
    np.testing.assert_allclose(agg.theta_d["w"], 0.2, rtol=1e-6)


def test_too_stale_rejected():
    agg = AsyncAggregator(theta_d=_tree(0.0), theta_aux=_tree(0.0),
                          max_delay=2)
    for _ in range(5):
        agg.aggregate(_tree(0.0), _tree(0.0), t_k=agg.version)
    v = agg.version
    ok = agg.aggregate(_tree(9.0), _tree(9.0), t_k=0)   # staleness 5 > D=2
    assert not ok and agg.version == v and agg.n_rejected == 1
    np.testing.assert_allclose(agg.theta_d["w"], 0.0)


def test_snapshot_roundtrip():
    agg = AsyncAggregator(theta_d=_tree(3.0), theta_aux=_tree(1.0))
    d, a, t = agg.snapshot()
    np.testing.assert_allclose(d["w"], 3.0)
    assert t == 0


def test_functional_update_matches_class():
    g, l = _tree(0.0), _tree(2.0)
    out = fedasync_update(g, l, staleness=3)     # α = 1/4
    np.testing.assert_allclose(out["w"], 0.5, rtol=1e-6)


def test_sequential_lerp_equals_weighted_average_telescoped():
    """The on-mesh round aggregation (fedopt_step.aggregate) uses a
    normalized weighted mean; K sequential fresh lerps with α=1/(i+1)
    telescope to the plain mean — the two implementations agree."""
    updates = [_tree(float(i)) for i in range(1, 5)]
    g = _tree(0.0)
    # sequential: α chosen so result is running mean of updates seen so far
    for i, u in enumerate(updates):
        g = fedasync_update(g, u, staleness=i)   # α = 1/(i+1)
    mean = np.mean([float(i) for i in range(1, 5)])
    np.testing.assert_allclose(g["w"], mean, rtol=1e-6)
