"""Activation flow control (paper §3.4.1): the global cap ω is a strict
invariant — buffered + in-flight + granted tokens never exceed ω."""
import numpy as np
from _propcheck import given, settings, strategies as st

from repro.core.flow_control import FlowController


def test_at_most_omega_tokens_granted():
    fc = FlowController(omega=3)
    for k in range(10):
        fc.register(k)
    assert fc.active_tokens <= 3
    assert sum(fc.can_send(k) for k in range(10)) <= 3


def test_send_enqueue_dequeue_cycle():
    fc = FlowController(omega=2)
    fc.register(0), fc.register(1), fc.register(2)
    senders = [k for k in range(3) if fc.can_send(k)]
    assert len(senders) == 2
    k = senders[0]
    fc.mark_sent(k)
    assert not fc.can_send(k)          # sender deactivates after one batch
    fc.on_enqueue(k)
    assert fc.buffered == 1
    fc.on_dequeue(k)                   # server consumed -> token regrantable
    assert fc.promised <= 2


def test_grants_are_round_robin_fair():
    fc = FlowController(omega=1)
    for k in range(4):
        fc.register(k)
    served = []
    for _ in range(12):
        k = next(d for d in range(4) if fc.can_send(d))
        served.append(k)
        fc.mark_sent(k)
        fc.on_enqueue(k)
        fc.on_dequeue(k)
    assert sorted(set(served)) == [0, 1, 2, 3]
    # near-fair over three cycles (startup may favour device 0 once)
    counts = [served.count(k) for k in range(4)]
    assert max(counts) - min(counts) <= 2
    # strict rotation after warm-up
    assert served[-8:] == served[-8:-4] + served[-8:-4][:0] or \
        len(set(served[-4:])) == 4


@given(st.lists(st.sampled_from(["reg", "send", "enq", "deq", "leave"]),
                max_size=200),
       st.integers(1, 6))
@settings(max_examples=60, deadline=None)
def test_cap_invariant_under_any_event_order(events, omega):
    """Property (Eq. 3): Σ|Q_act| ≤ ω AND promised ≤ ω at every step, for
    any interleaving of registrations, sends, enqueues, dequeues, churn."""
    fc = FlowController(omega=omega)
    rng = np.random.default_rng(omega)
    registered, inflight_k, buffered_k = [], [], []
    for ev in events:
        if ev == "reg":
            k = len(registered)
            registered.append(k)
            fc.register(k)
        elif ev == "send":
            armed = [k for k in registered if fc.can_send(k)]
            if armed:
                k = armed[rng.integers(len(armed))]
                fc.mark_sent(k)
                inflight_k.append(k)
        elif ev == "enq" and inflight_k:
            k = inflight_k.pop(0)
            fc.on_enqueue(k)
            buffered_k.append(k)
        elif ev == "deq" and buffered_k:
            fc.on_dequeue(buffered_k.pop(0))
        elif ev == "leave" and registered:
            k = registered.pop(rng.integers(len(registered)))
            fc.on_device_left(k)
        assert fc.buffered <= omega, "buffer exceeded the global cap"
        assert fc.promised <= omega, "cap not strict (tokens over-granted)"
        assert fc.active_tokens >= 0 and fc.inflight >= 0


def test_churn_reclaims_inflight_sends():
    """Regression: a device dropping with an in-flight send must not leave
    ``promised`` permanently inflated (grants would starve as departed
    devices eat into ω)."""
    fc = FlowController(omega=2)
    for k in range(4):
        fc.register(k)
    senders = [k for k in range(4) if fc.can_send(k)]
    for k in senders:
        fc.mark_sent(k)                    # both tokens now in flight
    assert fc.inflight == 2
    for k in senders:
        fc.on_device_left(k)               # drop with sends still in flight
    assert fc.inflight == 0
    assert fc.promised == fc.buffered + fc.active_tokens
    # the reclaimed budget is re-granted to surviving devices
    assert fc.active_tokens == 2
    assert all(fc.can_send(k) for k in range(4) if k not in senders)
    # a zombie arrival from a departed device is rejected, keeping the cap
    assert fc.on_enqueue(senders[0]) is False
    assert fc.buffered == 0 and fc.within_cap


def test_memory_eq3_vs_eq2():
    """Server memory: FedOptima μ = μ_model + ω·μ_act is K-independent,
    OAFL Eq. 2 grows linearly (Fig. 3)."""
    mu_model, mu_act, omega = 40e6, 2e6, 8
    fedoptima = [mu_model + omega * mu_act for _ in (8, 64, 512)]
    oafl = [(k + 1) * mu_model + k * mu_act for k in (8, 64, 512)]
    assert fedoptima[0] == fedoptima[-1]
    assert oafl[-1] > 50 * oafl[0] / 9
    assert fedoptima[-1] < oafl[0]


def test_tiered_budget_admits_past_omega_and_counts_tiers():
    """pool_cap > 0: grants and admission run against ω + pool_cap; units
    buffered past ω are spill-tier residents (n_spilled), promoted back
    on dequeue (n_filled).  pool_cap=0 stays the strict Eq. 3 cap."""
    fc = FlowController(omega=2, pool_cap=3)
    for k in range(8):
        fc.register(k)
    assert fc.cap == 5 and fc.active_tokens == 5      # tokens up to ω+pool
    senders = [k for k in range(8) if fc.can_send(k)]
    for k in senders:
        fc.mark_sent(k)
        assert fc.on_enqueue(k)
    assert fc.buffered == 5 > fc.omega                # past the mesh tier
    assert fc.within_cap and fc.promised == 5
    assert fc.n_spilled == 3                          # admissions beyond ω
    for k in senders:
        fc.on_dequeue(k)
    assert fc.n_filled == 3 and fc.buffered == 0
    # regrants resume against the tiered cap
    assert fc.active_tokens == 5


@settings(max_examples=30)
@given(st.integers(1, 4), st.integers(0, 4), st.integers(1, 12))
def test_tiered_cap_is_strict_invariant(omega, pool, n_devices):
    """promised = buffered + inflight + tokens never exceeds ω + pool_cap
    through a random-ish churn of send/enqueue/dequeue cycles."""
    fc = FlowController(omega=omega, pool_cap=pool)
    for k in range(n_devices):
        fc.register(k)
    rng = np.random.default_rng(omega * 100 + pool * 10 + n_devices)
    for _ in range(50):
        assert fc.promised <= fc.cap and fc.within_cap
        k = int(rng.integers(n_devices))
        if fc.can_send(k):
            fc.mark_sent(k)
            fc.on_enqueue(k)
        elif fc.buffered and rng.integers(2):
            fc.on_dequeue(k)
    assert fc.within_cap
