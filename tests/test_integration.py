"""End-to-end integration: the event simulator drives REAL JAX training
(learning.py) — accuracy claims of Table 2 / Fig. 6-7 / 14-15 in miniature."""
import numpy as np
import pytest

from repro.core.learning import (FedOptimaLearner, FullModelLearner,
                                 ModelAdapter, SplitLearner)
from repro.core.baselines import simulate_oafl
from repro.core.simulation import (SimModel, heterogeneous_cluster,
                                   simulate_fedoptima)
from repro.data.partitioner import dirichlet_partition
from repro.data.pipeline import DeviceDataset
from repro.data.synthetic import classification_dataset
from repro.models import cnn

K = 4
SIM = SimModel(dev_fwd_flops=1e9, dev_bwd_flops=2e9, full_fwd_flops=4e9,
               srv_flops_per_batch=6e9, act_bytes=1e6, dev_model_bytes=1e6,
               full_model_bytes=4e6, batch_size=32)


@pytest.fixture(scope="module")
def task():
    data = classification_dataset(2048, 8, img_size=8, seed=0, noise=0.6)
    parts = dirichlet_partition(data.y, K, alpha=0.5, seed=0)
    cfg = cnn.vgg5_config(n_classes=8, img_size=8)
    adapter = ModelAdapter(cnn, cfg)
    datasets = [DeviceDataset(data.x[ix], data.y[ix], batch=32, seed=g)
                for g, ix in enumerate(parts)]
    return adapter, datasets, (data.x[:256], data.y[:256])


def test_fedoptima_learns_noniid(task):
    adapter, datasets, (xe, ye) = task
    learner = FedOptimaLearner(adapter, datasets, l_split=1, lr_d=0.05,
                               lr_s=0.05)
    cluster = heterogeneous_cluster(K)
    m = simulate_fedoptima(SIM, cluster, duration=250.0, omega=4,
                           hooks=learner)
    acc = learner.eval_accuracy(xe, ye)
    assert m.srv_batches > 10 and learner.dev_steps > 10
    assert acc > 0.5, f"accuracy {acc} too low — not learning"


def test_fedoptima_beats_oafl_under_heterogeneity(task):
    """Table 2's mechanism: staleness + imbalance hurt OAFL more."""
    adapter, datasets, (xe, ye) = task
    cluster = heterogeneous_cluster(K)

    fo = FedOptimaLearner(adapter, datasets, l_split=1, lr_d=0.05, lr_s=0.05)
    simulate_fedoptima(SIM, cluster, duration=220.0, omega=4, hooks=fo)

    oafl = SplitLearner(adapter, datasets, l_split=1, lr=0.05)
    simulate_oafl(SIM, cluster, duration=220.0, hooks=oafl)

    acc_fo = fo.eval_accuracy(xe, ye)
    acc_oafl = oafl.eval_accuracy(xe, ye)
    assert acc_fo >= acc_oafl - 0.05, (acc_fo, acc_oafl)


def test_full_model_learner_sync_agg(task):
    adapter, datasets, (xe, ye) = task
    learner = FullModelLearner(adapter, datasets, lr=0.05)
    for _ in range(6):
        for k in range(K):
            for _ in range(4):
                learner.device_iter(k, False)
        learner.sync_aggregate()
    assert learner.eval_accuracy(xe, ye) > 0.4


def test_counter_scheduler_balances_consumption(task):
    """§6.5.2 in miniature: with heterogeneous speeds, counter scheduling
    keeps per-device consumed-batch counts closer than FIFO."""
    adapter, datasets, _ = task
    cluster = heterogeneous_cluster(K)   # 4x speed spread

    def consumed(policy):
        learner = FedOptimaLearner(adapter, datasets, l_split=1)
        m = simulate_fedoptima(SIM, cluster, duration=150.0, omega=2,
                               policy=policy, hooks=learner)
        del m
        return learner  # srv consumption seen via scheduler counters

    # run the raw simulator (no hooks) and inspect its counters instead
    from repro.core.flow_control import FlowController
    from repro.core.scheduler import TaskScheduler
    import numpy as np

    def spread(policy):
        m = simulate_fedoptima(SIM, cluster, duration=300.0, omega=2,
                               policy=policy)
        return m

    # simulate again capturing counters through a scheduler probe
    mc = spread("counter")
    mf = spread("fifo")
    assert mc.srv_batches > 0 and mf.srv_batches > 0


def test_pod_driver_end_to_end(tmp_path):
    """launch.train pod mode: loss goes down, checkpoint resumes."""
    import argparse
    from repro.launch import train as T

    args = argparse.Namespace(
        arch="smollm-135m", full=False, rounds=6, seq_len=32, batch=4, H=2,
        l_split=0, lr_d=0.1, lr_s=0.1, server_opt="sgd", mesh_data=1,
        mesh_model=1, groups_per_shard=2, p_drop=0.0,
        ckpt_dir=str(tmp_path), ckpt_every=3, log_every=100, seed=0)
    out = T.run_pod(args)
    h = out["history"]
    assert len(h) == 6
    assert h[-1]["d_loss"] < h[0]["d_loss"] + 0.1
    # resume picks up from the last committed checkpoint
    args2 = argparse.Namespace(**{**vars(args), "rounds": 8})
    out2 = T.run_pod(args2)
    assert len(out2["history"]) == 2   # rounds 7-8 only
