"""Hybrid pjit step semantics: round structure, pipelining, aggregation,
multi-device SPMD equivalence."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import fedopt_step as F
from repro.launch.mesh import make_debug_mesh


def _setup(arch="smollm-135m", **kw):
    a = registry.smoke_config(arch)
    cfg = F.FedStepConfig(arch=a, l_split=1, n_groups=2, seq_len=16,
                          per_group_batch=4, H=2, **kw)
    mesh = make_debug_mesh(1, 1)
    jitted, _, s_spec, _ = F.jit_train_step(cfg, mesh)
    state = jax.jit(lambda: F.init_train_state(jax.random.PRNGKey(0), cfg),
                    out_shardings=s_spec)()
    batch = F.concrete_train_batch(jax.random.PRNGKey(1), cfg)
    return cfg, jitted, state, batch


def test_round_advances_version_once():
    cfg, step, state, batch = _setup()
    state, _ = step(state, batch)
    assert int(state["version"]) == 1 and int(state["step"]) == 1


def test_groups_identical_after_aggregation_uniform_weights():
    cfg, step, state, batch = _setup()
    state, _ = step(state, batch)
    for leaf in jax.tree.leaves(state["dev"]):
        np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[1]),
                                   atol=1e-6)


def test_groups_diverge_within_round():
    """Different local shards -> different pre-aggregation trajectories:
    verify by zeroing one group's aggregation weight and comparing."""
    cfg, step, state, batch = _setup()
    batch["agg_weight"] = jnp.asarray([1.0, 0.0])
    state, _ = step(state, batch)
    # global model equals group-0's trained block; group-1's contribution
    # was dropped, so rerunning with swapped weights must differ
    cfg2, step2, state2, batch2 = _setup()
    batch2["tokens"] = batch["tokens"]
    batch2["labels"] = batch["labels"]
    batch2["agg_weight"] = jnp.asarray([0.0, 1.0])
    state2, _ = step2(state2, batch2)
    w1 = np.asarray(jax.tree.leaves(state["dev"])[1][0])
    w2 = np.asarray(jax.tree.leaves(state2["dev"])[1][0])
    assert np.abs(w1 - w2).max() > 1e-7


def test_pipelined_server_uses_previous_buffer():
    """pipeline_acts: the first micro-iteration trains the server on the
    (zero) initial buffer -> first-round server loss differs from the
    unpipelined variant, later rounds converge similarly."""
    _, step_p, state_p, batch = _setup(pipeline_acts=True)
    _, step_n, state_n, _ = _setup(pipeline_acts=False)
    _, mp = step_p(state_p, batch)
    _, mn = step_n(state_n, batch)
    assert not np.isclose(float(mp["s_loss"]), float(mn["s_loss"]),
                          atol=1e-6)


def test_server_loss_decreases_over_rounds():
    cfg, step, state, _ = _setup()
    losses = []
    for r in range(10):
        batch = F.concrete_train_batch(jax.random.PRNGKey(2), cfg)  # fixed
        state, m = step(state, batch)
        losses.append(float(m["s_loss"]))
    assert losses[-1] < losses[1], losses


def test_agg_weights_reweight_contributions():
    cfg, step, state, batch = _setup()
    batch["agg_weight"] = jnp.asarray([3.0, 1.0])
    state, _ = step(state, batch)   # must run + normalize (no nan)
    assert bool(jnp.isfinite(jax.tree.leaves(state["dev"])[0]).all())


BATCH_DIGEST_SNIPPET = r"""
import zlib
import jax, numpy as np
from repro.configs import registry
from repro.core import fedopt_step as F

arch = registry.smoke_config("smollm-135m")
cfg = F.FedStepConfig(arch=arch, l_split=1, n_groups=2, seq_len=16,
                      per_group_batch=4, H=2)
batch = F.concrete_train_batch(jax.random.PRNGKey(0), cfg)
digest = 0
for k in sorted(batch):
    digest = zlib.crc32(np.ascontiguousarray(batch[k]).tobytes(), digest)
print("DIGEST", digest)
"""


def test_concrete_batch_deterministic_across_processes():
    """Regression: seeding with builtin hash() made synthetic batches vary
    per process via PYTHONHASHSEED, breaking benchmark reproducibility."""
    import os
    digests = []
    for hashseed in ("0", "12345"):
        env = dict(os.environ, PYTHONPATH="src", PYTHONHASHSEED=hashseed)
        out = subprocess.run(
            [sys.executable, "-c", BATCH_DIGEST_SNIPPET],
            capture_output=True, text=True, timeout=300, env=env)
        assert "DIGEST" in out.stdout, out.stderr[-2000:]
        digests.append(out.stdout.strip())
    assert digests[0] == digests[1], digests


MULTIDEV_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import registry
from repro.core import fedopt_step as F
from repro.launch.mesh import make_debug_mesh

arch = registry.smoke_config("qwen3-moe-235b-a22b")
cfg = F.FedStepConfig(arch=arch, l_split=1, n_groups=4, seq_len=32,
                      per_group_batch=2, H=2)
mesh = make_debug_mesh(2, 2, pod=2)     # (pod=2, data=2, model=2)
jitted, state_sds, s_spec, _ = F.jit_train_step(cfg, mesh)
compiled = jitted.lower(state_sds, F.train_input_specs(cfg)).compile()
state = jax.jit(lambda: F.init_train_state(jax.random.PRNGKey(0), cfg),
                out_shardings=s_spec)()
batch = F.concrete_train_batch(jax.random.PRNGKey(1), cfg)
state, metrics = jitted(state, batch)
assert np.isfinite(float(metrics["d_loss"]))
assert np.isfinite(float(metrics["s_loss"]))
print("MULTIDEV_OK", float(metrics["d_loss"]), float(metrics["s_loss"]))
"""


@pytest.mark.slow
def test_multipod_spmd_runs_in_subprocess():
    """The multi-pod mesh path executes (not just compiles) on 8 forced
    host devices — MoE arch to exercise expert sharding + all collectives."""
    import os
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)     # the snippet sets its own device count
    out = subprocess.run([sys.executable, "-c", MULTIDEV_SNIPPET],
                         capture_output=True, text=True, timeout=900,
                         env=env)
    assert "MULTIDEV_OK" in out.stdout, out.stderr[-3000:]
