"""Fault tolerance + elasticity: checkpoint store, churn, elastic registry."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.runtime.elastic import ElasticRegistry
from repro.runtime.fault_tolerance import ChurnModel


# ---------------------------------------------------------------------------
# checkpoint store
# ---------------------------------------------------------------------------

def _state(v=0.0):
    return {"params": {"w": jnp.full((4, 4), v), "b": jnp.zeros((4,))},
            "step": jnp.asarray(int(v), jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path)
    store.save(d, 10, _state(3.0), metadata={"lr": 0.1})
    out = store.restore(d, 10, _state())
    np.testing.assert_allclose(out["params"]["w"], 3.0)
    assert store.restore_metadata(d, 10)["lr"] == 0.1


def test_latest_and_retention(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        store.save(d, s, _state(float(s)), retain=3)
    assert store.latest_step(d) == 5
    assert store.committed_steps(d) == [3, 4, 5]   # older GC'd


def test_uncommitted_snapshot_ignored(tmp_path):
    d = str(tmp_path)
    store.save(d, 1, _state(1.0))
    # simulate a crash mid-write: directory without COMMITTED marker
    os.makedirs(os.path.join(d, "step_00000002"))
    assert store.latest_step(d) == 1
    with pytest.raises(FileNotFoundError):
        store.restore(d, 2, _state())


def test_restore_into_shape_dtype_struct(tmp_path):
    import jax
    d = str(tmp_path)
    store.save(d, 7, _state(2.0))
    like = jax.eval_shape(lambda: _state())
    out = store.restore(d, 7, like)
    np.testing.assert_allclose(out["params"]["w"], 2.0)


# ---------------------------------------------------------------------------
# churn model (§6.4 protocol)
# ---------------------------------------------------------------------------

def test_churn_draw_rates():
    cm = ChurnModel(n_devices=1000, p_drop=0.3, seed=0)
    active, bw = cm.draw(0.0)
    assert 0.6 < active.mean() < 0.8
    assert np.all((bw >= cm.bw_lo) & (bw <= cm.bw_hi))


def test_churn_p_zero_keeps_everyone():
    cm = ChurnModel(n_devices=64, p_drop=0.0)
    active, _ = cm.draw(0.0)
    assert active.all()


# ---------------------------------------------------------------------------
# elastic registry (§3.4.2)
# ---------------------------------------------------------------------------

def test_join_leave_rejoin():
    reg = ElasticRegistry()
    a = reg.join(1e9, 1e6)
    b = reg.join(2e9, 2e6)
    assert set(reg.active_ids) == {a, b}
    reg.leave(a)
    assert reg.active_ids == [b]
    reg.rejoin(a, t=5.0)
    assert set(reg.active_ids) == {a, b}


def test_elastic_training_round_never_blocks():
    """Hybrid-step semantics: a round with dropped groups still advances
    (agg_weight zero for dropped groups; paper §3.4.2)."""
    import jax
    from repro.configs import registry as areg
    from repro.core import fedopt_step as F
    from repro.launch.mesh import make_debug_mesh

    arch = areg.smoke_config("smollm-135m")
    mesh = make_debug_mesh(1, 1)
    cfg = F.FedStepConfig(arch=arch, l_split=1, n_groups=4, seq_len=16,
                          per_group_batch=2, H=2)
    jitted, _, s_spec, _ = F.jit_train_step(cfg, mesh)
    state = jax.jit(lambda: F.init_train_state(jax.random.PRNGKey(0), cfg),
                    out_shardings=s_spec)()
    batch = F.concrete_train_batch(jax.random.PRNGKey(1), cfg)
    batch["agg_weight"] = jnp.asarray([1.0, 0.0, 0.0, 1.0])  # 2 dropped
    state, metrics = jitted(state, batch)
    assert int(state["version"]) == 1
    assert bool(jnp.isfinite(metrics["d_loss"]))
    # aggregated global model excludes dropped groups: groups 0 and 3 agree
    w = state["dev"]["embed"]
    np.testing.assert_allclose(np.asarray(w[0]), np.asarray(w[1]), atol=1e-6)
