"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device; only launch/dryrun.py forces 512 host devices."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    import jax
    return jax.random.PRNGKey(0)


@pytest.fixture()
def np_rng():
    return np.random.default_rng(0)
