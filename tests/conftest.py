"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device; only launch/dryrun.py forces 512 host devices."""
import os

import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _protocol_sanitizer(request):
    """REPRO_SANITIZE=1 runs the whole suite under the protocol sanitizer
    (the CI lane does): any control-plane invariant violation fails the
    offending test at the event that broke it.  Tests that deliberately
    violate invariants (the mutation tests) opt out with
    ``@pytest.mark.no_sanitize``."""
    if os.environ.get("REPRO_SANITIZE") != "1" or \
            request.node.get_closest_marker("no_sanitize") or \
            request.node.module.__name__ == "test_sanitize":
        yield
        return
    from repro.analysis.sanitize import sanitized
    with sanitized():
        yield


@pytest.fixture(scope="session")
def rng():
    import jax
    return jax.random.PRNGKey(0)


@pytest.fixture()
def np_rng():
    return np.random.default_rng(0)
