"""Repo lint: the tree is clean, each rule fires on a minimal violating
fixture (and stays quiet on the corrected form), and scope/allowlist/
waiver mechanics behave."""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis.lint import RULES, lint_file, lint_paths, main

SRC = Path(__file__).resolve().parent.parent / "src"


def _write(tmp_path, rel, body):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(body))
    return p


def _rules_of(errors):
    return sorted({e.rule for e in errors})


# ---------------------------------------------------------------------------
# the real tree
# ---------------------------------------------------------------------------

def test_src_tree_is_clean():
    errors = lint_paths([SRC])
    assert errors == [], "\n".join(str(e) for e in errors)


def test_rule_table_is_complete():
    assert set(RULES) == {f"RP00{i}" for i in range(1, 7)}
    assert len(set(RULES.values())) == len(RULES)


# ---------------------------------------------------------------------------
# per-rule fixtures: bad fires, good is quiet
# ---------------------------------------------------------------------------

def test_rp001_unseeded_random(tmp_path):
    bad = _write(tmp_path, "core/sim.py", """
        import numpy as np
        def draw():
            return np.random.uniform(0, 1)
    """)
    assert _rules_of(lint_file(bad)) == ["RP001"]
    bad2 = _write(tmp_path, "core/sim2.py", """
        import numpy as np
        def draw():
            return np.random.default_rng().uniform(0, 1)
    """)
    assert _rules_of(lint_file(bad2)) == ["RP001"]
    good = _write(tmp_path, "core/sim3.py", """
        import numpy as np
        def draw(seed):
            return np.random.default_rng(seed).uniform(0, 1)
    """)
    assert lint_file(good) == []


def test_rp002_wallclock(tmp_path):
    bad = _write(tmp_path, "fleet/traces.py", """
        import time
        def now():
            return time.time()
    """)
    assert _rules_of(lint_file(bad)) == ["RP002"]
    good = _write(tmp_path, "fleet/traces2.py", """
        import time
        def tick():
            return time.perf_counter()
    """)
    assert lint_file(good) == []


def test_rp003_hash_seed(tmp_path):
    bad = _write(tmp_path, "util/keys.py", """
        def seed_of(name):
            return hash(name) % 2**32
    """)
    assert _rules_of(lint_file(bad)) == ["RP003"]
    good = _write(tmp_path, "util/keys2.py", """
        import zlib
        def seed_of(name):
            return zlib.crc32(name.encode())
    """)
    assert lint_file(good) == []


def test_rp004_bare_assert_in_core(tmp_path):
    bad = _write(tmp_path, "core/flow.py", """
        def check(n, cap):
            assert n <= cap
    """)
    assert _rules_of(lint_file(bad)) == ["RP004"]
    # the same assert OUTSIDE core/ is fine
    ok = _write(tmp_path, "kernels/flow.py", """
        def check(n, cap):
            assert n <= cap
    """)
    assert lint_file(ok) == []
    good = _write(tmp_path, "core/flow2.py", """
        def check(n, cap):
            if n > cap:
                raise RuntimeError(f"cap violated: {n} > {cap}")
    """)
    assert lint_file(good) == []


def test_rp005_blockspec_divisibility(tmp_path):
    bad = _write(tmp_path, "kernels/attn.py", """
        import jax.experimental.pallas as pl
        def fwd(S, block_q):
            spec = pl.BlockSpec((block_q, 64), lambda i: (i, 0))
            return S // block_q, spec
    """)
    assert _rules_of(lint_file(bad)) == ["RP005"]
    good = _write(tmp_path, "kernels/attn2.py", """
        import jax.experimental.pallas as pl
        def fwd(S, block_q):
            if S % block_q:
                raise ValueError(f"{S} not divisible by {block_q}")
            spec = pl.BlockSpec((block_q, 64), lambda i: (i, 0))
            return S // block_q, spec
    """)
    assert lint_file(good) == []
    # full-dimension names (not block_*/chunk*) tile trivially: no finding
    triv = _write(tmp_path, "kernels/attn3.py", """
        import jax.experimental.pallas as pl
        def fwd(hd):
            return pl.BlockSpec((hd,), lambda i: (0,))
    """)
    assert lint_file(triv) == []


def test_rp006_statedict_version(tmp_path):
    bad = _write(tmp_path, "runtime/ckpt.py", """
        class Thing:
            def state_dict(self):
                return {"weights": self.w}
    """)
    assert _rules_of(lint_file(bad)) == ["RP006"]
    good = _write(tmp_path, "runtime/ckpt2.py", """
        class Thing:
            def state_dict(self):
                return {"version_tag": 3, "weights": self.w}
    """)
    assert lint_file(good) == []


# ---------------------------------------------------------------------------
# scope, allowlist, waiver
# ---------------------------------------------------------------------------

def test_hot_path_rules_exempt_data_and_launch(tmp_path):
    for seg in ("data", "launch"):
        f = _write(tmp_path, f"{seg}/loader.py", """
            import time
            import numpy as np
            def jitter():
                return np.random.uniform() + time.time()
        """)
        assert lint_file(f) == [], seg
    # ...but the identical code in core/ fires both hot-path rules
    f = _write(tmp_path, "core/loader.py", """
        import time
        import numpy as np
        def jitter():
            return np.random.uniform() + time.time()
    """)
    assert _rules_of(lint_file(f)) == ["RP001", "RP002"]


def test_waiver_comment_suppresses_one_line(tmp_path):
    f = _write(tmp_path, "core/sim.py", """
        import numpy as np
        def draw():
            a = np.random.uniform()  # lint: allow-unseeded-random
            b = np.random.uniform()
            return a + b
    """)
    errors = lint_file(f)
    assert len(errors) == 1 and errors[0].rule == "RP001"
    assert errors[0].line == 5


def test_syntax_error_reported_not_raised(tmp_path):
    f = _write(tmp_path, "core/broken.py", "def nope(:\n")
    errors = lint_file(f)
    assert len(errors) == 1 and errors[0].rule == "RP000"


def test_error_format_is_clickable(tmp_path):
    f = _write(tmp_path, "core/sim.py", """
        import numpy as np
        def draw():
            return np.random.uniform()
    """)
    msg = str(lint_file(f)[0])
    assert msg.startswith(f"{f}:4: RP001[unseeded-random] ")


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_main_exit_codes(tmp_path, capsys):
    clean = _write(tmp_path, "ok/mod.py", "X = 1\n")
    assert main([str(clean)]) == 0
    bad = _write(tmp_path, "core/bad.py", "def f():\n    assert True\n")
    assert main([str(bad)]) == 1
    assert main([]) == 2                     # usage
    capsys.readouterr()


@pytest.mark.slow
def test_cli_subprocess_on_real_tree():
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(SRC)],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin"})
    assert out.returncode == 0, out.stdout + out.stderr
    assert "clean" in out.stderr
