"""Unit tests for model building blocks (common/attention/mlp/mamba/cnn)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import common
from repro.models.attention import (AttentionConfig, attention_apply,
                                    attention_decode, attention_init,
                                    kv_cache_init)
from repro.models.mlp import (MlpConfig, MoeConfig, mlp_apply, mlp_init,
                              moe_apply, moe_apply_grouped, moe_init)


def test_rmsnorm_unit_scale():
    p = common.rmsnorm_init(8)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8)) * 10
    y = common.rmsnorm_apply(p, x)
    rms = jnp.sqrt(jnp.mean(y ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_rope_preserves_norm_and_relative_phase():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 2, 16))
    pos = jnp.arange(6)[None]
    y = common.apply_rope(x, pos)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # dot(q_i, k_j) depends only on i - j
    q = common.apply_rope(jnp.ones((1, 8, 1, 16)), jnp.arange(8)[None])
    d1 = float(jnp.vdot(q[0, 3, 0], q[0, 1, 0]))
    d2 = float(jnp.vdot(q[0, 6, 0], q[0, 4, 0]))
    assert abs(d1 - d2) < 1e-4


def test_softcap_bounds():
    x = jnp.linspace(-1000, 1000, 101)
    y = common.softcap(x, 30.0)
    assert float(jnp.abs(y).max()) <= 30.0
    np.testing.assert_allclose(y[50], x[50], atol=1e-3)   # ~identity near 0


def test_attention_gqa_head_broadcast():
    """GQA must equal MHA with kv heads repeated."""
    cfg_gqa = AttentionConfig(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8)
    p = attention_init(jax.random.PRNGKey(0), cfg_gqa)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 32))
    y_gqa = attention_apply(p, cfg_gqa, x)
    cfg_mha = AttentionConfig(d_model=32, n_heads=4, n_kv_heads=4, head_dim=8)
    p_mha = dict(p, wk=jnp.concatenate([p["wk"].reshape(32, 2, 8)] * 2, 1
                                       ).reshape(32, 32),
                 wv=jnp.concatenate([p["wv"].reshape(32, 2, 8)] * 2, 1
                                    ).reshape(32, 32))
    # interleave, not concat: build by repeating each kv head per group
    wk = p["wk"].reshape(32, 2, 8)
    wv = p["wv"].reshape(32, 2, 8)
    p_mha["wk"] = jnp.repeat(wk, 2, axis=1).reshape(32, 32)
    p_mha["wv"] = jnp.repeat(wv, 2, axis=1).reshape(32, 32)
    y_mha = attention_apply(p_mha, cfg_mha, x)
    np.testing.assert_allclose(y_gqa, y_mha, atol=1e-5)


def test_attention_decode_matches_full():
    cfg = AttentionConfig(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8)
    p = attention_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 7, 32))
    full = attention_apply(p, cfg, x)
    cache = kv_cache_init(cfg, 1, 16)
    for t in range(7):
        out, cache = attention_decode(p, cfg, x[:, t:t + 1], cache,
                                      jnp.int32(t))
    np.testing.assert_allclose(out[:, 0], full[:, -1], atol=1e-5)


def test_ring_decode_matches_window_attention():
    """Ring-buffered sliding-window decode == full local attention."""
    W = 4
    cfg = AttentionConfig(d_model=16, n_heads=2, n_kv_heads=2, head_dim=8,
                          window=W)
    p = attention_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 10, 16))
    full = attention_apply(p, cfg, x)
    cache = kv_cache_init(cfg, 1, W)      # ring cache of exactly W slots
    for t in range(10):
        out, cache = attention_decode(p, cfg, x[:, t:t + 1], cache,
                                      jnp.int32(t), ring=True)
    np.testing.assert_allclose(out[:, 0], full[:, -1], atol=1e-5)


def test_moe_grouped_matches_dense_when_dropless():
    cfg = MoeConfig(d_model=16, d_ff=32, n_experts=4, top_k=2)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 16))
    y_dense, _ = moe_apply(p, cfg, x)
    y_grp, _ = moe_apply_grouped(p, cfg, x, capacity_factor=2.0)  # C=T*k/E*2
    np.testing.assert_allclose(y_dense, y_grp, atol=1e-4)


def test_moe_capacity_drops_tokens():
    cfg = MoeConfig(d_model=8, d_ff=16, n_experts=8, top_k=1)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 8))
    y_lo, _ = moe_apply_grouped(p, cfg, x, capacity_factor=0.25)
    y_hi, _ = moe_apply_grouped(p, cfg, x, capacity_factor=8.0)
    # dropping must change some outputs (overflowed tokens contribute 0)
    assert float(jnp.abs(y_lo - y_hi).max()) > 1e-6


def test_moe_load_balance_aux_range():
    cfg = MoeConfig(d_model=16, d_ff=16, n_experts=8, top_k=2)
    p = moe_init(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 128, 16))
    _, aux = moe_apply_grouped(p, cfg, x, capacity_factor=2.0)
    assert 0.5 < float(aux) < 8.0       # ~1 at uniform routing


def test_mlp_gated_vs_gelu_paths():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 8))
    for act in ("swiglu", "gelu"):
        cfg = MlpConfig(d_model=8, d_ff=16, activation=act)
        p = mlp_init(jax.random.PRNGKey(1), cfg)
        y = mlp_apply(p, cfg, x)
        assert y.shape == x.shape and bool(jnp.isfinite(y).all())


def test_cnn_forward_and_split():
    from repro.models import cnn
    cfg = cnn.vgg5_config(n_classes=10, img_size=16)
    p = cnn.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    logits = cnn.forward(p, cfg, x)
    assert logits.shape == (2, 10)
    dev, srv = cnn.split_params(p, 2)
    acts = cnn.forward(dev, cfg, x, upto=2)
    loss = cnn.server_forward_loss(srv, cfg, acts,
                                   jnp.zeros((2,), jnp.int32), 2)
    assert bool(jnp.isfinite(loss))


def test_text_classifier_forward():
    from repro.models import text_classifier as tc
    cfg = tc.transformer6_config(vocab=100, n_classes=2, seq_len=16,
                                 n_layers=2)
    p = tc.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 100)
    logits = tc.forward(p, cfg, x)
    assert logits.shape == (2, 2)
