"""Communication volume (paper Fig. 2 + Challenge 1).

FedOptima removes the server->device gradient stream and gates activation
uploads with flow control; OAFL ships activations AND gradients every
iteration.  These orderings must hold in the event simulation."""
import pytest

from repro.core.baselines import simulate_oafl, simulate_splitfed
from repro.core.simulation import (SimModel, heterogeneous_cluster,
                                   simulate_fedoptima)

MODEL = SimModel(dev_fwd_flops=1e9, dev_bwd_flops=2e9, full_fwd_flops=5e9,
                 srv_flops_per_batch=8e9, act_bytes=2e6, dev_model_bytes=4e6,
                 full_model_bytes=2e7, batch_size=32)
CLUSTER = heterogeneous_cluster(8)
TOTAL = 8 * 4096


@pytest.fixture(scope="module")
def comm():
    fo = simulate_fedoptima(MODEL, CLUSTER, duration=400.0, omega=8)
    oafl = simulate_oafl(MODEL, CLUSTER, duration=400.0)
    sf = simulate_splitfed(MODEL, CLUSTER, duration=400.0)
    return fo, oafl, sf


def test_fedoptima_comm_below_oafl(comm):
    fo, oafl, _ = comm
    assert fo.comm_per_round(TOTAL) < oafl.comm_per_round(TOTAL)


def test_fedoptima_downlink_carries_no_gradients(comm):
    """Down traffic is only model refreshes — per sample processed it must
    be far below OAFL's per-sample gradient returns."""
    fo, oafl, _ = comm
    fo_down = fo.bytes_down / max(fo.dev_samples, 1)
    oafl_down = oafl.bytes_down / max(oafl.dev_samples, 1)
    assert fo_down < 0.5 * oafl_down


def test_flow_control_gates_uploads(comm):
    """With ω=8 and 8 devices the server grants at most one outstanding
    activation batch per device — uploads per device-iteration < 1."""
    fo, _, _ = comm
    iters = fo.dev_samples / MODEL.batch_size
    uploads = fo.bytes_up / MODEL.act_bytes
    assert uploads <= iters + 1


def test_small_omega_reduces_upload_volume():
    lo = simulate_fedoptima(MODEL, CLUSTER, duration=300.0, omega=1)
    hi = simulate_fedoptima(MODEL, CLUSTER, duration=300.0, omega=16)
    assert lo.bytes_up <= hi.bytes_up


def test_agg_compression_ratio():
    """int8 aggregation payload ≈ 4x smaller than f32 (cross-pod trick)."""
    import jax.numpy as jnp
    from repro.parallel.compression import compression_ratio, dequantize, quantize
    import numpy as np
    x = jnp.asarray(np.random.default_rng(0).normal(size=(128, 257)),
                    jnp.float32)
    codes, scale, n = quantize(x)
    back = dequantize(codes, scale, n, x.shape)
    err = float(jnp.abs(back - x).max())
    assert err < float(jnp.abs(x).max()) / 100    # <1% of range per block
    assert compression_ratio({"x": x}) < 0.3
