"""Pallas kernel correctness: sweep shapes/dtypes, assert_allclose against
the pure-jnp oracles (ref.py).  interpret=True executes the exact TPU
program logic on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_SHAPES = [
    # (B, S, Skv, H, Hkv, hd)
    (1, 128, 128, 4, 4, 64),      # MHA
    (2, 256, 256, 8, 2, 32),      # GQA 4:1
    (1, 128, 128, 9, 3, 64),      # odd head counts (smollm)
    (1, 384, 384, 4, 1, 64),      # MQA
]


def _qkv(shape, dtype, seed=0):
    B, S, Skv, H, Hkv, hd = shape
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(shape, dtype):
    q, k, v = _qkv(shape, dtype)
    got = ops.flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_reference(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    q, k, v = _qkv((1, 256, 256, 4, 4, 64), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, window=window)
    want = ref.flash_attention_reference(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_flash_attention_logit_softcap():
    q, k, v = _qkv((1, 128, 128, 4, 2, 64), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, logit_cap=30.0)
    want = ref.flash_attention_reference(q, k, v, causal=True, logit_cap=30.0)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_flash_attention_noncausal():
    q, k, v = _qkv((1, 128, 128, 4, 4, 32), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False)
    want = ref.flash_attention_reference(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@given(st.integers(1, 2), st.sampled_from([64, 128, 192]),
       st.sampled_from([(4, 4), (4, 2), (6, 3)]), st.sampled_from([32, 64]))
@settings(max_examples=8, deadline=None)
def test_flash_attention_property(b, s, heads, hd):
    H, Hkv = heads
    q, k, v = _qkv((b, s, s, H, Hkv, hd), jnp.float32, seed=s)
    got = ops.flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


def test_jnp_chunked_path_matches_reference():
    """The jnp fallback (sdpa_chunked) is numerically the oracle too."""
    from repro.models.attention import sdpa_chunked
    q, k, v = _qkv((2, 200, 200, 8, 2, 64), jnp.float32)
    got = sdpa_chunked(q, k, v, causal=True, window=None, logit_cap=None,
                       chunk_q=64)
    want = ref.flash_attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# SSD (Mamba2)
# ---------------------------------------------------------------------------

SSD_SHAPES = [
    # (B, T, H, P, G, N, chunk)
    (1, 128, 4, 32, 1, 16, 32),
    (2, 64, 8, 16, 2, 8, 16),
    (1, 96, 4, 64, 1, 32, 32),    # T % chunk == 0
]


def _ssd_inputs(shape, seed=0):
    B, T, H, P, G, N, _ = shape
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, T, G, N)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(ks[3], 1), (B, T, G, N)) * 0.5
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("shape", SSD_SHAPES)
def test_ssd_kernel_vs_sequential_reference(shape):
    x, dt, A, Bm, Cm = _ssd_inputs(shape)
    got = ops.ssd(x, dt, A, Bm, Cm, chunk=shape[-1])
    want, _ = ref.ssd_reference(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(got, want, atol=5e-4, rtol=5e-4)


def test_ssd_chunked_jnp_matches_reference():
    from repro.models.mamba import ssd_chunked
    x, dt, A, Bm, Cm = _ssd_inputs((2, 64, 4, 16, 2, 8, 16))
    got_y, got_h = ssd_chunked(x, dt, A, Bm, Cm, 16)
    want_y, want_h = ref.ssd_reference(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(got_y, want_y, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(got_h, want_h, atol=5e-4, rtol=5e-4)


def test_ssd_chunk_invariance():
    """Same result regardless of chunk size (chunking is exact algebra)."""
    from repro.models.mamba import ssd_chunked
    x, dt, A, Bm, Cm = _ssd_inputs((1, 96, 4, 16, 1, 8, 0))
    y1, h1 = ssd_chunked(x, dt, A, Bm, Cm, 8)
    y2, h2 = ssd_chunked(x, dt, A, Bm, Cm, 48)
    np.testing.assert_allclose(y1, y2, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(h1, h2, atol=5e-4, rtol=5e-4)


def test_ssd_padding_path():
    """ops.ssd pads T to a chunk multiple; result must match unpadded ref."""
    x, dt, A, Bm, Cm = _ssd_inputs((1, 50, 4, 16, 1, 8, 0))
    got = ops.ssd(x, dt, A, Bm, Cm, chunk=16)
    want, _ = ref.ssd_reference(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(got, want, atol=5e-4, rtol=5e-4)


@given(st.sampled_from([32, 64]), st.sampled_from([2, 4]),
       st.sampled_from([8, 16]))
@settings(max_examples=6, deadline=None)
def test_ssd_property(t, h, n):
    x, dt, A, Bm, Cm = _ssd_inputs((1, t, h, 16, 1, n, 0), seed=t + h)
    got = ops.ssd(x, dt, A, Bm, Cm, chunk=16)
    want, _ = ref.ssd_reference(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)
