"""Perf-option correctness: every §Perf configuration must compute the
same math (or a documented, bounded variation) as the baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import fedopt_step as F
from repro.launch.mesh import make_debug_mesh
from repro.models import transformer as tfm


def test_selective_remat_gradients_exact():
    """save_only_these_names("tp_out") changes scheduling, not math."""
    cfg = registry.smoke_config("qwen3-32b")
    p = tfm.init_params(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    lab = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, cfg.vocab)
    g_full = jax.grad(lambda x: tfm.lm_loss(x, cfg, tok, lab, remat=True)[0])(p)
    g_sel = jax.grad(lambda x: tfm.lm_loss(x, cfg, tok, lab,
                                           remat="selective")[0])(p)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_sel)):
        np.testing.assert_allclose(a, b, atol=5e-6)


def test_selective_remat_moe_and_mamba():
    for name in ("jamba-1.5-large-398b", "mamba2-780m"):
        cfg = registry.smoke_config(name)
        p = tfm.init_params(jax.random.PRNGKey(0), cfg)
        tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
        lab = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab)
        l1, _ = tfm.lm_loss(p, cfg, tok, lab, remat=True)
        l2, _ = tfm.lm_loss(p, cfg, tok, lab, remat="selective")
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def _run_rounds(cfg, n=4, seed=3):
    mesh = make_debug_mesh(1, 1)
    step, _, s_spec, _ = F.jit_train_step(cfg, mesh)
    state = jax.jit(lambda: F.init_train_state(jax.random.PRNGKey(0), cfg),
                    out_shardings=s_spec)()
    losses = []
    for r in range(n):
        batch = F.concrete_train_batch(jax.random.PRNGKey(seed), cfg)
        state, m = step(state, batch)
        losses.append(float(m["s_loss"]))
    return losses


def test_server_accum_still_learns():
    arch = registry.smoke_config("smollm-135m")
    base = F.FedStepConfig(arch=arch, l_split=1, n_groups=2, seq_len=16,
                           per_group_batch=4, H=2, lr_s=0.1)
    for accum in (False, True):
        cfg = F.FedStepConfig(**{**base.__dict__, "server_accum": accum})
        losses = _run_rounds(cfg, n=6)
        assert losses[-1] < losses[1], (accum, losses)


def test_selective_remat_step_matches_full():
    arch = registry.smoke_config("smollm-135m")
    kw = dict(arch=arch, l_split=1, n_groups=2, seq_len=16,
              per_group_batch=4, H=2)
    l_full = _run_rounds(F.FedStepConfig(**kw, remat=True))
    l_sel = _run_rounds(F.FedStepConfig(**kw, remat="selective"))
    np.testing.assert_allclose(l_full, l_sel, rtol=1e-5)


def test_agg_compress_close_to_exact():
    """int8 aggregation payload: the aggregated model differs from exact
    by < 1% of parameter scale (per-tensor quantization error)."""
    arch = registry.smoke_config("smollm-135m")
    kw = dict(arch=arch, l_split=1, n_groups=2, seq_len=16,
              per_group_batch=2, H=2)
    mesh = make_debug_mesh(1, 1)
    outs = {}
    for comp in (False, True):
        cfg = F.FedStepConfig(**kw, agg_compress=comp)
        step, _, s_spec, _ = F.jit_train_step(cfg, mesh)
        state = jax.jit(lambda c=cfg: F.init_train_state(
            jax.random.PRNGKey(0), c), out_shardings=s_spec)()
        batch = F.concrete_train_batch(jax.random.PRNGKey(1), cfg)
        state, _ = step(state, batch)
        outs[comp] = state["dev"]
    for a, b in zip(jax.tree.leaves(outs[False]), jax.tree.leaves(outs[True])):
        scale = float(jnp.abs(a).max()) + 1e-9
        assert float(jnp.abs(a - b).max()) / scale < 0.02
