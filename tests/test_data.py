"""Data pipeline: Dirichlet non-IID partitioning (§5.2), restartable
iterators, synthetic dataset learnability structure."""
import numpy as np
from _propcheck import given, settings, strategies as st

from repro.data.partitioner import dirichlet_partition, partition_stats
from repro.data.pipeline import DeviceDataset
from repro.data.synthetic import (classification_dataset, lm_batches,
                                  lm_dataset)


def test_partition_is_exact_cover():
    labels = np.random.default_rng(0).integers(0, 10, size=2000).astype(np.int32)
    parts = dirichlet_partition(labels, 8, alpha=0.5, seed=0)
    allidx = np.concatenate(parts)
    assert len(allidx) == 2000
    assert len(np.unique(allidx)) == 2000          # no duplicate, no loss


def test_partition_is_noniid():
    """Dirichlet(0.5) must produce skewed per-device class histograms."""
    labels = np.random.default_rng(1).integers(0, 10, size=4000).astype(np.int32)
    parts = dirichlet_partition(labels, 8, alpha=0.5, seed=1)
    stats = partition_stats(labels, parts)
    frac = stats / np.maximum(stats.sum(axis=1, keepdims=True), 1)
    # at least one device has one class >30% (uniform would be ~10%)
    assert (frac.max(axis=1) > 0.3).any()


@given(st.integers(2, 12), st.integers(2, 8))
@settings(max_examples=10, deadline=None)
def test_partition_property(n_devices, n_classes):
    labels = np.random.default_rng(7).integers(
        0, n_classes, size=400).astype(np.int32)
    parts = dirichlet_partition(labels, n_devices, seed=3)
    assert sum(len(p) for p in parts) == 400


def test_device_dataset_deterministic_and_restorable():
    x = np.arange(100, dtype=np.float32)[:, None]
    y = np.arange(100, dtype=np.int32)
    a = DeviceDataset(x, y, batch=16, seed=4)
    b = DeviceDataset(x, y, batch=16, seed=4)
    for _ in range(3):
        xa, _ = a.next_batch()
        xb, _ = b.next_batch()
        np.testing.assert_array_equal(xa, xb)
    snap = a.state()
    xa, _ = a.next_batch()
    c = DeviceDataset(x, y, batch=16, seed=4)
    c.restore(snap)
    xc, _ = c.next_batch()
    np.testing.assert_array_equal(xa, xc)


def test_classification_dataset_learnable():
    """Class structure must be visible to a nearest-prototype rule."""
    d = classification_dataset(512, 4, img_size=8, seed=0, noise=0.3)
    protos = np.stack([d.x[d.y == c].mean(axis=0) for c in range(4)])
    dists = ((d.x[:, None] - protos[None]) ** 2).sum(axis=(2, 3, 4))
    acc = (dists.argmin(axis=1) == d.y).mean()
    assert acc > 0.9


def test_lm_dataset_structure():
    toks = lm_dataset(5000, vocab=101, seed=0, structure=0.9)
    pred = (31 * toks[:-1] + 7) % 101
    agree = (pred == toks[1:]).mean()
    assert 0.8 < agree <= 0.95          # ~structure fraction deterministic


def test_lm_batches_shapes():
    toks = lm_dataset(2000, vocab=50, seed=1)
    it = lm_batches(toks, batch=4, seq=16, seed=0)
    x, y = next(it)
    assert x.shape == (4, 16) and y.shape == (4, 16)
    np.testing.assert_array_equal(x[:, 1:], y[:, :-1])  # shifted by one
