"""Protocol sanitizer: a clean protocol is silent, the two historical
bug classes (reintroduced behind test-only hooks) are caught online with
the right invariant name, and the checks themselves fire on hand-built
violations."""
import numpy as np
import pytest

from _propcheck import given, settings, strategies as st
from repro.analysis.sanitize import (INVARIANTS, InvariantViolation,
                                     ProtocolSanitizer, sanitized, suspended)
from repro.core import simulation
from repro.core.baselines import REGISTRY
from repro.core.flow_control import FlowController
from repro.core.scheduler import TaskScheduler
from repro.core.simulation import (SimModel, heterogeneous_cluster,
                                   simulate_fedoptima)
from repro.fleet import diurnal_trace, flaky_trace, sample_cluster

MODEL = SimModel(dev_fwd_flops=1e9, dev_bwd_flops=2e9, full_fwd_flops=5e9,
                 srv_flops_per_batch=8e9, act_bytes=1e6, dev_model_bytes=4e6,
                 full_model_bytes=2e7, batch_size=32)


def _churn_trace(K, dur, seed=7, cluster=None):
    bw = cluster.dev_bw if cluster is not None else 12.5e6
    return diurnal_trace(K, horizon=dur, interval=dur / 24.0, day=dur / 2.0,
                         on_frac=0.6, bw=bw, bw_jitter=0.3, seed=seed)


# ---------------------------------------------------------------------------
# a correct protocol is silent
# ---------------------------------------------------------------------------

def test_clean_churn_run_zero_violations():
    cluster = heterogeneous_cluster(16)
    trace = _churn_trace(16, 600.0, cluster=cluster)
    with sanitized() as san:
        m = simulate_fedoptima(MODEL, cluster, duration=600.0, omega=8,
                               fleet=trace, seed=5)
    assert san.n_violations == 0
    assert san.n_events > 1000          # the run was actually instrumented
    assert san.counts.get("sim.device_left", 0) > 0   # churn really happened
    assert m.throughput > 0


def test_acceptance_scenario_k32_diurnal():
    """ISSUE 6 acceptance: the bench_fleet K=32 diurnal-trace scenario
    completes under the sanitizer with zero violations."""
    cluster = sample_cluster(32, "low:2,mid:3,high:2,premium:1", seed=11)
    trace = _churn_trace(32, 120.0, cluster=cluster)
    with sanitized() as san:
        m = simulate_fedoptima(MODEL, cluster, duration=120.0, omega=8,
                               fleet=trace, seed=11)
    assert san.n_violations == 0
    assert san.counts.get("cp.arrival", 0) > 0
    assert m.srv_batches > 0


def test_baselines_clean_under_churn():
    cluster = heterogeneous_cluster(8)
    trace = flaky_trace(8, 300.0, interval=15.0, p_drop=0.2,
                        bw_lo=8e6, bw_hi=16e6, seed=3)
    with sanitized() as san:
        for name, fn in REGISTRY.items():
            fn(MODEL, cluster, duration=300.0, fleet=trace)
    assert san.n_violations == 0
    assert san.n_events > 0


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(["diurnal", "flaky"]),
       st.sampled_from([4, 8, 16]))
def test_property_seeded_churn_is_clean(seed, kind, omega):
    """Property: no (seed, trace kind, omega) combination produces a
    violation — the invariants hold on every code path churn can reach."""
    cluster = heterogeneous_cluster(12)
    if kind == "diurnal":
        trace = _churn_trace(12, 300.0, seed=seed, cluster=cluster)
    else:
        trace = flaky_trace(12, 300.0, interval=12.0, p_drop=0.15,
                            bw_lo=8e6, bw_hi=16e6, seed=seed)
    with sanitized() as san:
        simulate_fedoptima(MODEL, cluster, duration=300.0, omega=omega,
                           fleet=trace, seed=seed)
    assert san.n_violations == 0


# ---------------------------------------------------------------------------
# mutation tests: the two historical bugs, reintroduced behind hooks
# ---------------------------------------------------------------------------

def test_mutation_skipped_token_reclaim_is_caught():
    """PR 1's bug: ``on_device_left`` forgets to reclaim the departed
    device's token/in-flight budget.  The sanitizer must name
    flow-token-conservation at the first leaking departure."""
    cluster = heterogeneous_cluster(16)
    trace = _churn_trace(16, 600.0, cluster=cluster)
    FlowController._test_skip_reclaim = True
    try:
        with pytest.raises(InvariantViolation) as ei:
            with sanitized():
                simulate_fedoptima(MODEL, cluster, duration=600.0, omega=8,
                                   fleet=trace, seed=5)
    finally:
        FlowController._test_skip_reclaim = False
    assert ei.value.invariant == "flow-token-conservation"
    assert "not reclaimed" in str(ei.value)
    assert ei.value.window                     # diagnosis window attached


def test_mutation_skipped_epoch_check_is_caught(monkeypatch):
    """PR 5's bug: a model return from before a departure re-arms the
    device's chain, forking two concurrent chains after the rejoin.  The
    sanitizer must name single-live-chain."""
    monkeypatch.setattr(simulation, "_TEST_SKIP_EPOCH_CHECK", True)
    cluster = heterogeneous_cluster(16)
    trace = _churn_trace(16, 600.0, cluster=cluster)
    with pytest.raises(InvariantViolation) as ei:
        with sanitized():
            simulate_fedoptima(MODEL, cluster, duration=600.0, omega=8,
                               fleet=trace, seed=5)
    assert ei.value.invariant == "single-live-chain"


def test_posthoc_mode_collects_instead_of_raising():
    """raise_on_violation=False surveys ALL violations of a mutated build
    instead of stopping at the first."""
    cluster = heterogeneous_cluster(16)
    trace = _churn_trace(16, 600.0, cluster=cluster)
    FlowController._test_skip_reclaim = True
    try:
        san = ProtocolSanitizer(raise_on_violation=False)
        with sanitized(san):
            simulate_fedoptima(MODEL, cluster, duration=600.0, omega=8,
                               fleet=trace, seed=5)
    finally:
        FlowController._test_skip_reclaim = False
    assert san.n_violations >= 1
    assert all(v.invariant == "flow-token-conservation"
               for v in san.violations)
    rep = san.report()
    assert rep["n_violations"] == san.n_violations
    assert rep["violations"][0]["invariant"] == "flow-token-conservation"


# ---------------------------------------------------------------------------
# per-invariant unit triggers (hand-built violating event streams)
# ---------------------------------------------------------------------------

def test_unit_unregistered_arrival():
    flow = FlowController(omega=2)
    for k in range(4):
        flow.register(k)
    with sanitized() as san, pytest.raises(InvariantViolation) as ei:
        # forge an accepted arrival from a device the flow never met
        san.record("flow.enqueue", {"flow": flow, "device": 99,
                                    "accepted": True, "registered": False})
    assert ei.value.invariant == "no-unregistered-arrival"


def test_unit_counter_purge_on_rejoin():
    sched = TaskScheduler(n_devices=4)
    with sanitized() as san, pytest.raises(InvariantViolation) as ei:
        sched.q_act[1].append("act")      # backlog pending -> not drained
        sched.remove_device(1)
        sched.counters[1] = 3             # forge surviving stale history
        # real add_device zeroes the counter; forge the rejoin event
        san.record("sched.add", {"sched": sched, "device": 1})
    assert ei.value.invariant == "counter-purge"


def test_unit_staleness_monotonicity():
    from repro.core.control_plane import ControlPlane
    cp = ControlPlane.for_sim(4, 2)
    with sanitized() as san, pytest.raises(InvariantViolation) as ei:
        san.record("cp.finish", {"cp": cp})
        cp.version += 5
        san.record("cp.finish", {"cp": cp})
        cp.version -= 3                   # forge a version rollback
        san.record("cp.finish", {"cp": cp})
    assert ei.value.invariant == "staleness-monotonicity"


def test_unit_single_chain_double_start():
    sim_obj = object()
    with sanitized() as san, pytest.raises(InvariantViolation) as ei:
        san.record("sim.chain_start", {"sim": sim_obj, "device": 0,
                                       "epoch": 0})
        san.record("sim.chain_start", {"sim": sim_obj, "device": 0,
                                       "epoch": 0})
    assert ei.value.invariant == "single-live-chain"
    assert "second concurrent chain" in str(ei.value)


def test_unit_violation_window_is_bounded():
    sim_obj = object()
    san = ProtocolSanitizer(window=8, raise_on_violation=False)
    with sanitized(san):
        for i in range(50):
            san.record("sim.chain_end", {"sim": sim_obj, "device": i % 4,
                                         "epoch": 0})
        san.record("sim.chain_start", {"sim": sim_obj, "device": 0,
                                       "epoch": 3})   # stale epoch
    assert san.n_violations == 1
    assert len(san.violations[0].window) <= 8


# ---------------------------------------------------------------------------
# plumbing
# ---------------------------------------------------------------------------

def test_suspended_detaches_globally():
    from repro.analysis import sanitize as _san
    with sanitized() as san:
        assert _san.TRACING
        with suspended():
            assert not _san.TRACING
            _san.emit("flow.register", flow=None, device=0)  # goes nowhere
        assert _san.TRACING
    assert san.counts.get("flow.register", 0) == 0


def test_catalogue_names_are_unique_and_indexed():
    names = [inv.name for inv in INVARIANTS]
    assert len(names) == len(set(names))
    for inv in INVARIANTS:
        assert inv.events, inv.name
        assert inv.statement and inv.module and inv.caught


def test_sanitizer_does_not_perturb_the_run():
    """Read-only contract: same seed, same metrics with and without."""
    cluster = heterogeneous_cluster(8)
    trace = _churn_trace(8, 300.0, cluster=cluster)
    kw = dict(duration=300.0, omega=4, fleet=trace, seed=9)
    with suspended():
        plain = simulate_fedoptima(MODEL, cluster, **kw)
        with sanitized():
            checked = simulate_fedoptima(MODEL, cluster, **kw)
    assert plain.srv_idle_frac == checked.srv_idle_frac
    assert plain.dev_idle_frac == checked.dev_idle_frac
    assert plain.throughput == checked.throughput
