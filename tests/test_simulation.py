"""Event simulator: idle time, throughput, communication — the paper's
system-level claims as testable orderings (Fig. 1/2/8-11)."""
import numpy as np
import pytest

from repro.core.baselines import REGISTRY
from repro.core.simulation import (SimModel, heterogeneous_cluster,
                                   simulate_fedoptima)

MODEL = SimModel(dev_fwd_flops=1e9, dev_bwd_flops=2e9, full_fwd_flops=5e9,
                 srv_flops_per_batch=8e9, act_bytes=1e6, dev_model_bytes=4e6,
                 full_model_bytes=2e7, batch_size=32)
CLUSTER = heterogeneous_cluster(8)
DUR = 400.0


@pytest.fixture(scope="module")
def results():
    out = {"fedoptima": simulate_fedoptima(MODEL, CLUSTER, duration=DUR)}
    for name, fn in REGISTRY.items():
        out[name] = fn(MODEL, CLUSTER, duration=DUR)
    return out


def test_fedoptima_lowest_device_idle_among_offloading(results):
    """Fig. 8/9: FedOptima device idle ≤ all offloading baselines."""
    for base in ("splitfed", "pipar", "oafl"):
        assert results["fedoptima"].dev_idle_frac <= \
            results[base].dev_idle_frac + 1e-6


def test_fedoptima_lowest_server_idle(results):
    """Fig. 8/9: server idle lower than every baseline."""
    for name, m in results.items():
        if name == "fedoptima":
            continue
        assert results["fedoptima"].srv_idle_frac <= m.srv_idle_frac + 1e-6


def test_fedoptima_highest_throughput(results):
    """Fig. 10/11 (Observation 3)."""
    for name, m in results.items():
        assert results["fedoptima"].throughput >= m.throughput - 1e-6, name


def test_async_beats_sync_on_heterogeneous_devices(results):
    """Stragglers: FedAsync devices idle less than classic FL's."""
    assert results["fedasync"].dev_idle_frac < results["fl"].dev_idle_frac


def test_pipar_overlap_beats_splitfed(results):
    assert results["pipar"].throughput >= results["splitfed"].throughput


def test_fedoptima_comm_lower_than_oafl(results):
    """Fig. 2: flow control + no gradient return cut communication."""
    total = 8 * 4096  # nominal dataset size for per-round normalization
    fo = results["fedoptima"].comm_per_round(total)
    oafl = results["oafl"].comm_per_round(total)
    assert fo < oafl


def test_omega_bounds_buffer():
    """§3.4.1: peak buffered activations never exceed ω."""
    for omega in (1, 4, 16):
        m = simulate_fedoptima(MODEL, CLUSTER, duration=DUR, omega=omega)
        assert m.max_buffered <= omega


def test_larger_omega_no_less_server_work():
    served = [simulate_fedoptima(MODEL, CLUSTER, duration=DUR,
                                 omega=o).srv_batches for o in (1, 8)]
    assert served[1] >= served[0]


def test_churn_degrades_gracefully():
    """Fig. 12/13: retention ratio stays high under dropout for FedOptima
    and collapses for barrier-based SplitFed."""
    from repro.runtime.fault_tolerance import ChurnModel
    base = simulate_fedoptima(MODEL, CLUSTER, duration=DUR).throughput
    churn = ChurnModel(n_devices=8, p_drop=0.3, interval=50.0, seed=1)
    t = simulate_fedoptima(MODEL, CLUSTER, duration=DUR, churn=churn)
    retention = t.throughput / base
    assert retention > 0.4

    from repro.core.baselines import simulate_splitfed
    sf_base = simulate_splitfed(MODEL, CLUSTER, duration=DUR).throughput
    churn2 = ChurnModel(n_devices=8, p_drop=0.3, interval=50.0, seed=1)
    sf = simulate_splitfed(MODEL, CLUSTER, duration=DUR, churn=churn2)
    assert sf.throughput / max(sf_base, 1e-9) <= retention + 0.05


def test_deterministic_given_seed():
    a = simulate_fedoptima(MODEL, CLUSTER, duration=100.0, seed=3)
    b = simulate_fedoptima(MODEL, CLUSTER, duration=100.0, seed=3)
    assert a.dev_samples == b.dev_samples and a.bytes_up == b.bytes_up
