"""Seeded property-check shim: a drop-in subset of `hypothesis`.

Test modules import ``given`` / ``settings`` / ``strategies`` from here.
When the real `hypothesis` package is installed we re-export it verbatim;
otherwise a tiny deterministic fallback runs each property test over
``max_examples`` seeded draws (seed = crc32 of the test's qualified name),
so `PYTHONPATH=src python -m pytest` collects and passes with zero
third-party plugins beyond pytest.

Only the strategy combinators the suite uses are implemented:
integers, floats, booleans, sampled_from, lists, tuples.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401

except ImportError:
    import zlib
    from types import SimpleNamespace

    import numpy as np

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw = draw_fn

        def draw(self, rng):
            return self._draw(rng)

    def _integers(lo, hi):
        return _Strategy(lambda r: int(r.integers(lo, hi + 1)))

    def _floats(lo, hi):
        return _Strategy(lambda r: float(r.uniform(lo, hi)))

    def _booleans():
        return _Strategy(lambda r: bool(r.integers(0, 2)))

    def _sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda r: items[int(r.integers(len(items)))])

    def _lists(elem, *, min_size=0, max_size=10):
        def draw(r):
            n = int(r.integers(min_size, max_size + 1))
            return [elem.draw(r) for _ in range(n)]
        return _Strategy(draw)

    def _tuples(*elems):
        return _Strategy(lambda r: tuple(e.draw(r) for e in elems))

    strategies = SimpleNamespace(
        integers=_integers, floats=_floats, booleans=_booleans,
        sampled_from=_sampled_from, lists=_lists, tuples=_tuples)

    def settings(max_examples=20, **_ignored):
        def deco(fn):
            fn._pc_max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            def runner():
                # read at call time so @settings works above OR below @given
                n_examples = getattr(fn, "_pc_max_examples",
                                     getattr(runner, "_pc_max_examples", 20))
                seed = zlib.crc32(f"{fn.__module__}::{fn.__name__}".encode())
                for i in range(n_examples):
                    rng = np.random.default_rng((seed, i))
                    args = [s.draw(rng) for s in strats]
                    try:
                        fn(*args)
                    except Exception as e:  # pragma: no cover - repro aid
                        e.args = (f"{e.args[0] if e.args else ''} "
                                  f"[propcheck example {i}: {args!r}]",)
                        raise

            # no functools.wraps: pytest must see a zero-arg signature,
            # and __wrapped__ would leak the property arguments as fixtures
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner
        return deco
