"""Per-architecture smoke tests: reduced config of the same family, one
forward + one hybrid train step on CPU; output shapes + no NaNs.
(The FULL configs are exercised only via the dry-run — no allocation.)"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.core import fedopt_step as F
from repro.launch.mesh import make_debug_mesh
from repro.models import transformer as tfm

ARCHS = sorted(registry.ARCHS)


@pytest.mark.parametrize("name", ARCHS)
def test_forward_and_loss(name, rng):
    cfg = registry.smoke_config(name)
    params = tfm.init_params(rng, cfg)
    B, S = 2, 24
    tok = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    lab = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    fe = (jax.random.normal(rng, (B, cfg.frontend_len, cfg.d_model))
          if cfg.frontend_len else None)
    loss, (ce, aux) = tfm.lm_loss(params, cfg, tok, lab, frontend=fe)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{name}: non-finite loss"
    if not cfg.n_decoder_layers:
        h, _ = tfm.forward(params, cfg, tok, frontend=fe)
        assert h.shape == (B, S, cfg.d_model)
        assert bool(jnp.isfinite(h).all()), f"{name}: NaNs in hidden states"


@pytest.mark.parametrize("name", ARCHS)
def test_hybrid_train_step(name, rng):
    """One FedOptima round (H micro-iterations + aggregation) per arch."""
    arch = registry.smoke_config(name)
    mesh = make_debug_mesh(1, 1)
    cfg = F.FedStepConfig(arch=arch, l_split=1, n_groups=2, seq_len=16,
                          per_group_batch=2, H=2, param_dtype=jnp.float32)
    jitted, _, s_spec, _ = F.jit_train_step(cfg, mesh)
    state = jax.jit(lambda: F.init_train_state(rng, cfg),
                    out_shardings=s_spec)()
    batch = F.concrete_train_batch(rng, cfg)
    state, metrics = jitted(state, batch)
    assert bool(jnp.isfinite(metrics["d_loss"]))
    assert bool(jnp.isfinite(metrics["s_loss"]))
    assert int(state["step"]) == 1 and int(state["version"]) == 1
    # a second round continues from donated state
    state, metrics = jitted(state, F.concrete_train_batch(
        jax.random.fold_in(rng, 1), cfg))
    assert bool(jnp.isfinite(metrics["s_loss"]))


@pytest.mark.parametrize("name", ARCHS)
def test_decode_step(name, rng):
    cfg = registry.smoke_config(name)
    params = tfm.init_params(rng, cfg)
    B = 2
    caches = tfm.init_serve_state(cfg, B, max_len=32)
    tok = jax.random.randint(rng, (B, 1), 0, cfg.vocab)
    logits, caches = tfm.serve_decode_step(params, cfg, caches, tok,
                                           jnp.int32(0))
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("name", ["smollm-135m", "gemma2-27b", "mamba2-780m",
                                  "jamba-1.5-large-398b", "whisper-tiny",
                                  "llama-3.2-vision-90b"])
def test_prefill_decode_consistency(name, rng):
    """Decode after prefill == prefill of the longer sequence."""
    cfg = registry.smoke_config(name)
    if cfg.n_experts:  # exact match needs dropless capacity
        cfg = cfg.scaled(moe_capacity_factor=float(cfg.n_experts) / cfg.top_k)
    params = tfm.init_params(rng, cfg)
    B, S = 2, 12
    tok = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab)
    fe = (jax.random.normal(rng, (B, cfg.frontend_len, cfg.d_model))
          if cfg.frontend_len else None)
    _, caches = tfm.prefill(params, cfg, tok[:, :S], max_len=32, frontend=fe)
    got, _ = tfm.serve_decode_step(params, cfg, caches, tok[:, S:S + 1],
                                   jnp.int32(S))
    want, _ = tfm.prefill(params, cfg, tok, max_len=32, frontend=fe)
    assert jnp.allclose(got, want, atol=2e-4), \
        f"{name}: decode diverges from prefill"


def test_full_configs_match_assignment():
    """The exact assigned hyper-parameters (spot checks per arch)."""
    a = registry.get("command-r-plus-104b")
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads, a.d_ff,
            a.vocab) == (64, 12288, 96, 8, 33792, 256000)
    a = registry.get("qwen3-32b")
    assert a.qk_norm and (a.n_layers, a.d_model, a.vocab) == (64, 5120, 151936)
    a = registry.get("smollm-135m")
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads) == (30, 576, 9, 3)
    a = registry.get("gemma2-27b")
    assert a.attn_softcap == 50.0 and a.window == 4096 and a.n_layers == 46
    a = registry.get("llama-3.2-vision-90b")
    assert a.n_layers == 100 and any(m == "cross" for m, _ in a.pattern)
    a = registry.get("mamba2-780m")
    assert a.ssm_state == 128 and a.d_ff == 0 and a.n_layers == 48
    a = registry.get("whisper-tiny")
    assert a.n_decoder_layers == 4 and a.d_model == 384
    a = registry.get("jamba-1.5-large-398b")
    assert a.n_experts == 16 and a.top_k == 2 and len(a.pattern) == 8
    assert sum(m == "attn" for m, _ in a.pattern) == 1          # 1:7
    a = registry.get("qwen3-moe-235b-a22b")
    assert a.n_experts == 128 and a.top_k == 8 and a.n_layers == 94
    a = registry.get("llama4-maverick-400b-a17b")
    assert a.n_experts == 128 and a.top_k == 1 and a.vocab == 202048


def test_param_counts_plausible():
    """Analytic 6·N·D accounting lands near the advertised sizes."""
    from repro.analysis.roofline import count_params
    expect = {"command-r-plus-104b": 104e9, "qwen3-32b": 32e9,
              "smollm-135m": 135e6, "gemma2-27b": 27e9,
              "mamba2-780m": 780e6, "qwen3-moe-235b-a22b": 235e9,
              "llama4-maverick-400b-a17b": 400e9,
              "jamba-1.5-large-398b": 398e9}
    for name, n in expect.items():
        total, active = count_params(registry.get(name))
        assert 0.5 * n < total < 1.6 * n, (name, total)
        assert active <= total
