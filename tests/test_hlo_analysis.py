"""HLO cost parser: trip-count scaling, collective accounting, roofline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (Cost, analyze_compiled, analyze_text, roofline,
                            count_params, model_flops)
from repro.analysis.hlo import HloModule, _shape_dims, _type_bytes


def test_type_bytes():
    assert _type_bytes("f32[4,8]{1,0}") == 128
    assert _type_bytes("bf16[10]") == 20
    assert _type_bytes("(f32[2,2], s32[3])") == 28
    assert _type_bytes("pred[7]") == 7
    assert _shape_dims("f32[4,8]{1,0}") == [4, 8]


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_dot_flops_exact():
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((64, 32), jnp.float32),
                 jax.ShapeDtypeStruct((32, 16), jnp.float32))
    cost = analyze_compiled(c)
    assert cost.flops == 2 * 64 * 32 * 16


def test_while_trip_count_scaling():
    """A scan of N matmuls must count N×, not 1× (XLA counts 1×)."""
    n, d = 9, 32

    def fn(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), ()
        y, _ = jax.lax.scan(body, x, None, length=n)
        return y.sum()

    c = _compile(fn, jax.ShapeDtypeStruct((8, d), jnp.float32),
                 jax.ShapeDtypeStruct((d, d), jnp.float32))
    cost = analyze_compiled(c)
    expect = n * 2 * 8 * d * d
    assert abs(cost.flops - expect) / expect < 0.01
    xla = c.cost_analysis()
    if isinstance(xla, (list, tuple)):   # jax 0.4.x: one dict per device
        xla = xla[0]
    assert xla["flops"] < cost.flops / 2  # XLA undercounts (body once)


def test_nested_scan_scaling():
    def fn(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, ()
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, ()
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y.sum()

    c = _compile(fn, jax.ShapeDtypeStruct((4, 16), jnp.float32),
                 jax.ShapeDtypeStruct((16, 16), jnp.float32))
    cost = analyze_compiled(c)
    expect = 5 * 3 * 2 * 4 * 16 * 16
    assert abs(cost.flops - expect) / expect < 0.01


def test_dynamic_update_slice_counts_slice_not_buffer():
    """In-place accumulation traffic = slice, not the whole buffer."""
    def fn(buf, x):
        def body(b, i):
            return jax.lax.dynamic_update_slice_in_dim(b, x, i, 0), ()
        out, _ = jax.lax.scan(body, buf, jnp.arange(100))
        return out

    c = _compile(fn, jax.ShapeDtypeStruct((1024, 256), jnp.float32),
                 jax.ShapeDtypeStruct((1, 256), jnp.float32))
    cost = analyze_compiled(c)
    full_buffer_per_iter = 100 * 1024 * 256 * 4
    assert cost.bytes < full_buffer_per_iter  # would be 100x buffer if naive


def test_cost_add_and_scale():
    a = Cost(flops=2.0, bytes=4.0)
    a.collective_bytes["all-reduce"] += 8.0
    b = a.scaled(3)
    assert b.flops == 6.0 and b.collective_bytes["all-reduce"] == 24.0
    a += b
    assert a.flops == 8.0 and a.total_collective_bytes == 32.0


def test_exclude_fn_zeroes_matching_buffers():
    def fn(q, k):
        s = q @ k.T                    # (128, 128) score-like
        return jax.nn.softmax(s, axis=-1).sum()

    c = _compile(fn, jax.ShapeDtypeStruct((128, 64), jnp.float32),
                 jax.ShapeDtypeStruct((128, 64), jnp.float32))
    base = analyze_compiled(c)
    excl = analyze_compiled(c, exclude_fn=lambda d: tuple(d) == (128, 128))
    assert excl.bytes < base.bytes
    assert excl.flops == base.flops    # flops unchanged


def test_roofline_terms_and_dominance():
    cost = Cost(flops=197e12, bytes=819e9 / 2)
    cost.collective_bytes["all-reduce"] = 50e9 / 8
    t = roofline(cost, model_flops_total=197e12 / 2, n_chips=1)
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 0.5) < 1e-9
    assert abs(t.collective_s - 0.25) < 1e-9   # 2x ring factor
    assert t.dominant == "compute"
    assert abs(t.mfu - 0.5) < 1e-9


def test_model_flops_moe_counts_active_only():
    from repro.configs import registry
    dense_like = registry.get("qwen3-moe-235b-a22b")
    total, active = count_params(dense_like)
    assert active < 0.2 * total        # 235B total vs ~22B active
    mf_train = model_flops(dense_like, 1000, kind="train")
    mf_inf = model_flops(dense_like, 1000, kind="infer")
    assert abs(mf_train / mf_inf - 3.0) < 1e-6


def test_parser_handles_real_sharded_module():
    """End-to-end on an SPMD module would need >1 device; on 1 device the
    parser must still walk the entry and find the dots."""
    def fn(x, w1, w2):
        h = jax.nn.relu(x @ w1)
        return (h @ w2).sum()

    c = _compile(fn, jax.ShapeDtypeStruct((32, 64), jnp.float32),
                 jax.ShapeDtypeStruct((64, 128), jnp.float32),
                 jax.ShapeDtypeStruct((128, 8), jnp.float32))
    cost = analyze_compiled(c)
    expect = 2 * 32 * 64 * 128 + 2 * 32 * 128 * 8
    assert abs(cost.flops - expect) / expect < 0.01
