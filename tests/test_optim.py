"""Optimizers, schedules, clipping — pure pytree transforms."""
import jax
import jax.numpy as jnp
import numpy as np
from _propcheck import given, settings, strategies as st

from repro.optim.clip import clip_by_global_norm
from repro.optim.optimizers import (adamw_init, adamw_update, make_optimizer,
                                    sgd_init, sgd_update)
from repro.optim.schedule import cosine_schedule, warmup_cosine


def _params():
    return {"w": jnp.ones((3, 3)), "b": jnp.zeros((3,))}


def test_sgd_step():
    p = _params()
    g = jax.tree.map(jnp.ones_like, p)
    s = sgd_init(p)
    p2, s2 = sgd_update(p, g, s, lr=0.1)
    np.testing.assert_allclose(p2["w"], 0.9)
    assert int(s2["step"]) == 1


def test_sgd_momentum_accumulates():
    p = _params()
    g = jax.tree.map(jnp.ones_like, p)
    s = sgd_init(p, momentum=0.9)
    p1, s = sgd_update(p, g, s, 0.1, momentum=0.9)
    p2, s = sgd_update(p1, g, s, 0.1, momentum=0.9)
    # second step uses velocity 1.9
    np.testing.assert_allclose(p2["w"], 1.0 - 0.1 - 0.19, rtol=1e-6)


def test_adamw_converges_on_quadratic():
    """AdamW minimizes ||x - 3||^2 quickly."""
    x = {"x": jnp.zeros((4,))}
    s = adamw_init(x)
    for _ in range(300):
        g = jax.tree.map(lambda v: 2 * (v - 3.0), x)
        x, s = adamw_update(x, g, s, lr=0.05, weight_decay=0.0)
    np.testing.assert_allclose(x["x"], 3.0, atol=0.05)


def test_adamw_weight_decay_shrinks():
    x = {"x": jnp.full((2,), 10.0)}
    s = adamw_init(x)
    g = jax.tree.map(jnp.zeros_like, x)
    x2, _ = adamw_update(x, g, s, lr=0.1, weight_decay=0.5)
    assert float(x2["x"][0]) < 10.0


def test_make_optimizer_binds_hyper():
    init, update = make_optimizer("sgd", momentum=0.9)
    p = _params()
    s = init(p)
    assert "velocity" in s
    p2, _ = update(p, jax.tree.map(jnp.ones_like, p), s, 0.1)
    assert float(p2["w"][0, 0]) < 1.0


@given(st.floats(0.1, 10.0))
@settings(max_examples=10, deadline=None)
def test_clip_by_global_norm(max_norm):
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), -4.0)}
    clipped, norm = clip_by_global_norm(g, max_norm)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert float(total) <= max_norm * 1.001 + 1e-6
    assert float(norm) == 10.0


def test_schedules_monotone_sections():
    import jax.numpy as jnp
    s = warmup_cosine(lr=1.0, warmup=10, total_steps=100)
    vals = [float(s(jnp.asarray(i))) for i in range(100)]
    assert vals[0] < vals[9] <= 1.0 + 1e-6          # warmup rises
    assert vals[20] > vals[90]                       # cosine decays
    c = cosine_schedule(lr=2.0, total_steps=50)
    assert float(c(jnp.asarray(0))) == 2.0
    assert float(c(jnp.asarray(50))) <= 0.2 * 2.0 + 1e-6
