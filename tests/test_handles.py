"""Donation-safe round handles: snapshot independence from the donated
source, lazy slicing, readiness/host staging, and HandleRing eviction +
byte accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.handles import HandleRing, RoundHandle, snapshot_tree


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"dev": {"w": jnp.asarray(rng.standard_normal((2, 3)),
                                     jnp.float32)},
            "aux": {"b": jnp.arange(4, dtype=jnp.float32)},
            "act_buf": {"acts": jnp.asarray(
                rng.standard_normal((2, 5)), jnp.float32)},
            "host": np.arange(6.0),
            "step": 7}


# ---------------------------------------------------------------------------
# snapshot_tree: fresh buffers, not views of the donated source
# ---------------------------------------------------------------------------

def test_snapshot_survives_donation_of_the_source():
    """The whole point: a donated step invalidates the source buffers, and
    the snapshot taken before the donating dispatch must stay readable."""
    donating = jax.jit(lambda x: x + 1.0, donate_argnums=(0,))
    src = jnp.arange(8, dtype=jnp.float32)
    snap = snapshot_tree({"x": src})
    donating(src)                       # src's buffer is now donated
    with pytest.raises(Exception):
        np.asarray(src)                 # the source really is dead
    np.testing.assert_array_equal(np.asarray(snap["x"]),
                                  np.arange(8, dtype=np.float32))


def test_snapshot_copies_numpy_leaves_and_passes_scalars():
    host = np.arange(3.0)
    snap = snapshot_tree({"h": host, "s": 5})
    host[0] = 99.0                      # mutate AFTER the snapshot
    np.testing.assert_array_equal(snap["h"], [0.0, 1.0, 2.0])
    assert snap["s"] == 5


def test_snapshot_to_host_keeps_values_bitexact():
    t = _tree()
    a = snapshot_tree(t)
    b = snapshot_tree(t, to_host=True)  # async D2H staged, values identical
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# RoundHandle: capture subsets, slicing, readiness, host caching
# ---------------------------------------------------------------------------

def test_capture_keys_subset_and_has():
    h = RoundHandle.capture(3, _tree(), keys=("dev", "aux"))
    assert h.round == 3
    assert h.has("dev") and h.has("aux")
    assert not h.has("act_buf") and not h.has("host")


def test_group_state_and_act_slot_match_live_slices():
    t = _tree(seed=4)
    h = RoundHandle.capture(0, t, keys=("dev", "aux", "act_buf"))
    g, s = 1, 0
    got = h.group_state(g)
    np.testing.assert_array_equal(got["dev"]["w"],
                                  np.asarray(t["dev"]["w"])[g])
    np.testing.assert_array_equal(got["aux"]["b"],
                                  np.asarray(t["aux"]["b"])[g])
    np.testing.assert_array_equal(h.act_slot(s)["acts"],
                                  np.asarray(t["act_buf"]["acts"])[s])


def test_ready_and_host_tree_cached():
    h = RoundHandle.capture(0, _tree(), to_host=True, meta={"r": 0})
    jax.block_until_ready(h.tree)
    assert h.ready()
    ht = h.host_tree()
    assert h.host_tree() is ht          # cached
    assert isinstance(ht["dev"]["w"], np.ndarray)
    assert h.meta == {"r": 0}
    assert h.nbytes > 0


def test_capture_copy_false_wraps_live_tree():
    t = _tree()
    h = RoundHandle.capture(2, t, copy=False)
    assert h.tree is t                  # the flush path: no copies


# ---------------------------------------------------------------------------
# HandleRing: positional eviction + byte high-water mark
# ---------------------------------------------------------------------------

def test_ring_evicts_oldest_and_tracks_peak_bytes():
    ring = HandleRing(depth=2)
    for r in range(4):
        ring.push(RoundHandle.capture(r, {"x": np.zeros(8, np.float32)}))
    assert len(ring) == 2
    assert ring.get(0) is None and ring.get(1) is None
    assert ring.get(2).round == 2 and ring.get(3).round == 3
    s = ring.summary()
    assert s["held"] == 2 and s["captured"] == 4
    assert s["peak_bytes"] == s["bytes"] == 2 * 32
    assert ring.nbytes == 64


def test_ring_rejects_bad_depth():
    with pytest.raises(ValueError, match="depth"):
        HandleRing(depth=0)
