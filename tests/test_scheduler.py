"""Task Scheduler (paper Alg. 2-3): queues, model priority, counter balance."""
import numpy as np
from _propcheck import given, settings, strategies as st

from repro.core.scheduler import Message, TaskScheduler


def _act(k):
    return Message("activation", k)


def test_model_priority_over_activations():
    s = TaskScheduler(3)
    s.put(_act(0))
    s.put(Message("model", 1, content=7))
    s.put(_act(2))
    first = s.get()
    assert first.kind == "model" and first.origin == 1   # Alg. 3 line 1
    assert s.get().kind == "activation"


def test_counter_prefers_underserved_device():
    s = TaskScheduler(2)
    for _ in range(5):
        s.put(_act(0))
    s.put(_act(1))
    served = [s.get().origin for _ in range(4)]
    # device 1 must be served by the second get() at the latest
    assert 1 in served[:2]


def test_counter_balances_under_skewed_arrivals():
    """Fast device sends 9x more activations; consumption stays ~balanced
    while the slow device has anything pending (Challenge 3)."""
    s = TaskScheduler(2)
    rng = np.random.default_rng(0)
    consumed = {0: 0, 1: 0}
    for t in range(400):
        s.put(_act(0))
        if t % 9 == 0:
            s.put(_act(1))
        m = s.get()
        consumed[m.origin] += 1
    # slow device contributed every batch it sent (~45), fast fills the rest
    assert consumed[1] >= 40
    assert consumed[0] + consumed[1] == 400


def test_fifo_policy_follows_arrival_order():
    s = TaskScheduler(2, policy="fifo")
    s.put(_act(0)); s.put(_act(0)); s.put(_act(1))
    assert [s.get().origin for _ in range(3)] == [0, 0, 1]


def test_fifo_overserves_fast_devices():
    """The §6.5.2 ablation mechanism: FIFO consumption tracks arrivals."""
    fifo, ctr = TaskScheduler(2, policy="fifo"), TaskScheduler(2)
    cf = {0: 0, 1: 0}
    cc = {0: 0, 1: 0}
    for t in range(90):
        for s in (fifo, ctr):
            s.put(_act(0))
            if t % 3 == 0:
                s.put(_act(1))
        cf[fifo.get().origin] += 1
        cc[ctr.get().origin] += 1
    # counter policy serves the slow device at least as much as FIFO does
    assert cc[1] >= cf[1]
    assert cc[1] >= 28            # near-parity while slow dev has backlog


@given(st.lists(st.tuples(st.integers(0, 4), st.booleans()), max_size=60))
@settings(max_examples=40, deadline=None)
def test_scheduler_never_loses_messages(events):
    """Property: every put is eventually got exactly once; counters only
    count served activations."""
    s = TaskScheduler(5)
    n_put = n_got = 0
    for k, is_model in events:
        s.put(Message("model" if is_model else "activation", k))
        n_put += 1
        if len(events) % 2:
            if s.get() is not None:
                n_got += 1
    while s.get() is not None:
        n_got += 1
    assert n_got == n_put
    assert sum(s.counters.values()) == sum(1 for k, m in events if not m)


def test_arrival_log_bounded_under_counter_policy():
    """Regression: the counter policy never drains the FIFO arrival log, so
    appending to it unconditionally grows memory without bound — ironic for
    the memory-management paper."""
    s = TaskScheduler(2)
    for t in range(500):
        s.put(_act(t % 2))
        s.get()
    assert len(s._arrival) == 0
    f = TaskScheduler(2, policy="fifo")
    for t in range(500):
        f.put(_act(t % 2))
        f.get()
    assert len(f._arrival) <= 1            # lazily drained


def test_remove_device_purges_after_drain_keeps_buffered():
    """remove_device (Alg. 2/3 under churn): already-buffered activations
    still drain through get() — ranked under the device's accumulated
    counter, so the departed backlog cannot jump ahead of live underserved
    devices — and counter+queue are purged once drained."""
    s = TaskScheduler(2)
    for _ in range(3):
        s.put(_act(0))
    s.put(_act(1))
    assert s.get().origin == 0             # counters: {0: 1, 1: 0}
    s.remove_device(0)                     # 2 buffered leftovers remain
    # fairness survives departure: live device 1 (counter 0) served first
    assert s.get().origin == 1
    assert [s.get().origin for _ in range(2)] == [0, 0]   # leftovers train
    assert s.get() is None
    assert 0 not in s.q_act                # drained queue dropped
    assert 0 not in s.counters             # ...and counter purged with it
    # rejoin starts with fresh history
    s.add_device(0)
    assert s.counters[0] == 0


def test_remove_device_rejoin_before_drain_resets_history():
    s = TaskScheduler(2)
    for _ in range(4):
        s.put(_act(0))
        s.get()
    assert s.counters[0] == 4
    s.put(_act(0))
    s.remove_device(0)                     # backlog of 1 keeps counter 4
    s.add_device(0)                        # rejoin: fresh history
    assert s.counters[0] == 0
    assert s.buffered(0) == 1              # backlog survived the bounce


def test_elastic_add_device_mid_run():
    s = TaskScheduler(2)
    s.put(_act(0))
    s.put(_act(7))            # unseen device registers lazily (§3.4.2)
    origins = {s.get().origin, s.get().origin}
    assert origins == {0, 7}
