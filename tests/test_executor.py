"""RoundExecutor: pipelined dispatch ≡ synchronous loop (window=1, bit for
bit), host plan/build overlap at window=2, measured straggler profiles,
per-group state retention for dropped groups, ω-cap RuntimeError."""
import copy
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import fedopt_step as F
from repro.core.control_plane import ControlPlane
from repro.core.executor import RoundExecutor, StragglerProfiles
from repro.launch.mesh import make_debug_mesh
from repro.runtime.elastic import ElasticRegistry


def _setup(omega=1, n_groups=2, H=2):
    a = registry.smoke_config("smollm-135m")
    cfg = F.FedStepConfig(arch=a, l_split=1, n_groups=n_groups, seq_len=16,
                          per_group_batch=2 * H, H=H, omega=omega)
    mesh = make_debug_mesh(1, 1)
    jitted, _, s_spec, _ = F.jit_train_step(cfg, mesh, donate=False)
    state = jax.jit(lambda: F.init_train_state(jax.random.PRNGKey(0), cfg),
                    out_shardings=s_spec)()
    return cfg, jitted, state, s_spec


def _copy_state(state):
    return jax.tree.map(jnp.copy, state)


def _batch_fn(cfg):
    def fn(r, plan):
        batch = F.concrete_train_batch(jax.random.PRNGKey(r), cfg)
        batch.update(plan.batch_fields())
        return batch
    return fn


def _executor(cfg, step, s_spec, window, profiles=True, registry_=None):
    cp = ControlPlane(cfg.n_groups, cfg.omega, cfg.H)
    return cp, RoundExecutor(
        step, cp, window=window,
        profiles=StragglerProfiles(cfg.n_groups) if profiles else None,
        gather=F.gather_group_state,
        scatter=lambda st, g, p: F.scatter_group_state(
            st, g, p, state_shardings=s_spec),
        registry=registry_)


def _reference_sync_loop(cfg, step, state, actives):
    """The pre-executor run_pod round loop, verbatim semantics."""
    cp = ControlPlane(cfg.n_groups, cfg.omega, cfg.H)
    history = []
    batch_fn = _batch_fn(cfg)
    for r, active in enumerate(actives):
        plan = cp.plan_round(active=active)
        state, metrics = step(state, batch_fn(r, plan))
        cp.finish_round(active=active)
        assert cp.within_cap
        history.append({k: float(v) for k, v in metrics.items()})
    return state, history


# ---------------------------------------------------------------------------
# determinism: pipelining must not change values
# ---------------------------------------------------------------------------

def test_window1_bitforbit_matches_synchronous_loop():
    """Acceptance: executor(window=1) reproduces the synchronous round
    loop's metrics history and final state bit for bit."""
    cfg, step, state0, s_spec = _setup(omega=1, n_groups=2, H=2)
    actives = [np.ones(2, bool)] * 4
    ref_state, ref_hist = _reference_sync_loop(cfg, step,
                                               _copy_state(state0), actives)
    _, ex = _executor(cfg, step, s_spec, window=1)
    state, hist = ex.run(_copy_state(state0), 0, 4,
                         active_fn=lambda r: actives[r],
                         batch_fn=_batch_fn(cfg))
    assert hist == ref_hist            # exact float equality, round order
    for la, lb in zip(jax.tree.leaves(ref_state), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_window2_history_values_equal_window1_under_churn():
    """Metric values are window-invariant (planning never reads device
    values), including across a drop/rejoin with state retention."""
    cfg, step, state0, s_spec = _setup(omega=2, n_groups=2, H=2)
    actives = [np.array([True, True]), np.array([True, False]),
               np.array([True, False]), np.array([True, True]),
               np.array([True, True])]
    results = {}
    for window in (1, 2):
        _, ex = _executor(cfg, step, s_spec, window=window)
        results[window] = ex.run(_copy_state(state0), 0, len(actives),
                                 active_fn=lambda r: actives[r],
                                 batch_fn=_batch_fn(cfg))
    s1, h1 = results[1]
    s2, h2 = results[2]
    assert h1 == h2
    for la, lb in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# overlap: window=2 hides host plan/build time behind device execution
# ---------------------------------------------------------------------------

def test_window2_overlaps_host_batch_build():
    """Acceptance: with window=2 the host plan/batch-build time is hidden
    behind device execution — host wall per round strictly below the
    synchronous (window=1) baseline on the same config."""
    cfg, step, state0, s_spec = _setup(omega=1, n_groups=2, H=2)
    batch_fn = _batch_fn(cfg)
    batch0 = batch_fn(0, ControlPlane(2, 1, 2).plan_round())
    jax.block_until_ready(step(_copy_state(state0), batch0))   # warm jit
    t0 = time.perf_counter()
    jax.block_until_ready(step(_copy_state(state0), batch0))
    dev_s = time.perf_counter() - t0
    sleep_s = min(max(0.5 * dev_s, 0.02), 0.25)   # modeled host build cost
    rounds = 8

    def slow_batch_fn(r, plan):
        time.sleep(sleep_s)
        return batch_fn(r, plan)

    walls = {}
    for window in (1, 2):
        _, ex = _executor(cfg, step, s_spec, window=window)
        t0 = time.perf_counter()
        ex.run(_copy_state(state0), 0, rounds,
               active_fn=lambda r: np.ones(2, bool), batch_fn=slow_batch_fn)
        walls[window] = time.perf_counter() - t0
        if window == 2:
            assert ex.peak_in_flight == 2
            assert ex.hidden_host_s > 0.0
    # saving ≈ rounds * min(sleep, device); demand a third of it
    margin = 0.25 * rounds * min(sleep_s, dev_s)
    assert walls[2] < walls[1] - margin, (walls, dev_s, sleep_s)


# ---------------------------------------------------------------------------
# per-group state retention (dropped groups rejoin from their own params)
# ---------------------------------------------------------------------------

def test_dropped_group_retains_state_and_staleness_on_rejoin():
    """Acceptance: a group dropped for k rounds keeps its retained dev/aux
    params unchanged, is NOT resynced by the aggregation broadcast, and
    rejoins from exactly those params with α reflecting the recorded
    delay."""
    cfg, step, state0, s_spec = _setup(omega=1, n_groups=2, H=2)
    k = 2                                  # dropped rounds
    actives = [np.array([True, True]), np.array([True, False]),
               np.array([True, False]), np.array([True, True]),
               np.array([True, True])]
    registry_ = ElasticRegistry()
    for g in range(2):
        registry_.join(1.0, 1.0)
    cp, ex = _executor(cfg, step, s_spec, window=1, registry_=registry_)
    scattered = {}
    real_scatter = ex.scatter

    def spy_scatter(st, g, p):
        scattered[g] = p
        return real_scatter(st, g, p)

    ex.scatter = spy_scatter

    snaps = {}
    plans = {}

    def on_metrics(r, m, st):
        plans[r] = st.plan                 # plan is dropped after this hook
        if 1 in cp.retention:              # snapshot the retained entry
            snaps[r] = copy.deepcopy(cp.retention.params_of(1))

    state, hist = ex.run(_copy_state(state0), 0, len(actives),
                         active_fn=lambda r: actives[r],
                         batch_fn=_batch_fn(cfg), on_metrics=on_metrics)

    # retained while dropped, and UNCHANGED across the drop window
    assert set(snaps) == {1, 2}
    for la, lb in zip(jax.tree.leaves(snaps[1]), jax.tree.leaves(snaps[2])):
        np.testing.assert_array_equal(la, lb)
    # the rejoin scattered exactly the retained params back
    assert list(scattered) == [1]
    for la, lb in zip(jax.tree.leaves(scattered[1]),
                      jax.tree.leaves(snaps[1])):
        np.testing.assert_array_equal(la, lb)
    assert 1 not in cp.retention           # released on rejoin
    # staleness weight on rejoin reflects the recorded delay: absent for
    # k rounds -> staleness k -> α = 1/(k+1)
    rejoin_plan = plans[3]
    np.testing.assert_allclose(rejoin_plan.agg_weight,
                               [1.0, 1.0 / (k + 1)], rtol=1e-6)
    np.testing.assert_array_equal(rejoin_plan.bcast_mask, [1.0, 1.0])
    assert ex.stats[3].plan is None        # plans are not accumulated
    # registry mirrored the churn with round timestamps
    assert registry_.devices[1].absences == 1
    assert registry_.devices[1].active and registry_.devices[1].joined_at == 3.0
    assert len(hist) == len(actives)


def test_masked_broadcast_keeps_dropped_group_params():
    """bcast_mask gates Alg. 4 line 20: masked-out groups keep their own
    params (no resync), while receiving groups sync to the aggregate."""
    cfg, step, state0, _ = _setup(omega=1, n_groups=2, H=2)
    batch = F.concrete_train_batch(jax.random.PRNGKey(0), cfg)
    batch["agg_weight"] = jnp.asarray([1.0, 0.0])

    masked, _ = step(_copy_state(state0),
                     {**batch, "bcast_mask": jnp.asarray([1.0, 0.0])})
    resync, _ = step(_copy_state(state0),
                     {**batch, "bcast_mask": jnp.asarray([1.0, 1.0])})
    w_m = np.asarray(masked["dev"]["embed"])
    w_r = np.asarray(resync["dev"]["embed"])
    # all-ones mask: broadcast resyncs the groups to identical params
    np.testing.assert_allclose(w_r[0], w_r[1], atol=1e-6)
    # masked: group 1 kept its own (locally-trained) params
    assert np.abs(w_m[0] - w_m[1]).max() > 1e-6
    # the receiving group's params are identical either way
    np.testing.assert_array_equal(w_m[0], w_r[0])


# ---------------------------------------------------------------------------
# ω-cap violation is a real error (not a strippable assert)
# ---------------------------------------------------------------------------

def test_cap_violation_raises_runtime_error_with_occupancy():
    class BrokenPlane(ControlPlane):
        @property
        def within_cap(self):
            return False

    cp = BrokenPlane(2, 1, 2)
    ex = RoundExecutor(lambda s, b: (s, {"d_loss": 0.0, "s_loss": 0.0}),
                       cp, window=1)
    with pytest.raises(RuntimeError, match=r"ring slots.*occupancy"):
        ex.run(0, 0, 1, active_fn=lambda r: np.ones(2, bool),
               batch_fn=lambda r, plan: {})


def test_executor_rejects_bad_window():
    cp = ControlPlane(2, 1, 2)
    with pytest.raises(ValueError, match="window"):
        RoundExecutor(lambda s, b: (s, {}), cp, window=0)


# ---------------------------------------------------------------------------
# measured straggler profiles
# ---------------------------------------------------------------------------

def test_profiles_unseeded_patterns_match_placeholders():
    p = StragglerProfiles(3)
    assert p.produce(4).all() and p.reads(4).all()
    cp = ControlPlane(3, 1, 4)
    planned = cp.plan_round(produce=p.produce(4), reads=p.reads(4))
    default = ControlPlane(3, 1, 4).plan_round()
    np.testing.assert_array_equal(planned.send_mask, default.send_mask)
    np.testing.assert_array_equal(planned.read_slot, default.read_slot)


def test_profiles_heterogeneous_produce_and_reads():
    p = StragglerProfiles(4, step_s=[0.01, 0.02, 0.02, 0.04],
                          server_s=0.08)
    produce = p.produce(8)
    np.testing.assert_array_equal(produce.sum(axis=0), [8, 4, 4, 2])
    assert produce[:, 0].all()             # fastest emits every iteration
    # server at half the lockstep cadence (0.04) consumes every other iter
    assert p.reads(8).sum() == 4


def test_profiles_observe_round_keeps_uniform_profile_uniform():
    """Pod path on a homogeneous mesh: measured-round EMA must never
    introduce phantom heterogeneity (bit-for-bit compat)."""
    p = StragglerProfiles(3)
    for wall in (0.5, 0.3, 0.4):
        p.observe_round(wall, H=4)
        assert p.produce(4).all() and p.reads(4).all()
    assert np.allclose(p.step_s, p.step_s[0])


def test_profiles_observe_round_preserves_relative_speeds():
    p = StragglerProfiles(2, step_s=[0.01, 0.04])
    p.observe_round(wall_s=0.8, H=4)       # slowest binds: 0.2 per iter
    np.testing.assert_allclose(p.step_s[1] / p.step_s[0], 4.0, rtol=1e-6)
    assert p.step_s[1] < 0.04 + 0.25 * 0.2 + 1e-9   # EMA moved toward scale


def test_profiles_patterns_invariant_to_wall_clock_noise():
    """Seeded heterogeneous profiles: observe_round rescales step_s and
    server_s by the same cadence factor, so the produce/reads patterns
    are pure functions of the seeds — never of measured wall times (the
    executor's determinism/window-invariance guarantee)."""
    seeds = dict(step_s=[0.01, 0.02, 0.04], server_s=0.08)
    a = StragglerProfiles(3, **seeds)
    b = StragglerProfiles(3, **seeds)
    rng = np.random.default_rng(0)
    for wall_a, wall_b in zip(rng.uniform(0.1, 2.0, 12),
                              rng.uniform(0.1, 2.0, 12)):
        a.observe_round(wall_a, H=8)       # two different noisy histories
        b.observe_round(wall_b, H=8)
        np.testing.assert_array_equal(a.produce(8), b.produce(8))
        np.testing.assert_array_equal(a.reads(8), b.reads(8))
    # and the patterns still reflect the seeded heterogeneity
    np.testing.assert_array_equal(a.produce(8).sum(axis=0), [8, 4, 2])
    assert a.reads(8).sum() == 4           # server at half the cadence


def test_simulator_measures_straggler_profiles():
    """The event simulator observes real per-device step/transfer times;
    the EMAs converge to the cluster's configured heterogeneity and the
    derived patterns schedule slow devices fewer emissions."""
    from repro.core.simulation import (SimModel, heterogeneous_cluster,
                                       simulate_fedoptima)
    model = SimModel(dev_fwd_flops=1e9, dev_bwd_flops=2e9,
                     full_fwd_flops=5e9, srv_flops_per_batch=8e9,
                     act_bytes=1e6, dev_model_bytes=4e6,
                     full_model_bytes=2e7, batch_size=32)
    cluster = heterogeneous_cluster(4, speed_groups=(1.0, 2.0, 2.0, 4.0))
    m = simulate_fedoptima(model, cluster, duration=120.0)
    prof = m.profiles
    expected = (model.dev_fwd_flops + model.dev_bwd_flops) / cluster.dev_flops
    # EMAs converge to the configured heterogeneity (constant event times)
    np.testing.assert_allclose(prof.step_s, expected, rtol=1e-3)
    np.testing.assert_allclose(prof.transfer_s,
                               model.act_bytes / cluster.dev_bw, rtol=1e-3)
    assert prof.server_s == pytest.approx(
        model.srv_flops_per_batch / cluster.srv_flops, rel=1e-3)
    # measured patterns fed into plan_round: the 4x-slower device is
    # granted about a quarter of the fastest device's emissions (EMA
    # rounding may land the stride on either side of a floor boundary)
    H = 8
    produce = prof.produce(H)
    assert produce[:, 3].all()
    assert 1 <= produce[:, 0].sum() <= 3
    sums = produce.sum(axis=0)
    assert sums[0] <= sums[1] <= sums[3] and sums[0] <= sums[2] <= sums[3]
    cp = ControlPlane(4, 2, H)
    plan = cp.plan_round(produce=produce, reads=prof.reads(H))
    sends = plan.send_mask.sum(axis=0)
    assert sends[0] <= sends[3]
    assert cp.within_cap


# ---------------------------------------------------------------------------
# retention rides the checkpoint store (metadata + extras)
# ---------------------------------------------------------------------------

def test_retention_rides_checkpoint_extras(tmp_path):
    import json

    from repro.checkpoint import store

    cp = ControlPlane(3, 2, 4)
    cp.plan_round(active=np.array([True, True, False]))     # drops group 2
    params = {"dev": {"w": np.arange(6.0).reshape(2, 3)},
              "aux": {"b": np.ones(4, np.float32)}}
    cp.retain_group(2, params)
    cp.finish_round(active=np.array([True, True, False]))

    sd = cp.state_dict()
    json.dumps(sd)                         # checkpoint-metadata safe
    store.save(str(tmp_path), 5, {"x": np.zeros(2)},
               metadata={"control_plane": sd},
               extras=cp.retention.arrays())

    meta = store.restore_metadata(str(tmp_path), 5)
    cp2 = ControlPlane(3, 2, 4)
    cp2.load_state_dict(meta["control_plane"])
    assert cp2.retention.groups == [2]
    assert cp2.retention.version_of(2) == cp.retention.version_of(2)
    assert cp2.retention.params_of(2) is None      # arrays not yet loaded
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        cp.retention.arrays())
    cp2.retention.load_arrays(store.restore_extras(str(tmp_path), 5, like))
    for la, lb in zip(jax.tree.leaves(cp.retention.params_of(2)),
                      jax.tree.leaves(cp2.retention.params_of(2))):
        np.testing.assert_array_equal(la, lb)
    # restored plane plans the rejoin identically to the original
    p1 = cp.plan_round(active=np.ones(3, bool))
    p2 = cp2.plan_round(active=np.ones(3, bool))
    assert p1.restore == p2.restore == (2,)
    np.testing.assert_array_equal(p1.agg_weight, p2.agg_weight)


def test_rejoin_without_restored_arrays_raises():
    cp = ControlPlane(2, 1, 2)
    cp.plan_round(active=np.array([True, False]))
    cp.retain_group(1, {"dev": np.zeros(2), "aux": np.zeros(2)})
    sd = cp.state_dict()
    cp2 = ControlPlane(2, 1, 2)
    cp2.load_state_dict(sd)                # metadata only, no arrays
    ex = RoundExecutor(lambda s, b: (s, {"d_loss": 0.0}), cp2, window=1,
                       gather=lambda s, g: None,
                       scatter=lambda s, g, p: s)
    with pytest.raises(RuntimeError, match="extras"):
        ex.run(0, 0, 1, active_fn=lambda r: np.ones(2, bool),
               batch_fn=lambda r, plan: {})
    # the error path must not destroy the retained entry: a fixed-up rerun
    # (extras loaded) still needs it
    assert 1 in cp2.retention


def test_churn_without_retention_wiring_raises():
    """The masked broadcast makes unwired churn unsafe (a dropped group
    would rejoin with phantom-trained params) — the executor refuses."""
    cp = ControlPlane(2, 1, 2)
    ex = RoundExecutor(lambda s, b: (s, {"d_loss": 0.0}), cp, window=1)
    rosters = [np.ones(2, bool), np.array([True, False])]
    with pytest.raises(RuntimeError, match="gather"):
        ex.run(0, 0, 2, active_fn=lambda r: rosters[r],
               batch_fn=lambda r, plan: {})
