"""RoundExecutor: pipelined dispatch ≡ synchronous loop (window=1, bit for
bit), host plan/build overlap at window=2, measured straggler profiles,
per-group state retention for dropped groups, ω-cap RuntimeError."""
import copy
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import fedopt_step as F
from repro.core.control_plane import ControlPlane
from repro.core.executor import RoundExecutor, StragglerProfiles
from repro.launch.mesh import make_debug_mesh
from repro.runtime.elastic import ElasticRegistry


def _setup(omega=1, n_groups=2, H=2):
    a = registry.smoke_config("smollm-135m")
    cfg = F.FedStepConfig(arch=a, l_split=1, n_groups=n_groups, seq_len=16,
                          per_group_batch=2 * H, H=H, omega=omega)
    mesh = make_debug_mesh(1, 1)
    jitted, _, s_spec, _ = F.jit_train_step(cfg, mesh, donate=False)
    state = jax.jit(lambda: F.init_train_state(jax.random.PRNGKey(0), cfg),
                    out_shardings=s_spec)()
    return cfg, jitted, state, s_spec


def _copy_state(state):
    return jax.tree.map(jnp.copy, state)


def _batch_fn(cfg):
    def fn(r, plan):
        batch = F.concrete_train_batch(jax.random.PRNGKey(r), cfg)
        batch.update(plan.batch_fields())
        return batch
    return fn


def _executor(cfg, step, s_spec, window, profiles=True, registry_=None):
    cp = ControlPlane(cfg.n_groups, cfg.omega, cfg.H)
    return cp, RoundExecutor(
        step, cp, window=window,
        profiles=StragglerProfiles(cfg.n_groups) if profiles else None,
        gather=F.gather_group_state,
        scatter=lambda st, g, p: F.scatter_group_state(
            st, g, p, state_shardings=s_spec),
        registry=registry_)


def _reference_sync_loop(cfg, step, state, actives):
    """The pre-executor run_pod round loop, verbatim semantics."""
    cp = ControlPlane(cfg.n_groups, cfg.omega, cfg.H)
    history = []
    batch_fn = _batch_fn(cfg)
    for r, active in enumerate(actives):
        plan = cp.plan_round(active=active)
        state, metrics = step(state, batch_fn(r, plan))
        cp.finish_round(active=active)
        assert cp.within_cap
        history.append({k: float(v) for k, v in metrics.items()})
    return state, history


# ---------------------------------------------------------------------------
# determinism: pipelining must not change values
# ---------------------------------------------------------------------------

def test_window1_bitforbit_matches_synchronous_loop():
    """Acceptance: executor(window=1) reproduces the synchronous round
    loop's metrics history and final state bit for bit."""
    cfg, step, state0, s_spec = _setup(omega=1, n_groups=2, H=2)
    actives = [np.ones(2, bool)] * 4
    ref_state, ref_hist = _reference_sync_loop(cfg, step,
                                               _copy_state(state0), actives)
    _, ex = _executor(cfg, step, s_spec, window=1)
    state, hist = ex.run(_copy_state(state0), 0, 4,
                         active_fn=lambda r: actives[r],
                         batch_fn=_batch_fn(cfg))
    assert hist == ref_hist            # exact float equality, round order
    for la, lb in zip(jax.tree.leaves(ref_state), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_window2_history_values_equal_window1_under_churn():
    """Metric values are window-invariant (planning never reads device
    values), including across a drop/rejoin with state retention."""
    cfg, step, state0, s_spec = _setup(omega=2, n_groups=2, H=2)
    actives = [np.array([True, True]), np.array([True, False]),
               np.array([True, False]), np.array([True, True]),
               np.array([True, True])]
    results = {}
    for window in (1, 2):
        _, ex = _executor(cfg, step, s_spec, window=window)
        results[window] = ex.run(_copy_state(state0), 0, len(actives),
                                 active_fn=lambda r: actives[r],
                                 batch_fn=_batch_fn(cfg))
    s1, h1 = results[1]
    s2, h2 = results[2]
    assert h1 == h2
    for la, lb in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# overlap: window=2 hides host plan/build time behind device execution
# ---------------------------------------------------------------------------

def test_window2_overlaps_host_batch_build():
    """Acceptance: with window=2 the host plan/batch-build time is hidden
    behind device execution — host wall per round strictly below the
    synchronous (window=1) baseline on the same config."""
    cfg, step, state0, s_spec = _setup(omega=1, n_groups=2, H=2)
    batch_fn = _batch_fn(cfg)
    batch0 = batch_fn(0, ControlPlane(2, 1, 2).plan_round())
    jax.block_until_ready(step(_copy_state(state0), batch0))   # warm jit
    t0 = time.perf_counter()
    jax.block_until_ready(step(_copy_state(state0), batch0))
    dev_s = time.perf_counter() - t0
    sleep_s = min(max(0.5 * dev_s, 0.02), 0.25)   # modeled host build cost
    rounds = 8

    def slow_batch_fn(r, plan):
        time.sleep(sleep_s)
        return batch_fn(r, plan)

    walls = {}
    for window in (1, 2):
        _, ex = _executor(cfg, step, s_spec, window=window)
        t0 = time.perf_counter()
        ex.run(_copy_state(state0), 0, rounds,
               active_fn=lambda r: np.ones(2, bool), batch_fn=slow_batch_fn)
        walls[window] = time.perf_counter() - t0
        if window == 2:
            assert ex.peak_in_flight == 2
            assert ex.hidden_host_s > 0.0
    # saving ≈ rounds * min(sleep, device); demand a third of it
    margin = 0.25 * rounds * min(sleep_s, dev_s)
    assert walls[2] < walls[1] - margin, (walls, dev_s, sleep_s)


# ---------------------------------------------------------------------------
# per-group state retention (dropped groups rejoin from their own params)
# ---------------------------------------------------------------------------

def test_dropped_group_retains_state_and_staleness_on_rejoin():
    """Acceptance: a group dropped for k rounds keeps its retained dev/aux
    params unchanged, is NOT resynced by the aggregation broadcast, and
    rejoins from exactly those params with α reflecting the recorded
    delay."""
    cfg, step, state0, s_spec = _setup(omega=1, n_groups=2, H=2)
    k = 2                                  # dropped rounds
    actives = [np.array([True, True]), np.array([True, False]),
               np.array([True, False]), np.array([True, True]),
               np.array([True, True])]
    registry_ = ElasticRegistry()
    for g in range(2):
        registry_.join(1.0, 1.0)
    cp, ex = _executor(cfg, step, s_spec, window=1, registry_=registry_)
    scattered = {}
    real_scatter = ex.scatter

    def spy_scatter(st, g, p):
        scattered[g] = p
        return real_scatter(st, g, p)

    ex.scatter = spy_scatter

    snaps = {}
    plans = {}

    def on_metrics(r, m, st):
        plans[r] = st.plan                 # plan is dropped after this hook
        if 1 in cp.retention:              # snapshot the retained entry
            snaps[r] = copy.deepcopy(cp.retention.params_of(1))

    state, hist = ex.run(_copy_state(state0), 0, len(actives),
                         active_fn=lambda r: actives[r],
                         batch_fn=_batch_fn(cfg), on_metrics=on_metrics)

    # retained while dropped, and UNCHANGED across the drop window
    assert set(snaps) == {1, 2}
    for la, lb in zip(jax.tree.leaves(snaps[1]), jax.tree.leaves(snaps[2])):
        np.testing.assert_array_equal(la, lb)
    # the rejoin scattered exactly the retained params back
    assert list(scattered) == [1]
    for la, lb in zip(jax.tree.leaves(scattered[1]),
                      jax.tree.leaves(snaps[1])):
        np.testing.assert_array_equal(la, lb)
    assert 1 not in cp.retention           # released on rejoin
    # staleness weight on rejoin reflects the recorded delay: absent for
    # k rounds -> staleness k -> α = 1/(k+1)
    rejoin_plan = plans[3]
    np.testing.assert_allclose(rejoin_plan.agg_weight,
                               [1.0, 1.0 / (k + 1)], rtol=1e-6)
    np.testing.assert_array_equal(rejoin_plan.bcast_mask, [1.0, 1.0])
    assert ex.stats[3].plan is None        # plans are not accumulated
    # registry mirrored the churn with round timestamps
    assert registry_.devices[1].absences == 1
    assert registry_.devices[1].active and registry_.devices[1].joined_at == 3.0
    assert len(hist) == len(actives)


def test_masked_broadcast_keeps_dropped_group_params():
    """bcast_mask gates Alg. 4 line 20: masked-out groups keep their own
    params (no resync), while receiving groups sync to the aggregate."""
    cfg, step, state0, _ = _setup(omega=1, n_groups=2, H=2)
    batch = F.concrete_train_batch(jax.random.PRNGKey(0), cfg)
    batch["agg_weight"] = jnp.asarray([1.0, 0.0])

    masked, _ = step(_copy_state(state0),
                     {**batch, "bcast_mask": jnp.asarray([1.0, 0.0])})
    resync, _ = step(_copy_state(state0),
                     {**batch, "bcast_mask": jnp.asarray([1.0, 1.0])})
    w_m = np.asarray(masked["dev"]["embed"])
    w_r = np.asarray(resync["dev"]["embed"])
    # all-ones mask: broadcast resyncs the groups to identical params
    np.testing.assert_allclose(w_r[0], w_r[1], atol=1e-6)
    # masked: group 1 kept its own (locally-trained) params
    assert np.abs(w_m[0] - w_m[1]).max() > 1e-6
    # the receiving group's params are identical either way
    np.testing.assert_array_equal(w_m[0], w_r[0])


# ---------------------------------------------------------------------------
# ω-cap violation is a real error (not a strippable assert)
# ---------------------------------------------------------------------------

def test_cap_violation_raises_runtime_error_with_occupancy():
    class BrokenPlane(ControlPlane):
        @property
        def within_cap(self):
            return False

    cp = BrokenPlane(2, 1, 2)
    ex = RoundExecutor(lambda s, b: (s, {"d_loss": 0.0, "s_loss": 0.0}),
                       cp, window=1)
    with pytest.raises(RuntimeError, match=r"ring slots.*occupancy"):
        ex.run(0, 0, 1, active_fn=lambda r: np.ones(2, bool),
               batch_fn=lambda r, plan: {})


def test_executor_rejects_bad_window():
    cp = ControlPlane(2, 1, 2)
    with pytest.raises(ValueError, match="window"):
        RoundExecutor(lambda s, b: (s, {}), cp, window=0)


# ---------------------------------------------------------------------------
# measured straggler profiles
# ---------------------------------------------------------------------------

def test_profiles_unseeded_patterns_match_placeholders():
    p = StragglerProfiles(3)
    assert p.produce(4).all() and p.reads(4).all()
    cp = ControlPlane(3, 1, 4)
    planned = cp.plan_round(produce=p.produce(4), reads=p.reads(4))
    default = ControlPlane(3, 1, 4).plan_round()
    np.testing.assert_array_equal(planned.send_mask, default.send_mask)
    np.testing.assert_array_equal(planned.read_slot, default.read_slot)


def test_profiles_heterogeneous_produce_and_reads():
    p = StragglerProfiles(4, step_s=[0.01, 0.02, 0.02, 0.04],
                          server_s=0.08)
    produce = p.produce(8)
    np.testing.assert_array_equal(produce.sum(axis=0), [8, 4, 4, 2])
    assert produce[:, 0].all()             # fastest emits every iteration
    # server at half the lockstep cadence (0.04) consumes every other iter
    assert p.reads(8).sum() == 4


def test_profiles_observe_round_keeps_uniform_profile_uniform():
    """Pod path on a homogeneous mesh: measured-round EMA must never
    introduce phantom heterogeneity (bit-for-bit compat)."""
    p = StragglerProfiles(3)
    for wall in (0.5, 0.3, 0.4):
        p.observe_round(wall, H=4)
        assert p.produce(4).all() and p.reads(4).all()
    assert np.allclose(p.step_s, p.step_s[0])


def test_profiles_observe_round_preserves_relative_speeds():
    p = StragglerProfiles(2, step_s=[0.01, 0.04])
    p.observe_round(wall_s=0.8, H=4)       # slowest binds: 0.2 per iter
    np.testing.assert_allclose(p.step_s[1] / p.step_s[0], 4.0, rtol=1e-6)
    assert p.step_s[1] < 0.04 + 0.25 * 0.2 + 1e-9   # EMA moved toward scale


def test_profiles_patterns_invariant_to_wall_clock_noise():
    """Seeded heterogeneous profiles: observe_round rescales step_s and
    server_s by the same cadence factor, so the produce/reads patterns
    are pure functions of the seeds — never of measured wall times (the
    executor's determinism/window-invariance guarantee)."""
    seeds = dict(step_s=[0.01, 0.02, 0.04], server_s=0.08)
    a = StragglerProfiles(3, **seeds)
    b = StragglerProfiles(3, **seeds)
    rng = np.random.default_rng(0)
    for wall_a, wall_b in zip(rng.uniform(0.1, 2.0, 12),
                              rng.uniform(0.1, 2.0, 12)):
        a.observe_round(wall_a, H=8)       # two different noisy histories
        b.observe_round(wall_b, H=8)
        np.testing.assert_array_equal(a.produce(8), b.produce(8))
        np.testing.assert_array_equal(a.reads(8), b.reads(8))
    # and the patterns still reflect the seeded heterogeneity
    np.testing.assert_array_equal(a.produce(8).sum(axis=0), [8, 4, 2])
    assert a.reads(8).sum() == 4           # server at half the cadence


def test_simulator_measures_straggler_profiles():
    """The event simulator observes real per-device step/transfer times;
    the EMAs converge to the cluster's configured heterogeneity and the
    derived patterns schedule slow devices fewer emissions."""
    from repro.core.simulation import (SimModel, heterogeneous_cluster,
                                       simulate_fedoptima)
    model = SimModel(dev_fwd_flops=1e9, dev_bwd_flops=2e9,
                     full_fwd_flops=5e9, srv_flops_per_batch=8e9,
                     act_bytes=1e6, dev_model_bytes=4e6,
                     full_model_bytes=2e7, batch_size=32)
    cluster = heterogeneous_cluster(4, speed_groups=(1.0, 2.0, 2.0, 4.0))
    m = simulate_fedoptima(model, cluster, duration=120.0)
    prof = m.profiles
    expected = (model.dev_fwd_flops + model.dev_bwd_flops) / cluster.dev_flops
    # EMAs converge to the configured heterogeneity (constant event times)
    np.testing.assert_allclose(prof.step_s, expected, rtol=1e-3)
    np.testing.assert_allclose(prof.transfer_s,
                               model.act_bytes / cluster.dev_bw, rtol=1e-3)
    assert prof.server_s == pytest.approx(
        model.srv_flops_per_batch / cluster.srv_flops, rel=1e-3)
    # measured patterns fed into plan_round: the 4x-slower device is
    # granted about a quarter of the fastest device's emissions (EMA
    # rounding may land the stride on either side of a floor boundary)
    H = 8
    produce = prof.produce(H)
    assert produce[:, 3].all()
    assert 1 <= produce[:, 0].sum() <= 3
    sums = produce.sum(axis=0)
    assert sums[0] <= sums[1] <= sums[3] and sums[0] <= sums[2] <= sums[3]
    cp = ControlPlane(4, 2, H)
    plan = cp.plan_round(produce=produce, reads=prof.reads(H))
    sends = plan.send_mask.sum(axis=0)
    assert sends[0] <= sends[3]
    assert cp.within_cap


# ---------------------------------------------------------------------------
# retention rides the checkpoint store (metadata + extras)
# ---------------------------------------------------------------------------

def test_retention_rides_checkpoint_extras(tmp_path):
    import json

    from repro.checkpoint import store

    cp = ControlPlane(3, 2, 4)
    cp.plan_round(active=np.array([True, True, False]))     # drops group 2
    params = {"dev": {"w": np.arange(6.0).reshape(2, 3)},
              "aux": {"b": np.ones(4, np.float32)}}
    cp.retain_group(2, params)
    cp.finish_round(active=np.array([True, True, False]))

    sd = cp.state_dict()
    json.dumps(sd)                         # checkpoint-metadata safe
    store.save(str(tmp_path), 5, {"x": np.zeros(2)},
               metadata={"control_plane": sd},
               extras=cp.retention.arrays())

    meta = store.restore_metadata(str(tmp_path), 5)
    cp2 = ControlPlane(3, 2, 4)
    cp2.load_state_dict(meta["control_plane"])
    assert cp2.retention.groups == [2]
    assert cp2.retention.version_of(2) == cp.retention.version_of(2)
    assert cp2.retention.params_of(2) is None      # arrays not yet loaded
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        cp.retention.arrays())
    cp2.retention.load_arrays(store.restore_extras(str(tmp_path), 5, like))
    for la, lb in zip(jax.tree.leaves(cp.retention.params_of(2)),
                      jax.tree.leaves(cp2.retention.params_of(2))):
        np.testing.assert_array_equal(la, lb)
    # restored plane plans the rejoin identically to the original
    p1 = cp.plan_round(active=np.ones(3, bool))
    p2 = cp2.plan_round(active=np.ones(3, bool))
    assert p1.restore == p2.restore == (2,)
    np.testing.assert_array_equal(p1.agg_weight, p2.agg_weight)


def test_rejoin_without_restored_arrays_raises():
    cp = ControlPlane(2, 1, 2)
    cp.plan_round(active=np.array([True, False]))
    cp.retain_group(1, {"dev": np.zeros(2), "aux": np.zeros(2)})
    sd = cp.state_dict()
    cp2 = ControlPlane(2, 1, 2)
    cp2.load_state_dict(sd)                # metadata only, no arrays
    ex = RoundExecutor(lambda s, b: (s, {"d_loss": 0.0}), cp2, window=1,
                       gather=lambda s, g: None,
                       scatter=lambda s, g, p: s)
    with pytest.raises(RuntimeError, match="extras"):
        ex.run(0, 0, 1, active_fn=lambda r: np.ones(2, bool),
               batch_fn=lambda r, plan: {})
    # the error path must not destroy the retained entry: a fixed-up rerun
    # (extras loaded) still needs it
    assert 1 in cp2.retention


def test_churn_without_retention_wiring_raises():
    """The masked broadcast makes unwired churn unsafe (a dropped group
    would rejoin with phantom-trained params) — the executor refuses."""
    cp = ControlPlane(2, 1, 2)
    ex = RoundExecutor(lambda s, b: (s, {"d_loss": 0.0}), cp, window=1)
    rosters = [np.ones(2, bool), np.array([True, False])]
    with pytest.raises(RuntimeError, match="gather"):
        ex.run(0, 0, 2, active_fn=lambda r: rosters[r],
               batch_fn=lambda r, plan: {})


# ---------------------------------------------------------------------------
# deep pipeline (window >= 4) with DONATION: per-round handles keep
# retention/spill/checkpoint consumers off the invalidated live state
# ---------------------------------------------------------------------------

_DONATED = {}


def _donated_setup(omega=2, n_groups=2, H=2):
    """jit'd hybrid step with donate_argnums=(0,) — the deep-window
    acceptance configuration (cached: one compile per config)."""
    key = (omega, n_groups, H)
    if key not in _DONATED:
        a = registry.smoke_config("smollm-135m")
        cfg = F.FedStepConfig(arch=a, l_split=1, n_groups=n_groups,
                              seq_len=16, per_group_batch=2 * H, H=H,
                              omega=omega)
        mesh = make_debug_mesh(1, 1)
        jitted, _, s_spec, _ = F.jit_train_step(cfg, mesh, donate=True)
        init = jax.jit(lambda: F.init_train_state(jax.random.PRNGKey(0),
                                                  cfg),
                       out_shardings=s_spec)
        _DONATED[key] = (cfg, jitted, s_spec, init)
    return _DONATED[key]


class _StallThenDrain(StragglerProfiles):
    """Deterministic produce/reads: every group emits and the server never
    reads for ``stall_rounds`` plans (backlog -> spills), then emission
    stops and the server drains (fills).  Pure function of the plan call
    count, so identical for every window."""

    def __init__(self, n_groups, stall_rounds):
        super().__init__(n_groups)
        self.stall_rounds = stall_rounds
        self._planned = 0

    def produce(self, H):
        self._planned += 1
        return np.full((H, self.G), self._planned <= self.stall_rounds,
                       bool)

    def reads(self, H):
        return np.full(H, self._planned > self.stall_rounds, bool)


def _run_donated(window, actives, *, pool_cap=0, stall_rounds=0,
                 faults=None, ckpt=None):
    """One donated-step executor run; returns (history, final host state,
    executor).  ``ckpt`` = (every, flush, saves_dict) wires the
    checkpoint path with capture_fn metadata."""
    from repro.faults import PodFaultInjector, UpdateGate
    from repro.memory import ActivationStore

    cfg, step, s_spec, init = _donated_setup()
    cp = ControlPlane(cfg.n_groups, cfg.omega, cfg.H, pool_cap=pool_cap)
    kw = {}
    if pool_cap:
        kw = dict(store=ActivationStore(pool_cap),
                  gather_slot=F.gather_act_slot,
                  scatter_slot=lambda st, s, p: F.scatter_act_slot(
                      st, s, p, state_shardings=s_spec))
    if faults is not None:
        kw["faults"] = PodFaultInjector(faults, gate=UpdateGate())
    profiles = _StallThenDrain(cfg.n_groups, stall_rounds) \
        if stall_rounds else StragglerProfiles(cfg.n_groups)
    ex = RoundExecutor(
        step, cp, window=window, profiles=profiles,
        gather=F.gather_group_state,
        scatter=lambda st, g, p: F.scatter_group_state(
            st, g, p, state_shardings=s_spec), **kw)
    run_kw = {}
    if ckpt is not None:
        every, flush, saves = ckpt

        def checkpoint_fn(r, handle):
            saves[r] = {"tree": jax.tree.map(np.array, handle.host_tree()),
                        "meta": handle.meta,
                        "in_flight": len(ex._pending)}
        run_kw = dict(checkpoint_every=every, checkpoint_fn=checkpoint_fn,
                      capture_fn=lambda r: {"round": r},
                      checkpoint_flush=flush)
    state, hist = ex.run(init(), 0, len(actives),
                         active_fn=lambda r: actives[r],
                         batch_fn=_batch_fn(cfg), **run_kw)
    return hist, jax.tree.map(np.asarray, state), ex


def test_window4_donated_bitidentical_under_churn_spill_and_faults():
    """Acceptance: window=4 with donation ON, under churn (drop/rejoin
    retention through the handle ring), a spilling/filling tiered store,
    and dense injected faults, produces metrics and a final state
    bit-identical to window=1 — and the run is sanitizer-clean."""
    from repro.analysis.sanitize import sanitized
    from repro.faults import FaultEvent, FaultSchedule

    actives = [np.ones(2, bool)] * 3 + \
        [np.array([True, False])] * 2 + [np.ones(2, bool)] * 5
    sched = FaultSchedule(horizon=10.0, events=(
        FaultEvent(6.0, "timeout", device=0, param=1.0),
        FaultEvent(8.0, "corrupt_act", device=1, kind="inf")))
    results = {}
    for window in (1, 4):
        with sanitized() as san:
            results[window] = _run_donated(
                window, actives, pool_cap=2, stall_rounds=4,
                faults=FaultSchedule(horizon=sched.horizon,
                                     events=sched.events))
        assert san.n_violations == 0, san.violations
    h1, s1, ex1 = results[1]
    h4, s4, ex4 = results[4]
    assert h1 == h4                        # exact float equality
    for la, lb in zip(jax.tree.leaves(s1), jax.tree.leaves(s4)):
        np.testing.assert_array_equal(la, lb)
    # the scenario genuinely exercised every donated-handle consumer
    assert ex4.cplane.n_spills > 0
    assert ex4.summary()["faults"]["matched"] is True
    assert ex4.peak_in_flight == 4 and ex1.peak_in_flight == 1
    assert ex4.handles.n_captured > 0 and ex4.handle_bytes_peak > 0


def test_checkpoint_without_flush_bitexact_with_flush_saver():
    """Acceptance: checkpoint-without-flush (deferred handle saves, pipe
    kept full) writes byte-identical snapshots to the legacy flush saver
    at every boundary, never drains, and does not perturb training."""
    actives = [np.ones(2, bool)] * 8
    saves_f, saves_n = {}, {}
    hf, sf, exf = _run_donated(4, actives, ckpt=(2, True, saves_f))
    hn, sn, exn = _run_donated(4, actives, ckpt=(2, False, saves_n))
    assert hf == hn
    for la, lb in zip(jax.tree.leaves(sf), jax.tree.leaves(sn)):
        np.testing.assert_array_equal(la, lb)
    # same boundaries, same dispatch-time metadata, bit-identical arrays
    assert sorted(saves_f) == sorted(saves_n) == [1, 3, 5, 7]
    for r in saves_f:
        assert saves_f[r]["meta"] == saves_n[r]["meta"] == {"round": r}
        for la, lb in zip(jax.tree.leaves(saves_f[r]["tree"]),
                          jax.tree.leaves(saves_n[r]["tree"])):
            np.testing.assert_array_equal(la, lb)
    # the flush leg drained for every save; the no-flush leg never did
    assert exf.n_ckpt_flush == 4 and exf.n_ckpt_noflush == 0
    assert exn.n_ckpt_flush == 0 and exn.n_ckpt_noflush == 4
    assert all(s["in_flight"] == 0 for s in saves_f.values())
    assert any(s["in_flight"] > 0 for s in saves_n.values())
    s = exn.summary()["checkpoints"]
    assert s == {"flush_saves": 0, "noflush_saves": 4}


def test_legacy_checkpoint_contract_without_capture_fn():
    """capture_fn=None keeps the old contract: a full drain and
    checkpoint_fn(r, state) with the LIVE state object, not a handle."""
    cp = ControlPlane(2, 1, 2)
    ex = RoundExecutor(lambda s, b: (s, {"d_loss": 0.0}), cp, window=2)
    seen = []
    ex.run({"x": np.zeros(2)}, 0, 4,
           active_fn=lambda r: np.ones(2, bool),
           batch_fn=lambda r, plan: {},
           checkpoint_every=2, checkpoint_fn=lambda r, st: seen.append(st))
    assert [isinstance(s, dict) for s in seen] == [True, True]
    assert ex.n_ckpt_flush == 2 and ex.n_ckpt_noflush == 0


def test_summary_reports_steady_state_exposure_excluding_warmup():
    """The first ``window`` dispatches have nothing in flight to hide
    behind; summary() excludes them from the steady-state exposure."""
    cp = ControlPlane(2, 1, 2)
    ex = RoundExecutor(lambda s, b: (s, {"d_loss": 0.0}), cp, window=3)
    ex.run(0, 0, 7, active_fn=lambda r: np.ones(2, bool),
           batch_fn=lambda r, plan: {})
    s = ex.summary()
    assert s["warmup_rounds_excluded"] == 3
    assert s["rounds"] == 7
    assert 0.0 <= s["host_s_exposed_steady"] <= s["host_s_exposed"] + 1e-9
    assert 0.0 <= s["hidden_host_frac_steady"] <= 1.0
    assert s["handles"]["depth"] == 4
    # fewer rounds than the window: everything is warmup
    ex2 = RoundExecutor(lambda s, b: (s, {"d_loss": 0.0}),
                        ControlPlane(2, 1, 2), window=4)
    ex2.run(0, 0, 2, active_fn=lambda r: np.ones(2, bool),
            batch_fn=lambda r, plan: {})
    s2 = ex2.summary()
    assert s2["warmup_rounds_excluded"] == 2
    assert s2["host_s_exposed_steady"] == 0.0


def test_pipeline_window_validation():
    """--window 0 is a typed error, not a silent remap to the default
    (the old ``or 2`` idiom swallowed it); unset still defaults to 2."""
    import argparse

    from repro.launch.train import _pipeline_window

    assert _pipeline_window(argparse.Namespace()) == 2
    assert _pipeline_window(argparse.Namespace(window=None)) == 2
    assert _pipeline_window(argparse.Namespace(window=1)) == 1
    assert _pipeline_window(argparse.Namespace(window=4)) == 4
    for bad in (0, -3):
        with pytest.raises(ValueError, match="window must be >= 1"):
            _pipeline_window(argparse.Namespace(window=bad))
