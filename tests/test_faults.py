"""Chaos plane + crash-consistent recovery: seeded fault schedules,
poison-update quarantine, torn-snapshot fallback, injected-crash resume,
and the SIGKILL crash sweep (slow).

Every injected fault must be matched to a recovery counter — the report's
``matched`` flag is the acceptance contract: scheduled − injected events
are accounted ``unfired``, injected ones must equal recovered per class.
"""
import json
import os

import numpy as np
import pytest

from repro.checkpoint import store
from repro.core.baselines import REGISTRY
from repro.core.simulation import (SimModel, heterogeneous_cluster,
                                   simulate_fedoptima)
from repro.faults import (BASELINE_CLASSES, CORRUPT_KINDS, SIM_CLASSES,
                          FaultEvent, FaultSchedule, InjectedCrash,
                          PodFaultInjector, UpdateGate, make_fault_schedule,
                          make_payload, tear_snapshot)

MODEL = SimModel(dev_fwd_flops=1e9, dev_bwd_flops=2e9, full_fwd_flops=5e9,
                 srv_flops_per_batch=8e9, act_bytes=1e6, dev_model_bytes=4e6,
                 full_model_bytes=2e7, batch_size=32)


# ---------------------------------------------------------------------------
# schedules: deterministic, serializable, validated
# ---------------------------------------------------------------------------

def test_schedule_seeded_determinism():
    a = make_fault_schedule(16, 600.0, seed=3, density=2.0)
    b = make_fault_schedule(16, 600.0, seed=3, density=2.0)
    c = make_fault_schedule(16, 600.0, seed=4, density=2.0)
    assert a.events == b.events
    assert a.events != c.events
    assert all(a.events[i].t <= a.events[i + 1].t
               for i in range(len(a) - 1))
    assert set(a.counts()) == set(SIM_CLASSES)


def test_schedule_json_roundtrip(tmp_path):
    sched = make_fault_schedule(8, 300.0, seed=1)
    path = str(tmp_path / "faults.json")
    sched.save(path)
    with open(path) as f:
        assert json.load(f)["format"] == "fault-schedule-v1"
    back = FaultSchedule.load(path)
    assert back.events == sched.events
    assert back.horizon == sched.horizon


def test_schedule_validation():
    with pytest.raises(ValueError):
        FaultEvent(1.0, "meteor_strike")
    with pytest.raises(ValueError):
        FaultEvent(1.0, "corrupt_act", device=0, kind="soggy")
    with pytest.raises(ValueError):
        FaultEvent(1.0, "torn_checkpoint", kind="nan")
    with pytest.raises(ValueError):      # event at the horizon never fires
        FaultSchedule(horizon=5.0,
                      events=(FaultEvent(5.0, "delay", device=0),))


# ---------------------------------------------------------------------------
# quarantine gate: finite-check + norm fence, strikes, backoff
# ---------------------------------------------------------------------------

def test_gate_rejects_every_poison_kind():
    gate = UpdateGate()
    for kind in CORRUPT_KINDS:
        ok, reason = gate.validate(make_payload(kind, seed=2))
        assert not ok, kind
        assert reason in ("non_finite", "norm_fence")
    ok, reason = gate.validate(make_payload("", seed=2))  # clean payload
    assert ok and reason == ""


def test_gate_strikes_backoff_and_readmission():
    gate = UpdateGate(strike_limit=2, backoff=10.0, backoff_growth=2.0)
    assert gate.may_send(0, t=0.0)
    assert gate.note_reject(0, t=0.0) == 0.0       # strike 1: under the limit
    assert gate.note_reject(0, t=1.0) == pytest.approx(10.0)   # at the limit
    d = gate.note_reject(0, t=2.0)                  # strike 3: one over
    assert d == pytest.approx(20.0)                 # backoff * growth^(3-2)
    assert not gate.may_send(0, t=2.0 + d - 1e-6)
    assert gate.may_send(0, t=2.0 + d + 1e-6)       # re-admitted after backoff
    gate.note_accept(0)                             # good update heals a strike
    assert gate.strikes[0] == 2
    assert gate.may_send(1, t=0.0)                  # other devices unaffected
    s = gate.summary()
    assert s["devices_struck"] == 1 and s["max_strikes"] == 2


# ---------------------------------------------------------------------------
# flow-token conservation under quarantine
# ---------------------------------------------------------------------------

def test_flow_quarantine_withdraws_exactly_one_inflight_unit():
    from repro.analysis.sanitize import sanitized
    from repro.core.flow_control import FlowController
    with sanitized() as san:
        flow = FlowController(omega=2)
        flow.register(0)
        flow.register(1)
        assert flow.can_send(0)
        flow.mark_sent(0)
        assert flow.inflight_of(0) == 1
        flow.on_quarantined(0)                 # poisoned arrival withdrawn
        assert flow.inflight_of(0) == 0
        assert flow.buffered == 0              # never buffered
        assert flow.n_spilled == 0 and flow.n_filled == 0
        assert flow.can_send(0) or flow.can_send(1)  # budget re-granted
        # the freed budget is usable end-to-end: a clean send still admits
        k = 0 if flow.can_send(0) else 1
        flow.mark_sent(k)
        assert flow.on_enqueue(k)
        flow.on_dequeue(k)
    assert san.report()["n_violations"] == 0


# ---------------------------------------------------------------------------
# dense-fault acceptance: K=32 diurnal sim, every fault matched
# ---------------------------------------------------------------------------

def test_sim_dense_faults_all_matched_and_sanitizer_clean():
    from repro.analysis.sanitize import sanitized
    from repro.fleet import make_trace
    K, dur = 32, 900.0
    cluster = heterogeneous_cluster(K)
    trace = make_trace("diurnal", K, dur, interval=dur / 24.0, seed=7,
                       day=dur / 2.0, on_frac=0.6)
    sched = make_fault_schedule(K, dur, seed=5, density=1.0)
    with sanitized() as san:
        m = simulate_fedoptima(MODEL, cluster, duration=dur, fleet=trace,
                               faults=sched, seed=0)
    assert san.report()["n_violations"] == 0
    fr = m.faults
    assert fr is not None and fr["matched"] is True
    assert sum(fr["injected"].values()) > 0
    for cls in SIM_CLASSES:
        assert fr["injected"].get(cls, 0) == fr["recovered"].get(cls, 0), \
            (cls, fr)
        # unfired events are the scheduled ones that never reached a seam
        assert fr["unfired"][cls] == \
            fr["scheduled"][cls] - fr["injected"].get(cls, 0)
    assert fr["gate"]["n_rejected"] > 0     # poison actually hit the gate
    assert m.srv_batches > 0                # training still made progress


def test_sim_gate_off_consumes_poison_honestly():
    """The no-recovery leg: with the gate disabled, poisoned uploads are
    consumed (badput) and the report says so — matched must be False, not
    silently green."""
    K, dur = 8, 600.0
    cluster = heterogeneous_cluster(K)
    sched = make_fault_schedule(K, dur, seed=2, density=2.0,
                                classes=("corrupt_act", "corrupt_model"))
    m = simulate_fedoptima(MODEL, cluster, duration=dur, faults=sched,
                           fault_gate=False, seed=0)
    fr = m.faults
    assert fr["matched"] is False
    assert fr["gate"] is None
    badput = fr["disposition"].get("consumed_poisoned_act", 0) + \
        fr["disposition"].get("consumed_poisoned_model", 0) + \
        fr["disposition"].get("admitted_poisoned_act", 0)
    assert badput > 0


def test_all_baselines_inject_and_match():
    K, dur = 8, 400.0
    cluster = heterogeneous_cluster(K)
    sched = make_fault_schedule(K, dur, seed=9, density=2.0,
                                classes=BASELINE_CLASSES)
    for name, fn in REGISTRY.items():
        m = fn(MODEL, cluster, duration=dur, faults=sched)
        fr = m.faults
        assert fr is not None and fr["matched"] is True, (name, fr)
        assert sum(fr["injected"].values()) > 0, name


# ---------------------------------------------------------------------------
# torn snapshots: verified fallback, never half-loads
# ---------------------------------------------------------------------------

def _tree(v):
    return {"w": np.full((4, 3), float(v)), "step": np.asarray(v, np.int64)}


@pytest.mark.parametrize("mode", ["truncate", "bitflip", "manifest"])
def test_restore_torn_snapshot_raises_not_half_loads(tmp_path, mode):
    d = str(tmp_path)
    store.save(d, 1, _tree(1))
    tear_snapshot(d, 1, mode)
    ok, reason = store.verify_snapshot(d, 1)
    assert not ok and reason
    with pytest.raises(store.CorruptSnapshotError):
        store.restore(d, 1, _tree(0))


@pytest.mark.parametrize("mode", ["truncate", "bitflip", "manifest"])
def test_resume_falls_back_to_previous_verified_snapshot(tmp_path, mode):
    from repro.runtime.fault_tolerance import CheckpointPolicy, resume_or_init
    d = str(tmp_path)
    for s in (1, 2, 3):
        store.save(d, s, _tree(s))
    tear_snapshot(d, 3, mode)
    step, skipped = store.latest_verified_step(d)
    assert step == 2
    assert [s for s, _ in skipped] == [3]
    policy = CheckpointPolicy(d, every_steps=10)
    state, start = resume_or_init(d, lambda: _tree(0), policy=policy)
    assert start == 2
    np.testing.assert_array_equal(state["w"], _tree(2)["w"])
    assert policy._last_step == 2           # cadence seeded from the resume
    assert not policy.should_save(2)
    assert policy.should_save(12)


def test_resume_all_torn_initializes_fresh(tmp_path):
    from repro.runtime.fault_tolerance import resume_or_init
    d = str(tmp_path)
    store.save(d, 1, _tree(1), retain=1)
    tear_snapshot(d, 1, "truncate")
    state, start = resume_or_init(d, lambda: _tree(0))
    assert start == 0
    np.testing.assert_array_equal(state["w"], _tree(0)["w"])


def test_churn_draw_is_time_indexed_not_call_ordered():
    """Satellite pin: ChurnModel.draw(t) is a pure function of
    (seed, interval index) — call order and call count must not matter."""
    from repro.runtime.fault_tolerance import ChurnModel
    cm1 = ChurnModel(n_devices=32, p_drop=0.3, interval=100.0, seed=5)
    cm2 = ChurnModel(n_devices=32, p_drop=0.3, interval=100.0, seed=5)
    for _ in range(4):                      # burn "calls" on cm1 only
        cm1.draw(0.0)
    a1, b1 = cm1.draw(250.0)
    a2, b2 = cm2.draw(250.0)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(b1, b2)
    # same interval, any t within it: identical; different interval: differs
    a3, _ = cm2.draw(299.0)
    np.testing.assert_array_equal(a1, a3)
    a4, b4 = cm2.draw(300.0)
    assert not (np.array_equal(a1, a4) and np.array_equal(b1, b4))


# ---------------------------------------------------------------------------
# pod path: timeout -> retention -> rejoin, injected crash -> resume
# ---------------------------------------------------------------------------

def _pod_setup(n_groups=2, H=2):
    import jax
    from repro.configs import registry
    from repro.core import fedopt_step as F
    from repro.launch.mesh import make_debug_mesh

    a = registry.smoke_config("smollm-135m")
    cfg = F.FedStepConfig(arch=a, l_split=1, n_groups=n_groups, seq_len=16,
                          per_group_batch=2 * H, H=H, omega=1)
    mesh = make_debug_mesh(1, 1)
    jitted, _, s_spec, _ = F.jit_train_step(cfg, mesh, donate=False)
    state = jax.jit(lambda: F.init_train_state(jax.random.PRNGKey(0), cfg),
                    out_shardings=s_spec)()
    return cfg, jitted, state, s_spec


def _pod_executor(cfg, step, s_spec, injector):
    from repro.core import fedopt_step as F
    from repro.core.control_plane import ControlPlane
    from repro.core.executor import RoundExecutor, StragglerProfiles

    cp = ControlPlane(cfg.n_groups, cfg.omega, cfg.H)
    ex = RoundExecutor(
        step, cp, window=1, profiles=StragglerProfiles(cfg.n_groups),
        gather=F.gather_group_state,
        scatter=lambda st, g, p: F.scatter_group_state(
            st, g, p, state_shardings=s_spec),
        faults=injector)
    return cp, ex


def _pod_batch_fn(cfg):
    from repro.core import fedopt_step as F
    import jax

    def fn(r, plan):
        batch = F.concrete_train_batch(jax.random.PRNGKey(r), cfg)
        batch.update(plan.batch_fields())
        return batch
    return fn


def test_pod_timeout_reclaims_slot_and_rejoins():
    cfg, step, state, s_spec = _pod_setup(n_groups=2, H=2)
    sched = FaultSchedule(horizon=6.0, events=(
        FaultEvent(1.0, "timeout", device=0, param=2.0),))
    inj = PodFaultInjector(sched, gate=UpdateGate())
    cp, ex = _pod_executor(cfg, step, s_spec, inj)
    rosters = []
    _, hist = ex.run(state, 0, 6,
                     active_fn=lambda r: np.ones(2, bool),
                     batch_fn=_pod_batch_fn(cfg),
                     on_metrics=lambda r, m, st: rosters.append(
                         np.asarray(st.plan.bcast_mask) > 0.5))
    assert len(hist) == 6
    fr = inj.report()
    assert fr["matched"] is True
    assert fr["injected"]["timeout"] == 1
    assert fr["disposition"].get("timeout_rejoined") == 1
    # rounds 1..2 ran without group 0 (slot retired), round 3 rejoined it
    assert not rosters[1][0] and not rosters[2][0]
    assert rosters[3][0] and rosters[0][0]
    assert 0 not in cp.retention.groups            # restored, not leaked


def test_pod_injected_crash_resumes_from_snapshot(tmp_path):
    import jax
    cfg, step, state, s_spec = _pod_setup(n_groups=2, H=2)
    d = str(tmp_path)
    events = (FaultEvent(1.0, "server_crash", param=1.0),
              FaultEvent(2.0, "timeout", device=0, param=1.0),
              FaultEvent(3.0, "corrupt_act", device=1, kind="inf"),
              FaultEvent(3.0, "torn_checkpoint", kind="bitflip"))
    sched = FaultSchedule(horizon=6.0, events=events)

    def run_leg(state0, start, injector, cp, ex):
        def ckpt(r, st):
            store.save(d, r + 1, jax.tree.map(np.asarray, st),
                       metadata={"control_plane": cp.state_dict()})
            injector.on_checkpoint(r, d, r + 1)
        return ex.run(state0, start, 6,
                      active_fn=lambda r: np.ones(2, bool),
                      batch_fn=_pod_batch_fn(cfg),
                      checkpoint_every=1, checkpoint_fn=ckpt)

    inj1 = PodFaultInjector(sched, gate=UpdateGate())
    cp1, ex1 = _pod_executor(cfg, step, s_spec, inj1)
    with pytest.raises(InjectedCrash) as exc:
        run_leg(state, 0, inj1, cp1, ex1)
    assert exc.value.round_index == 1
    assert sorted(inj1.fired_crashes) == [1]

    # "process restart": resume from the newest verified snapshot with the
    # fired boundary carried over — the crash must not re-fire
    start, skipped = store.latest_verified_step(d)
    assert start == 1 and skipped == []
    state2 = store.restore(d, start, jax.eval_shape(lambda: state))
    inj2 = PodFaultInjector(sched, gate=UpdateGate(),
                            fired_crashes=sorted(inj1.fired_crashes))
    cp2, ex2 = _pod_executor(cfg, step, s_spec, inj2)
    cp2.load_state_dict(store.restore_metadata(d, start)["control_plane"])
    state2, hist = run_leg(state2, start, inj2, cp2, ex2)
    assert len(hist) == 5                          # rounds 1..5
    fr = inj2.report()
    assert fr["matched"] is True, fr
    assert fr["recovered"]["server_crash"] == 1    # crash_resumed
    assert fr["injected"]["timeout"] == 1
    assert fr["injected"]["corrupt_act"] == 1
    assert fr["injected"]["torn_checkpoint"] == 1
    # the torn snapshot is detectable and was skipped by any later resume
    torn = [s for s in store.committed_steps(d)
            if not store.verify_snapshot(d, s)[0]]
    assert torn == [4]


# ---------------------------------------------------------------------------
# SIGKILL crash sweep (subprocess; reduced boundaries for the smoke lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_crash_sweep_sigkill_bit_exact_smoke():
    from repro.faults.crash_harness import sweep
    out = sweep(boundaries=[1], rounds=2, ckpt_every=1,
                kill_modes=("after", "mid"))
    assert out["cases"] == {"after@1": "bit-exact", "mid@1": "bit-exact"}


@pytest.mark.slow
def test_crash_sweep_window4_checkpoint_without_flush():
    """Acceptance for the deep pipeline: SIGKILL sweep at window=4 with
    checkpoint-without-flush — children save from dispatch-time handles
    while rounds stay in flight (the sweep asserts flush_saves=0 on the
    reference and every resumed run), and resume is still bit-exact."""
    from repro.faults.crash_harness import sweep
    out = sweep(boundaries=[1], rounds=2, ckpt_every=1,
                kill_modes=("after", "mid"), window=4)
    assert out["window"] == 4 and out["ckpt_flush"] is False
    assert out["cases"] == {"after@1": "bit-exact", "mid@1": "bit-exact"}
