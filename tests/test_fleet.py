"""Fleet emulation plane: trace determinism + JSON artifacts, legacy
churn= equivalence, the always-on/random-selection bit-for-bit compat
pin, selection policies, tier sampling, contribution balance, and
trace-driven churn exercising ControlPlane.RetentionStore (propcheck)."""
import numpy as np
import pytest

from repro.core.baselines import simulate_classic_fl, simulate_fedasync
from repro.core.control_plane import ControlPlane
from repro.core.executor import RoundExecutor
from repro.core.simulation import (SimModel, heterogeneous_cluster,
                                   simulate_fedoptima)
from repro.fleet import (FleetTrace, SelectionContext, balance_summary,
                         diurnal_trace, flaky_trace, gini,
                         make_selection_policy, make_trace, parse_tiers,
                         sample_cluster, tier_counts, uniform_trace,
                         weibull_sessions_trace)
from repro.runtime.fault_tolerance import ChurnModel

from _propcheck import given, settings, strategies as st

MODEL = SimModel(dev_fwd_flops=1e9, dev_bwd_flops=2e9, full_fwd_flops=5e9,
                 srv_flops_per_batch=8e9, act_bytes=1e6, dev_model_bytes=4e6,
                 full_model_bytes=2e7, batch_size=32)
CLUSTER = heterogeneous_cluster(8)
DUR = 400.0


def _nums(m):
    """Every numeric Metrics field (the bit-for-bit comparison surface)."""
    return (m.duration, m.dev_busy.tolist(), m.srv_busy, m.bytes_up,
            m.bytes_down, m.dev_samples, m.srv_batches, m.aggregations,
            m.rounds, m.max_buffered, m.dev_consumed.tolist())


# ---------------------------------------------------------------------------
# traces: determinism, structure, JSON artifact round-trip
# ---------------------------------------------------------------------------

def test_generators_deterministic_under_seed():
    for kind in ("diurnal", "weibull", "flaky"):
        a = make_trace(kind, 6, 4000.0, interval=200.0, seed=3)
        b = make_trace(kind, 6, 4000.0, interval=200.0, seed=3)
        np.testing.assert_array_equal(a.active, b.active)
        np.testing.assert_array_equal(a.bw, b.bw)
        c = make_trace(kind, 6, 4000.0, interval=200.0, seed=4)
        assert not (np.array_equal(a.active, c.active) and
                    np.array_equal(a.bw, c.bw))


def test_trace_json_roundtrip(tmp_path):
    t = diurnal_trace(5, 6000.0, interval=300.0, day=2000.0, on_frac=0.4,
                      bw_jitter=0.2, seed=9)
    path = t.save(str(tmp_path / "trace.json"))
    t2 = FleetTrace.load(path)
    np.testing.assert_array_equal(t.active, t2.active)
    np.testing.assert_array_equal(t.bw, t2.bw)
    assert t2.meta == t.meta and t2.interval == t.interval
    with pytest.raises(ValueError, match="format"):
        FleetTrace.from_json({"format": "nope"})


def test_diurnal_windows_are_periodic_and_sized():
    day, interval = 2400.0, 100.0
    t = diurnal_trace(16, 2 * day, interval=interval, day=day, on_frac=0.5,
                      seed=0)
    per_day = int(day / interval)
    # each device is on for on_frac of every day, same phase every day
    np.testing.assert_array_equal(t.active[:per_day], t.active[per_day:])
    np.testing.assert_allclose(t.active.mean(axis=0), 0.5, atol=1e-9)
    assert not t.is_static


def test_weibull_sessions_alternate_and_flaky_drops():
    w = weibull_sessions_trace(8, 40000.0, interval=400.0, seed=1)
    up = w.availability()
    assert (up > 0).all() and (up < 1).any()     # sessions, not constants
    f = flaky_trace(8, 10000.0, interval=500.0, p_drop=0.3, seed=2)
    assert 0.4 < f.availability().mean() < 0.95
    assert f.bw.min() >= 25e6 / 8 and f.bw.max() <= 50e6 / 8
    with pytest.raises(ValueError, match="unknown trace kind"):
        make_trace("lunar", 4, 100.0)


def test_trace_wraps_past_horizon_and_validates():
    t = uniform_trace(3, 1000.0, interval=250.0)
    assert t.T == 4 and t.is_static
    np.testing.assert_array_equal(t.roster(7), t.roster(3))
    with pytest.raises(ValueError, match="matching"):
        FleetTrace(interval=1.0, active=np.ones((2, 3), bool),
                   bw=np.ones((2, 2)))


# ---------------------------------------------------------------------------
# compat pins: always-on trace ≡ tracefree, churn= ≡ materialized trace
# ---------------------------------------------------------------------------

def test_always_on_uniform_fleet_random_selection_bitforbit():
    """Acceptance pin: an always-on trace over a uniform fleet with
    selection="random" reproduces today's simulate_fedoptima metrics
    bit-for-bit (the trace schedules no events, select-all draws no
    RNG)."""
    plain = simulate_fedoptima(MODEL, CLUSTER, duration=DUR)
    trace = FleetTrace.from_cluster(CLUSTER, DUR)
    fleet = simulate_fedoptima(MODEL, CLUSTER, duration=DUR, fleet=trace,
                               selection="random")
    assert _nums(plain) == _nums(fleet)
    assert fleet.registry is not None          # roster mirrored regardless


def test_churn_arg_equals_materialized_fleet_trace():
    """Legacy churn= is the same run as its FleetTrace.from_churn
    materialization — identical draws, identical events."""
    mk = lambda: ChurnModel(n_devices=8, p_drop=0.3, interval=50.0, seed=4)
    via_churn = simulate_fedoptima(MODEL, CLUSTER, duration=DUR, churn=mk())
    trace = FleetTrace.from_churn(mk(), DUR, bw0=CLUSTER.dev_bw)
    via_fleet = simulate_fedoptima(MODEL, CLUSTER, duration=DUR, fleet=trace)
    assert _nums(via_churn) == _nums(via_fleet)


def test_baselines_churn_equals_fleet_and_reject_both():
    mk = lambda: ChurnModel(n_devices=8, p_drop=0.4, interval=60.0, seed=7)
    trace = FleetTrace.from_churn(mk(), DUR, bw0=CLUSTER.dev_bw)
    for fn in (simulate_classic_fl, simulate_fedasync):
        a = fn(MODEL, CLUSTER, duration=DUR, churn=mk())
        b = fn(MODEL, CLUSTER, duration=DUR, fleet=trace)
        assert _nums(a) == _nums(b), fn.__name__
    with pytest.raises(ValueError, match="not both"):
        simulate_fedasync(MODEL, CLUSTER, duration=DUR, churn=mk(),
                          fleet=trace)
    with pytest.raises(ValueError, match="devices"):
        simulate_fedasync(MODEL, CLUSTER, duration=DUR,
                          fleet=uniform_trace(4, DUR))


# ---------------------------------------------------------------------------
# trace-driven membership in the FedOptima simulation
# ---------------------------------------------------------------------------

def test_trace_churn_keeps_caps_and_mirrors_registry():
    trace = flaky_trace(8, DUR, interval=40.0, p_drop=0.4, seed=5)
    cp = ControlPlane.for_sim(8, 4)
    m = simulate_fedoptima(MODEL, CLUSTER, duration=DUR, omega=4,
                           fleet=trace, control=cp)
    assert cp.flow.within_cap and m.max_buffered <= 4
    assert m.dev_consumed.sum() == m.srv_batches
    reg = m.registry
    assert reg is not None
    assert sum(i.absences for i in reg.devices.values()) > 0
    final = trace.state_at(DUR)[0]
    assert [d for d in reg.active_ids] == list(np.flatnonzero(final))


def test_straddled_model_upload_cannot_fork_concurrent_chains():
    """A model upload still in flight across a leave+rejoin must not
    restart the device when it finally returns (the rejoined chain owns
    the device): dev_busy can never exceed wall-clock."""
    from repro.core.simulation import SimCluster
    model = SimModel(dev_fwd_flops=1e9, dev_bwd_flops=2e9,
                     full_fwd_flops=5e9, srv_flops_per_batch=8e9,
                     act_bytes=1e4, dev_model_bytes=6e4,
                     full_model_bytes=2e7, batch_size=32)
    cl = SimCluster(dev_flops=np.full(2, 3e9), dev_bw=np.full(2, 1e9),
                    srv_flops=1e12)
    active = np.ones((120, 2), bool)
    active[1, 0] = False            # off for one tick, rejoins the next —
    bw = np.full((120, 2), 1e9)     # — while its 600s first-round upload
    bw[0, 0] = 100.0                # (6e4 B / 100 B/s) is still in flight
    trace = FleetTrace(interval=12.0, active=active, bw=bw)
    m = simulate_fedoptima(model, cl, duration=1400.0, fleet=trace)
    assert m.dev_busy[0] <= m.duration + 1e-6
    assert m.dev_busy[0] > 0.9 * m.duration    # ...but the live chain runs


def test_async_baseline_flap_does_not_fork_chains():
    """A device flapping off->on INSIDE one iteration must not revive the
    pre-leave chain next to the rejoin-started one (fedasync and OAFL
    restart devices on rejoin): dev_busy can never exceed wall-clock."""
    from repro.core.baselines import simulate_oafl
    from repro.core.simulation import SimCluster
    cl = SimCluster(dev_flops=np.array([8.3e8]), dev_bw=np.array([1e9]),
                    srv_flops=1e12)          # one slow device, ~18s/iter
    active = np.ones((360, 1), bool)
    active[5, 0] = False                     # off at t=5, back at t=6
    trace = FleetTrace(interval=1.0, active=active, bw=np.full((360, 1), 1e9))
    for fn in (simulate_fedasync, simulate_oafl):
        m = fn(MODEL, cl, duration=360.0, fleet=trace)
        assert m.dev_busy[0] <= m.duration + 1e-6, fn.__name__


def test_offline_at_start_device_stays_idle_until_joined():
    active = np.zeros((4, 4), bool)
    active[:, :3] = True          # device 3 off for the whole run
    trace = FleetTrace(interval=DUR / 4, active=active,
                       bw=np.full((4, 4), 12.5e6))
    m = simulate_fedoptima(MODEL, heterogeneous_cluster(4), duration=DUR,
                           fleet=trace)
    assert m.dev_busy[3] == 0.0 and m.dev_consumed[3] == 0
    assert (m.dev_busy[:3] > 0).all()


def test_selection_restricts_cohort_in_sim():
    # horizon shorter than one tick: a single cohort for the whole run
    trace = FleetTrace.from_cluster(CLUSTER, 30.0, interval=600.0)
    m = simulate_fedoptima(MODEL, CLUSTER, duration=30.0, fleet=trace,
                           selection="random:0.25")
    assert int((m.dev_busy > 0).sum()) == 2    # ceil(0.25 * 8)
    # over many re-selection ticks the cohort rotates through the fleet
    m2 = simulate_fedoptima(MODEL, CLUSTER, duration=DUR,
                            fleet=FleetTrace.from_cluster(CLUSTER, DUR,
                                                          interval=40.0),
                            selection="random:0.25")
    assert int((m2.dev_busy > 0).sum()) > 2


# ---------------------------------------------------------------------------
# selection policies
# ---------------------------------------------------------------------------

def _ctx(counters=None, staleness=None, capability=None, K=6):
    return SelectionContext(
        t=0.0, counters=counters or {},
        staleness=np.zeros(K) if staleness is None else
        np.asarray(staleness),
        capability=capability)


def test_make_selection_policy_specs():
    assert make_selection_policy(None) is None
    p = make_selection_policy("refl:0.5", seed=3)
    assert p.name == "refl" and p.fraction == 0.5 and not p.trivial
    assert make_selection_policy("random").trivial
    assert make_selection_policy(p) is p
    with pytest.raises(ValueError, match="unknown selection"):
        make_selection_policy("greedy")
    with pytest.raises(ValueError, match="fraction"):
        make_selection_policy("random:0")


def test_random_selection_sizes_and_determinism():
    p = make_selection_policy("random:0.5", seed=0)
    avail = np.arange(6)
    picks = p.select(avail, _ctx())
    assert len(picks) == 3 and set(picks) <= set(range(6))
    q = make_selection_policy("random:0.5", seed=0)
    np.testing.assert_array_equal(picks, q.select(avail, _ctx()))
    # select-all consumes no RNG: the next draw is seed-fresh
    r = make_selection_policy("random", seed=0)
    np.testing.assert_array_equal(r.select(avail, _ctx()), avail)


def test_refl_selection_prefers_stale_then_underserved():
    p = make_selection_policy("refl:0.5")
    ctx = _ctx(counters={0: 9, 1: 0, 2: 2, 3: 2, 4: 5, 5: 5},
               staleness=[0, 0, 4, 4, 0, 0])
    picks = p.select([0, 1, 2, 3, 4, 5], ctx)
    # most-stale (2, 3) first; third slot goes to the least-consumed (1)
    np.testing.assert_array_equal(picks, [1, 2, 3])


def test_selection_survives_all_devices_off():
    for spec in ("random:0.5", "refl:0.5", "score:0.5"):
        p = make_selection_policy(spec)
        assert len(p.select([], _ctx(K=4, capability=np.ones(4)))) == 0
    # an all-off tick mid-run must not abort the simulation
    active = np.ones((4, 4), bool)
    active[1] = False
    trace = FleetTrace(interval=DUR / 4, active=active,
                       bw=np.full((4, 4), 12.5e6))
    m = simulate_fedoptima(MODEL, heterogeneous_cluster(4), duration=DUR,
                           fleet=trace, selection="score:0.5")
    assert m.dev_samples > 0


def test_generators_accept_per_device_bandwidth(tmp_path):
    """Tier-sampled clusters keep their bandwidth heterogeneity through
    trace generation: bw= takes a (K,) base, jitter multiplies it."""
    cl = sample_cluster(6, "low:1,premium:1", seed=0)
    t = diurnal_trace(6, 4000.0, interval=500.0, day=2000.0,
                      bw=cl.dev_bw, seed=1)
    np.testing.assert_allclose(t.bw, np.tile(cl.dev_bw, (t.T, 1)))
    j = diurnal_trace(6, 4000.0, interval=500.0, day=2000.0,
                      bw=cl.dev_bw, bw_jitter=0.2, seed=1)
    ratio = j.bw / cl.dev_bw[None, :]
    assert (ratio >= 0.8).all() and (ratio <= 1.2).all()
    # per-device bw meta stays a JSON-able artifact
    j2 = FleetTrace.load(j.save(str(tmp_path / "t.json")))
    np.testing.assert_array_equal(j.bw, j2.bw)
    assert j2.meta["bw"] == [float(v) for v in cl.dev_bw]


def test_score_selection_weighs_capability_and_balance():
    p = make_selection_policy("score:0.5")
    # equal staleness: fast + underserved devices outrank slow + served
    ctx = _ctx(counters={0: 10, 1: 0, 2: 10, 3: 0},
               capability=np.array([1e9, 4e9, 4e9, 1e9]), K=4)
    picks = p.select([0, 1, 2, 3], ctx)
    np.testing.assert_array_equal(picks, [1, 2])   # fast+fresh, fast
    # without capability data the balance/staleness terms decide
    picks = p.select([0, 1, 2, 3], _ctx(counters={0: 10, 1: 0, 2: 10, 3: 0},
                                        K=4))
    assert set(picks) == {1, 3}


# ---------------------------------------------------------------------------
# capability tiers
# ---------------------------------------------------------------------------

def test_parse_tiers_and_counts():
    pairs = parse_tiers("low:3,premium:1")
    assert [p.name for p, _ in pairs] == ["low", "premium"]
    assert tier_counts(8, "low:3,premium:1") == [6, 2]
    assert sum(tier_counts(7, "low,mid,high")) == 7
    with pytest.raises(ValueError, match="unknown device tier"):
        parse_tiers("low,ultra")


def test_sample_cluster_deterministic_and_tiered():
    a = sample_cluster(12, "low:1,premium:1", seed=0)
    b = sample_cluster(12, "low:1,premium:1", seed=0)
    np.testing.assert_array_equal(a.dev_flops, b.dev_flops)
    np.testing.assert_array_equal(a.dev_bw, b.dev_bw)
    assert a.K == 12
    # tier layout: first half low, second half premium — ~13x flops apart
    assert a.dev_flops[6:].mean() > 4 * a.dev_flops[:6].mean()
    assert a.srv_flops == a.dev_flops.max() * 50.0
    c = sample_cluster(12, "low:1,premium:1", seed=1)
    assert not np.array_equal(a.dev_flops, c.dev_flops)


def test_heterogeneous_cluster_pinned_values():
    """The moved helper stays bit-identical to the paper Table 3 layout."""
    cl = heterogeneous_cluster(8)
    np.testing.assert_allclose(
        cl.dev_flops,
        5e9 * np.array([1.0, 1.0, 1.33, 1.33, 2.67, 2.67, 3.84, 3.84]))
    np.testing.assert_allclose(cl.dev_bw, np.full(8, 100e6 / 8))
    np.testing.assert_allclose(cl.srv_flops, 5e9 * 3.84 * 50.0)


# ---------------------------------------------------------------------------
# contribution balance metric
# ---------------------------------------------------------------------------

def test_balance_summary_and_gini():
    assert gini([5, 5, 5, 5]) == pytest.approx(0.0)
    assert gini([0, 0, 0, 12]) == pytest.approx(0.75)
    assert gini([]) == 0.0 and gini([0, 0]) == 0.0
    bal = balance_summary([2, 2, 2, 10])
    assert bal["total"] == 16 and bal["participants"] == 4
    assert bal["gini"] > 0.2 and bal["cv"] > 0.5
    skew = simulate_fedoptima(MODEL, CLUSTER, duration=200.0)
    assert 0.0 <= skew.contribution_balance()["gini"] <= 1.0


# ---------------------------------------------------------------------------
# trace-driven churn hits ControlPlane.RetentionStore (pod path)
# ---------------------------------------------------------------------------

@settings(max_examples=10)
@given(st.integers(1, 4), st.integers(1, 3))
def test_trace_driven_retention_rejoins_at_recorded_staleness(k_gone, start):
    """Property (satellite acceptance): a group that leaves for k rounds
    VIA THE TRACE is retained at departure, its retained params survive
    the absence unchanged, and it rejoins from exactly those params with
    α = 1/(k+1) — the executor driving active_fn from trace rosters."""
    G, rounds = 3, start + k_gone + 2
    masks = np.ones((rounds, G), bool)
    masks[start:start + k_gone, 1] = False
    trace = FleetTrace(interval=1.0, active=masks,
                       bw=np.ones((rounds, G)))

    cp = ControlPlane(G, 1, 2)
    state = {"dev": 10.0 * np.arange(G, dtype=float)}

    def step(s, batch):
        # per-group "training": participants advance by 1 each round; the
        # masked broadcast means a dropped group's row must NOT matter —
        # its rejoin value comes from the retention scatter
        return {"dev": s["dev"] + np.asarray(batch["bcast"])}, {"l": 0.0}

    gathered, scattered, plans = {}, {}, {}

    def spy_gather(s, g):
        out = {"dev": np.array(s["dev"][g])}
        gathered.setdefault(g, out)
        return out

    def spy_scatter(s, g, p):
        scattered.setdefault(g, p)
        return {"dev": _with(s["dev"], g, p["dev"])}

    ex = RoundExecutor(step, cp, window=1,
                       gather=spy_gather, scatter=spy_scatter)

    def on_metrics(r, m, stats):
        plans[r] = stats.plan

    ex.run(state, 0, rounds,
           active_fn=lambda r: trace.roster(r),
           batch_fn=lambda r, plan: {"bcast": plan.bcast_mask},
           on_metrics=on_metrics)

    rejoin = start + k_gone
    # retained at departure with the pre-drop value, scattered back intact
    assert list(gathered) == [1] and list(scattered) == [1]
    assert gathered[1]["dev"] == pytest.approx(10.0 + start)
    assert scattered[1]["dev"] == pytest.approx(10.0 + start)
    assert 1 not in cp.retention               # released on rejoin
    # α at rejoin reflects the recorded absence: staleness k -> 1/(k+1)
    np.testing.assert_allclose(
        plans[rejoin].agg_weight,
        [1.0, 1.0 / (k_gone + 1), 1.0], rtol=1e-6)


def _with(arr, g, val):
    out = arr.copy()
    out[g] = val
    return out
