"""Auxiliary network (paper §3.2.2 + §6.5.1 ablation mechanics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer as tfm


def test_default_aux_structure():
    """Default: one block of the same type as the last device layer +
    factorized dense classifier."""
    cfg = registry.smoke_config("smollm-135m")
    aux = tfm.make_aux_params(jax.random.PRNGKey(0), cfg)
    assert set(aux) == {"block", "norm", "head_in", "head_out"}
    assert aux["head_in"].shape == (cfg.d_model, cfg.aux_dim)
    assert aux["head_out"].shape == (cfg.aux_dim, cfg.vocab)


def test_regression_aux_for_continuous_inputs():
    cfg = registry.smoke_config("whisper-tiny")
    aux = tfm.make_aux_params(jax.random.PRNGKey(0), cfg, regression=True)
    assert "head_reg" in aux and "head_out" not in aux
    B, S = 2, 12
    acts = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    frames = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model))
    loss = tfm.aux_head_loss(aux, cfg, acts, frames)
    assert loss.shape == () and bool(jnp.isfinite(loss)) and float(loss) > 0


def test_gradient_free_offloading():
    """The defining property: server-side training produces NO gradient
    w.r.t. device parameters (stop_gradient at the activation hand-off)."""
    cfg = registry.smoke_config("smollm-135m")
    rng = jax.random.PRNGKey(0)
    full = tfm.init_params(rng, cfg)
    dev, srv = tfm.split_params(full, cfg, 1)
    tok = jax.random.randint(rng, (2, 12), 0, cfg.vocab)
    lab = jax.random.randint(rng, (2, 12), 0, cfg.vocab)

    def srv_loss_via_dev(d):
        acts, _ = tfm.device_forward(d, cfg, tok)
        return tfm.server_forward_loss(srv, cfg, acts, lab)

    g = jax.grad(srv_loss_via_dev)(dev)
    norms = [float(jnp.abs(x).max()) for x in jax.tree.leaves(g)]
    assert max(norms) == 0.0, "gradient leaked from server to device"


def test_aux_loss_trains_device_block():
    """A few aux-loss SGD steps reduce the local loss (Alg. 1)."""
    cfg = registry.smoke_config("smollm-135m")
    rng = jax.random.PRNGKey(0)
    full = tfm.init_params(rng, cfg)
    dev, _ = tfm.split_params(full, cfg, 1)
    aux = tfm.make_aux_params(rng, cfg)
    tok = jax.random.randint(rng, (4, 16), 0, cfg.vocab)
    lab = jax.random.randint(rng, (4, 16), 0, cfg.vocab)

    @jax.jit
    def step(dev, aux):
        (loss, _), (gd, ga) = jax.value_and_grad(
            lambda d, a: tfm.device_train_loss(d, a, cfg, tok, lab),
            argnums=(0, 1), has_aux=True)(dev, aux)
        dev = jax.tree.map(lambda p, g: p - 0.1 * g, dev, gd)
        aux = jax.tree.map(lambda p, g: p - 0.1 * g, aux, ga)
        return dev, aux, loss

    losses = []
    for _ in range(12):
        dev, aux, loss = step(dev, aux)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.05, losses


def test_split_merge_roundtrip():
    cfg = registry.smoke_config("qwen3-32b")
    rng = jax.random.PRNGKey(0)
    full = tfm.init_params(rng, cfg)
    for l in (1, cfg.n_periods // 2, cfg.n_periods - 1):
        dev, srv = tfm.split_params(full, cfg, l)
        merged = tfm.merge_params(dev, srv, cfg)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                     full, merged)


def test_split_equivalence_full_forward():
    """device_forward + server stack == full forward (same math, split)."""
    cfg = registry.smoke_config("smollm-135m")
    rng = jax.random.PRNGKey(0)
    full = tfm.init_params(rng, cfg)
    tok = jax.random.randint(rng, (2, 8), 0, cfg.vocab)
    lab = jax.random.randint(rng, (2, 8), 0, cfg.vocab)
    want, _ = tfm.lm_loss(full, cfg, tok, lab, aux_weight=0.0)
    dev, srv = tfm.split_params(full, cfg, 2)
    acts, _ = tfm.device_forward(dev, cfg, tok)
    got = tfm.server_forward_loss(srv, cfg, acts, lab, aux_weight=0.0)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
