"""Tiered activation store (server memory manager, ``repro.memory``):
spill→fill round-trips (bit-exact fp32 / bounded-error int8), eviction
policies, the pool_cap=0 ≡ hard-ω pin, K ≫ ω admission past the old
cap, executor wiring, and checkpoint riding (state_dict v3 + extras,
v2 compatibility)."""
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core.control_plane import ControlPlane
from repro.core.executor import RoundExecutor, StragglerProfiles
from repro.memory import ActivationStore, make_eviction_policy

OMEGA, G4 = 2, 8        # K = 4ω acceptance scale (host-level tests)


# ---------------------------------------------------------------------------
# spill → fill round-trips (the store itself)
# ---------------------------------------------------------------------------

def _payload(rng, n, scale):
    return {"acts": (scale * rng.standard_normal((3, n))).astype(np.float32),
            "labels": rng.integers(0, 1000, (3, 4)).astype(np.int32)}


@settings(max_examples=25)
@given(st.integers(1, 64), st.floats(1e-3, 1e3))
def test_spill_fill_roundtrip_fp32_bitexact(n, scale):
    """fp32 spill is lossless: fill returns the gathered slot bit-for-bit."""
    rng = np.random.default_rng(n)
    store = ActivationStore(2, quant=False)
    p = _payload(rng, n, scale)
    store.spill(0, p)
    out = store.fill(0)
    np.testing.assert_array_equal(out["acts"], p["acts"])
    np.testing.assert_array_equal(out["labels"], p["labels"])
    assert out["acts"].dtype == np.float32
    assert out["labels"].dtype == np.int32


@settings(max_examples=25)
@given(st.integers(1, 64), st.floats(1e-3, 1e3))
def test_spill_fill_roundtrip_int8_tolerance(n, scale):
    """int8 spill: float leaves within the per-tensor quantization bound
    (max|x|/254 per element); integer leaves (labels) stay exact."""
    rng = np.random.default_rng(1000 + n)
    store = ActivationStore(2, quant=True)
    p = _payload(rng, n, scale)
    store.spill(5, p)
    out = store.fill(5)
    bound = np.abs(p["acts"]).max() / 254.0 + 1e-7
    assert np.abs(out["acts"] - p["acts"]).max() <= bound
    np.testing.assert_array_equal(out["labels"], p["labels"])


def test_store_cap_counts_and_bytes():
    rng = np.random.default_rng(0)
    store = ActivationStore(1, quant=False)
    store.spill(0, _payload(rng, 8, 1.0))
    assert len(store) == 1 and store.n_spills == 1
    assert store.pool_bytes == store.peak_pool_bytes > 0
    with pytest.raises(RuntimeError, match="pool full"):
        store.spill(1, _payload(rng, 8, 1.0))
    with pytest.raises(KeyError):
        store.spill(0, _payload(rng, 8, 1.0))   # key already held
    store.fill(0)
    assert len(store) == 0 and store.n_fills == 1 and store.pool_bytes == 0
    # int8 spill shrinks the float payload ~4x
    big = {"acts": rng.standard_normal((64, 64)).astype(np.float32)}
    fp = ActivationStore(1, quant=False)
    q8 = ActivationStore(1, quant=True)
    fp.spill(0, big)
    q8.spill(0, big)
    assert fp.pool_bytes > 3.5 * q8.pool_bytes


def test_eviction_policies_pick_expected_victims():
    """share: evict the slot whose contributors are best-served; lru:
    evict the least-recently-touched slot — over the same candidates."""
    share_of = {0: 0.7, 1: 0.1, 2: 0.4}.get
    groups_of = {10: {0}, 11: {1}, 12: {2}}.get     # slot -> contributors
    touch = {10: 5, 11: 9, 12: 1}
    lru = make_eviction_policy("lru")
    sh = make_eviction_policy("share")
    assert lru.victim([10, 11, 12], groups_of=groups_of, share=share_of,
                      touch=touch) == 12          # oldest touch
    assert sh.victim([10, 11, 12], groups_of=groups_of, share=share_of,
                     touch=touch) == 10           # best-served contributor
    # fills: share promotes the most-underserved entry first
    assert sh.fill_order([10, 11, 12], groups_of=groups_of,
                         share=share_of) == [11, 12, 10]
    assert lru.fill_order([12, 10, 11], groups_of=groups_of,
                          share=share_of) == [10, 11, 12]
    with pytest.raises(ValueError, match="unknown eviction"):
        make_eviction_policy("mru")


def test_fifo_withdraw_preserves_unspilled_arrival_order():
    """Evicting a NEWER contribution must not demote the group's older,
    unspilled one: withdraw_slot retires the arrival entry matching the
    withdrawn message, not the group's oldest."""
    from repro.core.scheduler import Message, TaskScheduler
    sched = TaskScheduler(3, policy="fifo")
    sched.put(Message("activation", 0, content="A"))   # g0 slot A (oldest)
    sched.put(Message("activation", 1, content="A"))
    sched.put(Message("activation", 2, content="B"))
    sched.put(Message("activation", 0, content="B"))   # g0 slot B (newer)
    sched.withdraw_slot("B", [0, 2])                   # evict slot B
    # g0's slot-A contribution kept arrival position 1: it is served first
    served = [sched.get().origin for _ in range(2)]
    assert served == [0, 1]
    assert sched.total_buffered == 0
    # the withdrawn messages re-enter at the back on fill
    sched.put(Message("activation", 2, content="C"))
    sched.put(Message("activation", 0, content="C"))
    assert [sched.get().origin, sched.get().origin] == [2, 0]


# ---------------------------------------------------------------------------
# control-plane planning: pool_cap=0 pin + K >= 4ω admission
# ---------------------------------------------------------------------------

def _stress(cp, rounds, stalled):
    """Two-phase workload: while ``stalled(r)`` the groups produce but the
    server never reads (pressure builds); afterwards production stops and
    the server drains the backlog.  Returns the plan trace."""
    H = cp.H
    plans = []
    for r in range(rounds):
        if stalled(r):
            produce, reads = None, np.zeros(H, bool)
        else:
            produce, reads = np.zeros((H, cp.G), bool), np.ones(H, bool)
        plans.append(cp.plan_round(produce=produce, reads=reads))
        assert cp.within_cap
        cp.finish_round()
    return plans


def test_pool_cap_zero_plans_are_hard_omega_behavior():
    """pool_cap=0 (the pod default): no spill/fill is ever planned, the
    flow budget is exactly ω·G, and a full ring gates sends — the plan
    trace is the pre-tiered hard-cap behavior, regardless of the
    eviction policy knob."""
    for eviction in ("share", "lru"):
        cp = ControlPlane(G4, OMEGA, 4, pool_cap=0, eviction=eviction)
        assert cp.flow.cap == cp.flow.omega == OMEGA * G4
        plans = _stress(cp, 6, stalled=lambda r: r < 3)
        assert all(p.spill == () and p.fill == () for p in plans)
        # ring full after ω write-iterations: every later stalled-round
        # send is gated (the ω cap as a strict invariant)
        stalled_sends = sum(int(p.send_mask.sum()) for p in plans[:3])
        assert stalled_sends == OMEGA * G4
        assert cp.n_spills == cp.n_fills == 0 and cp.pool_live == 0
        assert cp.peak_buffered <= OMEGA * G4


def test_k_4omega_admits_past_the_omega_ring():
    """K = 4ω groups with a stalled server: the tiered plane admits
    ω + pool slots of contributions (4× the old ceiling) while
    ``within_cap`` holds on the tiered budget; the same buffering level
    under the old ω-only cap is exactly the state the executor's
    RuntimeError refuses."""
    pool = 3 * OMEGA
    cp = ControlPlane(G4, OMEGA, 2, pool_cap=pool)
    _stress(cp, 4, stalled=lambda r: True)
    assert cp.peak_buffered == (OMEGA + pool) * G4    # 4x the old budget
    assert cp.peak_buffered > cp.flow.omega           # past the ω ring
    assert cp.pool_live == pool and cp.within_cap
    # the old path: same buffering with no spill tier violates ω —
    # RoundExecutor._check_cap raises the ω-cap RuntimeError
    ex = RoundExecutor(lambda s, b: (s, {}), cp)
    cp.flow.pool_cap = 0          # the old, un-tiered budget
    old_cap = cp.pool_cap
    cp.pool_cap = 0
    with pytest.raises(RuntimeError, match="activation cap"):
        ex._check_cap(3)
    cp.flow.pool_cap = pool * G4  # restore the tiered budget
    cp.pool_cap = old_cap
    assert cp.within_cap
    # server catches up: the pool drains back through fills
    _stress(cp, 12, stalled=lambda r: False)
    assert cp.n_fills == cp.n_spills > 0
    assert cp.pool_live == 0 and cp.flow.buffered == 0


# ---------------------------------------------------------------------------
# executor wiring (host-level stub mesh)
# ---------------------------------------------------------------------------

class _StalledProfiles(StragglerProfiles):
    """Deterministic two-phase pattern: for the first ``stall_rounds``
    plans every group emits and the server never reads (backlog builds,
    spills); afterwards emission stops and the server drains (fills)."""

    def __init__(self, n_groups, stall_rounds):
        super().__init__(n_groups)
        self.stall_rounds = stall_rounds
        self._planned = 0

    def produce(self, H):
        self._planned += 1          # produce() is called first each round
        stalled = self._planned <= self.stall_rounds
        return np.full((H, self.G), stalled, bool)

    def reads(self, H):
        return np.full(H, self._planned > self.stall_rounds, bool)


class _StubMesh:
    """Host-array ring standing in for the jit'd step: applies the plan's
    writes, stamping each written slot with (round, h)."""

    def __init__(self, omega):
        self.t = 0

    def step(self, state, plan):
        ring = list(state["ring"])
        for h in range(len(plan.write_slot)):
            if plan.send_mask[h].any():
                ring[int(plan.write_slot[h])] = {
                    "acts": np.full(4, 100.0 * self.t + h, np.float32)}
        self.t += 1
        return {"ring": ring}, {"d_loss": 0.0}


def _slot_ops():
    def gather(state, s):
        return state["ring"][s]

    def scatter(state, s, payload):
        ring = list(state["ring"])
        ring[s] = payload
        return {"ring": ring}
    return gather, scatter


def test_executor_runs_k_4omega_spills_and_fills():
    pool = 3 * OMEGA
    H = 2
    cp = ControlPlane(G4, OMEGA, H, pool_cap=pool)
    store = ActivationStore(pool)
    mesh = _StubMesh(OMEGA)
    gather, scatter = _slot_ops()
    profiles = _StalledProfiles(G4, stall_rounds=5)
    ex = RoundExecutor(mesh.step, cp, window=2, profiles=profiles,
                       store=store, gather_slot=gather,
                       scatter_slot=scatter)

    def on_metrics(r, m, stats):
        assert cp.within_cap
        # store payloads and control-plane bookkeeping track each other
        assert store.keys == sorted(cp.pool_occupancy)

    state = {"ring": [{"acts": np.zeros(4, np.float32)}] * OMEGA}
    state, hist = ex.run(state, 0, 14,
                         active_fn=lambda r: np.ones(G4, bool),
                         batch_fn=lambda r, plan: plan,
                         on_metrics=on_metrics)
    assert len(hist) == 14
    mem = ex.summary()["memory"]
    assert mem["spills"] == mem["store_spills"] > 0
    assert mem["fills"] == mem["store_fills"] == mem["spills"]
    assert mem["peak_pool"] > 0 and len(store) == 0
    assert cp.peak_buffered > OMEGA * G4      # admitted past the old cap


def test_executor_refuses_spills_without_store_wiring():
    cp = ControlPlane(G4, OMEGA, 2, pool_cap=2)
    profiles = _StalledProfiles(G4, stall_rounds=10)
    ex = RoundExecutor(_StubMesh(OMEGA).step, cp, profiles=profiles)
    with pytest.raises(RuntimeError, match="ActivationStore"):
        ex.run({"ring": [None] * OMEGA}, 0, 3,
               active_fn=lambda r: np.ones(G4, bool),
               batch_fn=lambda r, plan: plan)


# ---------------------------------------------------------------------------
# prefetch-ahead staging: pre-decoded fills, bit-identical and advisory
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quant", [False, True])
def test_prefetch_staged_fill_is_bitexact(quant):
    """A prefetch-staged fill returns exactly what an unstaged fill
    would (the decode is pure in the stored payload) — including through
    the int8 quantized path — and the staging counters balance."""
    rng = np.random.default_rng(3)
    payloads = {k: _payload(rng, 8, 1.0) for k in (0, 1)}
    a = ActivationStore(2, quant=quant)     # staged leg
    b = ActivationStore(2, quant=quant)     # plain leg
    for k, p in payloads.items():
        a.spill(k, p)
        b.spill(k, p)
    a.prefetch(0)
    assert a.n_prefetched == 1 and a.staged_bytes > 0
    a.prefetch(0)                           # idempotent: already staged
    assert a.n_prefetched == 1
    a.prefetch(99)                          # advisory: unknown key ignored
    assert a.n_prefetched == 1
    for k in (0, 1):
        fa, fb = a.fill(k), b.fill(k)
        for leaf in fa:
            np.testing.assert_array_equal(fa[leaf], fb[leaf])
    assert a.prefetch_hits == 1 and a.staged_bytes == 0
    assert a.peak_staged_bytes > 0
    s = a.summary()
    assert s["n_prefetched"] == 1 and s["prefetch_hits"] == 1


def test_prefetch_ignores_payloadless_restored_entries():
    """Post-restore, pre-load_arrays entries hold metadata only; a
    prefetch hint against them must be a no-op, not a crash."""
    src = ActivationStore(1)
    src.spill(0, _payload(np.random.default_rng(0), 4, 1.0))
    dst = ActivationStore(1)
    dst.load_meta(src.meta_dict())          # keys known, payloads absent
    dst.prefetch(0)
    assert dst.n_prefetched == 0 and dst.staged_bytes == 0


def test_executor_prefetch_stages_ahead_without_changing_values():
    """The executor's lookahead (= window) pre-stages pooled entries and
    the fills consume the staged decodes; the metric history is
    bit-identical across windows (prefetch is plan-neutral)."""
    hists = {}
    for window in (1, 2):
        cp = ControlPlane(G4, OMEGA, 2, pool_cap=3 * OMEGA)
        store = ActivationStore(3 * OMEGA)
        gather, scatter = _slot_ops()
        ex = RoundExecutor(_StubMesh(OMEGA).step, cp, window=window,
                           profiles=_StalledProfiles(G4, stall_rounds=5),
                           store=store, gather_slot=gather,
                           scatter_slot=scatter)
        state = {"ring": [{"acts": np.zeros(4, np.float32)}] * OMEGA}
        _, hists[window] = ex.run(
            state, 0, 14, active_fn=lambda r: np.ones(G4, bool),
            batch_fn=lambda r, plan: plan)
        mem = ex.summary()["memory"]
        assert mem["n_prefetched"] > 0
        assert mem["prefetch_hits"] > 0
        assert mem["fills"] == mem["spills"] > 0
    assert hists[1] == hists[2]


# ---------------------------------------------------------------------------
# checkpoint riding: state_dict v3 + extras, v2 compatibility
# ---------------------------------------------------------------------------

def _occupied_plane(pool=2, quant=False):
    """A plane + store mid-run with a genuinely occupied spill pool."""
    rng = np.random.default_rng(7)
    cp = ControlPlane(4, OMEGA, 2, pool_cap=pool)
    store = ActivationStore(pool, quant=quant)
    ring = [_payload(rng, 6, 1.0) for _ in range(OMEGA)]
    for r in range(2 + pool):
        plan = cp.plan_round(reads=np.zeros(2, bool))
        for key, s in plan.fill:
            ring[s] = store.fill(key)
        for s, key in plan.spill:
            store.spill(key, ring[s])
        for h in range(2):
            if plan.send_mask[h].any():
                ring[int(plan.write_slot[h])] = _payload(rng, 6, 1.0)
        cp.finish_round()
    assert cp.pool_live == pool and len(store) == pool
    return cp, store, ring


def test_state_dict_v3_roundtrip_with_occupied_pool():
    import json
    cp, store, _ = _occupied_plane()
    sd = cp.state_dict()
    json.dumps(sd)                                 # metadata-safe
    assert sd["version_tag"] == 3 and len(sd["pool"]) == 2
    cp2 = ControlPlane(4, OMEGA, 2, pool_cap=2)
    cp2.load_state_dict(sd)
    assert cp2.within_cap and cp2.pool_occupancy == cp.pool_occupancy
    assert cp2.flow.buffered == cp.flow.buffered   # pooled units counted
    # lockstep planning through the drain (fills included)
    quiet = np.zeros((2, 4), bool)
    for r in range(6):
        p1 = cp.plan_round(produce=quiet, reads=np.ones(2, bool))
        p2 = cp2.plan_round(produce=quiet, reads=np.ones(2, bool))
        np.testing.assert_array_equal(p1.read_slot, p2.read_slot)
        np.testing.assert_array_equal(p1.send_mask, p2.send_mask)
        assert p1.fill == p2.fill and p1.spill == p2.spill
        cp.finish_round()
        cp2.finish_round()
    assert cp.n_fills == cp2.n_fills > 0


def test_load_rejects_undersized_pool_and_policy_mismatch():
    cp, _, _ = _occupied_plane()
    sd = cp.state_dict()
    small = ControlPlane(4, OMEGA, 2, pool_cap=1)
    with pytest.raises(ValueError, match="pool_cap"):
        small.load_state_dict(sd)
    other = ControlPlane(4, OMEGA, 2, pool_cap=2, eviction="lru")
    with pytest.raises(ValueError, match="eviction"):
        other.load_state_dict(sd)


def test_v2_snapshot_without_spill_metadata_still_loads():
    """Snapshots from before the tiered store (no pool/eviction keys)
    restore into a pool-capable plane: empty tier, same plans."""
    cp = ControlPlane(3, OMEGA, 2)
    for _ in range(3):
        cp.plan_round(reads=np.array([True, False]))
        cp.finish_round()
    sd = cp.state_dict()
    for k in ("version_tag", "pool_cap", "eviction", "pool",
              "next_pool_key", "slot_touch", "tick", "n_spills",
              "n_fills", "peak_pool"):
        sd.pop(k)                                  # what a v2 writer wrote
    cp2 = ControlPlane(3, OMEGA, 2, pool_cap=4)
    cp2.load_state_dict(sd)
    assert cp2.within_cap and cp2.pool_live == 0
    p1 = cp.plan_round()
    p2 = cp2.plan_round()
    np.testing.assert_array_equal(p1.read_slot, p2.read_slot)
    np.testing.assert_array_equal(p1.send_mask, p2.send_mask)


@pytest.mark.parametrize("quant", [False, True])
def test_checkpoint_extras_roundtrip_occupied_pool(tmp_path, quant):
    """The spilled payloads ride the snapshot's extras.npz next to the
    retention params and restore losslessly (fp32) / within quantization
    tolerance (int8)."""
    import jax
    from repro.checkpoint import store as ckpt
    cp, astore, ring = _occupied_plane(quant=quant)
    originals = {k: astore._pool[k]["payload"] for k in astore.keys}
    extras = {"spill": astore.arrays()}
    ckpt.save(str(tmp_path), 1, {"x": np.arange(3.0)},
              metadata={"control_plane": cp.state_dict(),
                        "spill_store": astore.meta_dict()},
              extras=extras)

    meta = ckpt.restore_metadata(str(tmp_path), 1)
    cp2 = ControlPlane(4, OMEGA, 2, pool_cap=2)
    cp2.load_state_dict(meta["control_plane"])
    astore2 = ActivationStore(2, quant=quant)
    astore2.load_meta(meta["spill_store"])
    assert astore2.keys == astore.keys
    slot_like = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                 for k, v in ring[0].items()}
    ex = ckpt.restore_extras(str(tmp_path), 1,
                             {"spill": astore2.like_tree(slot_like)})
    astore2.load_arrays(ex["spill"], dtypes=astore2.slot_dtypes(slot_like))
    for key in list(astore2.keys):
        a = astore.fill(key)
        b = astore2.fill(key)
        np.testing.assert_array_equal(a["labels"], b["labels"])
        # identical stored form (int8 q + scale for quant) -> identical
        # dequantized fill, so the round-trip through the snapshot is
        # lossless relative to the in-memory store either way
        np.testing.assert_array_equal(a["acts"], b["acts"])
        if quant:
            np.testing.assert_array_equal(originals[key]["acts"]["q"],
                                          np.asarray(ex["spill"][str(key)]
                                                     ["acts"]["q"]))
    assert len(astore2) == 0 and astore2.pool_bytes == 0


# ---------------------------------------------------------------------------
# real jit'd step: spill rounds train, pool_cap=0 parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def jit_setup():
    import jax
    from repro.configs import registry
    from repro.core import fedopt_step as F
    from repro.launch.mesh import make_debug_mesh
    a = registry.smoke_config("smollm-135m")
    cfg = F.FedStepConfig(arch=a, l_split=1, n_groups=2, seq_len=16,
                          per_group_batch=4, H=2, omega=OMEGA)
    mesh = make_debug_mesh(1, 1)
    jitted, _, s_spec, _ = F.jit_train_step(cfg, mesh, donate=False)

    def fresh_state():
        return jax.jit(lambda: F.init_train_state(jax.random.PRNGKey(0),
                                                  cfg),
                       out_shardings=s_spec)()
    return cfg, jitted, s_spec, fresh_state


def _run_real(cfg, jitted, s_spec, state, *, pool_cap, quant=False,
              rounds=6, wire_store=True):
    import jax
    from repro.core import fedopt_step as F
    cp = ControlPlane(cfg.n_groups, cfg.omega, cfg.H, pool_cap=pool_cap)
    store = ActivationStore(pool_cap, quant=quant)
    kw = {}
    if wire_store:
        kw = dict(store=store, gather_slot=F.gather_act_slot,
                  scatter_slot=lambda st, s, p: F.scatter_act_slot(
                      st, s, p, state_shardings=s_spec))
    ex = RoundExecutor(jitted, cp, window=2,
                       profiles=_StalledProfiles(cfg.n_groups,
                                                 stall_rounds=3), **kw)

    def batch_fn(r, plan):
        batch = F.concrete_train_batch(jax.random.PRNGKey(r), cfg)
        batch.update(plan.batch_fields())
        return batch

    state, hist = ex.run(state, 0, rounds,
                         active_fn=lambda r: np.ones(cfg.n_groups, bool),
                         batch_fn=batch_fn)
    return cp, store, state, hist


def test_real_step_spill_rounds_train_and_drain(jit_setup):
    """ω=2 + pool_cap=2 on the real hybrid step: a stalled server forces
    real host↔mesh slot transfers; training stays finite, the tiered cap
    holds, and the pool drains once reads resume."""
    cfg, jitted, s_spec, fresh_state = jit_setup
    cp, store, state, hist = _run_real(cfg, jitted, s_spec, fresh_state(),
                                       pool_cap=2)
    assert len(hist) == 6
    assert all(np.isfinite(m["d_loss"]) and np.isfinite(m["s_loss"])
               for m in hist)
    assert cp.n_spills > 0 and cp.n_fills == cp.n_spills
    assert store.n_spills == cp.n_spills and len(store) == 0
    assert cp.within_cap
    assert cp.peak_buffered > cfg.omega * cfg.n_groups   # past the ring


def test_real_step_pool_cap_zero_is_bitforbit_storeless(jit_setup):
    """pool_cap=0 with the store wired is bit-for-bit the storeless
    (pre-tiered) executor run: same metric history, same final state."""
    import jax
    cfg, jitted, s_spec, fresh_state = jit_setup
    _, store, st_a, hist_a = _run_real(cfg, jitted, s_spec, fresh_state(),
                                       pool_cap=0, wire_store=True)
    _, _, st_b, hist_b = _run_real(cfg, jitted, s_spec, fresh_state(),
                                   pool_cap=0, wire_store=False)
    assert store.n_spills == store.n_fills == 0
    assert [m["d_loss"] for m in hist_a] == [m["d_loss"] for m in hist_b]
    assert [m["s_loss"] for m in hist_a] == [m["s_loss"] for m in hist_b]
    for la, lb in zip(jax.tree.leaves(st_a), jax.tree.leaves(st_b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
