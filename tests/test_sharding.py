"""Sharding rules: PartitionSpecs are always valid for their leaves.
Spec assignment only reads mesh.shape, so a stand-in mesh suffices (the
real 256/512-device meshes exist only under the dry-run's XLA_FLAGS)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.core import fedopt_step as F
from repro.models import transformer as tfm
from repro.parallel.sharding import Parallelism, param_specs


class FakeMesh:
    def __init__(self, data=16, model=16, pod=None):
        self.shape = {"data": data, "model": model}
        self.axis_names = ("data", "model")
        if pod:
            self.shape = {"pod": pod, **self.shape}
            self.axis_names = ("pod",) + self.axis_names


def _axis_size(mesh, axis):
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _check_specs(tree, specs, mesh):
    flat_p = jax.tree.leaves(tree)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        assert len(spec) <= len(leaf.shape), (leaf.shape, spec)
        for dim, axis in zip(leaf.shape, tuple(spec) + (None,) * 8):
            size = _axis_size(mesh, axis)
            assert dim % size == 0, \
                f"shape {leaf.shape} not divisible by {spec}"


@pytest.mark.parametrize("name", sorted(registry.ARCHS))
def test_param_specs_divisible_smoke(name, rng):
    mesh = FakeMesh(2, 2)
    par = Parallelism(mesh=mesh, dp_axes=("data",))
    cfg = registry.smoke_config(name)
    params = jax.eval_shape(lambda: tfm.init_params(rng, cfg))
    _check_specs(params, param_specs(params, par), mesh)


@pytest.mark.parametrize("name", sorted(registry.ARCHS))
def test_param_specs_divisible_full_production(name):
    """FULL configs on the (16,16) production layout (eval_shape only)."""
    mesh = FakeMesh(16, 16)
    par = Parallelism(mesh=mesh, dp_axes=("data",))
    cfg = registry.get(name)
    params = jax.eval_shape(
        lambda: tfm.init_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16))
    _check_specs(params, param_specs(params, par), mesh)


@pytest.mark.parametrize("name", ["smollm-135m", "qwen3-moe-235b-a22b",
                                  "whisper-tiny", "mamba2-780m"])
@pytest.mark.parametrize("pod", [None, 2])
def test_train_state_specs_divisible(name, pod):
    mesh = FakeMesh(4, 2, pod=pod)
    dp = ("pod", "data") if pod else ("data",)
    par = Parallelism(mesh=mesh, dp_axes=dp)
    arch = registry.smoke_config(name)
    G = 8 if pod else 4
    cfg = F.FedStepConfig(arch=arch, l_split=1, n_groups=G, seq_len=16,
                          per_group_batch=2, H=2)
    state = F.abstract_train_state(cfg)
    _check_specs(state, F.state_specs(state, cfg, par), mesh)


def test_full_train_state_specs_production_mesh():
    """The exact dry-run configuration: full arch, (16,16) layout."""
    mesh = FakeMesh(16, 16)
    par = Parallelism(mesh=mesh, dp_axes=("data",))
    arch = registry.get("qwen3-32b")
    cfg = F.FedStepConfig(arch=arch, l_split=F.default_l_split(arch),
                          n_groups=16, seq_len=4096, per_group_batch=16,
                          H=8, param_dtype=jnp.bfloat16)
    state = F.abstract_train_state(cfg)
    _check_specs(state, F.state_specs(state, cfg, par), mesh)


def test_tp_actually_assigned_to_big_leaves():
    """The rules must not silently replicate everything."""
    mesh = FakeMesh(2, 2)
    par = Parallelism(mesh=mesh, dp_axes=("data",))
    cfg = registry.smoke_config("qwen3-32b")
    params = jax.eval_shape(lambda: tfm.init_params(jax.random.PRNGKey(0), cfg))
    specs = param_specs(params, par)
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    n_tp = sum(1 for s in flat if any(a == "model" for a in s))
    n_dp = sum(1 for s in flat
               if any(a == ("data",) or a == "data" for a in s))
    assert n_tp >= 5, "attention/MLP projections must be TP-sharded"
    assert n_dp >= 3, "FSDP must shard some weight dims over data"


def test_cache_specs_divisibility():
    mesh = FakeMesh(16, 16)
    par = Parallelism(mesh=mesh, dp_axes=("data",))
    for name in ("qwen3-32b", "jamba-1.5-large-398b", "gemma2-27b"):
        arch = registry.get(name)
        caches = jax.eval_shape(
            lambda a=arch: tfm.init_serve_state(a, 128, 32768, jnp.bfloat16))
        specs = F._cache_specs(caches, par)
        _check_specs(caches, specs, mesh)


def test_validate_drops_nondivisible_axes():
    from repro.parallel.sharding import _validate
    mesh = FakeMesh(16, 16)
    par = Parallelism(mesh=mesh, dp_axes=("data",))
    out = _validate(P("model", None), (9, 4), par)     # 9 % 16 != 0
    assert out == P(None, None)
    out = _validate(P("model", "data"), (32, 64), par)
    assert out == P("model", "data")
