"""Split-point selection (paper §3.2.1, Eq. 6-8)."""
import numpy as np
from _propcheck import given, settings, strategies as st

from repro.configs import registry
from repro.core.partition import (cnn_profile, select_split, split_costs,
                                  transformer_profile)


def test_transformer_profile_monotone():
    cfg = registry.get("smollm-135m")
    prof = transformer_profile(cfg, seq=128)
    assert prof.n_units == cfg.n_periods
    cum = np.cumsum(prof.flops)
    assert np.all(np.diff(cum) > 0)                 # deeper = more compute
    assert prof.total_flops >= cum[-1]              # head included


def test_eq8_minimax_bruteforce():
    """select_split must equal the brute-force argmin of Eq. 8."""
    cfg = registry.get("smollm-135m")
    prof = transformer_profile(cfg, seq=64)
    o_k = np.array([1e9, 2e9, 4e9])
    b_k = np.array([1e6, 5e6, 2e6])
    l_star = select_split(prof, o_k, b_k)
    cum = np.cumsum(prof.flops)
    costs = [max(max(cum[l - 1] / o, prof.out_bytes[l - 1] / b)
                 for o, b in zip(o_k, b_k))
             for l in range(1, prof.n_units)]
    assert l_star == int(np.argmin(costs)) + 1


def test_weaker_devices_move_split_earlier():
    """Slower devices -> compute dominates -> fewer device-side layers."""
    cfg = registry.get("qwen3-32b")
    prof = transformer_profile(cfg, seq=128)
    b_k = np.array([1e9] * 4)
    weak = select_split(prof, np.array([1e8] * 4), b_k)
    strong = select_split(prof, np.array([1e13] * 4), b_k)
    assert weak <= strong


@given(st.lists(st.floats(1e8, 1e11), min_size=1, max_size=8),
       st.lists(st.floats(1e4, 1e9), min_size=1, max_size=8))
@settings(max_examples=25, deadline=None)
def test_split_valid_for_any_cluster(os_, bs_):
    k = min(len(os_), len(bs_))
    cfg = registry.get("smollm-135m")
    prof = transformer_profile(cfg, seq=32)
    o_k, b_k = np.array(os_[:k]), np.array(bs_[:k])
    l = select_split(prof, o_k, b_k)
    assert 1 <= l <= prof.n_units - 1
    c = split_costs(prof, o_k, b_k)
    assert np.all(np.isfinite(c)) and c.shape == (prof.n_units,)


def test_cnn_profile_matches_paper_models():
    from repro.models.cnn import mobilenetv3ish_config, vgg5_config
    for cfg in (vgg5_config(), mobilenetv3ish_config()):
        prof = cnn_profile(cfg)
        assert prof.n_units == len(cfg.layers)
        assert prof.total_flops > 0
        assert all(b >= 0 for b in prof.out_bytes)


def test_all_assigned_archs_profile():
    for name in registry.ARCHS:
        prof = transformer_profile(registry.get(name), seq=64)
        assert prof.n_units >= 2 and prof.total_flops > 0
