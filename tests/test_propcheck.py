"""The plugin-free property-check shim (tests/_propcheck.py) honors
max_examples for both decorator orders, like hypothesis."""
import importlib.util

from _propcheck import given, settings, strategies as st

SHIM_ACTIVE = importlib.util.find_spec("hypothesis") is None

_below = []
_above = []


@given(st.integers(0, 5))
@settings(max_examples=7, deadline=None)
def test_settings_below_given(x):
    _below.append(x)
    assert 0 <= x <= 5


@settings(max_examples=7, deadline=None)
@given(st.integers(0, 5))
def test_settings_above_given(x):
    _above.append(x)
    assert 0 <= x <= 5


def test_example_counts():
    if SHIM_ACTIVE:
        assert len(_below) == 7 and len(_above) == 7
    else:          # real hypothesis chooses its own example schedule
        assert _below and _above
