"""Differentiable Pallas kernels: gradient parity against the pure-jnp
oracles (interpret mode), LSE residual correctness, kernel_mode scoping,
chunk clamping, and the end-to-end kernel-mode hybrid train step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)

GTOL = 1e-4


def _qkv(shape, dtype=jnp.float32, seed=0):
    B, S, Skv, H, Hkv, hd = shape
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, hd), dtype)
    return q, k, v


def _attn_grads(fn, q, k, v, **kw):
    # non-linear readout so every output element contributes a distinct
    # cotangent (catches transposition/accumulation mistakes a plain sum
    # would mask)
    loss = lambda q, k, v: jnp.sum(jnp.sin(fn(q, k, v, **kw)))
    return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)


ATTN_GRAD_CASES = [
    # (shape, kwargs)
    ((1, 128, 128, 4, 4, 32), dict(causal=True)),              # MHA causal
    ((2, 128, 128, 8, 2, 32), dict(causal=True)),              # GQA 4:1
    ((1, 192, 192, 4, 4, 32), dict(causal=True, window=32)),   # sliding win
    ((1, 128, 128, 4, 2, 32), dict(causal=True, logit_cap=20.0)),  # softcap
    ((1, 64, 64, 4, 1, 32), dict(causal=False)),               # MQA, full
    ((1, 100, 100, 4, 2, 32), dict(causal=True)),              # ragged S
    ((1, 100, 72, 4, 2, 32), dict(causal=False)),              # ragged Skv
    ((1, 160, 160, 4, 2, 32),
     dict(causal=True, window=48, logit_cap=15.0)),            # all stacked
]


@pytest.mark.parametrize("shape,kw", ATTN_GRAD_CASES)
def test_flash_attention_grad_parity(shape, kw):
    q, k, v = _qkv(shape)
    with ops.kernel_mode(True):
        got = _attn_grads(ops.flash_attention, q, k, v, **kw)
    want = _attn_grads(ref.flash_attention_reference, q, k, v, **kw)
    for g, w, name in zip(got, want, "qkv"):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=GTOL, rtol=GTOL,
                                   err_msg=f"d{name} {shape} {kw}")


def test_flash_attention_grad_matches_sdpa_chunked():
    """The training fallback (sdpa_chunked) and the kernel agree on grads."""
    from repro.models.attention import sdpa_chunked
    q, k, v = _qkv((2, 96, 96, 8, 2, 32))
    kw = dict(causal=True, window=None, logit_cap=None)
    with ops.kernel_mode(True):
        got = _attn_grads(ops.flash_attention, q, k, v,
                          causal=True)
    want = _attn_grads(sdpa_chunked, q, k, v, chunk_q=32, **kw)
    for g, w, name in zip(got, want, "qkv"):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=GTOL, rtol=GTOL, err_msg=f"d{name}")


def test_flash_attention_lse_matches_reference():
    from repro.kernels.flash_attention import flash_attention_fwd_bhsd
    q, k, v = _qkv((2, 96, 96, 4, 2, 32))
    out, lse = flash_attention_fwd_bhsd(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=True, interpret=True)
    want_out, want_lse = ref.flash_attention_reference(q, k, v, causal=True,
                                                       return_lse=True)
    np.testing.assert_allclose(np.asarray(jnp.swapaxes(out, 1, 2)),
                               np.asarray(want_out), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(want_lse),
                               atol=2e-5, rtol=2e-5)


@given(st.sampled_from([64, 96, 128]), st.sampled_from([(4, 4), (4, 2)]),
       st.sampled_from([None, 32]), st.sampled_from([None, 25.0]))
@settings(max_examples=4, deadline=None)
def test_flash_attention_grad_property(s, heads, window, cap):
    H, Hkv = heads
    q, k, v = _qkv((1, s, s, H, Hkv, 32), seed=s + H)
    kw = dict(causal=True, window=window, logit_cap=cap)
    with ops.kernel_mode(True):
        got = _attn_grads(ops.flash_attention, q, k, v, **kw)
    want = _attn_grads(ref.flash_attention_reference, q, k, v, **kw)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=GTOL, rtol=GTOL)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

def _ssd_inputs(shape, seed=0):
    B, T, H, P, G, N = shape
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, T, G, N)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(ks[3], 1), (B, T, G, N)) * 0.5
    return x, dt, A, Bm, Cm


def _ssd_grads(fn, args):
    loss = lambda *a: jnp.sum(jnp.sin(fn(*a)))
    return jax.grad(loss, argnums=tuple(range(5)))(*args)


SSD_GRAD_CASES = [
    # ((B, T, H, P, G, N), chunk)
    ((1, 64, 4, 16, 1, 8), 16),
    ((2, 64, 8, 16, 2, 8), 32),     # grouped B/C (rep=4)
    ((1, 50, 4, 16, 1, 8), 16),     # ragged: T % chunk != 0 (padding bwd)
    ((1, 12, 4, 16, 1, 8), 32),     # T < chunk (clamp + single chunk)
]


@pytest.mark.parametrize("shape,chunk", SSD_GRAD_CASES)
def test_ssd_grad_parity(shape, chunk):
    args = _ssd_inputs(shape)
    with ops.kernel_mode(True):
        got = _ssd_grads(lambda *a: ops.ssd(*a, chunk=chunk), args)
    want = _ssd_grads(lambda *a: ref.ssd_reference(*a)[0], args)
    for g, w, name in zip(got, want, ["x", "dt", "A", "B", "C"]):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=GTOL, rtol=GTOL,
                                   err_msg=f"d{name} {shape} chunk={chunk}")


@given(st.sampled_from([24, 48, 64]), st.sampled_from([2, 4]),
       st.sampled_from([8, 16]))
@settings(max_examples=4, deadline=None)
def test_ssd_grad_property(t, h, n):
    args = _ssd_inputs((1, t, h, 16, 1, n), seed=t + h)
    with ops.kernel_mode(True):
        got = _ssd_grads(lambda *a: ops.ssd(*a, chunk=16), args)
    want = _ssd_grads(lambda *a: ref.ssd_reference(*a)[0], args)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# ops plumbing: chunk clamp + kernel_mode scoping
# ---------------------------------------------------------------------------

def test_ssd_chunk_clamped_and_padded():
    """chunk > T clamps once; T % chunk != 0 pads — both match the oracle
    (regression for the dead clamp expression that never re-padded)."""
    for T, chunk in ((12, 128), (50, 16), (48, 48)):
        args = _ssd_inputs((1, T, 4, 16, 1, 8), seed=T)
        got = ops.ssd(*args, chunk=chunk)
        want, _ = ref.ssd_reference(*args)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=5e-4, rtol=5e-4, err_msg=str((T, chunk)))


def test_kernel_mode_scopes_and_restores():
    import repro.kernels.ops as kops
    kops.set_kernel_mode(None)
    assert kops._FORCE_INTERPRET is None
    with kops.kernel_mode(True):
        assert kops._FORCE_INTERPRET is True
        with kops.kernel_mode(False):
            assert kops._FORCE_INTERPRET is False
        assert kops._FORCE_INTERPRET is True
    assert kops._FORCE_INTERPRET is None
    # exception-safe restore
    with pytest.raises(RuntimeError):
        with kops.kernel_mode(True):
            raise RuntimeError("boom")
    assert kops._FORCE_INTERPRET is None


# ---------------------------------------------------------------------------
# end-to-end: kernel-mode training
# ---------------------------------------------------------------------------

def test_selective_remat_composes_with_kernels():
    """remat="selective" (saves tp_out + kernel_out) must not change the
    kernel-path gradients."""
    from repro.configs import registry
    from repro.models import transformer as tfm
    cfg = registry.smoke_config("mamba2-780m")
    p = tfm.init_params(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    lab = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, cfg.vocab)
    with ops.kernel_mode(True):
        g_full = jax.grad(lambda x: tfm.lm_loss(
            x, cfg, tok, lab, use_kernel=True, remat=True)[0])(p)
        g_sel = jax.grad(lambda x: tfm.lm_loss(
            x, cfg, tok, lab, use_kernel=True, remat="selective")[0])(p)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_sel)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-6)


@pytest.mark.parametrize("arch", ["smollm-135m", "mamba2-780m"])
def test_train_step_use_kernel_full_round(arch):
    """Acceptance: make_train_step(use_kernel=True) traces, lowers, and runs
    a full round — device half + server half under value_and_grad + the
    end-of-round aggregation — through the fused kernels."""
    from repro.configs import registry
    from repro.core import fedopt_step as F
    from repro.launch.mesh import make_debug_mesh
    a = registry.smoke_config(arch)
    cfg = F.FedStepConfig(arch=a, l_split=1, n_groups=2, seq_len=16,
                          per_group_batch=4, H=2, use_kernel=True)
    mesh = make_debug_mesh(1, 1)
    jitted, _, s_spec, _ = F.jit_train_step(cfg, mesh)
    state = jax.jit(lambda: F.init_train_state(jax.random.PRNGKey(0), cfg),
                    out_shardings=s_spec)()
    batch = F.concrete_train_batch(jax.random.PRNGKey(1), cfg)
    state, metrics = jitted(state, batch)
    assert np.isfinite(float(metrics["d_loss"]))
    assert np.isfinite(float(metrics["s_loss"]))
    assert int(state["step"]) == 1
    # aggregation ran: groups identical after uniform-weight round
    for leaf in jax.tree.leaves(state["dev"]):
        np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[1]),
                                   atol=1e-6)


@pytest.mark.slow
def test_train_step_kernel_matches_fallback():
    """One kernel-mode round ≈ one fallback round (same data, same math up
    to reduction order): losses agree to f32 tolerance."""
    from repro.configs import registry
    from repro.core import fedopt_step as F
    from repro.launch.mesh import make_debug_mesh
    a = registry.smoke_config("smollm-135m")
    losses = {}
    for uk in (False, True):
        cfg = F.FedStepConfig(arch=a, l_split=1, n_groups=2, seq_len=16,
                              per_group_batch=4, H=2, use_kernel=uk)
        mesh = make_debug_mesh(1, 1)
        jitted, _, s_spec, _ = F.jit_train_step(cfg, mesh)
        state = jax.jit(lambda c=cfg: F.init_train_state(
            jax.random.PRNGKey(0), c), out_shardings=s_spec)()
        batch = F.concrete_train_batch(jax.random.PRNGKey(1), cfg)
        _, m = jitted(state, batch)
        losses[uk] = (float(m["d_loss"]), float(m["s_loss"]))
    np.testing.assert_allclose(losses[False], losses[True], rtol=1e-4)
