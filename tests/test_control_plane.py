"""ControlPlane (Alg. 2-4 host side) ↔ jit'd hybrid step round trip:
identity-plan equivalence, ω-cap invariants, counter-policy fairness,
staleness-derived aggregation weights."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import fedopt_step as F
from repro.core.control_plane import ControlPlane
from repro.launch.mesh import make_debug_mesh


def _setup(omega=1, n_groups=2, H=2, **kw):
    a = registry.smoke_config("smollm-135m")
    cfg = F.FedStepConfig(arch=a, l_split=1, n_groups=n_groups, seq_len=16,
                          per_group_batch=2 * H, H=H, omega=omega, **kw)
    mesh = make_debug_mesh(1, 1)
    jitted, _, s_spec, _ = F.jit_train_step(cfg, mesh, donate=False)
    state = jax.jit(lambda: F.init_train_state(jax.random.PRNGKey(0), cfg),
                    out_shardings=s_spec)()
    return cfg, jitted, state


# ---------------------------------------------------------------------------
# plan → jit round trip
# ---------------------------------------------------------------------------

def test_identity_plan_matches_default_schedule():
    """With every group active and ω=1, the planned schedule IS the
    uncontrolled identity schedule (seed pipeline semantics)."""
    cfg, _, _ = _setup(omega=1, n_groups=2, H=4)
    cp = ControlPlane(2, 1, 4)
    plan = cp.plan_round()
    ident = F.identity_schedule(cfg)
    np.testing.assert_array_equal(plan.read_slot, np.asarray(ident["read_slot"]))
    np.testing.assert_array_equal(plan.write_slot,
                                  np.asarray(ident["write_slot"]))
    np.testing.assert_array_equal(plan.send_mask,
                                  np.asarray(ident["send_mask"]))
    np.testing.assert_array_equal(plan.agg_weight, np.ones(2, np.float32))


def test_roundtrip_bitforbit_vs_seed_path():
    """The jit'd step driven by ControlPlane-planned batches reproduces the
    uncontrolled (identity-schedule, uniform-weight) losses bit-for-bit
    when ω=1 and all groups are active."""
    cfg, step, state_a = _setup(omega=1, n_groups=2, H=2)
    state_b = jax.tree.map(jnp.copy, state_a)
    cp = ControlPlane(cfg.n_groups, cfg.omega, cfg.H)
    for r in range(3):
        batch = F.concrete_train_batch(jax.random.PRNGKey(r), cfg)
        planned = dict(batch)
        planned.update(cp.plan_round().batch_fields())
        state_a, ma = step(state_a, batch)        # identity default
        state_b, mb = step(state_b, planned)      # control-plane derived
        cp.finish_round()
        assert float(ma["d_loss"]) == float(mb["d_loss"])
        assert float(ma["s_loss"]) == float(mb["s_loss"])
    for la, lb in zip(jax.tree.leaves(state_a), jax.tree.leaves(state_b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_roundtrip_deep_ring_trains():
    """ω=4: the step consumes a genuinely multi-slot schedule (reads lag
    writes by the ring depth) and stays finite; the cap invariant holds."""
    cfg, step, state = _setup(omega=4, n_groups=2, H=4)
    cp = ControlPlane(cfg.n_groups, cfg.omega, cfg.H)
    for r in range(3):
        plan = cp.plan_round()
        assert cp.within_cap
        batch = F.concrete_train_batch(jax.random.PRNGKey(r), cfg)
        batch.update(plan.batch_fields())
        state, m = step(state, batch)
        cp.finish_round()
        assert np.isfinite(float(m["d_loss"]))
        assert np.isfinite(float(m["s_loss"]))
    assert cp.peak_live_slots <= cfg.omega
    assert int(state["version"]) == 3


def test_straggler_agg_weights_reweight_on_mesh():
    """A group inactive for r rounds returns with α=1/(r+1): the jit'd step
    consumes the staleness-derived weight (not placeholder ones)."""
    cfg, step, state = _setup(omega=1, n_groups=2, H=2)
    cp = ControlPlane(cfg.n_groups, cfg.omega, cfg.H)
    profiles = [np.array([True, True]), np.array([True, False]),
                np.array([True, False]), np.array([True, True])]
    for r, active in enumerate(profiles):
        plan = cp.plan_round(active=active)
        batch = F.concrete_train_batch(jax.random.PRNGKey(r), cfg)
        batch.update(plan.batch_fields())
        state, m = step(state, batch)
        cp.finish_round(active=active)
        assert np.isfinite(float(m["d_loss"]))
    # round 3: group 1 was absent rounds 1-2 -> staleness 2 -> α = 1/3
    np.testing.assert_allclose(plan.agg_weight, [1.0, 1.0 / 3.0], rtol=1e-6)


# ---------------------------------------------------------------------------
# host-side invariants: ω cap + fairness
# ---------------------------------------------------------------------------

def _drive(policy, rounds=40, G=2, omega=2, H=8):
    """Straggler workload with a slow server: group 0 offers every
    micro-iteration, group 1 every 4th; the server consumes on alternate
    iterations, so a backlog forms and the scheduling policy matters."""
    cp = ControlPlane(G, omega, H, policy=policy)
    produce = np.zeros((H, G), bool)
    produce[:, 0] = True
    produce[::4, 1] = True
    reads = np.arange(H) % 2 == 0
    sent = np.zeros(G, int)
    for _ in range(rounds):
        plan = cp.plan_round(produce=produce, reads=reads)
        assert cp.within_cap
        assert cp.live_slots <= omega
        sent += plan.send_mask.sum(axis=0).astype(int)
    return cp, sent


def test_straggler_consumption_bounded_by_counter_policy():
    cp, sent = _drive("counter")
    consumed = cp.consumption
    total = sum(consumed.values())
    assert total > 0
    # the fast group's server share never exceeds what it shipped, and the
    # slow group's contributions are all eventually consumed (no backlog
    # starvation: at most ω slots of it can still be in flight)
    assert consumed[0] <= sent[0]
    assert consumed[1] >= sent[1] - cp.omega
    # fairness: under the counter policy the slow group's share is at least
    # its send share (the policy prefers underserved groups)
    assert consumed[1] / total >= sent[1] / sent.sum() - 1e-9


def test_counter_policy_serves_slow_group_at_least_as_much_as_fifo():
    cp_c, _ = _drive("counter")
    cp_f, _ = _drive("fifo")
    assert cp_c.consumption[1] >= cp_f.consumption.get(1, 0)


def test_full_ring_gates_sends():
    """With the server never reading, at most ω slots' worth of sends are
    granted, then send masks go to zero (Eq. 3 as a strict invariant)."""
    G, omega, H = 2, 2, 8
    cp = ControlPlane(G, omega, H)
    plan = cp.plan_round(reads=np.zeros(H, bool))
    granted_iters = (plan.send_mask.sum(axis=1) > 0).sum()
    assert granted_iters == omega          # one slot per micro-iteration
    assert plan.send_mask[omega:].sum() == 0
    assert cp.live_slots == omega and cp.within_cap
    # next round: still nothing consumed, nothing more may ship
    plan2 = cp.plan_round(reads=np.zeros(H, bool))
    assert plan2.send_mask.sum() == 0


def test_all_rejected_round_keeps_params():
    """All-zero agg weights (every update too stale) must keep the current
    params on-mesh — Alg. 4's skip — not zero the model."""
    cfg, step, state = _setup(omega=1, n_groups=2, H=2)
    batch = F.concrete_train_batch(jax.random.PRNGKey(0), cfg)
    batch["agg_weight"] = jnp.zeros(2, jnp.float32)
    state, m = step(state, batch)
    assert np.isfinite(float(m["d_loss"]))
    leaves = [np.asarray(l) for l in jax.tree.leaves(state["dev"])]
    # params not zeroed, and the groups stayed diverged (a weighted-mean
    # broadcast — even of zeros — would have made them identical)
    assert all(np.all(np.isfinite(l)) for l in leaves)
    assert any(np.abs(l).max() > 0 for l in leaves)
    assert any(np.abs(l[0] - l[1]).max() > 1e-7 for l in leaves)


def test_state_dict_roundtrip_preserves_plan():
    """Checkpoint/resume: a restored ControlPlane plans identically to the
    original (slot occupancy, queue order, flow tokens, counters and
    staleness all survive), under both scheduling policies."""
    import json
    produce = np.array([[True, True, False], [True, False, True],
                        [True, True, True], [False, True, False]])
    reads = np.array([True, False, True, False])
    for policy in ("counter", "fifo"):
        cp = ControlPlane(3, 2, 4, policy=policy)
        for _ in range(3):
            cp.plan_round(produce=produce, reads=reads)
            cp.finish_round(active=np.array([True, False, True]))
        sd = cp.state_dict()
        json.dumps(sd)                             # checkpoint-metadata safe
        cp2 = ControlPlane(3, 2, 4, policy=policy)
        cp2.load_state_dict(sd)
        assert cp2.within_cap
        for _ in range(3):                         # stays in lockstep
            p1 = cp.plan_round(produce=produce, reads=reads)
            p2 = cp2.plan_round(produce=produce, reads=reads)
            np.testing.assert_array_equal(p1.read_slot, p2.read_slot)
            np.testing.assert_array_equal(p1.write_slot, p2.write_slot)
            np.testing.assert_array_equal(p1.send_mask, p2.send_mask)
            np.testing.assert_array_equal(p1.agg_weight, p2.agg_weight)
            assert cp.consumption == cp2.consumption


def test_state_dict_roundtrip_partial_ring_with_dropped_groups():
    """Checkpoint/resume with an ω>1 ring only PARTIALLY occupied and
    dropped groups present: ring occupancy, the dropped-roster
    (prev_active) and the retention store (metadata + arrays) all survive,
    and the restored plane plans the rejoin identically — same restore
    list, same staleness weights."""
    import json

    G, omega, H = 3, 2, 4
    # group 0 produces every iteration, group 1 sparsely; the server reads
    # on alternate iterations -> a backlog leaves the ring partially live
    produce = np.zeros((H, G), bool)
    produce[:, 0] = True
    produce[::4, 1] = True
    reads = np.arange(H) % 2 == 0
    active = np.array([True, True, False])      # group 2 dropped

    cp = ControlPlane(G, omega, H)
    plans = [cp.plan_round(active=active, produce=produce, reads=reads)]
    assert plans[0].retire == (2,)
    cp.retain_group(2, {"dev": {"w": np.arange(4.0)},
                        "aux": {"b": np.full(2, 7.0)}})
    cp.finish_round(active=active)
    for _ in range(2):
        plans.append(cp.plan_round(active=active, produce=produce,
                                   reads=reads))
        cp.finish_round(active=active)
    assert 0 < cp.live_slots <= omega           # partially occupied ring

    sd = cp.state_dict()
    json.dumps(sd)                              # metadata-safe
    cp2 = ControlPlane(G, omega, H)
    cp2.load_state_dict(sd)
    cp2.retention.load_arrays(cp.retention.arrays())
    assert cp2.within_cap
    assert cp2.live_slots == cp.live_slots
    np.testing.assert_array_equal(cp2.prev_active, cp.prev_active)
    assert cp2.retention.groups == [2]
    assert cp2.retention.version_of(2) == cp.retention.version_of(2)
    np.testing.assert_array_equal(cp2.retention.params_of(2)["dev"]["w"],
                                  cp.retention.params_of(2)["dev"]["w"])
    np.testing.assert_array_equal(cp2.retention.params_of(2)["aux"]["b"],
                                  cp.retention.params_of(2)["aux"]["b"])

    # lockstep from the snapshot, through the rejoin round
    rosters = [np.array([True, True, False]), np.ones(G, bool),
               np.ones(G, bool)]
    for roster in rosters:
        p1 = cp.plan_round(active=roster, produce=produce, reads=reads)
        p2 = cp2.plan_round(active=roster, produce=produce, reads=reads)
        np.testing.assert_array_equal(p1.read_slot, p2.read_slot)
        np.testing.assert_array_equal(p1.write_slot, p2.write_slot)
        np.testing.assert_array_equal(p1.send_mask, p2.send_mask)
        np.testing.assert_array_equal(p1.agg_weight, p2.agg_weight)
        np.testing.assert_array_equal(p1.bcast_mask, p2.bcast_mask)
        assert p1.retire == p2.retire and p1.restore == p2.restore
        cp.finish_round(active=roster)
        cp2.finish_round(active=roster)
    assert p1.restore == ()                    # no transition in final round
    assert cp.consumption == cp2.consumption


def test_load_state_dict_rejects_policy_mismatch():
    import pytest
    cp = ControlPlane(2, 2, 4, policy="counter")
    cp.plan_round()
    sd = cp.state_dict()
    cp2 = ControlPlane(2, 2, 4, policy="fifo")
    with pytest.raises(ValueError, match="policy"):
        cp2.load_state_dict(sd)


def test_sim_rejects_mismatched_control_omega():
    import pytest
    from repro.core.simulation import SimModel, SimCluster, simulate_fedoptima
    model = SimModel(dev_fwd_flops=1e9, dev_bwd_flops=2e9, full_fwd_flops=4e9,
                     srv_flops_per_batch=6e9, act_bytes=1e6,
                     dev_model_bytes=1e6, full_model_bytes=4e6, batch_size=32)
    cluster = SimCluster(dev_flops=np.full(4, 5e9), dev_bw=np.full(4, 1e7),
                         srv_flops=1e11)
    with pytest.raises(ValueError, match="disagrees"):
        simulate_fedoptima(model, cluster, duration=10.0, omega=4,
                           control=ControlPlane.for_sim(4, 8))


def test_staleness_cap_rejects_then_readmits():
    cp = ControlPlane(2, 1, 2, max_delay=3)
    active = np.array([True, False])
    for _ in range(6):                     # group 1 absent 6 rounds > D=3
        cp.plan_round(active=active)
        cp.finish_round(active=active)
    w = cp.agg_weights(np.array([True, True]))
    assert w[1] == 0.0                     # too stale: Alg. 4 line 13 skip
    cp.finish_round(np.array([True, True]))
    assert cp.n_rejected >= 1
    # after the rejected round the group restarts fresh (Alg. 4 line 20)
    np.testing.assert_allclose(cp.agg_weights(np.array([True, True])),
                               [1.0, 1.0])


def test_prefetch_lookahead_is_plan_neutral():
    """Two identically-seeded planes, one planning with lookahead=0 and
    one with lookahead=4, must emit bit-identical plans forever — the
    ``prefetch`` field stages decodes, it never changes a decision."""
    def occupied(pool=3):
        cp = ControlPlane(4, 2, 2, pool_cap=pool)
        for _ in range(2 + pool):          # stall reads -> occupy the pool
            cp.plan_round(reads=np.zeros(2, bool))
            cp.finish_round()
        assert cp.pool_live == pool
        return cp

    a, b = occupied(), occupied()
    quiet = np.zeros((2, 4), bool)
    for r in range(8):
        reads = np.ones(2, bool) if r % 2 else np.zeros(2, bool)
        pa = a.plan_round(produce=quiet, reads=reads, lookahead=0)
        pb = b.plan_round(produce=quiet, reads=reads, lookahead=4)
        np.testing.assert_array_equal(pa.read_slot, pb.read_slot)
        np.testing.assert_array_equal(pa.send_mask, pb.send_mask)
        np.testing.assert_array_equal(pa.write_slot, pb.write_slot)
        np.testing.assert_array_equal(pa.agg_weight, pb.agg_weight)
        assert pa.fill == pb.fill and pa.spill == pb.spill
        assert pa.retire == pb.retire and pa.restore == pb.restore
        # the hint itself: no lookahead -> empty; lookahead -> a ranked
        # subset of the post-round pool, capped at the horizon
        assert pa.prefetch == ()
        assert len(pb.prefetch) <= 4
        assert set(pb.prefetch) <= set(b.pool_occupancy)
        a.finish_round()
        b.finish_round()
    assert a.n_fills == b.n_fills > 0
