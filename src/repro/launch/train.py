"""Training driver: FedOptima end-to-end.

Two modes:

``--mode pod``   — the datacenter hybrid step (core/fedopt_step) on a local
                   mesh: every FL device group trains its device-side block
                   on its own non-IID synthetic shard; the server block
                   trains centrally on the activation stream.  Rounds are
                   driven by the pipelined RoundExecutor (core/executor):
                   host planning + batch assembly for round r+1 overlap
                   round r's device execution (--window in-flight rounds;
                   --window 1 is the synchronous loop bit-for-bit), each
                   round is planned by the host ControlPlane
                   (core/control_plane) — the ω-deep activation ring
                   schedule (--omega), flow-control send masks, straggler
                   produce/reads patterns (relative speeds seeded via
                   ``args.profiles``, absolute scale from measured round
                   walls; uniform default ≡ placeholder patterns), and
                   staleness-derived aggregation weights all come from
                   real Alg. 2-4 state.
                   Supports checkpoint/restart (atomic store, retention
                   extras included) and elastic group dropout (--p-drop):
                   dropped groups are retained host-side and rejoin from
                   their OWN params at their recorded staleness (the
                   aggregation broadcast is masked — no resync-everyone).
                   Any ``--arch`` runs at its smoke reduction (--full uses
                   the real config; CPU-feasible only for the smallest
                   archs).

``--mode sim``   — the paper's lab-testbed experiment: the event-driven
                   cluster simulator drives real JAX training in event
                   order (Alg. 1-4), reproducing idle-time/throughput/
                   accuracy behaviour of §6.

Examples::

    python -m repro.launch.train --mode pod --arch smollm-135m --rounds 20
    python -m repro.launch.train --mode sim --devices 8 --duration 600
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs import registry
from repro.core import fedopt_step as F
from repro.core.control_plane import ControlPlane
from repro.core.executor import RoundExecutor, StragglerProfiles
from repro.data.partitioner import dirichlet_partition
from repro.data.synthetic import lm_dataset
from repro.faults import (POD_CLASSES, SIM_CLASSES, FaultSchedule,
                          InjectedCrash, PodFaultInjector, UpdateGate,
                          make_fault_schedule)
from repro.fleet import (FleetTrace, SelectionContext, balance_summary,
                         make_selection_policy, make_trace, sample_cluster)
from repro.launch.mesh import make_debug_mesh, n_groups_of
from repro.memory import ActivationStore
from repro.obs.metrics import MetricsRegistry
from repro.runtime.elastic import ElasticRegistry


def _fleet_trace(args, K: int, horizon: float, interval: float,
                 bw=None) -> FleetTrace | None:
    """Resolve --fleet-trace: a JSON artifact path, or a generator kind
    (diurnal | weibull | flaky | uniform) seeded by --seed with scenario
    scales derived from the run horizon.  ``bw`` (scalar or per-device
    array, e.g. a tier-sampled cluster's dev_bw) sets the generated
    trace's base bandwidths so --fleet-tiers heterogeneity survives."""
    spec = getattr(args, "fleet_trace", None)
    if spec is None:
        return None
    if spec.endswith(".json") or os.path.exists(spec):
        trace = FleetTrace.load(spec)
        if trace.K != K:
            raise ValueError(f"--fleet-trace describes {trace.K} devices, "
                             f"this run has {K}")
        return trace
    kw = {}
    if spec == "diurnal":
        kw = dict(day=horizon / 2.0, on_frac=0.6)   # two "days" per run
    elif spec == "weibull":
        kw = dict(on_scale=horizon / 4.0, off_scale=horizon / 8.0)
    if bw is not None and spec != "flaky":   # flaky re-draws bw per tick
        kw["bw"] = bw
    return make_trace(spec, K, horizon, interval=interval,
                      seed=args.seed, **kw)


def _fault_schedule(args, K: int, horizon: float,
                    classes) -> FaultSchedule | None:
    """Resolve --faults: a JSON artifact path (fault-schedule-v1), or
    ``random[:density]`` — a seeded schedule over the mode's supported
    fault classes (sim: time axis seconds; pod: time axis round index)."""
    spec = getattr(args, "faults", None)
    if spec is None:
        return None
    if spec.endswith(".json") or os.path.exists(spec):
        return FaultSchedule.load(spec)
    kind, _, dens = spec.partition(":")
    if kind != "random":
        raise ValueError(f"unknown --faults spec {spec!r}: expected a "
                         "schedule JSON path or 'random[:density]'")
    return make_fault_schedule(K, horizon, seed=args.seed, classes=classes,
                               density=float(dens) if dens else 1.0)


# ---------------------------------------------------------------------------
# pod mode
# ---------------------------------------------------------------------------

def _group_streams(cfg: F.FedStepConfig, seed: int = 0):
    """Per-group non-IID token streams (distinct synthetic grammars)."""
    streams = []
    for g in range(cfg.n_groups):
        toks = lm_dataset(200_000, cfg.arch.vocab, seed=seed + g,
                          structure=0.75 + 0.2 * (g % 3) / 2)
        streams.append(toks)
    return streams


def _make_batch(cfg: F.FedStepConfig, streams, rng: np.random.Generator,
                plan, put=None):
    """One round's inputs: per-group token shards + the ControlPlane's
    schedule/weight fields (ring slots, send masks, staleness weights).

    ``put`` (the jit step's batch sharding dict) pre-stages the host
    arrays with one ``jax.device_put`` — the H2D transfers start
    immediately and overlap the in-flight rounds instead of riding the
    dispatch.  Values are bit-identical to the lazy ``jnp.asarray``
    default; only when the copy happens changes."""
    G, H, b, S = cfg.n_groups, cfg.H, cfg.micro_batch, cfg.seq_len
    tokens = np.zeros((G, H, b, S), np.int32)
    labels = np.zeros((G, H, b, S), np.int32)
    for g in range(G):
        n = len(streams[g]) - S - 1
        idx = rng.integers(0, n, size=(H, b))
        for h in range(H):
            for i in range(b):
                j = idx[h, i]
                tokens[g, h, i] = streams[g][j:j + S]
                labels[g, h, i] = streams[g][j + 1:j + S + 1]
    batch = {"tokens": tokens, "labels": labels}
    batch.update(plan.batch_fields())
    arch = cfg.arch
    if arch.frontend_len:
        batch["frontend"] = np.zeros(
            (G, H, b, arch.frontend_len, arch.d_model),
            np.dtype(cfg.param_dtype))
    if put is not None:
        return jax.device_put(batch, {k: put[k] for k in batch})
    return {k: jnp.asarray(v) for k, v in batch.items()}


def _pipeline_window(args) -> int:
    """Resolve the pipeline window with explicit validation: an unset
    attribute (programmatic bare Namespace) defaults to 2; anything set
    must be an int >= 1 — ``--window 0`` is an error, not a silent remap
    to the default (``or 2`` used to swallow it)."""
    w = getattr(args, "window", None)
    if w is None:
        return 2
    w = int(w)
    if w < 1:
        raise ValueError(
            f"--window must be >= 1, got {w}: 1 is the synchronous loop, "
            ">= 2 keeps that many rounds in flight")
    return w


def run_pod(args) -> dict:
    arch = registry.smoke_config(args.arch) if not args.full \
        else registry.get(args.arch)
    mesh = make_debug_mesh(args.mesh_data, args.mesh_model)
    G = n_groups_of(mesh) * args.groups_per_shard
    # control-plane knobs default for programmatic callers' bare Namespaces
    omega = getattr(args, "omega", None) or 1
    window = _pipeline_window(args)
    H = getattr(args, "H", None) or 4
    # tiered-store knobs (pod default: spill disabled — bit-for-bit the
    # hard-ω ring; raise --pool-cap to admit past the ring)
    pool_cap = getattr(args, "pool_cap", None)
    pool_cap = 0 if pool_cap is None else pool_cap
    spill_quant = bool(getattr(args, "spill_quant", False))
    eviction = getattr(args, "eviction", None) or "share"
    cfg = F.FedStepConfig(
        arch=arch, l_split=args.l_split or F.default_l_split(arch),
        n_groups=G, seq_len=args.seq_len, per_group_batch=args.batch,
        H=H, lr_d=args.lr_d, lr_s=args.lr_s,
        server_opt=args.server_opt, omega=omega,
        use_kernel=getattr(args, "use_kernel", False))
    jitted, _, s_spec, b_spec = F.jit_train_step(cfg, mesh, donate=True)
    cplane = ControlPlane(G, omega, cfg.H,
                          policy=getattr(args, "policy", "counter"),
                          max_delay=getattr(args, "max_delay", 16),
                          pool_cap=pool_cap, eviction=eviction)
    # one registry backs the executor, spill store, and fault gate — the
    # per-round dump and final snapshot see every component's instruments
    reg = MetricsRegistry()
    act_store = ActivationStore(pool_cap, quant=spill_quant, metrics=reg)

    # chaos plane (pod axis: round index) — built before resume so a
    # restarted run replays the SAME schedule, minus already-fired crashes
    faults_sched = _fault_schedule(args, G, float(max(args.rounds, 1)),
                                   POD_CLASSES)
    injector, fired_path = None, None
    if faults_sched is not None:
        needs_store = any(e.cls in ("server_crash", "torn_checkpoint")
                          for e in faults_sched.events)
        if needs_store and not args.ckpt_dir:
            raise ValueError(
                "--faults schedules server_crash/torn_checkpoint events: "
                "--ckpt-dir is required so fired crash boundaries persist "
                "across restarts and recovery has a store to resume from")
        fired = ()
        if args.ckpt_dir:
            # a crash can fire before the first snapshot creates the dir
            os.makedirs(args.ckpt_dir, exist_ok=True)
            fired_path = os.path.join(args.ckpt_dir, "FAULTS_FIRED.json")
            if os.path.exists(fired_path):
                with open(fired_path) as f:
                    fired = tuple(json.load(f))
        injector = PodFaultInjector(faults_sched,
                                    gate=UpdateGate(metrics=reg),
                                    fired_crashes=fired)

    like = jax.eval_shape(lambda: F.init_train_state(
        jax.random.PRNGKey(args.seed), cfg))
    start_round = 0
    resumed_meta = None
    verified_step = None
    if args.ckpt_dir:
        verified_step, skipped = store.latest_verified_step(args.ckpt_dir)
        for bad_step, reason in skipped:
            print(f"resume: skipping torn snapshot step {bad_step}: "
                  f"{reason}")
    if verified_step is not None:
        start_round = verified_step
        state = store.restore(args.ckpt_dir, start_round, like)
        if "act_buf" in state:
            ring = jax.tree.leaves(state["act_buf"])[0].shape[0]
            if ring != omega:
                raise ValueError(
                    f"checkpoint has an ω={ring} activation ring but "
                    f"--omega={omega}; out-of-range slot indices would be "
                    f"silently clamped — restart with --omega {ring}")
        meta = store.restore_metadata(args.ckpt_dir, start_round)
        if "control_plane" in meta:
            # restore the host plan with the ring it describes, or slot
            # occupancy and staleness history silently reset on resume
            cplane.load_state_dict(meta["control_plane"])
            slice_like = {
                k: jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                    like[k]) for k in ("dev", "aux")}
            if "spill_store" in meta:
                # v3 layout: extras.npz is namespaced {"retention", "spill"}
                # — spilled ring slots ride the snapshot next to the
                # retained per-group params
                act_store.load_meta(meta["spill_store"])
                if sorted(cplane.pool_occupancy) != act_store.keys:
                    raise ValueError(
                        f"snapshot pool bookkeeping ({sorted(cplane.pool_occupancy)}) "
                        f"disagrees with its spill store ({act_store.keys})")
                like_extras, slot_like = {}, None
                if len(cplane.retention):
                    like_extras["retention"] = {
                        str(g): slice_like
                        for g in cplane.retention.groups}
                if len(act_store):
                    slot_like = jax.tree.map(
                        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                        like["act_buf"])
                    like_extras["spill"] = act_store.like_tree(slot_like)
                if like_extras:
                    ex = store.restore_extras(args.ckpt_dir, start_round,
                                              like_extras)
                    if "retention" in like_extras:
                        cplane.retention.load_arrays(ex["retention"])
                    if "spill" in like_extras:
                        act_store.load_arrays(
                            ex["spill"],
                            dtypes=act_store.slot_dtypes(slot_like))
            elif len(cplane.retention):
                # v2 layout: extras.npz holds the retention tree bare
                cplane.retention.load_arrays(store.restore_extras(
                    args.ckpt_dir, start_round,
                    {str(g): slice_like for g in cplane.retention.groups}))
        state = jax.device_put(state, s_spec)
        resumed_meta = meta
        print(f"resumed from round {start_round}")
    else:
        state = jax.jit(lambda: F.init_train_state(
            jax.random.PRNGKey(args.seed), cfg), out_shardings=s_spec)()

    streams = _group_streams(cfg, seed=args.seed)
    rng = np.random.default_rng(args.seed + start_round)
    if resumed_meta and "rng_state" in resumed_meta:
        # bit-exact continuation: restore the batch RNG mid-stream instead
        # of reseeding (reseeding resumes a DIFFERENT run than the one
        # that crashed — same distribution, different batches)
        rng.bit_generator.state = resumed_meta["rng_state"]

    # Fleet emulation (repro.fleet): --fleet-trace maps one trace tick to
    # one round (the pod roster for round r is trace row r, wrapping past
    # the horizon); --fleet-tiers samples per-group capabilities whose
    # relative speeds seed the straggler profiles; --selection picks the
    # participating cohort from each round's available groups, fed the
    # live Alg. 3 consumption counters + staleness accounting.
    fleet = _fleet_trace(args, G, horizon=float(max(args.rounds, 1)),
                         interval=1.0)
    sel = make_selection_policy(getattr(args, "selection", None),
                                seed=args.seed)
    caps = None
    if getattr(args, "fleet_tiers", None):
        tier_cluster = sample_cluster(G, args.fleet_tiers, seed=args.seed)
        caps = np.asarray(tier_cluster.dev_flops, float)

    registry_ = ElasticRegistry()
    for g in range(G):       # one pod "device" per mesh group
        registry_.join(flops_per_s=float(caps[g]) if caps is not None
                       else 1.0, bandwidth=1.0)
    # Straggler profiles: the lockstep mesh can only measure the round's
    # absolute scale, so RELATIVE group speeds come from the seeds —
    # programmatic callers inject a cost-model-seeded profile via
    # args.profiles (e.g. StragglerProfiles.from_sim_model), and
    # --fleet-tiers seeds one from the sampled capability mix (step time
    # inversely proportional to flops) to activate straggler-aware
    # produce/reads planning; the unseeded default is uniform, whose
    # patterns equal the placeholder defaults (that degeneracy is what
    # keeps homogeneous runs bit-for-bit reproducible).
    profiles = getattr(args, "profiles", None)
    if profiles is None and caps is not None:
        profiles = StragglerProfiles(G, step_s=1.0 / caps)
    if profiles is None:
        profiles = StragglerProfiles(G)
    if resumed_meta and "profiles" in resumed_meta:
        # restore the measured EMAs so the resumed run plans the same
        # produce/reads patterns the crashed run would have
        ps = resumed_meta["profiles"]
        profiles = StragglerProfiles(
            G, beta=ps.get("beta", 0.25), step_s=ps.get("step_s"),
            transfer_s=ps.get("transfer_s"), server_s=ps.get("server_s"))
        profiles.n_obs = int(ps.get("n_obs", 0))
    executor = RoundExecutor(
        jitted, cplane, window=window,
        profiles=profiles,
        gather=F.gather_group_state,
        scatter=lambda st, g, p: F.scatter_group_state(
            st, g, p, state_shardings=s_spec),
        registry=registry_,
        store=act_store,
        gather_slot=F.gather_act_slot,
        scatter_slot=lambda st, s, p: F.scatter_act_slot(
            st, s, p, state_shardings=s_spec),
        faults=injector, metrics=reg)

    if sel is not None and resumed_meta and "selection_rng" in resumed_meta \
            and hasattr(sel, "_rng"):
        sel._rng.bit_generator.state = resumed_meta["selection_rng"]

    def active_fn(r):
        if fleet is not None:
            roster = fleet.roster(r)
        else:
            roster = rng.random(G) >= args.p_drop
            if not roster.any():
                roster[rng.integers(0, G)] = True
        if sel is not None and not sel.trivial and roster.any():
            ctx = SelectionContext(t=float(r),
                                   counters=cplane.scheduler.counters,
                                   staleness=cplane.version - cplane.versions,
                                   capability=caps)
            chosen = sel.select(np.flatnonzero(roster), ctx)
            roster = np.zeros(G, bool)
            roster[np.asarray(chosen, int)] = True
        return roster

    def batch_fn(r, plan):
        return _make_batch(cfg, streams, rng, plan, put=b_spec)

    t0 = time.time()
    metrics_every = int(getattr(args, "metrics_every", 0) or 0)

    def on_metrics(r, m, st):
        nonlocal t0
        if (r + 1) % args.log_every == 0:
            tok_s = cfg.global_batch * cfg.seq_len * args.log_every / \
                (time.time() - t0)
            n_active = int(np.sum(np.asarray(st.plan.bcast_mask) > 0.5))
            print(f"round {r+1:4d}  d_loss {m['d_loss']:.4f}  "
                  f"s_loss {m['s_loss']:.4f}  active {n_active}/{G}"
                  f"  {tok_s:,.0f} tok/s")
            t0 = time.time()
        if metrics_every and (r + 1) % metrics_every == 0:
            print(executor.metrics.dump_line(prefix=f"[round {r+1}]"))

    def capture_fn(r):
        """Dispatch-time host bookkeeping for round r's checkpoint —
        snapshotted at the SAME boundary as the handle's arrays, so the
        eventual (possibly deferred) save describes exactly round r.
        The extras dicts are built fresh here and the payload pytrees
        they reference are never mutated in place (retention release
        pops; store fill pops), so a later save sees round-r values."""
        # v3 extras layout: retention params and spilled ring slots ride
        # the same atomic snapshot under their own namespaces
        extras = {}
        if cplane.retention.arrays():
            extras["retention"] = cplane.retention.arrays()
        if act_store.arrays():
            extras["spill"] = act_store.arrays()
        metadata = {"round": r + 1, "arch": arch.name,
                    "control_plane": cplane.state_dict(),
                    "spill_store": act_store.meta_dict(),
                    # host-loop continuation state: what a resumed run
                    # needs for bit-exact replay past this snapshot
                    "rng_state": rng.bit_generator.state,
                    "profiles": profiles.summary()}
        if sel is not None and hasattr(sel, "_rng"):
            metadata["selection_rng"] = sel._rng.bit_generator.state
        return {"metadata": metadata, "extras": extras or None}

    def checkpoint_fn(r, handle):
        """Save round r from its RoundHandle: donation-safe host copies
        of the captured arrays + the dispatch-time metadata.  In the
        no-flush path this runs while rounds r+1..r+window are still in
        flight; in the flush path the handle wraps the drained live
        state — the save itself is identical."""
        meta = handle.meta
        store.save(args.ckpt_dir, r + 1, handle.host_tree(),
                   metadata=meta["metadata"], extras=meta["extras"])
        if injector is not None:
            injector.on_checkpoint(r, args.ckpt_dir, r + 1)

    try:
        state, history = executor.run(
            state, start_round, args.rounds,
            active_fn=active_fn, batch_fn=batch_fn, on_metrics=on_metrics,
            checkpoint_every=args.ckpt_every if args.ckpt_dir else 0,
            checkpoint_fn=checkpoint_fn if args.ckpt_dir else None,
            capture_fn=capture_fn if args.ckpt_dir else None,
            checkpoint_flush=bool(getattr(args, "ckpt_flush", False)))
    except InjectedCrash as crash:
        # persist the fired boundary FIRST, then die: the restarted run
        # resumes from the newest verified snapshot and must not re-fire
        if fired_path is not None:
            with open(fired_path, "w") as f:
                json.dump(sorted(injector.fired_crashes), f)
        print(f"faults: {crash} (fired boundaries "
              f"{sorted(injector.fired_crashes)}) — restart to resume")
        raise
    xs = executor.summary()
    print(f"checkpoints: flush_saves={xs['checkpoints']['flush_saves']} "
          f"noflush_saves={xs['checkpoints']['noflush_saves']}  "
          f"handle_bytes_peak={xs['handle_bytes_peak']}")
    mem = {**cplane.memory_summary(), **act_store.summary()}
    print(f"memory: spills {mem['spills']}  fills {mem['fills']}  "
          f"evictions {mem['evictions']}  peak pool "
          f"{mem['peak_pool']}/{pool_cap} slots "
          f"({mem['peak_pool_bytes']/1e6:.1f} MB"
          f"{', int8 spill' if spill_quant else ''})")
    consumed = np.array([cplane.consumption.get(g, 0) for g in range(G)],
                        np.int64)
    bal = balance_summary(consumed)
    print(f"contribution balance: consumed={consumed.tolist()}  "
          f"gini={bal['gini']:.3f}  cv={bal['cv']:.3f}  "
          f"participants={bal['participants']}/{G}")
    if fleet is not None:
        absences = sum(i.absences for i in registry_.devices.values())
        print(f"fleet: trace={fleet.meta.get('kind', 'custom')}  "
              f"roster events={absences}  "
              f"selection={sel.describe() if sel else 'all'}")
    out = {"history": history, "final": history[-1] if history else None,
           "executor": xs, "memory": mem,
           "consumed": consumed.tolist(), "contribution_balance": bal,
           "registry": executor.metrics.snapshot()}
    if metrics_every:
        print(executor.metrics.dump_line(prefix="[final]"))
    if getattr(args, "metrics_out", None):
        executor.metrics.write_jsonl(args.metrics_out,
                                     extra={"mode": "pod",
                                            "rounds": args.rounds})
    if injector is not None:
        fr = injector.report()
        print(f"faults: injected={fr['injected']}  "
              f"recovered={fr['recovered']}  matched={fr['matched']}")
        out["faults"] = fr
    return out


# ---------------------------------------------------------------------------
# sim mode (paper testbed)
# ---------------------------------------------------------------------------

def run_sim(args) -> dict:
    from repro.core.learning import FedOptimaLearner, ModelAdapter
    from repro.core.simulation import (SimModel, heterogeneous_cluster,
                                       simulate_fedoptima)
    from repro.data.pipeline import DeviceDataset
    from repro.data.synthetic import classification_dataset
    from repro.models import cnn

    # sim-mode control-plane knobs: honor the CLI flags (the paper's lab
    # defaults ω=8, H=10 apply only when the flags are left unset)
    omega = getattr(args, "omega", None) or 8
    H = getattr(args, "H", None) or 10
    policy = getattr(args, "policy", "counter")
    max_delay = getattr(args, "max_delay", 16)
    # sim default pool = ω: the lab testbed showcases the tiered budget
    # (2ω admission), versus the pod default of 0 (spill off)
    pool_cap = getattr(args, "pool_cap", None)
    pool_cap = omega if pool_cap is None else pool_cap

    data = classification_dataset(4096, 10, img_size=16, seed=args.seed)
    parts = dirichlet_partition(data.y, args.devices, alpha=0.5,
                                seed=args.seed)
    mcfg = cnn.vgg5_config(n_classes=10, img_size=16)
    adapter = ModelAdapter(cnn, mcfg)
    datasets = [DeviceDataset(data.x[ix], data.y[ix], batch=32, seed=g)
                for g, ix in enumerate(parts)]
    learner = FedOptimaLearner(adapter, datasets, l_split=1,
                               lr_d=0.05, lr_s=0.05)
    sim_model = SimModel(dev_fwd_flops=2e9, dev_bwd_flops=4e9,
                         full_fwd_flops=6e9, srv_flops_per_batch=1.2e10,
                         act_bytes=2e6, dev_model_bytes=1e6,
                         full_model_bytes=4e6, batch_size=32)
    # fleet emulation: --fleet-tiers samples the cluster from a weighted
    # capability mix (default: the paper's 4 uniform speed groups), and
    # --fleet-trace/--selection drive availability + cohort choice
    if getattr(args, "fleet_tiers", None):
        cluster = sample_cluster(args.devices, args.fleet_tiers,
                                 seed=args.seed)
    else:
        cluster = heterogeneous_cluster(args.devices)
    fleet = _fleet_trace(args, args.devices, args.duration,
                         interval=max(args.duration / 12.0, 1.0),
                         bw=cluster.dev_bw)
    control = ControlPlane.for_sim(args.devices, omega, policy=policy,
                                   max_delay=max_delay, pool_cap=pool_cap)
    profiles = StragglerProfiles(args.devices)
    faults_sched = _fault_schedule(args, args.devices, args.duration,
                                   SIM_CLASSES)
    metrics = simulate_fedoptima(sim_model, cluster, duration=args.duration,
                                 omega=omega, H=H, policy=policy,
                                 max_delay=max_delay, pool_cap=pool_cap,
                                 seed=args.seed, fleet=fleet,
                                 selection=getattr(args, "selection", None),
                                 hooks=learner, control=control,
                                 profiles=profiles, faults=faults_sched,
                                 metrics_every=float(
                                     getattr(args, "metrics_every", 0) or 0))
    xte, yte = data.x[:512], data.y[:512]
    acc = learner.eval_accuracy(xte, yte)
    # the measured per-device profiles drive a straggler-aware plan: slow
    # devices are scheduled fewer emissions per round, the server reads at
    # its measured cadence — the same patterns run_pod feeds per round
    produce, reads = profiles.produce(H), profiles.reads(H)
    print(f"sim: {args.devices} devices, {args.duration}s simulated | "
          f"srv idle {metrics.srv_idle_frac:.1%}  dev idle "
          f"{metrics.dev_idle_frac:.1%}  throughput {metrics.throughput:.0f} "
          f"samples/s  train-set acc {acc:.3f}")
    print(f"measured straggler profile: emissions/round "
          f"{produce.sum(axis=0).tolist()} of H={H}, server reads "
          f"{int(reads.sum())}/{H}")
    mem = control.memory_summary()
    print(f"memory: tiered budget ω={omega}+pool={pool_cap}, peak buffered "
          f"{mem['peak_buffered']} batches, spills {mem['spills']}  "
          f"fills {mem['fills']}")
    bal = metrics.contribution_balance()
    print(f"contribution balance: consumed={metrics.dev_consumed.tolist()}  "
          f"gini={bal['gini']:.3f}  cv={bal['cv']:.3f}  "
          f"participants={bal['participants']}/{args.devices}")
    steady = metrics.steady_summary()
    if steady:
        print(f"steady state (post-warmup {steady['warmup_s']:.1f}s): "
              f"srv idle {steady['srv_idle_frac_steady']:.1%}  dev idle "
              f"{steady['dev_idle_frac_steady']:.1%}  throughput "
              f"{steady['throughput_steady']:.0f} samples/s")
    if metrics.registry is not None:
        absences = sum(i.absences
                       for i in metrics.registry.devices.values())
        kind = fleet.meta.get("kind", "custom") if fleet is not None \
            else "identity"     # selection-only runs get an identity trace
        print(f"fleet: trace={kind}  roster events={absences}  active now "
              f"{len(metrics.registry.active_ids)}/{args.devices}")
    reg = metrics.to_registry()
    out = {"accuracy": acc, "srv_idle": metrics.srv_idle_frac,
           "dev_idle": metrics.dev_idle_frac,
           "throughput": metrics.throughput,
           "profiles": profiles.summary(),
           "produce_per_round": produce.sum(axis=0).tolist(),
           "reads_per_round": int(reads.sum()),
           "memory": mem,
           "consumed": metrics.dev_consumed.tolist(),
           "contribution_balance": bal,
           "steady": steady, "registry": reg.snapshot()}
    if getattr(args, "metrics_every", 0):
        print(reg.dump_line(prefix="[final]"))
    if getattr(args, "metrics_out", None):
        reg.write_jsonl(args.metrics_out,
                        extra={"mode": "sim", "duration": args.duration,
                               "devices": args.devices})
    if metrics.faults is not None:
        fr = metrics.faults
        print(f"faults: injected={fr['injected']}  "
              f"recovered={fr['recovered']}  matched={fr['matched']}")
        out["faults"] = fr
    return out


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--mode", default="pod", choices=("pod", "sim"))
    p.add_argument("--arch", default="smollm-135m")
    p.add_argument("--full", action="store_true",
                   help="use the full config (not the smoke reduction)")
    p.add_argument("--rounds", type=int, default=10)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--batch", type=int, default=8, dest="batch",
                   help="sequences per group per round")
    p.add_argument("--H", type=int, default=None,
                   help="local iterations per round (pod default 4, "
                        "sim default 10)")
    p.add_argument("--l-split", type=int, default=0)
    p.add_argument("--lr-d", type=float, default=0.05)
    p.add_argument("--lr-s", type=float, default=0.05)
    p.add_argument("--server-opt", default="sgd", choices=("sgd", "adamw"))
    p.add_argument("--omega", type=int, default=None,
                   help="activation cap ω (scheduled batches, Eq. 3; pod "
                        "ring default 1, sim default 8)")
    p.add_argument("--pool-cap", type=int, default=None, dest="pool_cap",
                   help="host spill-pool depth backing the ω ring (tiered "
                        "activation store, repro.memory): admission runs "
                        "against ω + pool_cap.  Pod default 0 (spill off, "
                        "bit-for-bit the hard-ω ring), sim default ω")
    p.add_argument("--spill-quant", action="store_true", dest="spill_quant",
                   help="int8-quantize spilled activation slots (per-tensor"
                        "; labels/tokens stay exact) — pool bytes / ~4 for "
                        "a bounded dequantization error on refill")
    p.add_argument("--eviction", default="share", choices=("share", "lru"),
                   help="spill-victim policy: 'share' protects least-"
                        "consumption-share contributions (scheduler-aware)"
                        ", 'lru' evicts the least-recently-touched slot")
    p.add_argument("--window", type=int, default=2,
                   help="pipelined rounds in flight (pod mode): 1 = "
                        "synchronous host loop, 2 = double-buffered "
                        "planning (host plan/batch-build overlaps device "
                        "execution; metric values are window-invariant)")
    p.add_argument("--policy", default="counter", choices=("counter", "fifo"),
                   help="Task Scheduler consumption policy (Alg. 3)")
    p.add_argument("--max-delay", type=int, default=16,
                   help="staleness cap D for aggregation (Alg. 4)")
    p.add_argument("--use-kernel", action="store_true",
                   help="run attention/SSD through the fused Pallas kernels "
                        "(differentiable; interpret mode on CPU — see "
                        "EXPERIMENTS.md §Perf)")
    p.add_argument("--mesh-data", type=int, default=1)
    p.add_argument("--mesh-model", type=int, default=1)
    p.add_argument("--groups-per-shard", type=int, default=4)
    p.add_argument("--p-drop", type=float, default=0.0)
    p.add_argument("--fleet-trace", default=None, dest="fleet_trace",
                   help="device availability trace (repro.fleet): a JSON "
                        "artifact saved by FleetTrace.save, or a generator "
                        "kind — diurnal | weibull | flaky | uniform — "
                        "seeded by --seed.  Sim mode drives join/leave "
                        "from trace ticks; pod mode maps one tick to one "
                        "round (trace-driven churn exercises per-group "
                        "retention end-to-end, superseding --p-drop)")
    p.add_argument("--fleet-tiers", default=None, dest="fleet_tiers",
                   help="capability-tier mix for the fleet, e.g. "
                        "'low,mid,high,premium' or 'low:3,premium:1' "
                        "(repro.fleet.devices).  Sim mode samples the "
                        "cluster from it; pod mode seeds the straggler "
                        "profiles with the sampled relative speeds")
    p.add_argument("--selection", default=None,
                   help="participant-selection policy: random | refl | "
                        "score, optionally ':fraction' (e.g. refl:0.5 "
                        "runs the most-stale half each tick).  Fed the "
                        "Alg. 3 consumption counters + staleness "
                        "accounting; default: every available device")
    p.add_argument("--faults", default=None,
                   help="chaos plane (repro.faults): a fault-schedule JSON "
                        "path, or 'random[:density]' — a seeded schedule "
                        "of corrupt uploads, duplicates, delays, device "
                        "timeouts, server crashes and checkpoint tears.  "
                        "Sim mode injects at the event seams (time axis "
                        "seconds); pod mode at round boundaries (crash/"
                        "tear faults need --ckpt-dir; an injected crash "
                        "kills the run — rerun the same command to resume)")
    p.add_argument("--sanitize", action=argparse.BooleanOptionalAction,
                   default=False,
                   help="run under the protocol sanitizer "
                        "(repro.analysis.sanitize): control-plane events "
                        "are checked online against the invariant "
                        "catalogue and any violation aborts the run with "
                        "the offending event window")
    p.add_argument("--trace", default=None, metavar="PATH",
                   help="record a span trace of the run and export Chrome "
                        "trace-event JSON to PATH (open in Perfetto or "
                        "chrome://tracing).  Pod mode traces the host loop "
                        "on the wall clock; sim mode traces per-device/"
                        "server/network lanes in simulated time.  Off = "
                        "zero-instrumentation run (bit-identical)")
    p.add_argument("--metrics-every", type=float, default=0,
                   dest="metrics_every", metavar="N",
                   help="periodically dump the unified metrics registry: "
                        "every N rounds (pod) or every N simulated "
                        "seconds (sim); 0 = final summary only")
    p.add_argument("--metrics-out", default=None, dest="metrics_out",
                   metavar="PATH",
                   help="append the final metrics-registry snapshot to "
                        "PATH as one JSON line")
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=5)
    p.add_argument("--ckpt-flush", action="store_true", dest="ckpt_flush",
                   help="drain the pipeline at every checkpoint boundary "
                        "(the pre-handle saver) instead of the default "
                        "checkpoint-without-flush, which saves round r "
                        "from its dispatch-time handle while rounds "
                        "r+1..r+window stay in flight")
    p.add_argument("--log-every", type=int, default=1)
    p.add_argument("--devices", type=int, default=8)
    p.add_argument("--duration", type=float, default=300.0)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()
    run = run_pod if args.mode == "pod" else run_sim

    def _run_traced():
        if not args.trace:
            run(args)
            return
        from repro.obs.trace import Tracer, traced
        tracer = Tracer(domain="wall" if args.mode == "pod" else "sim")
        with traced(tracer):
            run(args)
        tracer.export_chrome(args.trace)
        print(f"trace: {len(tracer.spans)} spans on "
              f"{len(tracer.lanes())} lanes -> {args.trace}")

    # the sanitizer and tracer seams are independent and compose
    if args.sanitize:
        from repro.analysis.sanitize import sanitized
        with sanitized() as san:
            _run_traced()
        rep = san.report()
        print(f"sanitizer: {rep['events']} events checked, "
              f"{rep['n_violations']} violations")
    else:
        _run_traced()


if __name__ == "__main__":
    main()
