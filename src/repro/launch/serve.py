"""Serving driver: batched prefill + decode with the merged global model.

FedOptima is a *training* system; serving uses the merged (device+server)
model — ``transformer.merge_params`` — behind the standard prefill/decode
steps that the decode/long dry-run cells lower.  This driver demonstrates
batched request serving end-to-end on CPU with a smoke-scale arch::

    python -m repro.launch.serve --arch smollm-135m --batch 4 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import transformer as tfm


def generate(params, arch, prompts, *, new_tokens: int, max_len: int,
             frontend=None, greedy: bool = True, rng=None):
    """prompts: (B, S0) int32.  Returns (B, S0 + new_tokens)."""
    B, S0 = prompts.shape
    logits, caches = jax.jit(
        lambda p, t, f: tfm.prefill(p, arch, t, max_len=max_len, frontend=f)
    )(params, prompts, frontend)

    decode = jax.jit(
        lambda p, c, t, pos: tfm.serve_decode_step(p, arch, c, t, pos))
    out = [prompts]
    token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for i in range(new_tokens):
        out.append(token)
        if i == new_tokens - 1:
            break
        logits, caches = decode(params, caches, token, jnp.int32(S0 + i))
        if greedy:
            token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        else:
            rng, k = jax.random.split(rng)
            token = jax.random.categorical(k, logits)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="smollm-135m")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--new-tokens", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    arch = registry.smoke_config(args.arch)
    rng = jax.random.PRNGKey(args.seed)
    params = tfm.init_params(rng, arch)
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                 arch.vocab, jnp.int32)
    frontend = None
    if arch.frontend_len:
        frontend = jax.random.normal(
            rng, (args.batch, arch.frontend_len, arch.d_model))

    max_len = args.prompt_len + args.new_tokens
    t0 = time.time()
    out = generate(params, arch, prompts, new_tokens=args.new_tokens,
                   max_len=max_len, frontend=frontend)
    out.block_until_ready()
    dt = time.time() - t0
    tok_s = args.batch * args.new_tokens / dt
    assert out.shape == (args.batch, args.prompt_len + args.new_tokens)
    assert bool(jnp.isfinite(out).all())
    print(f"served {args.batch} requests × {args.new_tokens} new tokens "
          f"in {dt:.2f}s ({tok_s:.1f} tok/s, CPU smoke config '{arch.name}')")
    print("first request tokens:", out[0, -args.new_tokens:].tolist())


if __name__ == "__main__":
    main()
