"""Production mesh factories.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before *any* jax
import, and everything else must see the real single CPU device.

Mesh layout (TPU v5e pods):
  single-pod : (data=16, model=16)             = 256 chips
  multi-pod  : (pod=2, data=16, model=16)      = 512 chips

FedOptima mapping: one FL "device group" per (pod, data) index — 16 groups
single-pod, 32 groups multi-pod — each group owning a 16-chip ``model``
(TP) slice; the server-side block is trained centrally across the whole
mesh (DP over pod×data, TP over model).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *, pod: int = 0):
    """Small mesh for CPU smoke tests (requires host-device override)."""
    if pod:
        return jax.make_mesh((pod, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def dp_axes_of(mesh) -> tuple:
    """The data-parallel axes of a mesh: everything except 'model'."""
    return tuple(a for a in mesh.axis_names if a != "model")


def n_groups_of(mesh) -> int:
    """Number of FL device groups hosted on the mesh (= dp size)."""
    out = 1
    for a in dp_axes_of(mesh):
        out *= mesh.shape[a]
    return out
