import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax import (see dryrun.py).

DOC = """Perf hillclimbing driver (EXPERIMENTS.md §Perf).

Compiles named step-config variants of the three chosen (arch × shape)
cells, re-derives the roofline terms per variant, and appends the records
to results/perf/.  Each variant is one hypothesis → change → measure
iteration; the narrative lives in EXPERIMENTS.md.

    python -m repro.launch.perf --cell command-r   # one cell's ladder
    python -m repro.launch.perf                    # all three
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_production_mesh

# (label, overrides) ladders.  Overrides feed FedStepConfig (plus the
# special key arch_kw -> ArchConfig.scaled).  Each ladder starts from the
# paper-faithful baseline and applies ONE change at a time (cumulative).
LADDERS = {
    "command-r": ("command-r-plus-104b", "train_4k", [
        ("0_no_constraints", {"act_sharding": "none"}),
        ("1_tp_sp_constraints", {}),                       # default config
        ("2_server_accum", {"server_accum": True}),        # refuted (no hoist)
        ("3_H4", {"H": 4}),
        ("4_selective_remat", {"remat": "selective"}),
        ("5_selective_H4", {"remat": "selective", "H": 4}),
    ]),
    "jamba": ("jamba-1.5-large-398b", "train_4k", [
        ("2_expert_ep_constraints", {}),                   # new code default
        ("3_selective_remat", {"remat": "selective"}),
        ("4_selective_H4", {"remat": "selective", "H": 4}),
        ("5_sort_dispatch", {"remat": "selective"}),       # sort-based MoE
        ("6_sort_no_ep_pin", {"remat": "selective",
                              "ep_interior": False}),
        ("7_ep_shard_map", {"remat": "selective", "ep_interior": False,
                            "ep_shard_map": True}),
    ]),
    "qwen3-moe": ("qwen3-moe-235b-a22b", "train_4k", [
        ("2_expert_ep_constraints", {}),
        ("3_selective_remat", {"remat": "selective"}),
        ("4_selective_H4", {"remat": "selective", "H": 4}),
        ("5_sort_dispatch", {"remat": "selective"}),       # sort-based MoE
        ("6_sort_no_ep_pin", {"remat": "selective",
                              "ep_interior": False}),
        ("7_ep_shard_map", {"remat": "selective", "ep_interior": False,
                            "ep_shard_map": True}),
    ]),
}


def main() -> None:
    p = argparse.ArgumentParser(description=DOC)
    p.add_argument("--cell", default=None, choices=list(LADDERS) + [None])
    p.add_argument("--out", default="results/perf")
    p.add_argument("--mesh", default="single", choices=("single", "multi"))
    p.add_argument("--only", default=None,
                   help="run a single variant label within the ladder")
    args = p.parse_args()

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    os.makedirs(args.out, exist_ok=True)
    cells = [args.cell] if args.cell else list(LADDERS)
    for cell in cells:
        arch, shape, ladder = LADDERS[cell]
        for label, overrides in ladder:
            if args.only and label != args.only:
                continue
            t0 = time.time()
            rec = run_cell(arch, shape, mesh, step_overrides=dict(overrides),
                           verbose=False)
            rec.update(variant=label, cell=cell, mesh_kind=args.mesh,
                       overrides={k: str(v) for k, v in overrides.items()})
            path = os.path.join(args.out, f"{cell}__{label}.json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            if rec["status"] != "ok":
                print(f"[{cell}/{label}] {rec['status']}: "
                      f"{rec.get('error', '')[:200]}")
                continue
            t = rec["roofline_kernelized"]
            mem = rec["memory_analysis"]["temp_bytes"] / 1e9
            print(f"[{cell}/{label}] compile {rec['compile_s']}s  "
                  f"temp {mem:.1f}GB  compute {t['compute_s']:.2f}s  "
                  f"memory {t['memory_s']:.2f}s  "
                  f"collective {t['collective_s']:.2f}s  "
                  f"dominant={t['dominant']}  mfu={t['mfu_bound']:.3f}",
                  flush=True)


if __name__ == "__main__":
    main()
