import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax import: jax locks the device count on first init.
# (This also means no `from __future__ import annotations` in this module.)

DOC = """Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production mesh and record memory/cost/roofline evidence.

Usage::

    python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    python -m repro.launch.dryrun --all --mesh single --out results/dryrun
    python -m repro.launch.dryrun --all --mesh multi  --out results/dryrun

``--mesh single`` = (data=16, model=16), 256 chips (one pod);
``--mesh multi``  = (pod=2, data=16, model=16), 512 chips.  The multi-pod
pass proves the ``pod`` axis shards; the roofline table reads the
single-pod JSONs.

Per cell this prints (and writes to JSON): compiled.memory_analysis()
(proves it fits), compiled.cost_analysis() (XLA's while-body-once FLOPs/
bytes), and the trip-count-scaled HLO parse (FLOPs, HBM bytes, collective
bytes by kind) that feeds EXPERIMENTS.md §Roofline.
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.analysis import analyze_text, model_flops, roofline
from repro.configs import registry
from repro.core import fedopt_step as F
from repro.launch.mesh import make_production_mesh, n_groups_of


def lower_cell(arch_name: str, shape_name: str, mesh, *,
               step_overrides: dict | None = None):
    """Build + lower + compile one cell.  Returns (lowered, compiled, meta)."""
    arch = registry.get(arch_name)
    shape = registry.SHAPES[shape_name]
    overrides = dict(step_overrides or {})
    arch_kw = overrides.pop("arch_kw", None)
    if arch_kw:
        arch = arch.scaled(**arch_kw)
    meta = {"arch": arch_name, "shape": shape_name,
            "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
            "n_chips": mesh.devices.size}

    if shape.kind == "train":
        G = n_groups_of(mesh)
        per_group = shape.global_batch // G
        H = overrides.pop("H", min(8, per_group))
        cfg = F.FedStepConfig(
            arch=arch, l_split=F.default_l_split(arch), n_groups=G,
            seq_len=shape.seq_len, per_group_batch=per_group, H=H,
            param_dtype=jnp.bfloat16, **overrides)
        jitted, state_sds, _, _ = F.jit_train_step(cfg, mesh)
        lowered = jitted.lower(state_sds, F.train_input_specs(cfg))
        meta.update(kind="train", l_split=cfg.l_split, H=H,
                    global_batch=shape.global_batch, seq_len=shape.seq_len)
        n_tokens = shape.global_batch * shape.seq_len
        meta["model_flops"] = model_flops(arch, n_tokens, kind="train")
    elif shape.kind == "prefill":
        jitted, args = F.jit_prefill(arch, mesh, batch=shape.global_batch,
                                     seq_len=shape.seq_len,
                                     param_dtype=jnp.bfloat16, **overrides)
        lowered = jitted.lower(*args)
        meta.update(kind="prefill", global_batch=shape.global_batch,
                    seq_len=shape.seq_len)
        n_tokens = shape.global_batch * shape.seq_len
        meta["model_flops"] = model_flops(arch, n_tokens, kind="infer")
    else:  # decode
        jitted, args = F.jit_decode(arch, mesh, batch=shape.global_batch,
                                    cache_len=shape.seq_len,
                                    param_dtype=jnp.bfloat16, **overrides)
        lowered = jitted.lower(*args)
        meta.update(kind="decode", global_batch=shape.global_batch,
                    cache_len=shape.seq_len)
        meta["model_flops"] = model_flops(arch, shape.global_batch,
                                          kind="infer")

    t0 = time.time()
    compiled = lowered.compile()
    meta["compile_s"] = round(time.time() - t0, 1)
    return lowered, compiled, meta


def kernel_exclude_fn(arch, shape):
    """Shape predicate for the Pallas-deployed roofline: attention-score
    and SSD decay/score tiles (4-D, kv-length minor dim / square chunk
    dims) stay in VMEM inside the fused kernels and never round-trip HBM.
    The jnp fallback path materialises them — both numbers are reported."""
    S = shape.seq_len
    kv_lens = set()
    for base in {S, arch.frontend_len or 0, arch.window or 0}:
        for div in (1, 2, 4, 8, 16, 32):
            if base and base % div == 0 and base // div >= 256:
                kv_lens.add(base // div)
    Q = arch.ssm_chunk

    def fn(dims):
        if len(dims) != 4:
            return False
        if dims[-1] in kv_lens and dims[-2] >= 64:      # attention scores
            return True
        if arch.ssm_state and dims[1] == dims[2] and \
                dims[1] in (Q, min(Q, S)):              # SSD chunk tiles
            return True
        return False
    return fn


def run_cell(arch_name: str, shape_name: str, mesh, *,
             step_overrides: dict | None = None, verbose: bool = True,
             hlo_out: str = None):
    """Dry-run one cell; returns the result record (JSON-serializable)."""
    skip = registry.skip_reason(arch_name, shape_name)
    if skip:
        return {"arch": arch_name, "shape": shape_name, "status": "skip",
                "reason": skip}
    try:
        lowered, compiled, meta = lower_cell(arch_name, shape_name, mesh,
                                             step_overrides=step_overrides)
    except Exception as e:  # a dry-run failure is a bug in our system
        return {"arch": arch_name, "shape": shape_name, "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:]}
    if hlo_out:
        import gzip
        with gzip.open(hlo_out, "wt") as f:
            f.write(compiled.as_text())

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    mem = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
    }
    text = compiled.as_text()
    arch = registry.get(arch_name)
    shape = registry.SHAPES[shape_name]
    mf = meta.get("model_flops", 0.0)
    cost = analyze_text(text)
    terms = roofline(cost, model_flops_total=mf, n_chips=meta["n_chips"])
    kcost = analyze_text(text, exclude_fn=kernel_exclude_fn(arch, shape))
    kterms = roofline(kcost, model_flops_total=mf, n_chips=meta["n_chips"])
    rec = {"status": "ok", **meta, "memory_analysis": mem,
           "xla_cost_analysis": {"flops": ca.get("flops", 0.0),
                                 "bytes_accessed": ca.get("bytes accessed", 0.0)},
           "roofline": terms.to_dict(),
           "roofline_kernelized": kterms.to_dict()}
    if verbose:
        print(f"[{rec['arch']} × {rec['shape']}] compile {meta['compile_s']}s  "
              f"temp {mem['temp_bytes']/1e9:.2f} GB/dev  "
              f"flops/dev {terms.flops:.3e}  dominant={kterms.dominant}  "
              f"mfu_bound={kterms.mfu:.3f}")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis(XLA, body-once): {rec['xla_cost_analysis']}")
        print(f"  roofline (jnp path, s/chip): compute={terms.compute_s:.4f} "
              f"memory={terms.memory_s:.4f} collective={terms.collective_s:.4f}")
        print(f"  roofline (Pallas-fused, s/chip): compute={kterms.compute_s:.4f} "
              f"memory={kterms.memory_s:.4f} collective={kterms.collective_s:.4f}")
    return rec


def main() -> None:
    p = argparse.ArgumentParser(description=DOC)
    p.add_argument("--arch", default=None, help="architecture id")
    p.add_argument("--shape", default=None,
                   choices=list(registry.SHAPES) + [None])
    p.add_argument("--mesh", default="single", choices=("single", "multi"))
    p.add_argument("--all", action="store_true",
                   help="sweep every (arch × shape) cell")
    p.add_argument("--out", default=None, help="directory for JSON records")
    p.add_argument("--save-hlo", action="store_true",
                   help="also save gzipped optimized HLO per cell (enables "
                        "offline re-analysis without recompiling)")
    args = p.parse_args()

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    cells = ([(args.arch, args.shape)] if not args.all else
             [(a, s) for a in registry.ARCHS for s in registry.SHAPES])

    n_ok = n_skip = n_err = 0
    for arch_name, shape_name in cells:
        hlo_out = None
        if args.save_hlo and args.out:
            os.makedirs(args.out, exist_ok=True)
            hlo_out = os.path.join(
                args.out, f"{arch_name}__{shape_name}__{args.mesh}.hlo.gz")
        rec = run_cell(arch_name, shape_name, mesh, hlo_out=hlo_out)
        rec["mesh_kind"] = args.mesh
        status = rec["status"]
        n_ok += status == "ok"
        n_skip += status == "skip"
        n_err += status == "error"
        if status == "skip":
            print(f"[{arch_name} × {shape_name}] SKIP: {rec['reason']}")
        elif status == "error":
            print(f"[{arch_name} × {shape_name}] ERROR: {rec['error']}")
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            path = os.path.join(
                args.out, f"{arch_name}__{shape_name}__{args.mesh}.json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
    print(f"\ndry-run[{args.mesh}]: {n_ok} ok, {n_skip} skip, {n_err} error "
          f"of {len(cells)} cells")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
