"""Synthetic datasets (offline container: no real CIFAR/TinyImageNet/SST).

Two generators:
  * ``classification_dataset`` — class-conditional Gaussian images whose
    class structure is genuinely learnable, so FL training runs show real
    convergence curves (used for the paper-figure reproductions).
  * ``lm_dataset`` — Zipf-distributed token streams with a deterministic
    next-token structure (a noisy affine map over token ids) so LM loss
    decreases with training.

Everything is seeded and generated with numpy (cheap, no device memory).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class ClassificationData:
    x: np.ndarray      # (N, H, W, C) float32
    y: np.ndarray      # (N,) int32


def classification_dataset(n: int, n_classes: int, img_size: int = 32,
                           channels: int = 3, seed: int = 0,
                           noise: float = 0.8) -> ClassificationData:
    rng = np.random.default_rng(seed)
    # class prototypes with low-frequency spatial structure
    base = rng.normal(size=(n_classes, img_size // 4, img_size // 4, channels))
    protos = base.repeat(4, axis=1).repeat(4, axis=2).astype(np.float32)
    y = rng.integers(0, n_classes, size=n).astype(np.int32)
    x = protos[y] + noise * rng.normal(size=(n, img_size, img_size, channels)).astype(np.float32)
    return ClassificationData(x=x.astype(np.float32), y=y)


def lm_dataset(n_tokens: int, vocab: int, seed: int = 0,
               structure: float = 0.85) -> np.ndarray:
    """Token stream where next = (a*cur + b) % vocab with prob `structure`,
    else uniform — learnable by any LM, with entropy floor for realism."""
    rng = np.random.default_rng(seed)
    a, b = 31, 7
    toks = np.empty(n_tokens, dtype=np.int32)
    toks[0] = rng.integers(0, vocab)
    det = rng.random(n_tokens) < structure
    rnd = rng.integers(0, vocab, size=n_tokens)
    for i in range(1, n_tokens):
        toks[i] = (a * toks[i - 1] + b) % vocab if det[i] else rnd[i]
    return toks


def lm_batches(tokens: np.ndarray, batch: int, seq: int, seed: int = 0):
    """Infinite iterator of (inputs, labels) windows."""
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    while True:
        idx = rng.integers(0, n, size=batch)
        x = np.stack([tokens[i:i + seq] for i in idx])
        y = np.stack([tokens[i + 1:i + seq + 1] for i in idx])
        yield x, y
