from .synthetic import classification_dataset, lm_dataset, lm_batches
from .partitioner import dirichlet_partition, partition_stats
from .pipeline import DeviceDataset
