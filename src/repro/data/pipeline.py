"""Per-device data pipelines: seeded, restartable batch iterators."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class DeviceDataset:
    """A device's local shard with a deterministic, checkpointable cursor."""
    x: np.ndarray
    y: np.ndarray
    batch: int
    seed: int = 0
    _epoch: int = 0
    _pos: int = 0
    _order: np.ndarray | None = None

    def __post_init__(self):
        self._reshuffle()

    def _reshuffle(self):
        rng = np.random.default_rng((self.seed, self._epoch))
        self._order = rng.permutation(len(self.x))
        self._pos = 0

    def next_batch(self):
        if self._pos + self.batch > len(self.x):
            self._epoch += 1
            self._reshuffle()
        ix = self._order[self._pos:self._pos + self.batch]
        self._pos += self.batch
        if len(ix) < self.batch:  # tiny shards: sample with wraparound
            extra = self._order[: self.batch - len(ix)]
            ix = np.concatenate([ix, extra])
        return self.x[ix], self.y[ix]

    # --- checkpointing ---
    def state(self) -> dict:
        return {"epoch": self._epoch, "pos": self._pos}

    def restore(self, state: dict):
        self._epoch = state["epoch"]
        self._reshuffle()
        self._pos = state["pos"]
