"""Non-IID data partitioning across devices (paper §5.2).

"The dataset is split in a non-IID manner across devices using the
Dirichlet distribution with 0.5 prior [31]: each device is assigned a
vector with the size of the number of classes drawn from a Dirichlet
distribution.  For each device, a label is randomly selected based on its
corresponding vector, and a data point with this label is sampled without
replacement, until every data sample is allocated."
"""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_devices: int, alpha: float = 0.5,
                        seed: int = 0) -> list[np.ndarray]:
    """Returns per-device index arrays covering all samples exactly once."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    # per-device class preference vectors
    prefs = rng.dirichlet([alpha] * n_classes, size=n_devices)  # (K, C)
    # pools of indices per class, shuffled
    pools = [list(rng.permutation(np.flatnonzero(labels == c)))
             for c in range(n_classes)]
    remaining = np.array([len(p) for p in pools], dtype=np.float64)
    out: list[list[int]] = [[] for _ in range(n_devices)]
    n_total = len(labels)
    order = rng.permutation(n_total)  # round-robin device order with shuffle
    k = 0
    for _ in range(n_total):
        dev = k % n_devices
        k += 1
        # renormalise preference over classes that still have samples
        w = prefs[dev] * (remaining > 0)
        s = w.sum()
        if s <= 0:
            w = (remaining > 0).astype(np.float64)
            s = w.sum()
        c = rng.choice(n_classes, p=w / s)
        out[dev].append(pools[c].pop())
        remaining[c] -= 1
    return [np.array(sorted(ix), dtype=np.int64) for ix in out]


def partition_stats(labels: np.ndarray, parts: list[np.ndarray]) -> np.ndarray:
    """(K, C) matrix of class counts per device — for tests/diagnostics."""
    n_classes = int(labels.max()) + 1
    return np.stack([np.bincount(labels[ix], minlength=n_classes) for ix in parts])
