"""Sharding rules: map model parameter/activation pytrees to PartitionSpecs.

Scheme (GSPMD annotations; MoE experts additionally use explicit shard_map
expert-parallelism — see models/mlp.moe_apply_grouped):

  * ``data`` axes (pod × data): batch dim of activations; FSDP dim of
    parameters (ZeRO-3 style: the largest non-TP dim of each weight).
  * ``model`` axis: tensor-parallel dim — attention heads (qkv out dim,
    o_proj in dim), MLP hidden (d_ff), MoE expert axis, vocab dim of
    embedding/lm_head, mamba inner channels.

Optimizer state inherits parameter specs (mu/nu shard identically), which
is exactly ZeRO: optimizer memory scales 1/(dp·tp).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
from jax.sharding import PartitionSpec as P

if hasattr(jax, "shard_map"):                       # jax >= 0.6
    def shard_map(f, mesh, in_specs, out_specs):
        """Version-compat shard_map (replication checking off: the MoE
        psum pattern trips the checker on some jax versions)."""
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:                                               # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_experimental

    def shard_map(f, mesh, in_specs, out_specs):
        """Version-compat shard_map (see above)."""
        return _shard_map_experimental(f, mesh=mesh, in_specs=in_specs,
                                       out_specs=out_specs, check_rep=False)


@dataclass(frozen=True)
class Parallelism:
    """Runtime parallelism descriptor threaded through model code."""
    mesh: object                      # jax.sharding.Mesh
    dp_axes: tuple = ("data",)        # axes carrying the batch (may incl. "pod")
    tp_axis: str = "model"
    ep: bool = True                   # expert-parallel MoE via shard_map
    fsdp: bool = True                 # shard params over dp axes too
    # --- activation sharding constraints (Megatron TP/SP layout) ---
    # act_batch: mesh axes carrying the activation batch dim (None when the
    #   dp axes are already consumed, e.g. under vmap over FL device groups).
    # seq_shard: shard the residual-stream sequence dim over ``model``
    #   between blocks (SP) — saved scan carries shard too.
    # interior: constrain per-head / ffn-hidden intermediates over ``model``
    #   so weight gradients stay TP-sharded in backward.
    act_batch: tuple | None = None
    seq_shard: bool = False
    interior: bool = True
    moe_interior: bool = True         # pin expert-major tensors to EP axis
    constraints: bool = False         # master switch

    @property
    def dp_size(self) -> int:
        out = 1
        for a in self.dp_axes:
            out *= self.mesh.shape[a]
        return out

    def _constrain(self, x, spec: P):
        spec = _validate(spec, x.shape, self)
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec))

    def resid(self, h):
        """(B, S, D) residual stream between blocks."""
        if not self.constraints:
            return h
        return self._constrain(
            h, P(self.act_batch, self.tp_axis if self.seq_shard else None,
                 None))

    def ffn_hidden(self, h):
        """(B, S, F) MLP hidden — keeps dW_ffn TP-sharded in backward."""
        if not (self.constraints and self.interior):
            return h
        return self._constrain(h, P(self.act_batch, None, self.tp_axis))

    def heads(self, x):
        """(B, S, H, hd) per-head tensors — keeps dW_qkvo TP-sharded."""
        if not (self.constraints and self.interior):
            return x
        return self._constrain(x, P(self.act_batch, None, self.tp_axis, None))

    def experts(self, x):
        """(E, C, ·) expert-major MoE tensors — keeps expert dW sharded
        over ``model`` (EP) instead of materialising full per-chip
        partials in the backward pass."""
        if not (self.constraints and self.interior and self.moe_interior):
            return x
        return self._constrain(
            x, P(self.tp_axis, *([None] * (x.ndim - 1))))

    # Back-compat alias used by the scan carry constraint
    def constrain(self, h):
        return self.resid(h)

    @property
    def dp_size(self) -> int:
        out = 1
        for a in self.dp_axes:
            out *= self.mesh.shape[a]
        return out


# ---------------------------------------------------------------------------
# Parameter partition specs by leaf path
# ---------------------------------------------------------------------------

def _param_spec(path: str, shape, par: Parallelism) -> P:
    """Assign a PartitionSpec from the leaf's path and rank."""
    tp = par.tp_axis
    dp = tuple(par.dp_axes) if par.fsdp else None
    rank = len(shape)

    def fsdp_or_none(axis_idx, spec_list):
        """Put dp on axis_idx if divisible and fsdp on."""
        if dp is not None:
            spec_list[axis_idx] = dp
        return P(*spec_list)

    # --- embeddings / heads: shard vocab over tp, d_model over dp ---
    if "embed" in path or "lm_head" in path or "head_out" in path:
        if rank == 2:
            v_axis = 0 if shape[0] >= shape[1] else 1
            spec = [None, None]
            spec[v_axis] = tp
            return fsdp_or_none(1 - v_axis, spec)
        return P()
    # --- MoE experts (we_*): E over tp, FSDP over the input dim ---
    if "we_gate" in path or "we_up" in path or "we_down" in path:
        if rank == 4:   # stacked (n_periods, E, din, dout)
            return P(None, tp, dp, None) if dp else P(None, tp, None, None)
        # (E, din, dout)
        return P(tp, dp, None) if dp else P(tp, None, None)
    # --- dense MLP: tp on the hidden (d_ff) dim ---
    if "w_gate" in path or "w_up" in path or "w_down" in path:
        hidden_axis = rank - 1 if "w_down" not in path else rank - 2
        spec = [None] * rank
        spec[hidden_axis] = tp
        other = rank - 2 if hidden_axis == rank - 1 else rank - 1
        return fsdp_or_none(other, spec)
    if "router" in path:
        return P()
    # --- attention projections ---
    if "wq" in path or "wk" in path or "wv" in path:
        spec = [None] * rank
        spec[rank - 1] = tp            # heads dim
        return fsdp_or_none(rank - 2, spec)
    if "wo" in path:
        spec = [None] * rank
        spec[rank - 2] = tp            # heads dim (input)
        return fsdp_or_none(rank - 1, spec)
    # --- mamba ---
    if "in_proj" in path or "out_proj" in path:
        spec = [None] * rank
        inner_axis = rank - 1 if "in_proj" in path else rank - 2
        spec[inner_axis] = tp
        return fsdp_or_none(rank - 1 if inner_axis != rank - 1 else rank - 2, spec)
    if "conv_w" in path or "conv_b" in path or "A_log" in path or "D" in path \
            or "dt_bias" in path:
        return P(*([None] * rank))
    # --- norms, scalars, aux heads ---
    return P(*([None] * rank))


def param_specs(params, par: Parallelism):
    """Pytree of PartitionSpecs matching ``params``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        # divisibility guard: drop axes that don't divide
        spec = _param_spec(key, leaf.shape, par)
        spec = _validate(spec, leaf.shape, par)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _validate(spec: P, shape, par: Parallelism) -> P:
    """Remove spec entries that don't divide the dimension."""
    out = []
    for i, axis in enumerate(spec):
        if axis is None or i >= len(shape):
            out.append(None)
            continue
        if shape[i] % _axis_size(par.mesh, axis) == 0:
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


def batch_spec(par: Parallelism, rank: int = 2) -> P:
    """Activations/batch: leading dim over all dp axes."""
    axes = tuple(par.dp_axes)
    return P(axes, *([None] * (rank - 1)))


def opt_state_specs(opt_state, params_spec):
    """Optimizer state shards like its parameters (ZeRO)."""
    def spec_for(path_key, leaf):
        return P()
    # mu/nu mirror params; scalars replicated
    out = {}
    for k, v in opt_state.items():
        if k in ("mu", "nu", "velocity"):
            out[k] = params_spec
        else:
            out[k] = jax.tree.map(lambda _: P(), v)
    return out
