from .sharding import Parallelism, param_specs, batch_spec, opt_state_specs
