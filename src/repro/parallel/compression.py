"""Int8 gradient compression with error feedback for cross-pod all-reduce.

At 1000+ node scale the cross-pod (DCN) all-reduce of gradients dominates
step time for DP-heavy meshes.  We quantise per-tensor-block to int8 with a
float scale (32x1 blocks), all-reduce the int8 payload (4x fewer bytes),
and keep the quantisation residual locally (error feedback) so the scheme
is unbiased over time (Karimireddy et al., 2019).

Used by the hybrid FedOptima step for the *device-block* gradient sync over
the ``pod`` axis; exact (uncompressed) sync remains the default elsewhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    return jnp.pad(x.reshape(-1), (0, pad)), n


def quantize(x: jnp.ndarray):
    """x -> (int8 codes, per-block float16 scales, orig size)."""
    flat, n = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return codes, scale.astype(jnp.float32), n


def dequantize(codes, scale, n, shape):
    out = (codes.astype(jnp.float32) * scale).reshape(-1)[:n]
    return out.reshape(shape)


def compressed_psum(grads, axis: str, error: dict | None = None):
    """Error-feedback int8 psum over ``axis`` (call inside shard_map).

    grads/error: pytrees.  Returns (averaged grads, new error).  The int8
    codes are summed with psum in int32 (exact), then rescaled; the local
    quantisation residual goes into the next step's error buffer.
    """
    n_dev = jax.lax.psum(1, axis)

    def one(g, e):
        g32 = g.astype(jnp.float32) + (0.0 if e is None else e)
        codes, scale, n = quantize(g32)
        deq_local = dequantize(codes, scale, n, g.shape)
        new_err = g32 - deq_local
        # sum of dequantised local tensors across the axis (exact in f32)
        summed = jax.lax.psum(deq_local, axis)
        return (summed / n_dev).astype(g.dtype), new_err

    if error is None:
        error = jax.tree.map(lambda _: None, grads,
                             is_leaf=lambda x: x is None)
    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error) if jax.tree_util.tree_leaves(error) \
        else [None] * len(flat_g)
    if len(flat_e) != len(flat_g):
        flat_e = [None] * len(flat_g)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(tree, [o[0] for o in outs])
    new_e = jax.tree_util.tree_unflatten(tree, [o[1] for o in outs])
    return new_g, new_e


def compression_ratio(grads) -> float:
    """Bytes(int8+scales) / bytes(f32) for reporting."""
    total = sum(x.size for x in jax.tree.leaves(grads))
    comp = sum(x.size * 1 + (x.size // BLOCK + 1) * 4 for x in jax.tree.leaves(grads))
    return comp / (total * 4)
