"""Transformer text classifiers from the paper (Table 4):
Transformer-6 (EMB-100, ENC-100-5-100 x6, FC-X) and Transformer-12.

Layer-list structure mirroring cnn.py so the FedOptima learners treat CNNs
and transformers uniformly: layers are ("emb" | "enc" | "pool" | "fc"),
split points are layer indices, and the aux network is one layer of the
same type as the last device layer + a dense classifier (§3.2.2).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from .attention import AttentionConfig, attention_apply, attention_init
from .common import dense_init, embed_init, layernorm_apply, layernorm_init
from .mlp import MlpConfig, mlp_apply, mlp_init

Params = Any


@dataclass(frozen=True)
class TextClassifierConfig:
    name: str
    layers: tuple
    vocab: int
    n_classes: int
    seq_len: int
    d_model: int


def transformer6_config(vocab=8000, n_classes=2, seq_len=64, d_model=100,
                        n_heads=5, d_ff=100, n_layers=6) -> TextClassifierConfig:
    return TextClassifierConfig(
        name=f"transformer{n_layers}", vocab=vocab, n_classes=n_classes,
        seq_len=seq_len, d_model=d_model,
        layers=({"kind": "emb"},
                *({"kind": "enc", "heads": n_heads, "d_ff": d_ff},) * n_layers,
                {"kind": "pool"},
                {"kind": "fc", "dout": n_classes, "logits": True}))


def transformer12_config(vocab=12000, n_classes=2, seq_len=128, d_model=100,
                         n_heads=50, d_ff=100) -> TextClassifierConfig:
    return transformer6_config(vocab, n_classes, seq_len, d_model, n_heads,
                               d_ff, n_layers=12)


def _layer_init(rng, spec, cfg: TextClassifierConfig, din, dtype):
    kind = spec["kind"]
    if kind == "emb":
        return {"tok": embed_init(rng, cfg.vocab, cfg.d_model, dtype),
                "pos": embed_init(jax.random.fold_in(rng, 1), cfg.seq_len,
                                  cfg.d_model, dtype)}, cfg.d_model
    if kind == "enc":
        acfg = AttentionConfig(d_model=cfg.d_model, n_heads=spec["heads"],
                               n_kv_heads=spec["heads"], causal=False)
        k1, k2 = jax.random.split(rng)
        return {"attn": attention_init(k1, acfg, dtype),
                "ln1": layernorm_init(cfg.d_model, dtype),
                "mlp": mlp_init(k2, MlpConfig(cfg.d_model, spec["d_ff"], "gelu"), dtype),
                "ln2": layernorm_init(cfg.d_model, dtype)}, cfg.d_model
    if kind == "pool":
        return {}, din
    if kind == "fc":
        return {"w": jax.random.normal(rng, (din, spec["dout"]), dtype) / math.sqrt(din),
                "b": jnp.zeros((spec["dout"],), dtype)}, spec["dout"]
    raise ValueError(kind)


def init_params(rng, cfg: TextClassifierConfig, dtype=jnp.float32) -> list:
    params, d = [], cfg.d_model
    for i, spec in enumerate(cfg.layers):
        p, d = _layer_init(jax.random.fold_in(rng, i), spec, cfg, d, dtype)
        params.append(p)
    return params


def _layer_apply(p, spec, cfg: TextClassifierConfig, x):
    kind = spec["kind"]
    if kind == "emb":
        S = x.shape[1]
        return p["tok"][x] + p["pos"][None, :S]
    if kind == "enc":
        acfg = AttentionConfig(d_model=cfg.d_model, n_heads=spec["heads"],
                               n_kv_heads=spec["heads"], causal=False)
        h = x + attention_apply(p["attn"], acfg, layernorm_apply(p["ln1"], x))
        return h + mlp_apply(p["mlp"], MlpConfig(cfg.d_model, spec["d_ff"], "gelu"),
                             layernorm_apply(p["ln2"], h))
    if kind == "pool":
        return jnp.mean(x, axis=1)
    if kind == "fc":
        return x @ p["w"] + p["b"]
    raise ValueError(kind)


def forward(params: list, cfg: TextClassifierConfig, x, *, upto=None,
            from_layer: int = 0):
    hi = len(cfg.layers) if upto is None else upto
    for i in range(from_layer, hi):
        x = _layer_apply(params[i], cfg.layers[i], cfg, x)
    return x


def ce_loss(logits, labels):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def loss_fn(params, cfg, x, labels):
    return ce_loss(forward(params, cfg, x), labels)


def accuracy(params, cfg, x, labels):
    return jnp.mean((jnp.argmax(forward(params, cfg, x), -1) == labels).astype(jnp.float32))


# --- FedOptima split API (mirrors cnn.py) ---

def split_params(params: list, l_split: int):
    return params[:l_split], params[l_split:]


def make_aux_params(rng, cfg: TextClassifierConfig, l_split: int,
                    variant: str = "default", dtype=jnp.float32) -> Params:
    """Aux-network variants for the §6.5.1 ablation:
       default          — one enc layer + dense classifier
       classifier_only  — dense classifier only
       deep             — two enc layers + dense classifier"""
    spec = {"kind": "enc", "heads": 5 if cfg.d_model % 5 == 0 else 4,
            "d_ff": cfg.d_model}
    ks = jax.random.split(rng, 3)
    layers = []
    n_enc = {"default": 1, "classifier_only": 0, "deep": 2}[variant]
    for i in range(n_enc):
        p, _ = _layer_init(ks[i], spec, cfg, cfg.d_model, dtype)
        layers.append(p)
    head = {"w": jax.random.normal(ks[2], (cfg.d_model, cfg.n_classes), dtype)
            / math.sqrt(cfg.d_model),
            "b": jnp.zeros((cfg.n_classes,), dtype)}
    return {"layers": layers, "head": head}, {"layer_spec": spec}


def aux_head_loss(aux_params: Params, spec: dict, cfg: TextClassifierConfig,
                  acts, labels):
    h = acts
    for p in aux_params["layers"]:
        h = _layer_apply(p, spec["layer_spec"], cfg, h)
    h = jnp.mean(h, axis=1) if h.ndim == 3 else h
    logits = h @ aux_params["head"]["w"] + aux_params["head"]["b"]
    return ce_loss(logits, labels)


def device_train_loss(dev_params, aux_params, aux_spec, cfg, x, labels, l_split):
    acts = forward(dev_params, cfg, x, upto=l_split)
    return aux_head_loss(aux_params, aux_spec, cfg, acts, labels), acts


def server_forward_loss(srv_params, cfg, acts, labels, l_split):
    acts = jax.lax.stop_gradient(acts)
    logits = forward([None] * l_split + srv_params, cfg, acts, from_layer=l_split)
    return ce_loss(logits, labels)
