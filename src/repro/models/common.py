"""Common model building blocks: norms, rotary embeddings, initializers.

Pure JAX (no flax). Parameters are plain pytrees (nested dicts of arrays).
Every block follows the convention::

    params = block_init(rng, cfg)          # build params
    out    = block_apply(params, x, ...)   # pure function

Weights that repeat across layers are *stacked* on a leading axis so the
forward pass can ``jax.lax.scan`` over them — this keeps compiled HLO size
independent of depth (critical for 64–100 layer dry-runs).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree of jnp arrays


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(rng, in_dim: int, out_dim: int, dtype=jnp.float32, scale: float | None = None):
    """Variance-scaling (fan-in) init, the standard for transformer dense layers."""
    std = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return jax.random.normal(rng, (in_dim, out_dim), dtype) * jnp.asarray(std, dtype)


def embed_init(rng, vocab: int, dim: int, dtype=jnp.float32):
    return jax.random.normal(rng, (vocab, dim), dtype) * jnp.asarray(0.02, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm_apply(params: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(orig_dtype)


def layernorm_init(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm_apply(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)).astype(orig_dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    """Inverse frequencies for RoPE; shape (head_dim // 2,), float32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """Rotate pairs of channels. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)                     # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * inv_freq  # (..., seq, hd/2)
    angles = angles[..., None, :]                                    # (..., seq, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activation functions
# ---------------------------------------------------------------------------

def silu(x):
    return x * jax.nn.sigmoid(x)


def gelu(x):
    return jax.nn.gelu(x)


def hardswish(x):
    return x * jnp.clip(x + 3.0, 0.0, 6.0) / 6.0


def softcap(logits: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(logits / cap)


# ---------------------------------------------------------------------------
# Pytree helpers
# ---------------------------------------------------------------------------

def tree_stack(trees):
    """Stack a list of identically-structured pytrees on a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)


def tree_lerp(a, b, alpha):
    """alpha * b + (1 - alpha) * a, elementwise over pytrees (FedAsync update)."""
    return jax.tree.map(lambda x, y: (1.0 - alpha) * x + alpha * y, a, b)


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_param_count(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


def split_rngs(rng, n: int):
    return list(jax.random.split(rng, n))
