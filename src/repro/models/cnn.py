"""CNNs from the paper's own experiments (Table 4): VGG-5 and a
MobileNetV3-style bottleneck CNN.  Pure JAX, NHWC.

These are the models the faithful reproduction trains (image
classification task, §5.2); they exercise FedOptima's claim of supporting
any *sequential* DNN.  The split API mirrors the transformer one: the
network is a list of layers; the split point is a layer index; the
auxiliary network is one layer of the same type as the last device layer +
a dense classifier (§3.2.2).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from .common import hardswish

Params = Any


def conv_init(rng, kh, kw, cin, cout, dtype=jnp.float32):
    fan_in = kh * kw * cin
    return jax.random.normal(rng, (kh, kw, cin, cout), dtype) / math.sqrt(fan_in)


def conv2d(x, w, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"), feature_group_count=groups)


# ---------------------------------------------------------------------------
# Layer descriptors: each layer is (kind, init_fn, apply_fn) driven by specs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CnnConfig:
    name: str
    layers: tuple            # tuple of layer spec dicts
    n_classes: int
    in_channels: int = 3
    img_size: int = 32


def vgg5_config(n_classes=10, img_size=32) -> CnnConfig:
    """VGG-5 (Table 4): CONV-3-32, CONV-3-64 x2, FC-128, FC-X."""
    return CnnConfig(name="vgg5", n_classes=n_classes, img_size=img_size, layers=(
        {"kind": "conv", "k": 3, "cout": 32, "pool": True},
        {"kind": "conv", "k": 3, "cout": 64, "pool": True},
        {"kind": "conv", "k": 3, "cout": 64, "pool": True},
        {"kind": "flatten"},
        {"kind": "fc", "dout": 128},
        {"kind": "fc", "dout": n_classes, "logits": True},
    ))


def mobilenetv3ish_config(n_classes=200, img_size=64) -> CnnConfig:
    """MobileNetV3-Large-style stack (Table 4, reduced faithfully in shape):
    stem conv + BNECK residual blocks (expand->depthwise->project, SE
    omitted for determinism) + head convs + classifier."""
    bnecks = []
    plan = [  # (kernel, cout, stride, expand)
        (3, 16, 1, 1), (3, 24, 2, 4), (3, 24, 1, 3),
        (5, 40, 2, 3), (5, 40, 1, 3), (5, 40, 1, 3),
        (3, 80, 2, 6), (3, 80, 1, 2.5), (3, 80, 1, 2.3), (3, 80, 1, 2.3),
        (3, 112, 1, 6), (3, 112, 1, 6),
        (5, 160, 2, 6), (5, 160, 1, 6), (5, 160, 1, 6),
    ]
    for k, cout, s, e in plan:
        bnecks.append({"kind": "bneck", "k": k, "cout": cout, "stride": s, "expand": e})
    return CnnConfig(name="mobilenetv3ish", n_classes=n_classes, img_size=img_size, layers=(
        {"kind": "conv", "k": 3, "cout": 16, "stride": 2, "act": "hswish"},
        *bnecks,
        {"kind": "conv", "k": 1, "cout": 960, "act": "hswish"},
        {"kind": "gap"},
        {"kind": "fc", "dout": 1280, "act": "hswish"},
        {"kind": "fc", "dout": n_classes, "logits": True},
    ))


# ---------------------------------------------------------------------------
# Init / apply
# ---------------------------------------------------------------------------

def _layer_init(rng, spec, cin, hw, dtype):
    """Returns (params, cout, hw_out)."""
    kind = spec["kind"]
    if kind == "conv":
        s = spec.get("stride", 1)
        p = {"w": conv_init(rng, spec["k"], spec["k"], cin, spec["cout"], dtype),
             "b": jnp.zeros((spec["cout"],), dtype)}
        hw = hw // s
        if spec.get("pool"):
            hw //= 2
        return p, spec["cout"], hw
    if kind == "bneck":
        ce = int(round(cin * spec["expand"]))
        k1, k2, k3 = jax.random.split(rng, 3)
        p = {"w_exp": conv_init(k1, 1, 1, cin, ce, dtype),
             "w_dw": conv_init(k2, spec["k"], spec["k"], 1, ce, dtype),
             "w_proj": conv_init(k3, 1, 1, ce, spec["cout"], dtype),
             "b": jnp.zeros((spec["cout"],), dtype)}
        return p, spec["cout"], hw // spec.get("stride", 1)
    if kind == "flatten":
        return {}, cin * hw * hw, 1
    if kind == "gap":
        return {}, cin, 1
    if kind == "fc":
        p = {"w": jax.random.normal(rng, (cin, spec["dout"]), dtype) / math.sqrt(cin),
             "b": jnp.zeros((spec["dout"],), dtype)}
        return p, spec["dout"], hw
    raise ValueError(kind)


def init_params(rng, cfg: CnnConfig, dtype=jnp.float32) -> list:
    params, cin, hw = [], cfg.in_channels, cfg.img_size
    for i, spec in enumerate(cfg.layers):
        p, cin, hw = _layer_init(jax.random.fold_in(rng, i), spec, cin, hw, dtype)
        params.append(p)
    return params


def _layer_apply(p, spec, x):
    kind = spec["kind"]
    if kind == "conv":
        x = conv2d(x, p["w"], stride=spec.get("stride", 1)) + p["b"]
        x = hardswish(x) if spec.get("act") == "hswish" else jax.nn.relu(x)
        if spec.get("pool"):
            x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                      (1, 2, 2, 1), "VALID")
        return x
    if kind == "bneck":
        s = spec.get("stride", 1)
        h = hardswish(conv2d(x, p["w_exp"]))
        h = hardswish(conv2d(h, p["w_dw"], stride=s, groups=h.shape[-1]))
        h = conv2d(h, p["w_proj"]) + p["b"]
        if s == 1 and x.shape[-1] == h.shape[-1]:
            h = h + x
        return h
    if kind == "flatten":
        return x.reshape(x.shape[0], -1)
    if kind == "gap":
        return jnp.mean(x, axis=(1, 2))
    if kind == "fc":
        x = x @ p["w"] + p["b"]
        if spec.get("logits"):
            return x
        return hardswish(x) if spec.get("act") == "hswish" else jax.nn.relu(x)
    raise ValueError(kind)


def forward(params: list, cfg: CnnConfig, x, *, upto: int | None = None,
            from_layer: int = 0):
    """Apply layers [from_layer, upto).  Default: whole network -> logits."""
    hi = len(cfg.layers) if upto is None else upto
    for i in range(from_layer, hi):
        x = _layer_apply(params[i], cfg.layers[i], x)
    return x


def ce_loss(logits, labels):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def loss_fn(params: list, cfg: CnnConfig, x, labels):
    return ce_loss(forward(params, cfg, x), labels)


def accuracy(params: list, cfg: CnnConfig, x, labels):
    return jnp.mean((jnp.argmax(forward(params, cfg, x), -1) == labels).astype(jnp.float32))


# ---------------------------------------------------------------------------
# FedOptima split API for CNNs
# ---------------------------------------------------------------------------

def split_params(params: list, l_split: int):
    return params[:l_split], params[l_split:]


def make_aux_params(rng, cfg: CnnConfig, l_split: int,
                    variant: str = "default", dtype=jnp.float32) -> Params:
    """Aux network (§3.2.2): layer(s) of the same type as the last device
    layer + dense classifier.  Variants for the §6.5.1 ablation:
       default          — one aux layer + classifier
       classifier_only  — classifier directly on (pooled) activations
       deep             — two aux layers + classifier
    """
    spec = cfg.layers[l_split - 1]
    ks = jax.random.split(rng, 4)
    # trace shapes up to the split
    cin, hw = cfg.in_channels, cfg.img_size
    for s in cfg.layers[:l_split]:
        _, cin, hw = _layer_init(jax.random.PRNGKey(0), s, cin, hw, dtype)
    conv_like = spec["kind"] in ("conv", "bneck")
    n_layers = {"default": 1, "classifier_only": 0, "deep": 2}[variant]
    if conv_like:
        aux_spec = {"kind": "conv", "k": 3, "cout": cin}
    else:
        aux_spec = {"kind": "fc", "dout": cin}
    layers = [_layer_init(ks[i], aux_spec, cin, hw, dtype)[0]
              for i in range(n_layers)]
    head = {"w": jax.random.normal(ks[3], (cin, cfg.n_classes), dtype) / math.sqrt(cin),
            "b": jnp.zeros((cfg.n_classes,), dtype)}
    params = {"layers": layers, "head": head}
    spec = {"layer_spec": aux_spec, "pool": conv_like}
    return params, spec


def aux_head_loss(aux_params: Params, spec: dict, acts, labels):
    h = acts
    for p in aux_params["layers"]:
        h = _layer_apply(p, spec["layer_spec"], h)
    if spec["pool"] and h.ndim == 4:
        h = jnp.mean(h, axis=(1, 2))
    logits = h @ aux_params["head"]["w"] + aux_params["head"]["b"]
    return ce_loss(logits, labels)


def device_train_loss(dev_params: list, aux_params: Params, aux_spec: dict,
                      cfg: CnnConfig, x, labels, l_split: int):
    acts = forward(dev_params, cfg, x, upto=l_split)
    return aux_head_loss(aux_params, aux_spec, acts, labels), acts


def server_forward_loss(srv_params: list, cfg: CnnConfig, acts, labels,
                        l_split: int):
    acts = jax.lax.stop_gradient(acts)
    logits = forward([None] * l_split + srv_params, cfg, acts, from_layer=l_split)
    return ce_loss(logits, labels)
