"""Multi-head attention with the variants needed by the assigned archs.

Supported features (all composable):
  * grouped-query attention (n_kv_heads < n_heads)
  * qk-norm (Qwen3)
  * attention logit soft-capping (Gemma-2)
  * sliding-window ("local") attention (Gemma-2 alternating layers)
  * cross-attention (Llama-3.2-Vision image layers, Whisper decoder)
  * KV-cache single-token decode path

The public entry point dispatches to the Pallas flash-attention kernel
(`repro.kernels.ops.flash_attention`) when enabled, otherwise to the pure
jnp reference path below.  Both paths share parameter layout, and both are
differentiable: the kernel path carries a ``jax.custom_vjp`` whose backward
recomputes attention tiles from (q, k, v, o, lse) in fused Pallas kernels,
so ``use_kernel=True`` works under ``jax.value_and_grad`` (training), not
just inference.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from .common import (apply_rope, dense_init, rmsnorm_apply, rmsnorm_init,
                     softcap)

Params = Any


@dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int | None = None          # default d_model // n_heads
    qk_norm: bool = False                # Qwen3
    attn_softcap: float | None = None    # Gemma-2 (e.g. 50.0)
    window: int | None = None            # sliding-window size; None = global
    rope_theta: float = 10000.0
    causal: bool = True
    use_bias: bool = False
    chunk_q: int = 1024                  # query-chunk size (memory bound)

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads


def attention_init(rng, cfg: AttentionConfig, dtype=jnp.float32) -> Params:
    hd = cfg.hd
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dtype,
                         scale=1.0 / (cfg.n_heads * hd) ** 0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _project_qkv(params, cfg: AttentionConfig, x, xkv=None):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,Skv,Hkv,hd)."""
    hd = cfg.hd
    xkv = x if xkv is None else xkv
    B, S, _ = x.shape
    Skv = xkv.shape[1]
    q = (x @ params["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (xkv @ params["wk"]).reshape(B, Skv, cfg.n_kv_heads, hd)
    v = (xkv @ params["wv"]).reshape(B, Skv, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm_apply(params["q_norm"], q)
        k = rmsnorm_apply(params["k_norm"], k)
    return q, k, v


def sdpa_reference(q, k, v, *, causal: bool, window: int | None,
                   logit_cap: float | None, q_positions=None, kv_positions=None):
    """Pure-jnp scaled dot-product attention with GQA.

    q: (B, S, H, hd); k, v: (B, Skv, Hkv, hd).  Grouped heads are expanded
    by reshaping q into (Hkv, group) and contracting per kv head.
    """
    B, S, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    qg = q.reshape(B, S, Hkv, group, hd)
    # logits: (B, Hkv, group, S, Skv)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if logit_cap is not None:
        logits = softcap(logits, logit_cap)

    if q_positions is None:
        q_positions = jnp.arange(S)
    if kv_positions is None:
        kv_positions = jnp.arange(Skv)
    qpos = q_positions[:, None]      # (S, 1)
    kpos = kv_positions[None, :]     # (1, Skv)
    mask = jnp.ones((S, Skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)

    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def sdpa_chunked(q, k, v, *, causal: bool, window: int | None,
                 logit_cap: float | None, chunk_q: int = 1024):
    """Query-chunked attention: numerically identical to sdpa_reference but
    never materialises the full (S, Skv) score matrix — the scan body is
    remat'd so peak memory is one chunk's (B, H, cq, Skv) logits.  K/V are
    expanded to H heads so the head dim stays cleanly shardable under TP
    (GQA kv counts rarely divide the ``model`` axis; q heads do)."""
    B, S, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    kf = jnp.repeat(k, group, axis=2)       # (B, Skv, H, hd)
    vf = jnp.repeat(v, group, axis=2)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    kv_pos = jnp.arange(Skv)

    @jax.checkpoint
    def chunk_attn(qc, qpos):
        logits = jnp.einsum("bqhd,bthd->bhqt", qc.astype(jnp.float32),
                            kf.astype(jnp.float32)) * scale
        if logit_cap is not None:
            logits = softcap(logits, logit_cap)
        mask = jnp.ones((qc.shape[1], Skv), bool)
        if causal:
            mask &= kv_pos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kv_pos[None, :] > qpos[:, None] - window
        logits = jnp.where(mask[None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhqt,bthd->bqhd", probs, vf.astype(jnp.float32))
        return out.astype(q.dtype)

    cq = min(chunk_q, S)
    n = S // cq
    rem = S - n * cq
    pos = jnp.arange(S)
    xs = (jnp.moveaxis(q[:, :n * cq].reshape(B, n, cq, H, hd), 1, 0),
          pos[: n * cq].reshape(n, cq))
    _, ys = jax.lax.scan(lambda c, x: (c, chunk_attn(*x)), None, xs)
    out = jnp.moveaxis(ys, 0, 1).reshape(B, n * cq, H, hd)
    if rem:
        out = jnp.concatenate(
            [out, chunk_attn(q[:, n * cq:], pos[n * cq:])], axis=1)
    return out


def attention_apply(params: Params, cfg: AttentionConfig, x, *, xkv=None,
                    positions=None, use_kernel: bool = False,
                    return_kv: bool = False, parallelism=None):
    """Full-sequence attention (training / prefill). x: (B, S, D).
    With return_kv=True also returns the rotated {"k","v"} for cache
    priming (prefill)."""
    B, S, _ = x.shape
    con = parallelism.heads if parallelism is not None else (lambda t: t)
    q, k, v = _project_qkv(params, cfg, x, xkv)
    q, k, v = con(q), con(k), con(v)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if xkv is None:  # self-attention: RoPE on q and k
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        causal = cfg.causal
    else:            # cross-attention: no RoPE, no causal mask
        causal = False
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=causal, window=cfg.window,
                                   logit_cap=cfg.attn_softcap)
    else:
        out = sdpa_chunked(q, k, v, causal=causal, window=cfg.window,
                           logit_cap=cfg.attn_softcap, chunk_q=cfg.chunk_q)
    out = con(out).reshape(B, S, cfg.n_heads * cfg.hd) @ params["wo"]
    if return_kv:
        return out, {"k": k, "v": v}
    return out


# ---------------------------------------------------------------------------
# KV-cache decode path
# ---------------------------------------------------------------------------

def kv_cache_init(cfg: AttentionConfig, batch: int, max_len: int, dtype=jnp.float32):
    hd = cfg.hd
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
    }


def attention_decode(params: Params, cfg: AttentionConfig, x, cache, position,
                     ring: bool = False):
    """Single-token decode step.

    x: (B, 1, D); cache: {"k","v"}: (B, T, Hkv, hd); position: scalar int —
    the index of the new token (same for the whole batch; per-request offsets
    are handled a level above by the serving layer).
    Returns (out (B, 1, D), new_cache).

    ring=True treats the cache as a ring buffer of length T (sliding-window
    layers keep only the last ``window`` K/V): the write index is
    ``position % T`` and slot j holds position p_j = position-((position-j)%T),
    valid iff p_j >= 0.  RoPE uses absolute positions, so ring slots stay
    correctly rotated.
    """
    B = x.shape[0]
    T = cache["k"].shape[1]
    q, k, v = _project_qkv(params, cfg, x)
    pos = jnp.full((B, 1), position, dtype=jnp.int32)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    write_idx = position % T if ring else position
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), write_idx, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), write_idx, axis=1)

    kv_positions = jnp.arange(T)
    if ring:
        # slot j holds absolute position p_j; valid once written (p_j >= 0);
        # the ring length IS the window, so no further window mask is needed.
        p_j = position - jnp.mod(position - kv_positions, T)
        valid = p_j >= 0
    else:
        # valid: kv slot <= current position (and within window if local)
        valid = kv_positions <= position
        if cfg.window is not None:
            valid &= kv_positions > position - cfg.window
    hd = cfg.hd
    Hkv = cfg.n_kv_heads
    group = cfg.n_heads // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qg = q.reshape(B, 1, Hkv, group, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                        ck.astype(jnp.float32)) * scale
    if cfg.attn_softcap is not None:
        logits = softcap(logits, cfg.attn_softcap)
    logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, cv.astype(jnp.float32))
    out = out.reshape(B, 1, cfg.n_heads * hd).astype(x.dtype) @ params["wo"]
    return out, {"k": ck, "v": cv}
