"""Backbone assembly: decoder LMs, hybrids, enc-dec, VLM — scan over periods.

Public API
----------
  init_params(rng, cfg, dtype)                  -> params pytree
  forward(params, cfg, tokens, ...)             -> final hidden (B, S, D)
  lm_loss(params, cfg, tokens, labels, ...)     -> (scalar loss, aux)
  init_decode_state(cfg, batch, max_len, dtype) -> caches
  decode_step(params, cfg, state, token, pos)   -> (logits, new state)

FedOptima split API (device/server halves + auxiliary network):
  split_params(params, cfg, l_split)            -> (device_params, server_params)
  device_forward(dev_params, cfg, tokens, l_split)  -> activations
  aux_head_loss(dev_params, cfg, acts, labels)  -> scalar local loss
  server_forward_loss(srv_params, cfg, acts, labels, l_split) -> scalar loss
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from .api import ArchConfig
from .attention import (attention_apply, attention_decode, attention_init,
                        kv_cache_init, sdpa_reference)
from .common import (dense_init, embed_init, rmsnorm_apply, rmsnorm_init,
                     softcap)
from .mamba import (mamba_apply, mamba_decode, mamba_init, mamba_state_init)
from .mlp import mlp_apply, mlp_init, moe_apply_grouped, moe_init

Params = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _block_init(rng, cfg: ArchConfig, mixer: str, ffn: str, dtype) -> Params:
    k1, k2 = jax.random.split(rng)
    p: dict = {"ln1": rmsnorm_init(cfg.d_model, dtype)}
    if mixer in ("attn", "local"):
        p["mixer"] = attention_init(k1, cfg.attn_cfg(mixer), dtype)
    elif mixer == "cross":
        p["mixer"] = attention_init(k1, cfg.cross_cfg(), dtype)
        p["gate"] = jnp.zeros((), dtype)      # zero-init gated cross-attn
    elif mixer == "mamba":
        p["mixer"] = mamba_init(k1, cfg.mamba_cfg(), dtype)
    elif mixer != "none":
        raise ValueError(mixer)
    if ffn == "dense":
        p["ln2"] = rmsnorm_init(cfg.d_model, dtype)
        p["ffn"] = mlp_init(k2, cfg.mlp_cfg(), dtype)
    elif ffn == "moe":
        p["ln2"] = rmsnorm_init(cfg.d_model, dtype)
        p["ffn"] = moe_init(k2, cfg.moe_cfg(), dtype)
    elif ffn != "none":
        raise ValueError(ffn)
    return p


def _stack_init(rng, cfg: ArchConfig, n_periods: int, dtype) -> list:
    """Per-position-in-period param stacks, leaves shaped (n_periods, ...)."""
    stacks = []
    for pos, (mixer, ffn) in enumerate(cfg.pattern):
        rngs = jax.random.split(jax.random.fold_in(rng, pos), n_periods)
        per = [_block_init(r, cfg, mixer, ffn, dtype) for r in rngs]
        stacks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
    return stacks


def init_params(rng, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    ke, kb, kh, kd = jax.random.split(rng, 4)
    params: dict = {
        "embed": embed_init(ke, cfg.vocab, cfg.d_model, dtype),
        "blocks": _stack_init(kb, cfg, cfg.n_periods, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kh, cfg.d_model, cfg.vocab, dtype)
    if cfg.n_decoder_layers:  # enc-dec (audio family): decoder stack
        dec_cfg = _decoder_cfg(cfg)
        params["dec_blocks"] = _stack_init(kd, dec_cfg, dec_cfg.n_periods, dtype)
        params["dec_norm"] = rmsnorm_init(cfg.d_model, dtype)
    return params


def _decoder_cfg(cfg: ArchConfig) -> ArchConfig:
    """Decoder stack of an enc-dec model: self-attn + cross-attn + mlp."""
    return cfg.scaled(n_layers=cfg.n_decoder_layers,
                      pattern=(("attn", "none"), ("cross", "dense")),
                      n_decoder_layers=0)


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------

def _apply_block(p: Params, cfg: ArchConfig, mixer: str, ffn: str, h, *,
                 positions, frontend=None, use_kernel=False, parallelism=None,
                 return_state=False):
    """One block.  Mixer/FFN outputs are `checkpoint_name`d "tp_out": with
    the selective remat policy these post-TP-collective tensors are saved,
    so the backward pass never re-runs the forward all-reduces."""
    from jax.ad_checkpoint import checkpoint_name
    aux = jnp.zeros((), jnp.float32)
    state = {}
    if mixer in ("attn", "local"):
        y = attention_apply(p["mixer"], cfg.attn_cfg(mixer),
                            rmsnorm_apply(p["ln1"], h),
                            positions=positions, use_kernel=use_kernel,
                            return_kv=return_state, parallelism=parallelism)
        if return_state:
            y, state = y
        h = h + checkpoint_name(y, "tp_out")
    elif mixer == "cross":
        y = attention_apply(p["mixer"], cfg.cross_cfg(),
                            rmsnorm_apply(p["ln1"], h), xkv=frontend,
                            return_kv=return_state, parallelism=parallelism)
        if return_state:
            y, state = y
        h = h + jnp.tanh(p["gate"]) * checkpoint_name(y, "tp_out")
    elif mixer == "mamba":
        y = mamba_apply(p["mixer"], cfg.mamba_cfg(),
                        rmsnorm_apply(p["ln1"], h), use_kernel=use_kernel,
                        return_state=return_state)
        if return_state:
            y, state = y
        h = h + checkpoint_name(y, "tp_out")
    if ffn == "dense":
        y = mlp_apply(p["ffn"], cfg.mlp_cfg(),
                      rmsnorm_apply(p["ln2"], h), parallelism=parallelism)
        h = h + checkpoint_name(y, "tp_out")
    elif ffn == "moe":
        y, aux = _moe_dispatch(p["ffn"], cfg, rmsnorm_apply(p["ln2"], h),
                               parallelism)
        h = h + checkpoint_name(y, "tp_out")
    if return_state:
        return h, aux, state
    return h, aux


def _moe_dispatch(p, cfg: ArchConfig, x, parallelism):
    """MoE ffn, optionally expert-parallel over the mesh 'model' axis.

    With a `parallelism` spec, runs under shard_map: tokens sharded over the
    dp axes and replicated over 'model'; each model shard holds E/tp experts
    and computes only tokens routed to them; partial outputs are psum'd over
    'model' (expert parallelism fused onto the TP axis).
    """
    mcfg = cfg.moe_cfg()
    if parallelism is None or not parallelism.ep:
        return moe_apply_grouped(p, mcfg, x,
                                 capacity_factor=cfg.moe_capacity_factor,
                                 parallelism=parallelism)
    P = jax.sharding.PartitionSpec
    mesh = parallelism.mesh
    tp = mesh.shape[parallelism.tp_axis]
    E_l = mcfg.n_experts // tp
    dp_spec = P(parallelism.dp_axes, None, None)
    expert_spec = jax.tree.map(lambda _: P(parallelism.tp_axis), p)
    expert_spec["router"] = P()  # router replicated

    def local_moe(p_l, x_l):
        idx = jax.lax.axis_index(parallelism.tp_axis)
        y, aux = moe_apply_grouped(
            p_l, mcfg, x_l, expert_offset=idx * E_l, n_local_experts=E_l,
            capacity_factor=cfg.moe_capacity_factor,
            psum_axis=parallelism.tp_axis)
        return y, aux

    from repro.parallel.sharding import shard_map
    y, aux = shard_map(
        local_moe, mesh=mesh, in_specs=(expert_spec, dp_spec),
        out_specs=(dp_spec, P()))(p, x)
    return y, aux


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def _run_stack(blocks, cfg: ArchConfig, h, *, positions, frontend=None,
               use_kernel=False, parallelism=None, remat=True):
    def period_fn(h, stacks_slice):
        aux_total = jnp.zeros((), jnp.float32)
        for pos, (mixer, ffn) in enumerate(cfg.pattern):
            h, aux = _apply_block(stacks_slice[pos], cfg, mixer, ffn, h,
                                  positions=positions, frontend=frontend,
                                  use_kernel=use_kernel, parallelism=parallelism)
            aux_total = aux_total + aux
        return h, aux_total

    if remat == "selective":
        # full remat EXCEPT the post-TP-collective block outputs: backward
        # recompute stops at the saved tensors, so the forward's TP
        # all-reduces are never re-issued (collective term / ~1.5).
        # "kernel_out" additionally saves the Pallas kernels' (o, lse) /
        # chunk-state residuals — O(S·hd), never the (S×S) scores — so the
        # custom_vjp backward doesn't re-run the forward kernel either.
        fn = jax.checkpoint(
            period_fn,
            policy=jax.checkpoint_policies.save_only_these_names(
                "tp_out", "kernel_out"))
    elif remat:
        fn = jax.checkpoint(period_fn)
    else:
        fn = period_fn

    def body(carry, stacks_slice):
        h, aux_sum = carry
        if parallelism is not None:
            h = parallelism.constrain(h)   # seq-parallel saved carries
        h, aux = fn(h, stacks_slice)
        return (h, aux_sum + aux), ()

    (h, aux_sum), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                                   tuple(blocks))
    if parallelism is not None:
        h = parallelism.constrain(h)
    return h, aux_sum


def forward(params: Params, cfg: ArchConfig, tokens, *, frontend=None,
            use_kernel=False, parallelism=None, remat=True):
    """tokens: (B, S) int32 (or (B, S, D) pre-embedded frontend stub for
    audio encoders).  Returns final hidden states (B, S, D)."""
    if tokens.ndim == 2:
        h = params["embed"][tokens]
    else:
        h = tokens
    S = h.shape[1]
    positions = jnp.arange(S)[None, :]
    h, aux = _run_stack(params["blocks"], cfg, h, positions=positions,
                        frontend=frontend, use_kernel=use_kernel,
                        parallelism=parallelism, remat=remat)
    return rmsnorm_apply(params["final_norm"], h), aux


def _lm_logits(params, cfg: ArchConfig, h):
    w = params["lm_head"] if not cfg.tie_embeddings else params["embed"].T
    logits = h @ w
    if cfg.final_softcap is not None:
        logits = softcap(logits, cfg.final_softcap)
    return logits


def _chunked_ce(logits_fn, h, labels, mask, s_chunk: int):
    """Sequence-chunked CE on (B, S, D) hidden states: scan over S-chunks;
    per step the (B, sc, V) logits keep batch sharded over dp and vocab over
    ``model`` (all chips busy), and the remat'd body means the chunk logits
    are never live across steps.  The gold logit uses a masked sum (not a
    gather) so vocab-sharding reduces with one psum."""
    B, S, D = h.shape
    sc = min(s_chunk, S)
    n = S // sc
    rem = S - n * sc

    @jax.checkpoint
    def chunk_loss(hc, lc, mc):
        logits = logits_fn(hc).astype(jnp.float32)          # (B, sc, V)
        lse = jax.nn.logsumexp(logits, axis=-1)             # (B, sc)
        hit = lc[..., None] == jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, 2)
        gold = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
        return jnp.sum((lse - gold) * mc), jnp.sum(mc)

    def body(acc, xs):
        loss, cnt = chunk_loss(*xs)
        return (acc[0] + loss, acc[1] + cnt), ()

    xs = (jnp.moveaxis(h[:, : n * sc].reshape(B, n, sc, D), 1, 0),
          jnp.moveaxis(labels[:, : n * sc].reshape(B, n, sc), 1, 0),
          jnp.moveaxis(mask[:, : n * sc].reshape(B, n, sc), 1, 0))
    (loss, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),) * 2, xs)
    if rem:
        l2, c2 = chunk_loss(h[:, n * sc:], labels[:, n * sc:], mask[:, n * sc:])
        loss, cnt = loss + l2, cnt + c2
    return loss / jnp.maximum(cnt, 1.0)


def chunked_ce_loss(params, cfg: ArchConfig, h, labels, mask=None):
    """Cross-entropy over (B, S, D) hidden states without materialising the
    full (B, S, V) logits: scan over sequence chunks (memory-roofline win
    for vocab 256k).  Labels: (B, S) int32; mask optional (B, S) {0,1}."""
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    w = params["lm_head"] if not cfg.tie_embeddings else params["embed"].T

    def logits_fn(hc):
        logits = hc @ w
        if cfg.final_softcap is not None:
            logits = softcap(logits, cfg.final_softcap)
        return logits

    return _chunked_ce(logits_fn, h, labels, mask.astype(jnp.float32),
                       cfg.ce_chunk)


def lm_loss(params: Params, cfg: ArchConfig, tokens, labels, *, frontend=None,
            use_kernel=False, parallelism=None, aux_weight=0.01, remat=True):
    """Next-token loss.  For enc-dec (audio): tokens is the decoder input,
    frontend the encoder input embeddings."""
    if cfg.n_decoder_layers:
        enc_h, aux_e = forward(params, cfg, frontend, use_kernel=use_kernel,
                               parallelism=parallelism, remat=remat)
        dec_cfg = _decoder_cfg(cfg)
        h = params["embed"][tokens]
        positions = jnp.arange(h.shape[1])[None, :]
        h, aux_d = _run_stack(params["dec_blocks"], dec_cfg, h,
                              positions=positions, frontend=enc_h,
                              use_kernel=use_kernel, parallelism=parallelism,
                              remat=remat)
        h = rmsnorm_apply(params["dec_norm"], h)
        aux = aux_e + aux_d
    else:
        h, aux = forward(params, cfg, tokens, frontend=frontend,
                         use_kernel=use_kernel, parallelism=parallelism,
                         remat=remat)
    loss = chunked_ce_loss(params, cfg, h, labels)
    return loss + aux_weight * aux, (loss, aux)


# ---------------------------------------------------------------------------
# Decode (one token, full cache)
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.float32,
                      frontend_len: int | None = None):
    """Per-(period, position) cache stacks for the mixers that need state."""
    n = cfg.n_periods
    caches = []
    for mixer, _ in cfg.pattern:
        if mixer in ("attn", "local"):
            L = min(max_len, cfg.window) if (mixer == "local" and cfg.window) else max_len
            c = kv_cache_init(cfg.attn_cfg(mixer), batch, L, dtype)
        elif mixer == "mamba":
            c = mamba_state_init(cfg.mamba_cfg(), batch, dtype)
        elif mixer == "cross":
            fl = frontend_len or cfg.frontend_len
            c = kv_cache_init(cfg.cross_cfg(), batch, fl, dtype)
        else:
            c = {}
        caches.append(jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), c))
    return caches


def decode_step(params: Params, cfg: ArchConfig, caches, token, position, *,
                frontend=None):
    """token: (B, 1) int32; position: scalar int32.  Returns (logits (B, V),
    new caches).  For enc-dec models, `params["dec_blocks"]`/decoder caches
    should be passed through cfg=_decoder_cfg(cfg) by the serving layer."""
    h = params["embed"][token]

    # scan over periods, threading h as carry, caches as xs -> ys
    def period_fn(h, inp):
        stacks_slice, cache_slice = inp
        new_cache = []
        for pos, (mixer, ffn) in enumerate(cfg.pattern):
            p = stacks_slice[pos]
            c = cache_slice[pos]
            if mixer in ("attn", "local"):
                acfg = cfg.attn_cfg(mixer)
                ring = mixer == "local" and cfg.window is not None
                y, c = attention_decode(p["mixer"], acfg,
                                        rmsnorm_apply(p["ln1"], h), c, position,
                                        ring=ring)
                h = h + y
            elif mixer == "mamba":
                y, c = mamba_decode(p["mixer"], cfg.mamba_cfg(),
                                    rmsnorm_apply(p["ln1"], h), c)
                h = h + y
            elif mixer == "cross":
                q = rmsnorm_apply(p["ln1"], h)
                # cached cross K/V (precomputed from frontend at prefill)
                y = _cross_decode(p["mixer"], cfg.cross_cfg(), q, c)
                h = h + jnp.tanh(p["gate"]) * y
            if ffn == "dense":
                h = h + mlp_apply(p["ffn"], cfg.mlp_cfg(), rmsnorm_apply(p["ln2"], h))
            elif ffn == "moe":
                y, _aux = moe_apply_grouped(
                    p["ffn"], cfg.moe_cfg(), rmsnorm_apply(p["ln2"], h),
                    capacity_factor=max(4.0, cfg.moe_capacity_factor))
                h = h + y
            new_cache.append(c)
        return h, tuple(new_cache)

    h, new_caches = jax.lax.scan(period_fn, h, (tuple(params["blocks"]), tuple(caches)))
    h = rmsnorm_apply(params["final_norm"], h)
    logits = _lm_logits(params, cfg, h)[:, 0]
    return logits, list(new_caches)


def _cross_decode(p, acfg, q_in, cache):
    """Cross-attn during decode: K/V from the (static) frontend cache."""
    B = q_in.shape[0]
    hd = acfg.hd
    q = (q_in @ p["wq"]).reshape(B, 1, acfg.n_heads, hd)
    out = sdpa_reference(q, cache["k"], cache["v"], causal=False, window=None,
                         logit_cap=None)
    return out.reshape(B, 1, acfg.n_heads * hd) @ p["wo"]


# ---------------------------------------------------------------------------
# Prefill (full sequence -> decode caches + last-token logits)
# ---------------------------------------------------------------------------

def _state_to_cache(cfg: ArchConfig, mixer: str, st, S: int, max_len: int):
    """Convert a per-block prefill state into the decode-cache layout of
    init_decode_state (so decode_step continues seamlessly at position S)."""
    if mixer in ("attn", "local"):
        W = min(max_len, cfg.window) if (mixer == "local" and cfg.window) else max_len
        k, v = st["k"], st["v"]

        def place(x):
            B, _, Hkv, hd = x.shape
            if S >= W:
                # ring layout: slot j holds position p with p % W == j
                last = x[:, S - W:]
                idx = jnp.mod(jnp.arange(W) - (S % W), W)
                return last[:, idx]
            pad = jnp.zeros((B, W - S, Hkv, hd), x.dtype)
            return jnp.concatenate([x, pad], axis=1)

        return {"k": place(k), "v": place(v)}
    if mixer in ("mamba", "cross"):
        return st
    return {}


def prefill(params: Params, cfg: ArchConfig, tokens, *, max_len=None,
            frontend=None, use_kernel=False, parallelism=None, remat=True):
    """Run the full forward over ``tokens`` collecting decode caches.

    Returns (last_logits (B, V), caches) with caches in the layout of
    init_decode_state, primed so decode continues at position S.  For
    enc-dec archs (audio) the encoder runs on ``frontend`` and the decoder
    prefills on ``tokens`` with cross caches from the encoder output.
    """
    if cfg.n_decoder_layers:
        enc_h, _ = forward(params, cfg, frontend, use_kernel=use_kernel,
                           parallelism=parallelism, remat=remat)
        dec_cfg = _decoder_cfg(cfg)
        dec_params = {"embed": params["embed"], "blocks": params["dec_blocks"],
                      "final_norm": params["dec_norm"]}
        if "lm_head" in params:
            dec_params["lm_head"] = params["lm_head"]
        return prefill(dec_params, dec_cfg, tokens, max_len=max_len,
                       frontend=enc_h, use_kernel=use_kernel,
                       parallelism=parallelism, remat=remat)

    h = params["embed"][tokens] if tokens.ndim == 2 else tokens
    B, S = h.shape[0], h.shape[1]
    L = max_len or S
    positions = jnp.arange(S)[None, :]

    def period_fn(h, stacks_slice):
        caches = []
        for pos, (mixer, ffn) in enumerate(cfg.pattern):
            h, _aux, st = _apply_block(stacks_slice[pos], cfg, mixer, ffn, h,
                                       positions=positions, frontend=frontend,
                                       use_kernel=use_kernel,
                                       parallelism=parallelism,
                                       return_state=True)
            caches.append(_state_to_cache(cfg, mixer, st, S, L))
        return h, tuple(caches)

    fn = jax.checkpoint(period_fn) if remat else period_fn

    def body(h, stacks_slice):
        if parallelism is not None:
            h = parallelism.constrain(h)
        return fn(h, stacks_slice)

    h, caches = jax.lax.scan(body, h, tuple(params["blocks"]))
    h = rmsnorm_apply(params["final_norm"], h[:, -1:])
    logits = _lm_logits(params, cfg, h)[:, 0]
    return logits, list(caches)


def serve_decode_step(params: Params, cfg: ArchConfig, caches, token,
                      position):
    """decode_step that also handles enc-dec archs (uses the decoder stack;
    cross caches must have been primed by ``prefill``)."""
    if cfg.n_decoder_layers:
        dec_params = {"embed": params["embed"], "blocks": params["dec_blocks"],
                      "final_norm": params["dec_norm"]}
        if "lm_head" in params:
            dec_params["lm_head"] = params["lm_head"]
        return decode_step(dec_params, _decoder_cfg(cfg), caches, token,
                           position)
    return decode_step(params, cfg, caches, token, position)


def init_serve_state(cfg: ArchConfig, batch: int, max_len: int,
                     dtype=jnp.float32):
    """init_decode_state that routes enc-dec archs to their decoder stack."""
    if cfg.n_decoder_layers:
        return init_decode_state(_decoder_cfg(cfg), batch, max_len, dtype,
                                 frontend_len=cfg.frontend_len)
    return init_decode_state(cfg, batch, max_len, dtype,
                             frontend_len=cfg.frontend_len or None)


def prefill_cross_cache(params, cfg: ArchConfig, frontend):
    """Precompute cross-attention K/V from frontend embeddings for decode."""
    caches = []
    hd = cfg.cross_cfg().hd
    B, F, _ = frontend.shape
    for pos, (mixer, _f) in enumerate(cfg.pattern):
        if mixer != "cross":
            caches.append(None)
            continue
        p = params["blocks"][pos]  # stacked (n_periods, ...)

        def kv(px):
            k = (frontend @ px["mixer"]["wk"]).reshape(B, F, cfg.n_kv_heads, hd)
            v = (frontend @ px["mixer"]["wv"]).reshape(B, F, cfg.n_kv_heads, hd)
            return {"k": k, "v": v}

        caches.append(jax.lax.map(kv, p))
    return caches


# ---------------------------------------------------------------------------
# FedOptima split API
# ---------------------------------------------------------------------------
# The DNN is split at a *period* boundary l_split (so alternation patterns
# like gemma2 local/global or jamba 1:7 stay intact).  The device half is
# ``embed + blocks[:l_split]`` plus an auxiliary network (one extra block of
# the same type as the last device block + a factorized classifier head,
# §3.2.2 default).  The server half is ``blocks[l_split:] + final_norm +
# lm_head`` and trains *centrally* on activations (§3.3.2).

def _slice_stacks(blocks, lo, hi):
    return [jax.tree.map(lambda x: x[lo:hi], s) for s in blocks]


def make_aux_params(rng, cfg: ArchConfig, dtype=jnp.float32, *,
                    regression: bool = False) -> Params:
    """Auxiliary network: one block (same type as last device-side block,
    i.e. the last pattern position) + factorized dense classifier.  With
    ``regression=True`` (continuous-input device blocks, e.g. the whisper
    encoder) the head projects back to d_model for next-frame MSE."""
    mixer, ffn = cfg.pattern[-1]
    k1, k2, k3 = jax.random.split(rng, 3)
    p = {
        "block": _block_init(k1, cfg, mixer, ffn, dtype),
        "norm": rmsnorm_init(cfg.d_model, dtype),
        "head_in": dense_init(k2, cfg.d_model, cfg.aux_dim, dtype),
    }
    if regression:
        p["head_reg"] = dense_init(k3, cfg.aux_dim, cfg.d_model, dtype)
    else:
        p["head_out"] = dense_init(k3, cfg.aux_dim, cfg.vocab, dtype)
    return p


def split_params(params: Params, cfg: ArchConfig, l_split: int):
    """Split at period boundary l_split in [1, n_periods - 1].

    Enc-dec (audio): the device block is the *encoder prefix* (input = the
    frontend frame embeddings, so no token embedding on device); the whole
    decoder stays server-side (it cross-attends to the *final* encoder
    states, which only the server produces)."""
    dev = {"blocks": _slice_stacks(params["blocks"], 0, l_split)}
    srv = {"blocks": _slice_stacks(params["blocks"], l_split, cfg.n_periods),
           "final_norm": params["final_norm"]}
    if not cfg.n_decoder_layers:
        dev["embed"] = params["embed"]
    if not cfg.tie_embeddings:
        srv["lm_head"] = params["lm_head"]
    else:
        srv["embed_out"] = params["embed"]  # tied head lives server-side
    if cfg.n_decoder_layers:
        srv["dec_blocks"] = params["dec_blocks"]
        srv["dec_norm"] = params["dec_norm"]
    return dev, srv


def merge_params(dev: Params, srv: Params, cfg: ArchConfig) -> Params:
    blocks = [jax.tree.map(lambda a, b: jnp.concatenate([a, b]), d, s)
              for d, s in zip(dev["blocks"], srv["blocks"])]
    out = {"embed": dev.get("embed", srv.get("embed_out")), "blocks": blocks,
           "final_norm": srv["final_norm"]}
    if "lm_head" in srv:
        out["lm_head"] = srv["lm_head"]
    if "dec_blocks" in srv:
        out["dec_blocks"] = srv["dec_blocks"]
        out["dec_norm"] = srv["dec_norm"]
    return out


def device_forward(dev_params: Params, cfg: ArchConfig, tokens, *,
                   frontend=None, use_kernel=False, parallelism=None,
                   remat=True):
    """Run the device-side block; returns activations (B, S, D).

    For enc-dec (whisper) the device block is the *encoder* prefix, so the
    input is the frontend frame embeddings (tokens is (B, F, D) floats).
    For VLM the device block may contain cross-attn layers: `frontend`
    carries the local image-patch embeddings (devices own their data)."""
    h = dev_params["embed"][tokens] if tokens.ndim == 2 else tokens
    positions = jnp.arange(h.shape[1])[None, :]
    h, aux = _run_stack(dev_params["blocks"], cfg, h, positions=positions,
                        frontend=frontend, use_kernel=use_kernel,
                        parallelism=parallelism, remat=remat)
    return h, aux


def aux_head_loss(aux_params: Params, cfg: ArchConfig, acts, labels, *,
                  frontend=None):
    """Local loss f_d through the auxiliary network (Alg. 1 lines 7-8).

    Default (§3.2.2): one block of the same type as the last device-side
    layer + a factorized dense classifier; CE against the local labels.
    For continuous-input device blocks (whisper encoder: no token labels at
    frame granularity) the head regresses the next frame embedding and the
    loss is MSE — labels is then the (B, S, D) input embedding stream."""
    mixer, ffn = cfg.pattern[-1]
    positions = jnp.arange(acts.shape[1])[None, :]
    h, _ = _apply_block(aux_params["block"], cfg, mixer, ffn, acts,
                        positions=positions, frontend=frontend)
    h = rmsnorm_apply(aux_params["norm"], h)
    if labels.ndim == 3:  # regression: predict next input frame
        pred = (h @ aux_params["head_in"]) @ aux_params["head_reg"]
        target = jnp.roll(labels, -1, axis=1)
        err = (pred[:, :-1] - target[:, :-1]).astype(jnp.float32)
        return jnp.mean(jnp.square(err))
    return _chunked_ce(
        lambda hc: (hc @ aux_params["head_in"]) @ aux_params["head_out"],
        h, labels, jnp.ones(labels.shape, jnp.float32), cfg.ce_chunk)


def device_train_loss(dev_params: Params, aux_params: Params, cfg: ArchConfig,
                      tokens, labels, *, frontend=None, use_kernel=False,
                      parallelism=None, remat=True):
    """Device-side objective F_d (Eq. 4): aux-head CE on local data.
    Returns (loss, activations) — activations are what gets shipped to the
    server (detached there; the server never sends gradients back)."""
    acts, moe_aux = device_forward(dev_params, cfg, tokens, frontend=frontend,
                                   use_kernel=use_kernel,
                                   parallelism=parallelism, remat=remat)
    loss = aux_head_loss(aux_params, cfg, acts, labels, frontend=frontend) \
        + 0.01 * moe_aux
    return loss, acts


def server_forward_loss(srv_params: Params, cfg: ArchConfig, acts, labels, *,
                        frontend=None, use_kernel=False, parallelism=None,
                        remat=True, aux_weight=0.01):
    """Server-side objective F_s (Eq. 5): centralized training on activations
    ξ ~ A.  `acts` arrive detached (lax.stop_gradient at call site mirrors
    the no-gradient-to-device property).  `frontend` carries patch/frame
    embeddings for server-side cross-attention layers (VLM)."""
    acts = jax.lax.stop_gradient(acts)
    positions = jnp.arange(acts.shape[1])[None, :]
    h, moe_aux = _run_stack(srv_params["blocks"], cfg, acts,
                            positions=positions, frontend=frontend,
                            use_kernel=use_kernel, parallelism=parallelism,
                            remat=remat)
    h = rmsnorm_apply(srv_params["final_norm"], h)
    if "lm_head" in srv_params:
        head = {"lm_head": srv_params["lm_head"]}
    else:
        head = {"embed": srv_params["embed_out"]}
    loss = chunked_ce_loss(head, cfg, h, labels)
    return loss + aux_weight * moe_aux


def server_encdec_loss(srv_params: Params, cfg: ArchConfig, acts, tokens,
                       labels, *, use_kernel=False, parallelism=None,
                       remat=True, aux_weight=0.01):
    """Server-side objective for enc-dec archs (whisper): finish the encoder
    on the device activations, then run the full decoder with cross-attn to
    the final encoder states, next-token CE on the local transcript."""
    acts = jax.lax.stop_gradient(acts)
    positions = jnp.arange(acts.shape[1])[None, :]
    enc_h, aux_e = _run_stack(srv_params["blocks"], cfg, acts,
                              positions=positions, use_kernel=use_kernel,
                              parallelism=parallelism, remat=remat)
    enc_h = rmsnorm_apply(srv_params["final_norm"], enc_h)
    dec_cfg = _decoder_cfg(cfg)
    h = srv_params["embed_out"][tokens] if "embed_out" in srv_params \
        else srv_params["lm_head"].T[tokens]
    dpos = jnp.arange(h.shape[1])[None, :]
    h, aux_d = _run_stack(srv_params["dec_blocks"], dec_cfg, h,
                          positions=dpos, frontend=enc_h,
                          use_kernel=use_kernel, parallelism=parallelism,
                          remat=remat)
    h = rmsnorm_apply(srv_params["dec_norm"], h)
    head = {"embed": srv_params["embed_out"]} if "embed_out" in srv_params \
        else {"lm_head": srv_params["lm_head"]}
    loss = chunked_ce_loss(head, cfg, h, labels)
    return loss + aux_weight * (aux_e + aux_d)
