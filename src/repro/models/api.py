"""Unified architecture description consumed by the model zoo.

One :class:`ArchConfig` describes any of the assigned architectures.  The
layer stack is a repeating *period*: ``pattern`` lists (mixer, ffn) pairs;
the stack is ``pattern * n_periods`` where ``n_periods = n_layers /
len(pattern)``.  Per-position parameters are stacked over periods so the
forward pass scans over periods (HLO size independent of depth).

Mixers:  "attn" (global self-attn), "local" (sliding window), "mamba",
         "cross" (cross-attention to frontend embeddings), "none"
FFNs:    "dense", "moe", "none"
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

from .attention import AttentionConfig
from .mamba import MambaConfig
from .mlp import MlpConfig, MoeConfig


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # lm | vlm | ssm | hybrid | moe | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    pattern: tuple = (("attn", "dense"),)
    head_dim: int | None = None
    qk_norm: bool = False
    attn_softcap: float | None = None
    final_softcap: float | None = None
    window: int | None = None          # sliding-window size for "local" mixers
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    activation: str = "swiglu"
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.0
    # Mamba / SSD
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # enc-dec (audio): n_layers counts encoder layers; decoder mirrors it
    n_decoder_layers: int = 0
    # vlm / audio frontend stub: number of frontend embedding positions
    # (supplied pre-computed by input_specs); 0 = not used
    frontend_len: int = 0
    # FedOptima aux head bottleneck dim (factorized aux classifier)
    aux_dim: int = 512
    # loss chunking (sequence positions per CE chunk)
    ce_chunk: int = 512
    # query-chunk size for the jnp attention path (memory bound)
    attn_chunk: int = 1024

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % self.period == 0, (self.name, self.n_layers, self.period)
        return self.n_layers // self.period

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def attn_cfg(self, mixer: str) -> AttentionConfig:
        return AttentionConfig(
            d_model=self.d_model, n_heads=self.n_heads, n_kv_heads=self.n_kv_heads,
            head_dim=self.head_dim, qk_norm=self.qk_norm,
            attn_softcap=self.attn_softcap,
            window=self.window if mixer == "local" else None,
            rope_theta=self.rope_theta, causal=(self.family != "audio_enc"),
            chunk_q=self.attn_chunk)

    def cross_cfg(self) -> AttentionConfig:
        return AttentionConfig(
            d_model=self.d_model, n_heads=self.n_heads, n_kv_heads=self.n_kv_heads,
            head_dim=self.head_dim, causal=False, chunk_q=self.attn_chunk)

    def mlp_cfg(self) -> MlpConfig:
        return MlpConfig(d_model=self.d_model, d_ff=self.d_ff, activation=self.activation)

    def moe_cfg(self) -> MoeConfig:
        return MoeConfig(d_model=self.d_model, d_ff=self.d_ff,
                         n_experts=self.n_experts, top_k=self.top_k,
                         activation=self.activation)

    def mamba_cfg(self) -> MambaConfig:
        return MambaConfig(d_model=self.d_model, d_state=self.ssm_state,
                           head_dim=self.ssm_head_dim, chunk=self.ssm_chunk)

    def scaled(self, **kw) -> "ArchConfig":
        """Reduced copy for smoke tests."""
        return replace(self, **kw)

    @property
    def long_context_ok(self) -> bool:
        """True when the arch runs the long_500k cell: SSM/hybrid families
        (state-space layers carry the context; the few attention layers in a
        hybrid hold an O(T) KV cache at batch 1, which is fine for decode).
        Pure full-attention archs are skipped per the assignment brief."""
        return self.family in ("ssm", "hybrid")
