"""Feed-forward blocks: dense (SwiGLU / GELU) and mixture-of-experts.

MoE design (TPU-native):
  * experts' weights are stacked on a leading ``experts`` axis and sharded
    over the ``model`` mesh axis (expert parallelism);
  * routing uses top-k gating with softmax-renormalised weights;
  * dispatch is dense "einsum-style" (one-hot combine) — on TPU this lowers
    to an all-to-all across the expert axis when sharded.  A capacity factor
    bounds per-expert work for the dropping variant; the default path is
    dropless dense-dispatch which is exactly what the oracle computes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from .common import dense_init, gelu, silu

Params = Any


@dataclass(frozen=True)
class MlpConfig:
    d_model: int
    d_ff: int
    activation: str = "swiglu"   # "swiglu" | "gelu"


@dataclass(frozen=True)
class MoeConfig:
    d_model: int
    d_ff: int                    # per-expert hidden dim
    n_experts: int
    top_k: int
    router_jitter: float = 0.0
    activation: str = "swiglu"


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------

GATED = ("swiglu", "geglu")


def _act(name):
    return silu if name == "swiglu" else gelu


def mlp_init(rng, cfg: MlpConfig, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(rng, 3)
    if cfg.activation in GATED:
        return {
            "w_gate": dense_init(k1, cfg.d_model, cfg.d_ff, dtype),
            "w_up": dense_init(k2, cfg.d_model, cfg.d_ff, dtype),
            "w_down": dense_init(k3, cfg.d_ff, cfg.d_model, dtype),
        }
    return {
        "w_up": dense_init(k1, cfg.d_model, cfg.d_ff, dtype),
        "w_down": dense_init(k2, cfg.d_ff, cfg.d_model, dtype),
    }


def mlp_apply(params: Params, cfg: MlpConfig, x: jnp.ndarray,
              parallelism=None) -> jnp.ndarray:
    con = parallelism.ffn_hidden if parallelism is not None else (lambda t: t)
    if cfg.activation in GATED:
        a = _act(cfg.activation)
        h = a(con(x @ params["w_gate"])) * con(x @ params["w_up"])
        return h @ params["w_down"]
    return gelu(con(x @ params["w_up"])) @ params["w_down"]


# ---------------------------------------------------------------------------
# Mixture of experts
# ---------------------------------------------------------------------------

def moe_init(rng, cfg: MoeConfig, dtype=jnp.float32) -> Params:
    k0, k1, k2, k3 = jax.random.split(rng, 4)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    scale_in = 1.0 / D ** 0.5
    scale_out = 1.0 / F ** 0.5
    p = {
        "router": dense_init(k0, D, E, dtype),
        "we_gate": jax.random.normal(k1, (E, D, F), dtype) * scale_in,
        "we_up": jax.random.normal(k2, (E, D, F), dtype) * scale_in,
        "we_down": jax.random.normal(k3, (E, F, D), dtype) * scale_out,
    }
    if cfg.activation not in GATED:
        del p["we_gate"]
    return p


def moe_routing(params: Params, cfg: MoeConfig, x: jnp.ndarray):
    """x: (T, D) -> (weights (T, E) sparse in top-k, aux_loss scalar)."""
    logits = x.astype(jnp.float32) @ params["router"].astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, cfg.top_k)                       # (T, k)
    top_w = top_w / jnp.clip(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)
    # dense combine weights: scatter top-k back to (T, E)
    combine = jnp.zeros_like(probs)
    combine = jax.vmap(lambda c, i, w: c.at[i].set(w))(combine, top_idx, top_w)
    # load-balancing auxiliary loss (Switch-style): E * sum_e f_e * p_e
    f = jnp.mean((combine > 0).astype(jnp.float32), axis=0)   # fraction routed
    p = jnp.mean(probs, axis=0)                               # mean router prob
    aux = cfg.n_experts * jnp.sum(f * p)
    return combine, aux


def moe_apply(params: Params, cfg: MoeConfig, x: jnp.ndarray):
    """x: (B, S, D) -> (out (B, S, D), aux_loss). Dense (dropless) dispatch.

    einsum formulation: per-expert FFN applied to the full token set,
    weighted by the sparse combine matrix.  XLA's SPMD partitioner turns the
    (T, E) contraction into an all-to-all when experts are sharded on the
    ``model`` axis.  FLOP-accurate for roofline purposes in the dense form;
    MODEL_FLOPS accounting uses top_k/E of it (active experts only).
    """
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    combine, aux = moe_routing(params, cfg, xt)               # (T, E)
    combine = combine.astype(x.dtype)

    # Gather tokens per expert via dense einsum (dropless).
    # h_e = act(x W_gate^e) * (x W_up^e);  y = sum_e combine[:, e] * h_e W_down^e
    if cfg.activation in GATED:
        gate = jnp.einsum("td,edf->tef", xt, params["we_gate"])
        up = jnp.einsum("td,edf->tef", xt, params["we_up"])
        h = _act(cfg.activation)(gate) * up
    else:
        h = gelu(jnp.einsum("td,edf->tef", xt, params["we_up"]))
    y = jnp.einsum("tef,efd,te->td", h, params["we_down"], combine)
    return y.reshape(B, S, D), aux


def _top_k_route(params: Params, cfg: MoeConfig, xt: jnp.ndarray):
    """xt: (T, D) -> (top_idx (T,k) int32, top_w (T,k) f32, aux scalar)."""
    logits = xt.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / jnp.clip(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)
    # load-balance aux loss on the full distribution
    oh = jax.nn.one_hot(top_idx, cfg.n_experts, dtype=jnp.float32).sum(axis=1)
    f = jnp.mean(oh, axis=0) / cfg.top_k
    p = jnp.mean(probs, axis=0)
    aux = cfg.n_experts * jnp.sum(f * p)
    return top_idx.astype(jnp.int32), top_w, aux


def moe_apply_grouped(params: Params, cfg: MoeConfig, x: jnp.ndarray, *,
                      expert_offset: int = 0, n_local_experts: int | None = None,
                      capacity_factor: float = 1.0, psum_axis: str | None = None,
                      parallelism=None):
    """Capacity-bounded grouped-matmul MoE (FLOPs ∝ top_k, not E).

    Scalable dispatch: no (T, E, C) one-hot.  Tokens hitting a local expert
    are scattered into per-expert slot queues (gather/scatter of indices),
    the experts run as one batched matmul (E_l, C, D) x (E_l, D, F), and
    contributions are combined back per token.  Overflow beyond the static
    capacity C is dropped (GShard/Switch semantics).

    Expert parallelism: call under ``shard_map`` with tokens replicated over
    the ``model`` axis and ``params`` holding only this shard's experts
    (leading E axis pre-sliced).  Pass ``expert_offset``/``n_local_experts``
    for this shard's range and ``psum_axis="model"`` to sum partial outputs.
    Without those arguments this is a standalone exact (modulo drops)
    single-host MoE.
    """
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    E = cfg.n_experts
    E_l = n_local_experts if n_local_experts is not None else E
    k = cfg.top_k
    N = T * k
    C = max(1, int(capacity_factor * T * k / E))

    top_idx, top_w, aux = _top_k_route(params, cfg, xt)       # router is replicated
    eflat = top_idx.reshape(N)                                 # expert id per assignment
    tflat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)      # token id per assignment
    wflat = top_w.reshape(N)

    # Sort-based dispatch: stable-sort assignments by (local) expert id —
    # position within the sorted run is the slot index.  O(N log N) with no
    # (N, E) one-hot/cumsum intermediates (those dominate HBM+collective
    # traffic at pod scale; see EXPERIMENTS.md §Perf).  Stable sort keeps
    # earlier tokens first, so capacity drops match the cumsum formulation.
    e_rel = eflat - expert_offset
    own = (e_rel >= 0) & (e_rel < E_l)
    e_key = jnp.where(own, e_rel, E_l).astype(jnp.int32)       # foreign -> end
    order = jnp.argsort(e_key, stable=True)
    e_sorted = e_key[order]
    counts = jnp.zeros((E_l + 1,), jnp.int32).at[e_sorted].add(1)
    starts = jnp.cumsum(counts) - counts                       # (E_l + 1,)
    pos_sorted = jnp.arange(N, dtype=jnp.int32) - starts[e_sorted]
    keep_sorted = (e_sorted < E_l) & (pos_sorted < C)
    slot_sorted = jnp.where(keep_sorted, e_sorted * C + pos_sorted, E_l * C)
    # back to assignment order for the combine
    inv = jnp.zeros((N,), jnp.int32).at[order].set(
        jnp.arange(N, dtype=jnp.int32))
    keep = keep_sorted[inv]
    slot = slot_sorted[inv]

    # scatter token ids into slots; sentinel row (index T) stays zero
    slot_token = jnp.full((E_l * C + 1,), T, dtype=jnp.int32)
    slot_token = slot_token.at[slot].set(tflat, mode="drop")
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)], axis=0)
    xe = xt_pad[slot_token[:-1]].reshape(E_l, C, D)            # (E_l, C, D)

    con = parallelism.experts if parallelism is not None else (lambda t: t)
    xe = con(xe)
    if cfg.activation in GATED:
        h = _act(cfg.activation)(con(jnp.einsum("ecd,edf->ecf", xe, params["we_gate"]))) * \
            con(jnp.einsum("ecd,edf->ecf", xe, params["we_up"]))
    else:
        h = gelu(con(jnp.einsum("ecd,edf->ecf", xe, params["we_up"])))
    ye = con(jnp.einsum("ecf,efd->ecd", h, params["we_down"])).reshape(E_l * C, D)

    # combine back: each kept assignment adds w * ye[slot] to its token
    ye_pad = jnp.concatenate([ye, jnp.zeros((1, D), ye.dtype)], axis=0)
    contrib = ye_pad[jnp.where(keep, slot, E_l * C)]           # (N, D)
    y = jnp.zeros((T, D), x.dtype).at[tflat].add(
        contrib * jnp.where(keep, wflat, 0.0)[:, None].astype(x.dtype))
    if psum_axis is not None:
        y = jax.lax.psum(y, psum_axis)
        aux = jax.lax.psum(aux, psum_axis) / jax.lax.psum(1, psum_axis)
    return y.reshape(B, S, D), aux
