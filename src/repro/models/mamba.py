"""Mamba2 block (state-space duality / SSD), pure JAX.

Follows arXiv:2405.21060.  The sequence mixer is the chunked SSD algorithm:
within-chunk quadratic (attention-like) term + across-chunk linear
recurrence, which is the TPU-friendly form (big matmuls for the MXU, scan
only over T/Q chunks).  A step-by-step recurrence is provided for decode,
and `repro.kernels.ref.ssd_reference` holds the naive oracle.

Shapes (per mamba2 conventions):
  x      (B, T, H, P)   inputs per head      (P = head_dim)
  dt     (B, T, H)      per-head step size (after softplus + bias)
  A      (H,)           negative decay rates (stored as A_log)
  B, C   (B, T, G, N)   input/output projections (G groups, N = ssm state)
  state  (B, H, N, P)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from .common import dense_init, rmsnorm_apply, rmsnorm_init, silu

Params = Any


@dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_state: int = 128          # N
    head_dim: int = 64          # P
    expand: int = 2
    n_groups: int = 1
    conv_kernel: int = 4
    chunk: int = 256            # SSD chunk length Q
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def mamba_init(rng, cfg: MambaConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(rng, 5)
    H, G, N = cfg.n_heads, cfg.n_groups, cfg.d_state
    d_in_proj = 2 * cfg.d_inner + 2 * G * N + H  # z, x, B, C, dt
    # dt bias so softplus(dt_bias) spans [dt_min, dt_max] log-uniformly
    u = jax.random.uniform(ks[3], (H,), jnp.float32)
    dt_init = jnp.exp(u * (jnp.log(cfg.dt_max) - jnp.log(cfg.dt_min)) + jnp.log(cfg.dt_min))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inverse softplus
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, d_in_proj, dtype),
        "conv_w": jax.random.normal(ks[1], (cfg.conv_kernel, cfg.conv_dim), dtype) * 0.2,
        "conv_b": jnp.zeros((cfg.conv_dim,), dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm": rmsnorm_init(cfg.d_inner, dtype),
        "out_proj": dense_init(ks[4], cfg.d_inner, cfg.d_model, dtype),
    }


def _split_in_proj(cfg: MambaConfig, zxbcdt: jnp.ndarray):
    H, G, N, Di = cfg.n_heads, cfg.n_groups, cfg.d_state, cfg.d_inner
    z, xBC, dt = jnp.split(zxbcdt, [Di, Di + cfg.conv_dim], axis=-1)
    return z, xBC, dt  # dt: (..., H)


def _causal_conv(xBC: jnp.ndarray, conv_w, conv_b, cache=None):
    """Depthwise causal conv over time.  xBC: (B, T, Cd); conv_w: (K, Cd)."""
    K = conv_w.shape[0]
    if cache is None:
        pad = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = cache  # (B, K-1, Cd) — the last K-1 inputs
    xp = jnp.concatenate([pad, xBC], axis=1)
    new_cache = xp[:, -(K - 1):, :]
    # sum_k w[k] * x[t - (K-1) + k]
    out = sum(xp[:, k:k + xBC.shape[1], :] * conv_w[k][None, None, :] for k in range(K))
    return silu(out + conv_b), new_cache


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None):
    """Chunked SSD scan (single pass over chunks, remat'd body).

    x: (b, T, H, P); dt: (b, T, H); A: (H,); B, C: (b, T, G, N).
    Returns (y (b, T, H, P), final_state (b, H, N, P)).
    T must be divisible by ``chunk``.

    Per chunk: the quadratic intra-chunk term (C_t·B_s masked-decay matmul),
    the inter-chunk contribution from the carried state, and the state
    update — one ``lax.scan`` over T/Q chunks carrying (b, H, N, P).  The
    body is checkpointed so the (Q, Q) decay matrix is never live across
    chunks; this is the same schedule the Pallas ``ssd`` kernel runs on TPU
    (grid over chunks, state in VMEM).
    """
    b, T, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    Q = chunk
    nc = T // Q
    rep = H // G

    xb = (x * dt[..., None]).astype(jnp.float32)                  # dt-weighted input
    la = (dt * A[None, None, :]).astype(jnp.float32)              # log decay per step (<0)

    # chunked, scan-major layouts: (nc, b, Q, ...)
    xb = jnp.moveaxis(xb.reshape(b, nc, Q, H, P), 1, 0)
    la = jnp.moveaxis(la.reshape(b, nc, Q, H), 1, 0)
    Bc = jnp.moveaxis(B.reshape(b, nc, Q, G, N).astype(jnp.float32), 1, 0)
    Cc = jnp.moveaxis(C.reshape(b, nc, Q, G, N).astype(jnp.float32), 1, 0)
    mask = jnp.tril(jnp.ones((Q, Q), bool))

    h0 = (jnp.zeros((b, H, N, P), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    @jax.checkpoint
    def chunk_fn(h, xb_c, la_c, B_c, C_c):
        Bh = jnp.repeat(B_c, rep, axis=2)                         # (b,Q,H,N)
        Ch = jnp.repeat(C_c, rep, axis=2)
        Lcum = jnp.cumsum(la_c, axis=1)                           # (b,Q,H)
        Ltot = Lcum[:, -1, :]                                     # (b,H)
        # intra-chunk quadratic term
        diff = Lcum[:, :, None, :] - Lcum[:, None, :, :]          # (b,Q,Q,H)
        decay = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bthn,bshn->btsh", Ch, Bh) * decay
        y = jnp.einsum("btsh,bshp->bthp", scores, xb_c)
        # inter-chunk contribution from the carried state
        y = y + jnp.einsum("bthn,bhnp->bthp", Ch * jnp.exp(Lcum)[..., None], h)
        # state update
        w_state = jnp.exp(Ltot[:, None, :] - Lcum)                # (b,Q,H)
        S_c = jnp.einsum("bshn,bsh,bshp->bhnp", Bh, w_state, xb_c)
        h = h * jnp.exp(Ltot)[..., None, None] + S_c
        return h, y

    def body(h, inp):
        return chunk_fn(h, *inp)

    h_final, ys = jax.lax.scan(body, h0, (xb, la, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, T, H, P)
    return y, h_final


def mamba_apply(params: Params, cfg: MambaConfig, x: jnp.ndarray,
                use_kernel: bool = False, return_state: bool = False):
    """Full-sequence forward.  x: (B, T, d_model) -> (B, T, d_model).
    With return_state=True also returns the decode state ({"ssm","conv"})
    after the last position — used by prefill to prime caches."""
    Bb, T, _ = x.shape
    H, G, N, P = cfg.n_heads, cfg.n_groups, cfg.d_state, cfg.head_dim
    zxbcdt = x @ params["in_proj"]
    z, xBC_raw, dt = _split_in_proj(cfg, zxbcdt)
    xBC, _ = _causal_conv(xBC_raw, params["conv_w"], params["conv_b"])
    xi, Bm, Cm = jnp.split(xBC, [cfg.d_inner, cfg.d_inner + G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xi = xi.reshape(Bb, T, H, P)
    Bm = Bm.reshape(Bb, T, G, N)
    Cm = Cm.reshape(Bb, T, G, N)
    if use_kernel and not return_state:
        from repro.kernels import ops as kops
        # differentiable (custom_vjp); ops.ssd clamps chunk to T and pads
        y = kops.ssd(xi, dt, A, Bm, Cm, chunk=cfg.chunk)
        state = None
    else:
        # pad T to a chunk multiple (zero dt => identity decay, zero input)
        Q = min(cfg.chunk, T)
        pad = (-T) % Q
        if pad:
            xi_p = jnp.pad(xi, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bm_p = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Cm_p = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
            y, h_final = ssd_chunked(xi_p, dt_p, A, Bm_p, Cm_p, Q)
            y = y[:, :T]
        else:
            y, h_final = ssd_chunked(xi, dt, A, Bm, Cm, Q)
        state = h_final
    y = y + params["D"][None, None, :, None] * xi.astype(jnp.float32)
    y = y.reshape(Bb, T, cfg.d_inner).astype(x.dtype)
    y = rmsnorm_apply(params["norm"], y * silu(z))
    out = y @ params["out_proj"]
    if return_state:
        K = cfg.conv_kernel
        pad = jnp.zeros((Bb, K - 1, xBC_raw.shape[-1]), xBC_raw.dtype)
        conv_cache = jnp.concatenate([pad, xBC_raw], axis=1)[:, -(K - 1):, :]
        return out, {"ssm": state, "conv": conv_cache}
    return out


# ---------------------------------------------------------------------------
# Decode (single token, recurrent state)
# ---------------------------------------------------------------------------

def mamba_state_init(cfg: MambaConfig, batch: int, dtype=jnp.float32):
    return {
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.conv_dim), dtype),
    }


def mamba_decode(params: Params, cfg: MambaConfig, x: jnp.ndarray, state):
    """One-step decode.  x: (B, 1, d_model) -> (y (B, 1, d_model), new state)."""
    Bb = x.shape[0]
    H, G, N, P = cfg.n_heads, cfg.n_groups, cfg.d_state, cfg.head_dim
    zxbcdt = x @ params["in_proj"]
    z, xBC, dt = _split_in_proj(cfg, zxbcdt)
    xBC, conv_cache = _causal_conv(xBC, params["conv_w"], params["conv_b"],
                                   cache=state["conv"])
    xi, Bm, Cm = jnp.split(xBC, [cfg.d_inner, cfg.d_inner + G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]   # (B,H)
    A = -jnp.exp(params["A_log"])
    xi = xi.reshape(Bb, H, P).astype(jnp.float32)
    Bm = jnp.repeat(Bm.reshape(Bb, G, N), H // G, axis=1).astype(jnp.float32)  # (B,H,N)
    Cm = jnp.repeat(Cm.reshape(Bb, G, N), H // G, axis=1).astype(jnp.float32)

    a = jnp.exp(dt * A[None, :])                                  # (B,H)
    h = state["ssm"] * a[..., None, None] + \
        jnp.einsum("bhn,bh,bhp->bhnp", Bm, dt, xi)
    y = jnp.einsum("bhn,bhnp->bhp", Cm, h) + params["D"][None, :, None] * xi
    y = y.reshape(Bb, 1, cfg.d_inner).astype(x.dtype)
    y = rmsnorm_apply(params["norm"], y * silu(z))
    return y @ params["out_proj"], {"ssm": h, "conv": conv_cache}
