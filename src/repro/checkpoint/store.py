"""Fault-tolerant checkpoint store: atomic, versioned, verified snapshots.

Layout::

    <dir>/step_000120/arrays.npz     # flattened leaves
    <dir>/step_000120/extras.npz     # optional side payload (same format)
    <dir>/step_000120/tree.json      # treedef + keys + checksums + metadata
    <dir>/step_000120/COMMITTED      # written last — presence = valid

Crash consistency is layered:

* **atomic commit** — writes go to a temp dir, every file (and the temp
  dir itself) is fsynced, then the dir is renamed into place; a crash
  mid-write never corrupts the store, and a committed rename implies the
  payload bytes are durable (rename-before-data is the classic torn-
  checkpoint bug checkpoint-without-flush would otherwise widen — the
  save now runs while later rounds are still in flight, so the window
  between "save returned" and "data on disk" overlaps live training).
  ``latest_step`` ignores uncommitted snapshots (a missing COMMITTED
  marker = the rename never happened).
* **per-array checksums** — the manifest records a CRC32 per leaf
  (``checksums`` / ``extra_checksums``), so a snapshot torn AFTER commit
  (bit rot, truncation, a partial copy) is detected at restore instead of
  silently half-loading; every restore path raises
  :class:`CorruptSnapshotError` rather than returning damaged arrays.
* **verified fallback** — :func:`verify_snapshot` checks one snapshot end
  to end and :func:`latest_verified_step` walks committed snapshots newest
  first, returning the newest one that verifies plus the list it skipped
  (``runtime.fault_tolerance.resume_or_init`` resumes from that and
  reports the skips).  Pre-checksum snapshots verify by loadability only.

``extras`` is a second, independently-structured pytree riding the same
atomic snapshot — used for state whose structure varies run-to-run and so
can't live inside the main tree (e.g. the control plane's per-group
retention store: which groups are held changes with churn; the JSON
``metadata`` describes the structure, ``extras.npz`` carries the arrays).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import zlib
from typing import Any

import jax
import numpy as np


class CorruptSnapshotError(RuntimeError):
    """A committed snapshot failed verification (torn file, checksum
    mismatch, unreadable manifest, missing/mismatched leaves)."""


def _flatten_with_paths(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def _checksums(flat: dict[str, np.ndarray]) -> dict[str, int]:
    return {k: _crc(v) for k, v in flat.items()}


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return          # platforms without O_RDONLY dirs: best effort
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(directory: str, step: int, tree: Any, metadata: dict | None = None,
         retain: int = 3, extras: Any = None) -> str:
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    final = os.path.join(directory, name)
    tmp = tempfile.mkdtemp(dir=directory, prefix=f".tmp_{name}_")
    try:
        flat = _flatten_with_paths(tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        treedef = jax.tree_util.tree_structure(tree)
        meta = {"step": step, "treedef": str(treedef),
                "keys": list(flat.keys()),
                "checksums": _checksums(flat),
                "metadata": metadata or {}}
        if extras is not None and jax.tree_util.tree_leaves(extras):
            eflat = _flatten_with_paths(extras)
            np.savez(os.path.join(tmp, "extras.npz"), **eflat)
            meta["extra_keys"] = list(eflat.keys())
            meta["extra_checksums"] = _checksums(eflat)
        with open(os.path.join(tmp, "tree.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
        # durability before visibility: every payload byte must be on disk
        # before the rename makes the snapshot discoverable — otherwise a
        # power cut after commit leaves a COMMITTED marker over torn data
        # (the checksums would catch it, but the snapshot is lost; with
        # fsync it is never lost)
        for fname in os.listdir(tmp):
            _fsync_file(os.path.join(tmp, fname))
        _fsync_dir(tmp)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _fsync_dir(directory)     # persist the rename itself
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(directory, retain)
    return final


def _gc(directory: str, retain: int):
    steps = committed_steps(directory)
    for s in steps[:-retain] if retain else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)


def committed_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and \
                os.path.exists(os.path.join(directory, name, "COMMITTED")):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = committed_steps(directory)
    return steps[-1] if steps else None


def _load_manifest(directory: str, step: int) -> dict:
    path = os.path.join(directory, f"step_{step:08d}", "tree.json")
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as err:
        raise CorruptSnapshotError(
            f"unreadable snapshot manifest {path}: {err}") from err


def _load_npz(npz_path: str) -> dict[str, np.ndarray]:
    try:
        with np.load(npz_path) as z:
            return {k: z[k] for k in z.files}
    except Exception as err:     # zipfile/npy corruption surfaces variedly
        raise CorruptSnapshotError(
            f"unreadable snapshot payload {npz_path}: {err}") from err


def _verify_flat(flat: dict, keys: list, checksums: dict | None,
                 npz_path: str) -> None:
    missing = [k for k in keys if k not in flat]
    if missing:
        raise CorruptSnapshotError(
            f"{npz_path} is missing {len(missing)} manifest leaves "
            f"(first: {missing[0]!r})")
    if checksums:
        for k in keys:
            want = checksums.get(k)
            if want is not None and _crc(flat[k]) != want:
                raise CorruptSnapshotError(
                    f"checksum mismatch for leaf {k!r} in {npz_path} — "
                    "the snapshot was torn after commit; restore from an "
                    "older verified snapshot instead")


def _restore_npz(npz_path: str, like: Any, *, keys: list | None = None,
                 checksums: dict | None = None) -> Any:
    """Load a flat-keyed npz back into the structure of ``like``,
    verifying manifest checksums when available."""
    flat = _load_npz(npz_path)
    ref = _flatten_with_paths(jax.tree.map(
        lambda x: np.zeros(x.shape, x.dtype) if hasattr(x, "shape") else x, like))
    leaves, treedef = jax.tree_util.tree_flatten(like)
    ref_keys = list(ref.keys())
    if len(ref_keys) != len(leaves):
        raise CorruptSnapshotError(
            f"restore template flattens to {len(ref_keys)} keyed leaves "
            f"but {len(leaves)} tree leaves — the template's structure "
            "cannot address the snapshot")
    _verify_flat(flat, keys if keys is not None else ref_keys, checksums,
                 npz_path)
    try:
        out = [flat[k] for k in ref_keys]
    except KeyError as err:
        raise CorruptSnapshotError(
            f"{npz_path} has no leaf {err.args[0]!r} — the restore "
            "template does not match the snapshot's structure") from err
    return jax.tree_util.tree_unflatten(treedef, out)


def _committed_path(directory: str, step: int) -> str:
    path = os.path.join(directory, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, "COMMITTED")):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    return path


def restore(directory: str, step: int, like: Any) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  Leaf order follows ``like``'s treedef.  Raises
    :class:`CorruptSnapshotError` (never half-loads) when the snapshot
    was torn after commit."""
    path = _committed_path(directory, step)
    meta = _load_manifest(directory, step)
    return _restore_npz(os.path.join(path, "arrays.npz"), like,
                        keys=meta.get("keys"),
                        checksums=meta.get("checksums"))


def restore_extras(directory: str, step: int, like: Any) -> Any:
    """Restore the snapshot's side payload (see ``save(..., extras=)``)
    into the structure of ``like``.  Raises FileNotFoundError when the
    snapshot was written without extras — callers know from the metadata
    whether to expect one."""
    path = _committed_path(directory, step)
    npz = os.path.join(path, "extras.npz")
    if not os.path.exists(npz):
        raise FileNotFoundError(f"snapshot {path} has no extras payload")
    meta = _load_manifest(directory, step)
    return _restore_npz(npz, like, keys=meta.get("extra_keys"),
                        checksums=meta.get("extra_checksums"))


def restore_metadata(directory: str, step: int) -> dict:
    return _load_manifest(directory, step)["metadata"]


def verify_snapshot(directory: str, step: int) -> tuple[bool, str]:
    """End-to-end integrity check of one committed snapshot: manifest
    parses, payloads load, every manifest leaf is present, and every
    recorded checksum matches.  Snapshots written before checksums were
    recorded verify by loadability alone.  Returns (ok, reason)."""
    path = os.path.join(directory, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, "COMMITTED")):
        return False, "no COMMITTED marker"
    try:
        meta = _load_manifest(directory, step)
        flat = _load_npz(os.path.join(path, "arrays.npz"))
        _verify_flat(flat, meta.get("keys", list(flat)),
                     meta.get("checksums"), os.path.join(path, "arrays.npz"))
        if meta.get("extra_keys"):
            eflat = _load_npz(os.path.join(path, "extras.npz"))
            _verify_flat(eflat, meta["extra_keys"],
                         meta.get("extra_checksums"),
                         os.path.join(path, "extras.npz"))
    except CorruptSnapshotError as err:
        return False, str(err)
    return True, ""


def latest_verified_step(directory: str) -> tuple[int | None, list]:
    """The newest committed snapshot that passes :func:`verify_snapshot`,
    walking newest-first; snapshots skipped on the way are returned as
    ``(step, reason)`` pairs so resumers can report what was lost."""
    skipped: list[tuple[int, str]] = []
    for step in reversed(committed_steps(directory)):
        ok, reason = verify_snapshot(directory, step)
        if ok:
            return step, skipped
        skipped.append((step, reason))
    return None, skipped
