"""Fault-tolerant checkpoint store: atomic, versioned pytree snapshots.

Layout::

    <dir>/step_000120/arrays.npz     # flattened leaves
    <dir>/step_000120/extras.npz     # optional side payload (same format)
    <dir>/step_000120/tree.json      # treedef + leaf dtypes + metadata
    <dir>/step_000120/COMMITTED      # written last — presence = valid

Writes go to a temp dir and are renamed into place, so a crash mid-write
never corrupts the store (restart-safe).  ``latest_step`` ignores
uncommitted snapshots.  ``retain`` garbage-collects old snapshots.

``extras`` is a second, independently-structured pytree riding the same
atomic snapshot — used for state whose structure varies run-to-run and so
can't live inside the main tree (e.g. the control plane's per-group
retention store: which groups are held changes with churn; the JSON
``metadata`` describes the structure, ``extras.npz`` carries the arrays).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(directory: str, step: int, tree: Any, metadata: dict | None = None,
         retain: int = 3, extras: Any = None) -> str:
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    final = os.path.join(directory, name)
    tmp = tempfile.mkdtemp(dir=directory, prefix=f".tmp_{name}_")
    try:
        flat = _flatten_with_paths(tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        treedef = jax.tree_util.tree_structure(tree)
        meta = {"step": step, "treedef": str(treedef),
                "keys": list(flat.keys()), "metadata": metadata or {}}
        if extras is not None and jax.tree_util.tree_leaves(extras):
            eflat = _flatten_with_paths(extras)
            np.savez(os.path.join(tmp, "extras.npz"), **eflat)
            meta["extra_keys"] = list(eflat.keys())
        with open(os.path.join(tmp, "tree.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(directory, retain)
    return final


def _gc(directory: str, retain: int):
    steps = committed_steps(directory)
    for s in steps[:-retain] if retain else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)


def committed_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and \
                os.path.exists(os.path.join(directory, name, "COMMITTED")):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = committed_steps(directory)
    return steps[-1] if steps else None


def _restore_npz(npz_path: str, like: Any) -> Any:
    """Load a flat-keyed npz back into the structure of ``like``."""
    with np.load(npz_path) as z:
        flat = {k: z[k] for k in z.files}
    ref = _flatten_with_paths(jax.tree.map(
        lambda x: np.zeros(x.shape, x.dtype) if hasattr(x, "shape") else x, like))
    leaves, treedef = jax.tree_util.tree_flatten(like)
    keys = list(ref.keys())
    assert len(keys) == len(leaves)
    out = [flat[k] for k in keys]
    return jax.tree_util.tree_unflatten(treedef, out)


def _committed_path(directory: str, step: int) -> str:
    path = os.path.join(directory, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, "COMMITTED")):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    return path


def restore(directory: str, step: int, like: Any) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  Leaf order follows ``like``'s treedef."""
    path = _committed_path(directory, step)
    return _restore_npz(os.path.join(path, "arrays.npz"), like)


def restore_extras(directory: str, step: int, like: Any) -> Any:
    """Restore the snapshot's side payload (see ``save(..., extras=)``)
    into the structure of ``like``.  Raises FileNotFoundError when the
    snapshot was written without extras — callers know from the metadata
    whether to expect one."""
    path = _committed_path(directory, step)
    npz = os.path.join(path, "extras.npz")
    if not os.path.exists(npz):
        raise FileNotFoundError(f"snapshot {path} has no extras payload")
    return _restore_npz(npz, like)


def restore_metadata(directory: str, step: int) -> dict:
    with open(os.path.join(directory, f"step_{step:08d}", "tree.json")) as f:
        return json.load(f)["metadata"]
