from . import store
