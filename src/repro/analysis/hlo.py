"""Post-SPMD HLO cost parser: FLOPs / HBM bytes / collective bytes.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, so for
scan-over-layers models it undercounts by ~n_layers×.  This parser walks
the optimized per-device HLO text (``compiled.as_text()``), multiplies
loop bodies by their ``known_trip_count``, and reports:

  * flops            — dot/convolution FLOPs (the roofline compute term)
  * bytes            — per-op operand+output bytes of non-trivial ops (an
                       HBM-traffic estimate: optimized HLO is post-fusion,
                       so each op ≈ one kernel ≈ one round trip)
  * collective_bytes — per-collective-kind operand bytes (all-gather /
                       all-reduce / reduce-scatter / all-to-all /
                       collective-permute), trip-scaled

Everything is *per device*: the module text is the SPMD-partitioned
program, shapes are shard shapes.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast", "ragged-all-to-all")

# ops that don't move real bytes (aliases/metadata)
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "iota", "partition-id", "replica-id"}

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(\([^)]*\)|[\w\[\]{},./]+)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _type_bytes(type_str: str) -> int:
    """Bytes of an array or tuple type string."""
    total = 0
    for dtype, dims in _ARRAY_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _ARRAY_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []


@dataclass
class Op:
    name: str
    out_type: str
    opcode: str
    rest: str              # operands + attributes (raw tail of the line)
    is_root: bool = False

    @property
    def out_bytes(self) -> int:
        return _type_bytes(self.out_type)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: dict = field(default_factory=lambda: defaultdict(float))

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] += v
        return self

    def scaled(self, m: float) -> "Cost":
        out = Cost(self.flops * m, self.bytes * m)
        out.collective_bytes = defaultdict(
            float, {k: v * m for k, v in self.collective_bytes.items()})
        return out

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


class HloModule:
    """Parsed computations of one HLO module."""

    def __init__(self, text: str):
        self.comps: dict[str, list[Op]] = {}
        self.entry: str | None = None
        self.shapes: dict[str, str] = {}      # op name -> output type string
        self._body_memo: dict[str, frozenset] = {}
        cur: list[Op] | None = None
        for line in text.splitlines():
            stripped = line.strip()
            if not stripped or stripped.startswith("//"):
                continue
            if stripped.startswith("HloModule"):
                continue
            m = _COMP_RE.match(line) if line and not line.startswith(" ") else None
            if m and stripped.endswith("{"):
                cur = []
                self.comps[m.group(1)] = cur
                if line.startswith("ENTRY"):
                    self.entry = m.group(1)
                continue
            if stripped == "}":
                cur = None
                continue
            om = _OP_RE.match(line)
            if om and cur is not None:
                op = Op(om.group(1), om.group(2).strip(), om.group(3),
                        om.group(4), is_root="ROOT" in line.split("=")[0])
                cur.append(op)
                self.shapes[op.name] = op.out_type
        if self.entry is None and self.comps:
            # fall back: last computation is usually entry
            self.entry = list(self.comps)[-1]

    # ------------------------------------------------------------------
    def _operand_names(self, op: Op) -> list[str]:
        """Operand op-names cited before the first attribute."""
        head = op.rest.split("),", 1)[0]
        return re.findall(r"%([\w.\-]+)", head)

    def _dot_flops(self, op: Op) -> float:
        out_dims = _shape_dims(op.out_type)
        n_out = 1
        for d in out_dims:
            n_out *= d
        cm = _CONTRACT_RE.search(op.rest)
        operands = self._operand_names(op)
        if not cm or not operands:
            return 2.0 * n_out  # degenerate
        lhs_type = self.shapes.get(operands[0], "")
        lhs_dims = _shape_dims(lhs_type)
        k = 1
        for i in (int(x) for x in cm.group(1).split(",") if x):
            if i < len(lhs_dims):
                k *= lhs_dims[i]
        return 2.0 * n_out * k

    def _fusion_dot_flops(self, comp_name: str) -> float:
        total = 0.0
        for op in self.comps.get(comp_name, ()):
            if op.opcode == "dot":
                total += self._dot_flops(op)
            elif op.opcode == "fusion":
                cm = _CALL_RE.search(op.rest)
                if cm:
                    total += self._fusion_dot_flops(cm.group(1))
        return total

    def _root_opcode(self, comp_name: str) -> str:
        ops = self.comps.get(comp_name, ())
        for op in ops:
            if op.is_root:
                return op.opcode
        return ops[-1].opcode if ops else ""

    def _body_opcodes(self, comp_name: str) -> frozenset:
        """Opcodes inside a fusion body (nested fusions included)."""
        if comp_name in self._body_memo:
            return self._body_memo[comp_name]
        out = set()
        self._body_memo[comp_name] = frozenset()  # cycle guard
        for op in self.comps.get(comp_name, ()):
            out.add(op.opcode)
            if op.opcode == "fusion":
                cm = _CALL_RE.search(op.rest)
                if cm:
                    out |= self._body_opcodes(cm.group(1))
        self._body_memo[comp_name] = frozenset(out)
        return self._body_memo[comp_name]

    def _io_bytes(self, op: Op, exclude_fn=None) -> float:
        """HBM traffic of one kernel.

        Access-pattern-aware: in-place updates (dynamic-update-slice /
        scatter anywhere in a fusion body) touch only the update, not the
        aliased buffer; sliced reads (dynamic-slice / gather) touch only
        the slice; fusions whose real ops are only dtype ``convert``s are
        bf16-dot emulation on the CPU backend and cost nothing on TPU.

        ``exclude_fn(dims)``: buffers whose shape matches are counted as
        ZERO traffic — used to model Pallas-fused deployment, where e.g.
        attention score / SSD decay tiles live in VMEM and never round-trip
        HBM (see kernels/flash_attention.py, kernels/ssd.py)."""
        code = op.opcode
        body = frozenset((code,))
        if code == "fusion":
            cm = _CALL_RE.search(op.rest)
            if cm:
                body = self._body_opcodes(cm.group(1))

        def nbytes(type_str: str) -> float:
            if exclude_fn is not None and type_str:
                dims = _shape_dims(type_str)
                if dims and exclude_fn(tuple(dims)):
                    return 0.0
            return _type_bytes(type_str)

        out_b = nbytes(op.out_type)
        operands = [nbytes(self.shapes.get(n, ""))
                    for n in self._operand_names(op)]
        real = body - _FREE_OPS - {"convert", "copy", "bitcast", "reshape",
                                   "broadcast", "transpose"}
        if code == "fusion" and not (real - {"fusion"}):
            # pure dtype-conversion / layout fusion around a CPU f32 dot:
            # absent on a bf16-native backend — count the output write once
            return out_b
        if "dynamic-update-slice" in body or "scatter" in body:
            # in-place: buffer-sized operands are aliased (incl. dtype-copy
            # variants); traffic = the update slices, read + write
            return 2.0 * sum(b for b in operands if b < out_b)
        if "dynamic-slice" in body or "gather" in body:
            # sliced read: the big operand is touched only slice-wise
            small = sum(b for b in operands if b <= 4 * out_b)
            return 2.0 * out_b + small
        return out_b + sum(operands)

    def cost(self, comp_name: str | None = None, _memo=None,
             exclude_fn=None) -> Cost:
        """Trip-count-scaled cost of a computation (default: entry)."""
        if _memo is None:
            _memo = {}
        comp_name = comp_name or self.entry
        if comp_name in _memo:
            return _memo[comp_name]
        total = Cost()
        _memo[comp_name] = total  # break cycles defensively
        for op in self.comps.get(comp_name, ()):
            code = op.opcode
            if code in _FREE_OPS:
                continue
            base = code.removesuffix("-start").removesuffix("-done")
            if code.endswith("-done"):
                continue  # counted at -start
            io_bytes = self._io_bytes(op, exclude_fn)
            if code == "while":
                trip = 1
                tm = _TRIP_RE.search(op.rest)
                if tm:
                    trip = int(tm.group(1))
                bm = _CALL_RE.search(op.rest)
                if bm:
                    total += self.cost(bm.group(1), _memo,
                                       exclude_fn).scaled(trip)
                cm = _COND_RE.search(op.rest)
                if cm:
                    total += self.cost(cm.group(1), _memo,
                                       exclude_fn).scaled(trip)
            elif code == "conditional":
                bm = _BRANCH_RE.search(op.rest)
                if bm:
                    for b in re.findall(r"%?([\w.\-]+)", bm.group(1)):
                        total += self.cost(b, _memo, exclude_fn)
            elif code in ("call", "async-start"):
                bm = _CALL_RE.search(op.rest)
                if bm:
                    total += self.cost(bm.group(1), _memo, exclude_fn)
            elif code == "fusion":
                total.bytes += io_bytes
                bm = _CALL_RE.search(op.rest)
                if bm:
                    total.flops += self._fusion_dot_flops(bm.group(1))
            elif base in COLLECTIVES:
                operand_bytes = sum(
                    _type_bytes(self.shapes.get(n, "")) for n in
                    self._operand_names(op))
                total.collective_bytes[base] += operand_bytes
                total.bytes += io_bytes
            elif code == "dot":
                total.flops += self._dot_flops(op)
                total.bytes += io_bytes
            elif code == "convolution":
                # rough: 2 * out_elems * prod(kernel spatial + in-ch)
                total.flops += 2.0 * op.out_bytes  # placeholder lower bound
                total.bytes += io_bytes
            elif code == "custom-call":
                total.bytes += io_bytes
            else:
                total.bytes += io_bytes
        _memo[comp_name] = total
        return total


def analyze_text(hlo_text: str, exclude_fn=None) -> Cost:
    return HloModule(hlo_text).cost(exclude_fn=exclude_fn)


def analyze_compiled(compiled, exclude_fn=None) -> Cost:
    """Cost of a jax compiled executable (per device)."""
    return analyze_text(compiled.as_text(), exclude_fn=exclude_fn)
