"""Roofline model for TPU v5e meshes.

Three terms per (arch × shape × mesh), all in seconds *per step per chip*
(the HLO parsed is the per-device SPMD program, so parsed quantities are
already per-chip):

  compute_s    = HLO_FLOPs / peak_FLOPs
  memory_s     = HLO_bytes / HBM_bw
  collective_s = Σ_kind alg_factor(kind) × bytes_kind / link_bw

Hardware constants (assignment brief): 197 TFLOP/s bf16 per chip, 819 GB/s
HBM, ~50 GB/s/link ICI.  Algorithm factors model ring collectives: an
all-reduce moves ≈2× its payload per chip (reduce-scatter + all-gather
phases); one-shot collectives move ≈1×.

Also reports MODEL_FLOPS = 6·N·D (6·N_active·D for MoE) and the usefulness
ratio MODEL_FLOPS / HLO_FLOPs — remat/dispatch waste shows up here.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.models.api import ArchConfig
from .hlo import Cost

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

ALG_FACTOR = {
    "all-reduce": 2.0,           # ring RS + AG
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "ragged-all-to-all": 1.0,
    "collective-permute": 1.0,
    "collective-broadcast": 1.0,
}


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes: float
    collective_bytes: dict
    model_flops_per_chip: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Lower-bound step time: terms overlap, so max (roofline)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (per chip) — remat/redundancy waste."""
        return self.model_flops_per_chip / self.flops if self.flops else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline bound."""
        if self.step_s == 0:
            return 0.0
        return self.model_flops_per_chip / (self.step_s * PEAK_FLOPS)

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "flops": self.flops,
            "bytes": self.bytes,
            "collective_bytes": dict(self.collective_bytes),
            "model_flops_per_chip": self.model_flops_per_chip,
            "dominant": self.dominant, "useful_ratio": self.useful_ratio,
            "mfu_bound": self.mfu, "step_s": self.step_s,
        }


def roofline(cost: Cost, *, model_flops_total: float = 0.0,
             n_chips: int = 1) -> RooflineTerms:
    coll_s = sum(ALG_FACTOR.get(k, 1.0) * v / LINK_BW
                 for k, v in cost.collective_bytes.items())
    return RooflineTerms(
        compute_s=cost.flops / PEAK_FLOPS,
        memory_s=cost.bytes / HBM_BW,
        collective_s=coll_s,
        flops=cost.flops,
        bytes=cost.bytes,
        collective_bytes=dict(cost.collective_bytes),
        model_flops_per_chip=model_flops_total / max(n_chips, 1),
    )


# ---------------------------------------------------------------------------
# MODEL_FLOPS accounting (6·N·D; MoE counts active experts only)
# ---------------------------------------------------------------------------

def count_params(arch: ArchConfig) -> tuple[float, float]:
    """(total, active) parameter counts from the config (analytic)."""
    D, hd = arch.d_model, arch.hd
    per_pos_total = per_pos_active = 0.0
    for mixer, ffn in arch.pattern:
        p = 0.0
        if mixer in ("attn", "local", "cross"):
            p += D * arch.n_heads * hd + 2 * D * arch.n_kv_heads * hd \
                + arch.n_heads * hd * D
        elif mixer == "mamba":
            d_in = 2 * D
            G, N = 1, arch.ssm_state
            H = d_in // arch.ssm_head_dim
            d_in_proj = 2 * d_in + 2 * G * N + H
            p += D * d_in_proj + d_in * D         # in_proj + out_proj
        per_pos_total += p
        per_pos_active += p
        if ffn == "dense":
            mats = 3 if arch.activation in ("swiglu", "geglu") else 2
            per_pos_total += mats * D * arch.d_ff
            per_pos_active += mats * D * arch.d_ff
        elif ffn == "moe":
            mats = 3 if arch.activation in ("swiglu", "geglu") else 2
            per_expert = mats * D * arch.d_ff
            per_pos_total += arch.n_experts * per_expert + D * arch.n_experts
            per_pos_active += arch.top_k * per_expert + D * arch.n_experts
    n_periods = arch.n_periods
    total = per_pos_total * n_periods
    active = per_pos_active * n_periods
    # embeddings + head (counted once; tied or not, compute touches it once)
    total += arch.vocab * D
    active += arch.vocab * D
    if not arch.tie_embeddings:
        total += arch.vocab * D
        active += arch.vocab * D
    if arch.n_decoder_layers:
        # decoder stack: self-attn + cross-attn + mlp per 2-layer period
        dec = (2 * (D * arch.n_heads * hd + 2 * D * arch.n_kv_heads * hd
                    + arch.n_heads * hd * D)
               + (3 if arch.activation in ("swiglu", "geglu") else 2)
               * D * arch.d_ff) * (arch.n_decoder_layers // 2 or 1)
        total += dec
        active += dec
    return total, active


def model_flops(arch: ArchConfig, n_tokens: float, *,
                kind: str = "train") -> float:
    """6·N_active·D for training; 2·N_active·D for inference forward."""
    _, active = count_params(arch)
    mult = 6.0 if kind == "train" else 2.0
    return mult * active * n_tokens
