"""Protocol sanitizer: online invariant checking for the async control plane.

Every async-state bug shipped so far was found by hand, after the fact:
zombie arrivals and leaked in-flight flow tokens (PR 1), forked per-device
chains under churn flaps (PR 5).  This module turns those lessons into
*mechanical* checks: the control-plane modules (``FlowController``,
``TaskScheduler``, ``ControlPlane``, ``RoundExecutor``,
``ActivationStore``) and the event-simulation loops emit lightweight
events at their state transitions, and an attached
:class:`ProtocolSanitizer` checks a declarative invariant catalogue
online — a violation raises :class:`InvariantViolation` carrying the
invariant's name and a bounded window of the preceding events, so the
failure is diagnosable from the traceback alone.

The instrumentation is OFF by default: call sites guard on the module
flag ``TRACING`` (one global read per event site), so un-sanitized runs
pay a branch, nothing more.  Attach a sanitizer explicitly::

    from repro.analysis.sanitize import sanitized

    with sanitized() as san:
        simulate_fedoptima(...)
    assert san.n_violations == 0      # online mode raised already
    print(san.report())

or run the drivers with ``--sanitize`` (``launch/train.py``,
``benchmarks/run.py`` — default on in ``--smoke``).

Invariant catalogue (see also EXPERIMENTS.md §Static analysis):

================================  ==========================================
flow-token-conservation           buffered + inflight + granted tokens ≤
                                  ω + pool_cap at every flow transition, and
                                  ``on_device_left`` reclaims the departed
                                  device's token/in-flight budget (PR 1's
                                  leaked-token bug, stated as an invariant)
no-unregistered-arrival           an arrival is never *accepted* for a
                                  device the flow controller does not know
                                  (PR 1's zombie-arrival bug)
ring-pool-occupancy               live ring slots ≤ ω, occupied pool
                                  entries ≤ pool_cap, and the planner's
                                  pool bookkeeping matches the
                                  ActivationStore's held keys at every
                                  round boundary (PR 4's tiered budget)
single-live-chain                 at most one live round chain per device
                                  in the async sim loops; a chain event
                                  carrying a stale epoch means a dead
                                  chain acted on the device (PR 5's
                                  churn-flap forked-chain bug)
counter-purge                     a removed device's Alg. 3 consumption
                                  counter is purged once its backlog
                                  drains, and a rejoin starts with fresh
                                  history (§3.4.2; PR 1's unbounded
                                  arrival-log / counter leak class)
staleness-monotonicity            the global model version never
                                  decreases, and no per-device version is
                                  ahead of it (Alg. 4 bookkeeping)
retention-rejoin-alpha            a rejoining group aggregates at
                                  α = 1/(staleness+1) from its RETAINED
                                  version — retention metadata, staleness
                                  counters and the planned agg weight must
                                  agree (PR 3's retention contract)
================================  ==========================================

The sanitizer mirrors a tiny amount of state per *source object* (keyed
by the emitting scheduler/flow/sim instance, which it keeps alive), so
several runs may interleave under one attached sanitizer — benchmarks
drive many simulations per process.  Not thread-safe: attach/detach from
the driving thread only (the executor's async dispatch keeps all host
bookkeeping on one thread).
"""
from __future__ import annotations

from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field

__all__ = [
    "ProtocolSanitizer", "InvariantViolation", "Invariant", "INVARIANTS",
    "TRACING", "emit", "attach", "detach", "sanitized", "suspended",
]

#: Fast-path guard read by every instrumented call site:
#: ``if _san.TRACING: _san.emit(...)``.  True iff a sanitizer is attached.
TRACING = False

_STACK: list["ProtocolSanitizer"] = []


class InvariantViolation(RuntimeError):
    """A protocol invariant failed.  ``invariant`` is the catalogue name;
    the message embeds the bounded window of events that led here."""

    def __init__(self, invariant: str, message: str, window=()):
        self.invariant = invariant
        self.window = tuple(window)
        tail = ""
        if self.window:
            lines = "\n".join(f"    {i:4d}  {k}  {f}"
                              for i, k, f in self.window)
            tail = f"\n  last {len(self.window)} events:\n{lines}"
        super().__init__(f"[{invariant}] {message}{tail}")


@dataclass(frozen=True)
class Invariant:
    """One declarative protocol invariant.

    ``events`` lists the event kinds the check fires on; ``check`` is
    ``check(san, kind, fields) -> str | None`` returning a violation
    message (None = holds).  ``caught`` names the historical bug class the
    invariant would have caught — the catalogue doubles as documentation.
    """
    name: str
    statement: str
    module: str
    caught: str
    events: tuple
    check: callable = field(compare=False)


# ---------------------------------------------------------------------------
# invariant checks
# ---------------------------------------------------------------------------

def _check_flow_conservation(san, kind, f):
    flow = f["flow"]
    if flow.buffered < 0:
        return f"negative buffered count ({flow.buffered})"
    promised = flow.promised
    if promised > flow.cap:
        return (f"promised={promised} exceeds cap={flow.cap} "
                f"(buffered={flow.buffered}, inflight={flow.inflight}, "
                f"tokens={flow.active_tokens})")
    if kind == "flow.device_left":
        k = f["device"]
        leaks = []
        if k in flow.sender_active:
            leaks.append("sender token")
        if k in flow.inflight_by:
            leaks.append(f"{flow.inflight_by[k]} in-flight send(s)")
        if k in flow._rr:
            leaks.append("round-robin slot")
        if leaks:
            return (f"device {k} left but its {' + '.join(leaks)} "
                    "was not reclaimed — departed devices would "
                    "permanently eat into the ω budget")
    return None


def _check_unregistered_arrival(san, kind, f):
    if f["accepted"] and not f["registered"]:
        return (f"arrival from device {f['device']} was ACCEPTED but the "
                "device is not registered with the flow controller — a "
                "zombie packet retroactively violates the ω cap")
    return None


def _check_ring_pool(san, kind, f):
    cp = f.get("cp")
    if cp is not None and cp.unit == "group":
        if cp.live_slots > cp.omega:
            return (f"{cp.live_slots} live ring slots exceed ω={cp.omega} "
                    f"(occupancy={cp.slot_occupancy})")
        if cp.pool_live > cp.pool_cap:
            return (f"{cp.pool_live} occupied pool entries exceed "
                    f"pool_cap={cp.pool_cap}")
    if cp is not None and not cp.flow.within_cap:
        return (f"flow budget outside the tiered cap: "
                f"buffered={cp.flow.buffered}, promised={cp.flow.promised} "
                f"of cap={cp.flow.cap}")
    store = f.get("store")
    if store is not None and len(store) > store.pool_cap:
        return (f"ActivationStore holds {len(store)} entries past "
                f"pool_cap={store.pool_cap}")
    if store is not None and cp is not None:
        plan_keys = sorted(cp.pool_occupancy)
        if plan_keys != store.keys:
            return (f"planner pool bookkeeping {plan_keys} disagrees with "
                    f"the ActivationStore's held keys {store.keys}")
    return None


def _check_single_chain(san, kind, f):
    st = san._mirror(f["sim"], "chain", lambda: {"epoch": {}, "live": {}})
    k = f["device"]
    if kind == "sim.device_left":
        st["epoch"][k] = st["epoch"].get(k, 0) + 1
        st["live"][k] = False
        return None
    if kind == "sim.device_join":
        if st["live"].get(k, False):
            return (f"device {k} rejoined while a chain from before its "
                    "departure is still live")
        return None
    e, cur = f["epoch"], st["epoch"].get(k, 0)
    if e != cur:
        return (f"{kind} for device {k} carries epoch {e} but the "
                f"device's live epoch is {cur} — a chain that should have "
                "died at departure acted on the device (two concurrent "
                "chains double-count busy time and samples)")
    if kind == "sim.chain_start":
        if st["live"].get(k, False):
            return (f"device {k} started a second concurrent chain "
                    f"(epoch {e})")
        st["live"][k] = True
    elif kind == "sim.chain_end":
        st["live"][k] = False
    return None


def _check_counter_purge(san, kind, f):
    sched = f["sched"]
    st = san._mirror(sched, "sched", lambda: {"removed": set()})
    k = f["device"]
    if kind == "sched.remove":
        if f["drained"]:
            st["removed"].discard(k)
            if k in sched.counters or sched.q_act.get(k):
                return (f"device {k} was removed with a drained backlog "
                        "but its counter/queue was not purged")
        else:
            st["removed"].add(k)
        return None
    if kind == "sched.purge":
        st["removed"].discard(k)
        if k in sched.counters or sched.q_act.get(k):
            return (f"device {k}'s backlog drained after removal but its "
                    "Alg. 3 counter/queue survives — the departed device "
                    "would keep competing under stale history")
        return None
    if kind == "sched.add":
        was_removed = k in st["removed"]
        st["removed"].discard(k)
        if was_removed and sched.counters.get(k, 0) != 0:
            return (f"device {k} rejoined with counter="
                    f"{sched.counters.get(k)} — §3.4.2 requires fresh "
                    "history on rejoin")
    return None


def _check_staleness(san, kind, f):
    cp = f["cp"]
    st = san._mirror(cp, "version", lambda: {"v": None})
    v = int(cp.version)
    if st["v"] is not None and v < st["v"]:
        return (f"global model version went backwards: {st['v']} -> {v}")
    st["v"] = v
    ahead = [int(g) for g in range(cp.G) if int(cp.versions[g]) > v]
    if ahead:
        return (f"device versions {ahead} are ahead of the global "
                f"version {v} (negative staleness)")
    return None


def _check_rejoin_alpha(san, kind, f):
    from repro.core.aggregator import staleness_weight
    cp = f["cp"]
    if kind == "cp.arrival":
        want = staleness_weight(f["version_before"] - f["t_k"],
                                cp.max_delay, cp.alpha_power)
        if abs(f["weight"] - want) > 1e-9:
            return (f"device {f['device']} aggregated at α={f['weight']} "
                    f"but its staleness {f['version_before'] - f['t_k']} "
                    f"implies α={want}")
        return None
    plan = f["plan"]
    for g in plan.restore:
        held = cp.retention.version_of(g) if g in cp.retention else None
        if held is not None and held != int(cp.versions[g]):
            return (f"group {g} rejoins from retained version {held} but "
                    f"its staleness counter says {int(cp.versions[g])} — "
                    "the rejoin would not aggregate at α=1/(k+1)")
    import numpy as np
    active = np.asarray(plan.bcast_mask, float) > 0.5
    for g in range(cp.G):
        want = staleness_weight(cp.version - int(cp.versions[g]),
                                cp.max_delay, cp.alpha_power) \
            if active[g] else 0.0
        if abs(float(plan.agg_weight[g]) - want) > 1e-6:
            return (f"group {g}'s planned agg weight "
                    f"{float(plan.agg_weight[g]):.6f} disagrees with "
                    f"α=1/(staleness+1)={want:.6f} at staleness "
                    f"{cp.version - int(cp.versions[g])}")
    return None


INVARIANTS: tuple[Invariant, ...] = (
    Invariant(
        name="flow-token-conservation",
        statement="buffered + inflight + granted tokens <= omega + "
                  "pool_cap, and on_device_left reclaims the departed "
                  "device's token and in-flight budget",
        module="core/flow_control.py",
        caught="PR 1: leaked in-flight tokens under churn",
        events=("flow.register", "flow.grant", "flow.sent", "flow.enqueue",
                "flow.dequeue", "flow.device_left", "flow.quarantine"),
        check=_check_flow_conservation),
    Invariant(
        name="no-unregistered-arrival",
        statement="an activation arrival is never accepted for a device "
                  "unknown to the flow controller",
        module="core/flow_control.py",
        caught="PR 1: zombie arrivals after a drop/rejoin",
        events=("flow.enqueue",),
        check=_check_unregistered_arrival),
    Invariant(
        name="ring-pool-occupancy",
        statement="live ring slots <= omega and pool entries <= pool_cap "
                  "at every round boundary, with planner and "
                  "ActivationStore bookkeeping in agreement",
        module="core/control_plane.py + memory/store.py",
        caught="PR 4 class: tiered-budget bookkeeping drift",
        events=("cp.plan", "exec.round"),
        check=_check_ring_pool),
    Invariant(
        name="single-live-chain",
        statement="at most one live round chain per device; chain events "
                  "must carry the device's current epoch",
        module="core/simulation.py + core/baselines.py",
        caught="PR 5: churn flap forking two concurrent device chains",
        events=("sim.chain_start", "sim.chain_end", "sim.device_left",
                "sim.device_join"),
        check=_check_single_chain),
    Invariant(
        name="counter-purge",
        statement="a removed device's Alg. 3 counter is purged once its "
                  "backlog drains; a rejoin starts with fresh history",
        module="core/scheduler.py",
        caught="PR 1: counter/arrival-log leak on departure",
        events=("sched.remove", "sched.purge", "sched.add"),
        check=_check_counter_purge),
    Invariant(
        name="staleness-monotonicity",
        statement="the global model version never decreases and no "
                  "per-device version is ahead of it",
        module="core/control_plane.py",
        caught="guards the Alg. 4 bookkeeping the weights derive from",
        events=("cp.plan", "cp.finish", "cp.arrival", "exec.round"),
        check=_check_staleness),
    Invariant(
        name="retention-rejoin-alpha",
        statement="a rejoining group aggregates at alpha=1/(staleness+1) "
                  "from its retained version; planned agg weights match "
                  "the Alg. 4 formula",
        module="core/control_plane.py",
        caught="PR 3: retention/rejoin contract",
        events=("cp.plan", "cp.arrival"),
        check=_check_rejoin_alpha),
)

_BY_EVENT: dict[str, tuple] = {}
for _inv in INVARIANTS:
    for _ev in _inv.events:
        _BY_EVENT.setdefault(_ev, ())
        _BY_EVENT[_ev] = _BY_EVENT[_ev] + (_inv,)


# ---------------------------------------------------------------------------
# the sanitizer
# ---------------------------------------------------------------------------

_SCALARS = (bool, int, float, str, type(None))


class ProtocolSanitizer:
    """Receives instrumentation events and checks the invariant catalogue.

    window : bounded count of preceding events kept for violation reports
        (scalar fields only — object references are passed to checks but
        never retained in the window).
    raise_on_violation : online mode (default) raises
        :class:`InvariantViolation` at the offending event; post-hoc mode
        (False) collects violations on ``self.violations`` for later
        inspection — e.g. to survey ALL failures of a mutated build
        instead of the first.
    """

    def __init__(self, *, window: int = 64, raise_on_violation: bool = True):
        if window < 1:
            raise ValueError(f"need window >= 1, got {window}")
        self.window = deque(maxlen=window)
        self.raise_on_violation = raise_on_violation
        self.violations: list[InvariantViolation] = []
        self.n_events = 0
        self.counts: dict[str, int] = {}
        # per-source-object mirrors, keyed by id(); the entry holds the
        # object itself so a recycled id can never alias a dead source
        self._mirrors: dict[tuple, tuple] = {}

    # -- event intake ----------------------------------------------------
    def record(self, kind: str, fields: dict):
        self.n_events += 1
        self.counts[kind] = self.counts.get(kind, 0) + 1
        scalars = {k: v for k, v in fields.items()
                   if isinstance(v, _SCALARS)}
        self.window.append((self.n_events, kind, scalars))
        for inv in _BY_EVENT.get(kind, ()):
            msg = inv.check(self, kind, fields)
            if msg is not None:
                self._violate(inv, msg)

    def _violate(self, inv: Invariant, msg: str):
        v = InvariantViolation(inv.name, msg, tuple(self.window))
        self.violations.append(v)
        if self.raise_on_violation:
            raise v

    def _mirror(self, obj, tag: str, factory):
        """Per-source mirror state (see class docstring)."""
        key = (id(obj), tag)
        entry = self._mirrors.get(key)
        if entry is None or entry[0] is not obj:
            entry = (obj, factory())
            self._mirrors[key] = entry
        return entry[1]

    # -- reporting -------------------------------------------------------
    @property
    def n_violations(self) -> int:
        return len(self.violations)

    def report(self) -> dict:
        """JSON-able summary: event totals per kind + violations."""
        return {"events": self.n_events,
                "by_kind": dict(sorted(self.counts.items())),
                "violations": [
                    {"invariant": v.invariant, "message": str(v).split(
                        "\n  last ", 1)[0]}
                    for v in self.violations],
                "n_violations": self.n_violations}


# ---------------------------------------------------------------------------
# attach / emit plumbing
# ---------------------------------------------------------------------------

def emit(kind: str, **fields):
    """Deliver one event to every attached sanitizer.  Call sites guard on
    ``TRACING`` so detached runs never build the kwargs dict."""
    for s in _STACK:
        s.record(kind, fields)


def attach(san: ProtocolSanitizer):
    global TRACING
    _STACK.append(san)
    TRACING = True


def detach(san: ProtocolSanitizer):
    global TRACING
    _STACK.remove(san)
    TRACING = bool(_STACK)


@contextmanager
def sanitized(san: ProtocolSanitizer | None = None, **kw):
    """Attach a sanitizer for the duration of the block (building one from
    ``**kw`` if not supplied) and yield it."""
    s = san if san is not None else ProtocolSanitizer(**kw)
    attach(s)
    try:
        yield s
    finally:
        detach(s)


@contextmanager
def suspended():
    """Temporarily detach ALL sanitizers (overhead baselines: the
    un-sanitized leg of an A/B measurement must not see a globally
    attached sanitizer)."""
    global TRACING, _STACK
    saved, _STACK = _STACK, []
    TRACING = False
    try:
        yield
    finally:
        _STACK = saved
        TRACING = bool(_STACK)
