"""Repo lint: stdlib-``ast`` rules for determinism and kernel hygiene.

Run ``python -m repro.analysis.lint src/`` (exit 0 = clean, 1 = findings).
Pure stdlib on purpose — no jax import, so the CI lane and editor hooks
start in milliseconds.

Rules
-----
RP001  unseeded-random       no global/unseeded ``np.random.*`` in hot
                             paths (``core/``, ``fleet/``, ``runtime/``,
                             ``checkpoint/``, ``faults/``): every draw
                             must go through a seeded ``default_rng`` so
                             sim results replay bit-for-bit.  ``data/``
                             and ``launch/`` are exempt (allowlist).
RP002  wallclock             no direct ``time.*`` clock reads in hot
                             paths: ``time.time()``/``time_ns()`` because
                             simulated time is the only logic clock, and
                             ``perf_counter``/``monotonic`` (+ ``_ns``)
                             because instrumented intervals must come
                             from the ONE sanctioned wall clock,
                             ``repro.obs.clock.now()`` — one clock per
                             time domain, so span/stats intervals agree.
RP003  hash-seed             builtin ``hash()`` is salted per process
                             (PYTHONHASHSEED) and must never derive seeds
                             or keys; use ``zlib.crc32`` or a Generator.
RP004  bare-assert           no ``assert`` guarding runtime state in the
                             strict segments (``core/``, ``runtime/``,
                             ``checkpoint/``, ``faults/``) — asserts
                             vanish under ``-O`` (the executor's
                             ``_check_cap`` lesson); raise a typed error
                             with the violating state.
RP005  blockspec-div         every Pallas ``BlockSpec`` block-shape name
                             (``block_*``/``chunk*``) must appear in a
                             ``%`` divisibility check in the same
                             function — a grid of ``S // block`` silently
                             drops the ragged tail otherwise.
RP006  statedict-version     every ``state_dict`` writer must emit an
                             explicit version key ("version"/
                             "version_tag"), or restored snapshots can't
                             be migrated.

A finding can be waived per line with ``# lint: allow-<rule-name>`` or
``# lint: allow-rp00N`` (the lowercase rule id).
"""
from __future__ import annotations

import ast
import sys
from dataclasses import dataclass
from pathlib import Path

__all__ = ["LintError", "RULES", "lint_file", "lint_paths", "main"]

#: path segments in scope for the hot-path rules (RP001/RP002)
HOT_SEGMENTS = ("core", "fleet", "runtime", "checkpoint", "faults",
                "memory")
#: path segments where bare asserts are banned outright (RP004): state
#: these modules guard must survive ``python -O``
STRICT_SEGMENTS = ("core", "runtime", "checkpoint", "faults")
#: path segments exempt from the hot-path rules even when nested oddly
EXEMPT_SEGMENTS = ("data", "launch", "configs", "tests")

#: legacy module-level numpy RNG entry points (global hidden state) plus
#: the argless ``default_rng()`` — both unreproducible
_NP_GLOBAL_RNG = frozenset({
    "rand", "randn", "randint", "random", "random_sample", "ranf",
    "sample", "choice", "shuffle", "permutation", "uniform", "normal",
    "standard_normal", "beta", "binomial", "exponential", "gamma",
    "poisson", "seed", "get_state", "set_state", "bytes",
})

#: BlockSpec shape names that denote a tile size (divisibility hazards);
#: full-dimension names (hd, N, P, ...) tile trivially and are ignored
_BLOCK_NAME_PREFIXES = ("block", "chunk")


@dataclass(frozen=True)
class LintError:
    path: str
    line: int
    rule: str          # "RP001"
    name: str          # "unseeded-random"
    message: str

    def __str__(self) -> str:
        return (f"{self.path}:{self.line}: {self.rule}[{self.name}] "
                f"{self.message}")


RULES = {
    "RP001": "unseeded-random",
    "RP002": "wallclock",
    "RP003": "hash-seed",
    "RP004": "bare-assert",
    "RP005": "blockspec-div",
    "RP006": "statedict-version",
}


def _segments(path: Path) -> tuple:
    return tuple(p.lower() for p in path.parts)


def _in_hot_path(path: Path) -> bool:
    segs = _segments(path)
    return any(s in segs for s in HOT_SEGMENTS) and \
        not any(s in segs for s in EXEMPT_SEGMENTS)


def _is_np_random_attr(node: ast.AST) -> str | None:
    """``np.random.X`` / ``numpy.random.X`` -> "X", else None."""
    if not (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Attribute)
            and node.value.attr == "random"
            and isinstance(node.value.value, ast.Name)
            and node.value.value.id in ("np", "numpy")):
        return None
    return node.attr


def _names_in(node: ast.AST):
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            yield n.id


class _Pass(ast.NodeVisitor):
    """One file's lint pass.  Tracks the innermost enclosing function so
    RP005/RP006 can attribute expressions to their kernel wrapper."""

    def __init__(self, path: Path, rel: str, lines: list[str]):
        self.path = path
        self.rel = rel
        self.lines = lines
        self.hot = _in_hot_path(path)
        self.strict = any(s in _segments(path) for s in STRICT_SEGMENTS) \
            and not any(s in _segments(path) for s in EXEMPT_SEGMENTS)
        self.errors: list[LintError] = []
        self._func_stack: list[dict] = []

    # -- helpers ---------------------------------------------------------
    def _waived(self, line: int, rule: str, rule_name: str) -> bool:
        if 1 <= line <= len(self.lines):
            text = self.lines[line - 1]
            return f"# lint: allow-{rule_name}" in text or \
                f"# lint: allow-{rule.lower()}" in text
        return False

    def _err(self, node: ast.AST, rule: str, message: str):
        name = RULES[rule]
        if not self._waived(node.lineno, rule, name):
            self.errors.append(LintError(self.rel, node.lineno, rule,
                                         name, message))

    # -- function context (RP005 / RP006) --------------------------------
    def visit_FunctionDef(self, node: ast.FunctionDef):
        self._visit_func(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef):
        self._visit_func(node)

    def _visit_func(self, node):
        ctx = {"node": node, "blockspecs": [], "mod_names": set()}
        self._func_stack.append(ctx)
        self.generic_visit(node)
        self._func_stack.pop()
        # RP005: every tile-size name used in a BlockSpec shape needs a
        # divisibility (%) check somewhere in the same function
        for call, names in ctx["blockspecs"]:
            missing = sorted(n for n in names
                             if n not in ctx["mod_names"])
            if missing:
                self._err(call, "RP005",
                          f"BlockSpec tile size(s) {', '.join(missing)} "
                          "have no divisibility check (no '%' test) in "
                          f"'{node.name}'; a grid of dim // block "
                          "silently drops the ragged tail")
        # RP006: state_dict writers carry an explicit version key
        if node.name == "state_dict":
            consts = {n.value for n in ast.walk(node)
                      if isinstance(n, ast.Constant)
                      and isinstance(n.value, str)}
            if not any(c in ("version", "version_tag") for c in consts):
                self._err(node, "RP006",
                          "state_dict() emits no 'version'/'version_tag' "
                          "key; unversioned snapshots cannot be migrated "
                          "on load")

    # -- expression rules -------------------------------------------------
    def visit_BinOp(self, node: ast.BinOp):
        if isinstance(node.op, ast.Mod) and self._func_stack:
            for side in (node.left, node.right):
                self._func_stack[-1]["mod_names"].update(_names_in(side))
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        func = node.func
        # RP001 — unseeded / global-state numpy RNG in hot paths
        if self.hot:
            attr = _is_np_random_attr(func)
            if attr in _NP_GLOBAL_RNG:
                self._err(node, "RP001",
                          f"np.random.{attr}() uses the global RNG; draw "
                          "from a seeded np.random.default_rng(seed) "
                          "Generator instead")
            elif attr == "default_rng" and not node.args and \
                    not node.keywords:
                self._err(node, "RP001",
                          "np.random.default_rng() without a seed is "
                          "entropy-seeded; pass an explicit seed")
            # RP002 — wall clock in hot paths
            if isinstance(func, ast.Attribute) and \
                    isinstance(func.value, ast.Name) and \
                    func.value.id == "time":
                if func.attr in ("time", "time_ns"):
                    self._err(node, "RP002",
                              f"time.{func.attr}() in a hot path; "
                              "simulated runs must not read the wall "
                              "clock (use the sim clock; for wall "
                              "intervals use repro.obs.clock.now())")
                elif func.attr in ("perf_counter", "perf_counter_ns",
                                   "monotonic", "monotonic_ns"):
                    self._err(node, "RP002",
                              f"time.{func.attr}() in an instrumented "
                              "hot path; read the obs clock "
                              "(repro.obs.clock.now()) so spans and "
                              "stats share one time domain")
        # RP003 — builtin hash() anywhere
        if isinstance(func, ast.Name) and func.id == "hash":
            self._err(node, "RP003",
                      "builtin hash() is salted per process "
                      "(PYTHONHASHSEED); use zlib.crc32 or a seeded "
                      "Generator for stable seeds/keys")
        # RP005 bookkeeping — BlockSpec block-shape tile names
        if isinstance(func, ast.Attribute) and func.attr == "BlockSpec" \
                or isinstance(func, ast.Name) and func.id == "BlockSpec":
            if node.args and self._func_stack:
                names = {n for n in _names_in(node.args[0])
                         if n.lower().startswith(_BLOCK_NAME_PREFIXES)}
                if names:
                    self._func_stack[-1]["blockspecs"].append((node, names))
        self.generic_visit(node)

    # -- statement rules --------------------------------------------------
    def visit_Assert(self, node: ast.Assert):
        if self.strict:
            self._err(node, "RP004",
                      "bare assert in a strict segment guards runtime "
                      "state but vanishes under python -O; raise a typed "
                      "error (ValueError/RuntimeError) with the state in "
                      "the message")
        self.generic_visit(node)


def lint_file(path: Path) -> list[LintError]:
    try:
        src = path.read_text()
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as e:
        return [LintError(str(path), e.lineno or 0, "RP000", "syntax",
                          f"could not parse: {e.msg}")]
    p = _Pass(path, str(path), src.splitlines())
    p.visit(tree)
    return sorted(p.errors, key=lambda e: (e.path, e.line, e.rule))


def lint_paths(paths) -> list[LintError]:
    errors: list[LintError] = []
    for p in paths:
        p = Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            errors.extend(lint_file(f))
    return errors


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or any(a in ("-h", "--help") for a in argv):
        print(__doc__)
        print("usage: python -m repro.analysis.lint <path> [path ...]")
        return 0 if argv else 2
    errors = lint_paths(argv)
    for e in errors:
        print(e)
    n_files = sum(1 for p in argv for _ in
                  (Path(p).rglob("*.py") if Path(p).is_dir() else (p,)))
    status = f"{len(errors)} finding(s) in {n_files} file(s)" \
        if errors else f"clean ({n_files} file(s))"
    print(f"repro-lint: {status}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
