"""Assemble the EXPERIMENTS.md §Dry-run / §Roofline tables from the
dry-run JSON records."""
from __future__ import annotations

import glob
import json
import os


def load_records(results_dir: str, mesh_kind: str | None = None) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        if mesh_kind and r.get("mesh_kind") != mesh_kind:
            continue
        recs.append(r)
    return recs


def _fmt_bytes(n: float) -> str:
    return f"{n / 1e9:.2f}"


def roofline_table(recs: list[dict], which: str = "roofline_kernelized") -> str:
    """Markdown table: per-cell terms, dominant bottleneck, MFU bound."""
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful ratio | MFU bound | temp GB/chip |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs = sorted(recs, key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in recs:
        if r.get("status") == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR "
                         f"{r.get('error', '')[:40]} | | | | | | |")
            continue
        t = r[which]
        mem = r["memory_analysis"]["temp_bytes"] / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
            f"{t['dominant']} | {t['useful_ratio']:.3f} | "
            f"{t['mfu_bound']:.3f} | {mem:.2f} |")
    return "\n".join(lines)


def dryrun_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | status | compile s | args GB | temp GB | "
        "HLO GFLOPs/chip | coll GB/chip |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs = sorted(recs, key=lambda r: (r["arch"], order.get(r["shape"], 9),
                                       r.get("mesh_kind", "")))
    for r in recs:
        mesh = r.get("mesh_kind", "?")
        if r.get("status") == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | SKIP | — "
                         f"| — | — | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | ERROR | "
                         f"| | | | |")
            continue
        m = r["memory_analysis"]
        t = r["roofline"]
        coll = sum(t["collective_bytes"].values()) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | ok | "
            f"{r.get('compile_s', 0):.0f} | {m['argument_bytes']/1e9:.2f} | "
            f"{m['temp_bytes']/1e9:.2f} | {t['flops']/1e9:.0f} | "
            f"{coll:.2f} |")
    return "\n".join(lines)


def pick_hillclimb_cells(recs: list[dict]) -> dict:
    """worst roofline fraction / most collective-bound / most
    representative-of-the-technique (train_4k on a big dense arch)."""
    ok = [r for r in recs if r.get("status") == "ok"]
    worst = min(ok, key=lambda r: r["roofline_kernelized"]["mfu_bound"])
    coll = max(ok, key=lambda r: (r["roofline_kernelized"]["collective_s"] /
                                  max(r["roofline_kernelized"]["step_s"],
                                      1e-12)))
    rep = next(r for r in ok
               if r["arch"] == "command-r-plus-104b" and
               r["shape"] == "train_4k")
    return {"worst_mfu": worst, "most_collective": coll,
            "representative": rep}


if __name__ == "__main__":
    import sys
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load_records(d, "single")
    print("## Dry-run (single-pod)\n")
    print(dryrun_table(recs))
    print("\n## Roofline (kernelized)\n")
    print(roofline_table(recs))
