"""Analysis layer: HLO/roofline cost models + static analysis tooling.

Re-exports are lazy: ``hlo``/``roofline`` pull in jax, but the
``sanitize`` instrumentation hooks live on hot control-plane paths and
the ``lint`` CLI must start fast — importing this package must stay
cheap (stdlib only) so ``from repro.analysis import sanitize`` inside
``repro.core`` neither costs a jax import nor creates a cycle.
"""
from __future__ import annotations

_HLO = ("Cost", "HloModule", "analyze_compiled", "analyze_text")
_ROOFLINE = ("RooflineTerms", "count_params", "model_flops", "roofline",
             "PEAK_FLOPS", "HBM_BW", "LINK_BW")

__all__ = [*_HLO, *_ROOFLINE, "hlo", "roofline", "sanitize", "lint"]


def __getattr__(name: str):
    import importlib
    if name in ("hlo", "sanitize", "lint"):
        return importlib.import_module(f"repro.analysis.{name}")
    if name in _HLO or name in _ROOFLINE:
        sub = "hlo" if name in _HLO else "roofline"
        mod = importlib.import_module(f"repro.analysis.{sub}")
        val = getattr(mod, name)
        # pin the resolved attribute: the submodule import just rebound
        # ``roofline`` on this package to the MODULE, but the seed API
        # exported the roofline() function under that name
        globals()[name] = val
        return val
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
