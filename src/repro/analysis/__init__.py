from .hlo import Cost, HloModule, analyze_compiled, analyze_text
from .roofline import (RooflineTerms, count_params, model_flops, roofline,
                       PEAK_FLOPS, HBM_BW, LINK_BW)
