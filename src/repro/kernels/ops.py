"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) the kernels run with interpret=True — the kernel
body executes in Python per grid step, validating the exact TPU program
logic.  On TPU backends they compile to Mosaic.  `use_kernels` is decided
per-call or globally via set_kernel_mode.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd
from .ssd import ssd_chunked_pallas

_FORCE_INTERPRET: bool | None = None


def set_kernel_mode(interpret: bool | None):
    """None = auto (interpret on CPU); True/False forces."""
    global _FORCE_INTERPRET
    _FORCE_INTERPRET = interpret


def _interpret() -> bool:
    if _FORCE_INTERPRET is not None:
        return _FORCE_INTERPRET
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("causal", "window", "logit_cap",
                                   "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, window=None, logit_cap=None,
                    block_q=128, block_k=128):
    """q: (B, S, H, hd); k, v: (B, Skv, Hkv, hd) -> (B, S, H, hd)."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               logit_cap=logit_cap, block_q=block_q,
                               block_k=block_k, interpret=_interpret())
    return jnp.swapaxes(out, 1, 2)


@partial(jax.jit, static_argnames=("chunk",))
def ssd(x, dt, A, Bm, Cm, *, chunk=128):
    """Chunked SSD sequence mixer.  x: (B, T, H, P); dt: (B, T, H);
    A: (H,); Bm, Cm: (B, T, G, N) -> y (B, T, H, P).  Pads T to a chunk
    multiple (zero dt ⇒ identity decay, zero input ⇒ no state change)."""
    T = x.shape[1]
    chunk = min(chunk, T) if T % min(chunk, T) == 0 else chunk
    pad = (-T) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y = ssd_chunked_pallas(x, dt, A, Bm, Cm, chunk=chunk,
                           interpret=_interpret())
    return y[:, :T]
