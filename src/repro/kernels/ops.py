"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) the kernels run with interpret=True — the kernel
body executes in Python per grid step, validating the exact TPU program
logic.  On TPU backends they compile to Mosaic.  Interpret mode is decided
per-call (``interpret=``), scoped (``kernel_mode``), or globally
(``set_kernel_mode``); it is resolved OUTSIDE the jit boundary and passed
as a static argument, so overrides actually retrace instead of being
swallowed by the jit cache.

Both ops are differentiable: ``jax.custom_vjp`` routes their backward
passes through the fused Pallas backward kernels (FlashAttention-style
recompute from (q, k, v, o, lse); reverse chunk scan for SSD), so
``use_kernel=True`` survives ``jax.value_and_grad`` in the hybrid train
step with no Python-level branching.  The (o, lse) / chunk-state residuals
are ``checkpoint_name``d "kernel_out" so the selective-remat policy
(transformer.py) can save them instead of recomputing the forward kernel —
never anything (S × S)-shaped.
"""
from __future__ import annotations

from contextlib import contextmanager
from functools import partial

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from .flash_attention import (flash_attention_bwd_bhsd,
                              flash_attention_fwd_bhsd)
from .ssd import ssd_bwd_chunked_pallas, ssd_fwd_chunked_pallas

_FORCE_INTERPRET: bool | None = None


def set_kernel_mode(interpret: bool | None):
    """None = auto (interpret on CPU); True/False forces."""
    global _FORCE_INTERPRET
    _FORCE_INTERPRET = interpret


@contextmanager
def kernel_mode(interpret: bool | None):
    """Scoped ``set_kernel_mode``: restores the previous mode on exit, so
    tests/benchmarks can't leak the global override across modules."""
    global _FORCE_INTERPRET
    prev = _FORCE_INTERPRET
    _FORCE_INTERPRET = interpret
    try:
        yield
    finally:
        _FORCE_INTERPRET = prev


def _interpret() -> bool:
    if _FORCE_INTERPRET is not None:
        return _FORCE_INTERPRET
    return jax.default_backend() == "cpu"


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _fa(q, k, v, causal, window, logit_cap, block_q, block_k, interpret):
    out, _ = flash_attention_fwd_bhsd(
        q, k, v, causal=causal, window=window, logit_cap=logit_cap,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return out


def _fa_fwd(q, k, v, causal, window, logit_cap, block_q, block_k, interpret):
    out, lse = flash_attention_fwd_bhsd(
        q, k, v, causal=causal, window=window, logit_cap=logit_cap,
        block_q=block_q, block_k=block_k, interpret=interpret)
    out = checkpoint_name(out, "kernel_out")
    lse = checkpoint_name(lse, "kernel_out")
    return out, (q, k, v, out, lse)


def _fa_bwd(causal, window, logit_cap, block_q, block_k, interpret, res, do):
    q, k, v, out, lse = res
    dq, dk, dv = flash_attention_bwd_bhsd(
        q, k, v, out, lse, do, causal=causal, window=window,
        logit_cap=logit_cap, block_q=block_q, block_k=block_k,
        interpret=interpret)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_fa.defvjp(_fa_fwd, _fa_bwd)


@partial(jax.jit, static_argnames=("causal", "window", "logit_cap",
                                   "block_q", "block_k", "interpret"))
def _flash_attention_jit(q, k, v, *, causal, window, logit_cap, block_q,
                         block_k, interpret):
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = _fa(qt, kt, vt, causal, window, logit_cap, block_q, block_k,
              interpret)
    return jnp.swapaxes(out, 1, 2)


def flash_attention(q, k, v, *, causal=True, window=None, logit_cap=None,
                    block_q=128, block_k=128, interpret=None):
    """q: (B, S, H, hd); k, v: (B, Skv, Hkv, hd) -> (B, S, H, hd).
    Differentiable (custom_vjp through the Pallas backward kernels)."""
    if interpret is None:
        interpret = _interpret()
    return _flash_attention_jit(q, k, v, causal=causal, window=window,
                                logit_cap=logit_cap, block_q=block_q,
                                block_k=block_k, interpret=bool(interpret))


# ---------------------------------------------------------------------------
# SSD (Mamba2 sequence mixer)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd(x, dt, A, Bm, Cm, chunk, interpret):
    y, _ = ssd_fwd_chunked_pallas(x, dt, A, Bm, Cm, chunk=chunk,
                                  interpret=interpret)
    return y


def _ssd_fwd(x, dt, A, Bm, Cm, chunk, interpret):
    y, states = ssd_fwd_chunked_pallas(x, dt, A, Bm, Cm, chunk=chunk,
                                       interpret=interpret)
    states = checkpoint_name(states, "kernel_out")
    return y, (x, dt, A, Bm, Cm, states)


def _ssd_bwd(chunk, interpret, res, dy):
    x, dt, A, Bm, Cm, states = res
    dx, ddt, dA, dBm, dCm = ssd_bwd_chunked_pallas(
        x, dt, A, Bm, Cm, states, dy.astype(jnp.float32), chunk=chunk,
        interpret=interpret)
    return (dx.astype(x.dtype), ddt.astype(dt.dtype), dA.astype(A.dtype),
            dBm.astype(Bm.dtype), dCm.astype(Cm.dtype))


_ssd.defvjp(_ssd_fwd, _ssd_bwd)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd_jit(x, dt, A, Bm, Cm, *, chunk, interpret):
    T = x.shape[1]
    pad = (-T) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y = _ssd(x, dt, A, Bm, Cm, chunk, interpret)
    return y[:, :T]


def ssd(x, dt, A, Bm, Cm, *, chunk=128, interpret=None):
    """Chunked SSD sequence mixer.  x: (B, T, H, P); dt: (B, T, H);
    A: (H,); Bm, Cm: (B, T, G, N) -> y (B, T, H, P).  Differentiable
    (custom_vjp reverse chunk scan).  ``chunk`` is clamped to T, then T is
    padded to a chunk multiple (zero dt ⇒ identity decay, zero input ⇒ no
    state change)."""
    T = x.shape[1]
    chunk = min(chunk, T)
    assert chunk >= 1, f"empty sequence: T={T}"
    # _ssd_jit pads T up to a chunk multiple; the kernel wrappers assert
    # the padded T % chunk == 0 invariant they actually consume.
    if interpret is None:
        interpret = _interpret()
    return _ssd_jit(x, dt, A, Bm, Cm, chunk=chunk, interpret=bool(interpret))
