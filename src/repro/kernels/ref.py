"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references the kernel tests sweep against
(shapes × dtypes, interpret=True).  They are deliberately simple and
readable — no tiling, no numerics tricks beyond f32 accumulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_reference(q, k, v, *, causal=True, window=None,
                              logit_cap=None, return_lse=False):
    """q: (B, S, H, hd); k, v: (B, Skv, Hkv, hd) with H % Hkv == 0.
    Returns (B, S, H, hd).  f32 softmax, input dtype out.  With
    ``return_lse=True`` also returns the per-row logsumexp (B, H, S) —
    the oracle for the kernel's backward residual."""
    B, S, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qg = q.reshape(B, S, Hkv, group, hd).astype(jnp.float32)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k.astype(jnp.float32)) * scale
    if logit_cap is not None:
        logits = logit_cap * jnp.tanh(logits / logit_cap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((S, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    out = out.reshape(B, S, H, hd).astype(q.dtype)
    if return_lse:
        lse = jax.nn.logsumexp(logits, axis=-1)           # (B, Hkv, g, S)
        return out, lse.reshape(B, H, S)
    return out


def ssd_reference(x, dt, A, B, C, initial_state=None):
    """Naive sequential SSD scan (Mamba2 §3): per-step recurrence

        h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T
        y_t = C_t · h_t

    x: (b, T, H, P); dt: (b, T, H); A: (H,) (negative); B, C: (b, T, G, N).
    Returns (y (b, T, H, P), final state (b, H, N, P)).  All f32.
    """
    b, T, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    Bf = jnp.repeat(B.astype(jnp.float32), rep, axis=2)  # (b, T, H, N)
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=2)
    h0 = (jnp.zeros((b, H, N, P), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(h, inp):
        xt, dtt, Bt, Ct = inp
        a = jnp.exp(dtt * A[None, :])                     # (b, H)
        h = h * a[..., None, None] + jnp.einsum("bhn,bh,bhp->bhnp", Bt, dtt, xt)
        y = jnp.einsum("bhn,bhnp->bhp", Ct, h)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h
