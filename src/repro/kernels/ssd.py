"""Mamba2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

Algorithm (per batch × head, chunk length Q):
  intra-chunk:  Y_intra = ((C B^T) ⊙ decay_tril) (dt ⊙ X)       — MXU matmuls
  chunk state:  S_c     = B^T diag(w) (dt ⊙ X),  w_s = e^{L_Q - L_s}
  recurrence:   h_c     = e^{L_Q} h_{c-1} + S_c                 — VMEM carry
  inter-chunk:  Y_inter = (C ⊙ e^{L})  h_{c-1}

TPU adaptation: the chunk dimension is the innermost grid axis; TPU grid
steps run sequentially, so the (N × P) state lives in VMEM scratch and is
carried across chunks — this replaces the GPU implementation's separate
state-passing kernel + inter-block sync.  All matmuls are (Q×N)(N×P)-style
MXU shapes; Q, N, P default to 128/128/64.

Layouts: x (B, T, H, P); dt (B, T, H); A (H,); Bm/Cm (B, T, G, N);
out (B, T, H, P).  T % Q == 0 (ops.py pads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_scr, *,
                chunk: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # (Q,)
    a = a_ref[0]                                       # scalar A_h (negative)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)         # (Q, N)

    la = dt * a                                        # log-decay per step, <= 0
    Lcum = jnp.cumsum(la)                              # (Q,)
    Ltot = Lcum[-1]

    xb = x * dt[:, None]                               # dt-weighted input (Q, P)

    # intra-chunk quadratic term
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q, Q)
    diff = Lcum[:, None] - Lcum[None, :]               # L_t - L_s
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(tri, jnp.exp(diff), 0.0)
    y_intra = jax.lax.dot_general(scores * decay, xb, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk contribution from carried state
    h_prev = h_scr[...]                                # (N, P)
    y_inter = jax.lax.dot_general(Cm * jnp.exp(Lcum)[:, None], h_prev,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # state update: h = e^{Ltot} h + B^T diag(e^{Ltot - Lcum}) xb
    w = jnp.exp(Ltot - Lcum)                           # (Q,)
    S_c = jax.lax.dot_general(Bm * w[:, None], xb, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)     # (N, P)
    h_scr[...] = jnp.exp(Ltot) * h_prev + S_c

    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)


def ssd_chunked_pallas(x, dt, A, Bm, Cm, *, chunk=128, interpret=False):
    """x: (B, T, H, P); dt: (B, T, H); A: (H,); Bm, Cm: (B, T, G, N).
    Returns y (B, T, H, P).  T must be divisible by chunk (ops.py pads)."""
    Bb, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert T % chunk == 0, (T, chunk)
    rep = H // G
    nc = T // chunk
    grid = (Bb, H, nc)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda b, h, c, r=rep: (b, c, h // r, 0)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda b, h, c, r=rep: (b, c, h // r, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Bb, T, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bm, Cm)
