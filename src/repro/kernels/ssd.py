"""Mamba2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

Algorithm (per batch × head, chunk length Q):
  intra-chunk:  Y_intra = ((C B^T) ⊙ decay_tril) (dt ⊙ X)       — MXU matmuls
  chunk state:  S_c     = B^T diag(w) (dt ⊙ X),  w_s = e^{L_Q - L_s}
  recurrence:   h_c     = e^{L_Q} h_{c-1} + S_c                 — VMEM carry
  inter-chunk:  Y_inter = (C ⊙ e^{L})  h_{c-1}

TPU adaptation: the chunk dimension is the innermost grid axis; TPU grid
steps run sequentially, so the (N × P) state lives in VMEM scratch and is
carried across chunks — this replaces the GPU implementation's separate
state-passing kernel + inter-block sync.  All matmuls are (Q×N)(N×P)-style
MXU shapes; Q, N, P default to 128/128/64.

Backward: the forward also emits each chunk's *entry* state h_{c-1}
(an (nc, N, P) residual per batch × head — the linear-recurrence analogue
of flash attention's LSE), and the backward kernel walks the chunks in
REVERSE grid order carrying dh (the gradient of the carried state) in VMEM
scratch, recomputing the decay/score tiles per chunk to produce
dx/ddt/dA/dB/dC.  dB/dC come out per *head* and are group-summed to the
(B, T, G, N) layout by the JAX wrapper; dA accumulates per (batch, head)
in scratch and is reduced outside.

Layouts: x (B, T, H, P); dt (B, T, H); A (H,); Bm/Cm (B, T, G, N);
out (B, T, H, P).  T % Q == 0 (ops.py pads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _chunk_tiles(dt, a, Bm, Cm, *, chunk: int):
    """Shared forward recomputation: log-decay cumsum and the masked decay /
    score tiles every term of the chunk algebra is built from."""
    la = dt * a                                        # log-decay per step, <= 0
    Lcum = jnp.cumsum(la)                              # (Q,)
    Ltot = Lcum[-1]
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q, Q)
    diff = Lcum[:, None] - Lcum[None, :]               # L_t - L_s
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(tri, jnp.exp(diff), 0.0)
    return la, Lcum, Ltot, scores, decay, tri


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, h_scr, *,
                chunk: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # (Q,)
    a = a_ref[0]                                       # scalar A_h (negative)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)         # (Q, N)

    _, Lcum, Ltot, scores, decay, _ = _chunk_tiles(dt, a, Bm, Cm, chunk=chunk)

    xb = x * dt[:, None]                               # dt-weighted input (Q, P)

    # intra-chunk quadratic term
    y_intra = jax.lax.dot_general(scores * decay, xb, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk contribution from carried state
    h_prev = h_scr[...]                                # (N, P)
    st_ref[0, 0, 0] = h_prev                           # backward residual
    y_inter = jax.lax.dot_general(Cm * jnp.exp(Lcum)[:, None], h_prev,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # state update: h = e^{Ltot} h + B^T diag(e^{Ltot - Lcum}) xb
    w = jnp.exp(Ltot - Lcum)                           # (Q,)
    S_c = jax.lax.dot_general(Bm * w[:, None], xb, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)     # (N, P)
    h_scr[...] = jnp.exp(Ltot) * h_prev + S_c

    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)


def ssd_fwd_chunked_pallas(x, dt, A, Bm, Cm, *, chunk=128, interpret=False):
    """x: (B, T, H, P); dt: (B, T, H); A: (H,); Bm, Cm: (B, T, G, N).
    Returns (y (B, T, H, P), states (B, H, nc, N, P)) where states[..., c]
    is the carried state *entering* chunk c.  T % chunk == 0 (ops.py pads).
    """
    Bb, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert T % chunk == 0, (T, chunk)
    rep = H // G
    nc = T // chunk
    grid = (Bb, H, nc)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, states = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda b, h, c, r=rep: (b, c, h // r, 0)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda b, h, c, r=rep: (b, c, h // r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, 1, N, P), lambda b, h, c: (b, h, c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, T, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, nc, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bm, Cm)
    return y, states


def ssd_chunked_pallas(x, dt, A, Bm, Cm, *, chunk=128, interpret=False):
    """Forward-only wrapper returning y (B, T, H, P)."""
    y, _ = ssd_fwd_chunked_pallas(x, dt, A, Bm, Cm, chunk=chunk,
                                  interpret=interpret)
    return y


# ---------------------------------------------------------------------------
# Backward (reverse chunk scan carrying dh in VMEM)
# ---------------------------------------------------------------------------

def _ssd_bwd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, st_ref, dy_ref,
                    dx_ref, ddt_ref, db_ref, dc_ref, da_ref,
                    dh_scr, da_scr, *, chunk: int):
    """One reverse grid step = one chunk.  dh_scr carries ∂L/∂h_c from the
    chunks *after* this one (the reverse of the forward's VMEM state carry);
    da_scr accumulates the per-(batch, head) scalar ∂L/∂A over all chunks."""
    c_idx = pl.program_id(2)        # 0 == LAST chunk (index maps reverse)
    nc = pl.num_programs(2)

    @pl.when(c_idx == 0)
    def _init():
        dh_scr[...] = jnp.zeros_like(dh_scr)
        da_scr[...] = jnp.zeros_like(da_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # (Q,)
    a = a_ref[0]
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)         # (Q, N)
    h_prev = st_ref[0, 0, 0].astype(jnp.float32)       # (N, P) entry state
    dy = dy_ref[0, :, 0, :].astype(jnp.float32)        # (Q, P)
    dh = dh_scr[...]                                   # (N, P) ∂L/∂h_c

    _, Lcum, Ltot, scores, decay, tri = _chunk_tiles(dt, a, Bm, Cm,
                                                     chunk=chunk)
    xb = x * dt[:, None]
    expL = jnp.exp(Lcum)
    w = jnp.exp(Ltot - Lcum)

    def mm(lhs, rhs, contract):
        return jax.lax.dot_general(lhs, rhs, (contract, ((), ())),
                                   preferred_element_type=jnp.float32)

    # y = (scores ⊙ decay) xb + (C ⊙ e^{L}) h_prev
    dM = mm(dy, xb, ((1,), (1,)))                      # (Q, Q)
    dxb = mm(scores * decay, dy, ((0,), (0,)))         # Mᵀ dy   (Q, P)
    dscores = dM * decay
    dCm = mm(dscores, Bm, ((1,), (0,)))                # (Q, N)
    dBm = mm(dscores, Cm, ((0,), (0,)))                # dscoresᵀ C (Q, N)
    ddiff = jnp.where(tri, dM * scores * decay, 0.0)   # decay = e^{diff} ⊙ tri
    dLcum = jnp.sum(ddiff, axis=1) - jnp.sum(ddiff, axis=0)

    dyh = mm(dy, h_prev, ((1,), (1,)))                 # dy h_prevᵀ (Q, N)
    dCm += dyh * expL[:, None]
    dLcum += jnp.sum(dyh * Cm, axis=1) * expL
    dh_prev = mm(Cm * expL[:, None], dy, ((0,), (0,)))  # (N, P)

    # h = e^{Ltot} h_prev + (B ⊙ w)ᵀ xb,   ∂L/∂h = dh
    dxb += mm(Bm * w[:, None], dh, ((1,), (0,)))       # (Q, P)
    dBw = mm(xb, dh, ((1,), (1,)))                     # xb dhᵀ (Q, N)
    dBm += dBw * w[:, None]
    dw = jnp.sum(dBw * Bm, axis=1)                     # (Q,)
    dLtot = jnp.exp(Ltot) * jnp.sum(dh * h_prev) + jnp.sum(dw * w)
    dLcum -= dw * w
    dh_prev += jnp.exp(Ltot) * dh

    # Lcum = cumsum(la), Ltot = Lcum[-1] ⇒ dla_s = Σ_{t≥s} dLcum_t + dLtot
    dla = jnp.sum(dLcum) - jnp.cumsum(dLcum) + dLcum + dLtot

    # la = dt·a; xb = x·dt
    ddt = dla * a + jnp.sum(dxb * x, axis=1)
    da_scr[...] += jnp.sum(dla * dt)[None, None]
    dx = dxb * dt[:, None]

    dx_ref[0, :, 0, :] = dx
    ddt_ref[0, :, 0] = ddt
    db_ref[0, :, 0, :] = dBm
    dc_ref[0, :, 0, :] = dCm
    dh_scr[...] = dh_prev

    @pl.when(c_idx == nc - 1)
    def _finalize():
        da_ref[0, 0] = da_scr[0, 0]


def ssd_bwd_chunked_pallas(x, dt, A, Bm, Cm, states, dy, *, chunk=128,
                           interpret=False):
    """Reverse-scan backward.  states: (B, H, nc, N, P) chunk entry states
    from the forward.  Returns (dx, ddt, dA, dBm, dCm) — dBm/dCm already
    group-summed to (B, T, G, N), everything float32."""
    Bb, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert T % chunk == 0, (T, chunk)
    rep = H // G
    nc = T // chunk
    grid = (Bb, H, nc)

    # grid step c processes chunk nc-1-c: the reverse scan is pure index
    # arithmetic, the kernel body only sees "its" chunk.
    def rev(c, n=nc):
        return n - 1 - c

    kernel = functools.partial(_ssd_bwd_kernel, chunk=chunk)
    dx, ddt, dbh, dch, dab = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, rev(c), h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, rev(c), h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda b, h, c, r=rep: (b, rev(c), h // r, 0)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda b, h, c, r=rep: (b, rev(c), h // r, 0)),
            pl.BlockSpec((1, 1, 1, N, P), lambda b, h, c: (b, h, rev(c), 0, 0)),
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, rev(c), h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, rev(c), h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, rev(c), h)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, rev(c), h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, rev(c), h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (b, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, T, H, P), jnp.float32),
            jax.ShapeDtypeStruct((Bb, T, H), jnp.float32),
            jax.ShapeDtypeStruct((Bb, T, H, N), jnp.float32),
            jax.ShapeDtypeStruct((Bb, T, H, N), jnp.float32),
            jax.ShapeDtypeStruct((Bb, H), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((N, P), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bm, Cm, states, dy)

    dA = jnp.sum(dab, axis=0)                               # (H,)
    # B/C are shared across each group's rep = H//G heads: sum the group.
    dBm = dbh.reshape(Bb, T, G, rep, N).sum(axis=3)
    dCm = dch.reshape(Bb, T, G, rep, N).sum(axis=3)
    return dx, ddt, dA, dBm, dCm
