"""Fused flash attention for TPU (Pallas), with GQA, causal masking,
sliding-window ("local") attention, and Gemma-2 logit soft-capping.

TPU adaptation notes (vs the CUDA flash-attention algorithm):
  * tiling targets VMEM: one (block_q × head_dim) query tile and one
    (block_k × head_dim) K/V tile resident per grid step; the MXU consumes
    (block_q × head_dim) @ (head_dim × block_k) matmuls, so block sizes are
    multiples of 128 and head_dim is the contracting dim;
  * the online-softmax running state (m, l, acc) lives in VMEM scratch and
    is carried across the innermost grid dimension (TPU grid steps execute
    sequentially, which replaces CUDA's per-CTA shared-memory loop);
  * causal/window block skipping is a `pl.when` guard on whole tiles (the
    TPU equivalent of warp-level early exit).

Layout: q (B, H, S, hd); k, v (B, Hkv, Skv, hd).  `ops.flash_attention`
wraps the (B, S, H, hd) public layout.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: int | None,
               logit_cap: float | None, block_q: int, block_k: int,
               seq_q: int, seq_k: int):
    i = pl.program_id(2)          # query block
    j = pl.program_id(3)          # kv block
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = i * block_q
    k_start = j * block_k

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if logit_cap is not None:
            s = logit_cap * jnp.tanh(s / logit_cap)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_k                             # padding
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)                 # NEG_INF-safe: exp(-inf)≈0
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    # tile-level skip: in causal/window mode many (i, j) tiles are fully
    # masked — skip their compute entirely (TPU analogue of early exit).
    if causal or window is not None:
        relevant = jnp.bool_(True)
        if causal:
            relevant = jnp.logical_and(relevant,
                                       k_start <= q_start + block_q - 1)
        if window is not None:
            relevant = jnp.logical_and(
                relevant, k_start + block_k - 1 > q_start - window)
        pl.when(relevant)(_compute)
    else:
        _compute()

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal=True, window=None, logit_cap=None,
                         block_q=128, block_k=128, interpret=False):
    """q: (B, H, S, hd); k, v: (B, Hkv, Skv, hd).  Returns (B, H, S, hd)."""
    B, H, S, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    group = H // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, Skv)

    # pad sequences to block multiples (mask handles the tail)
    def pad_to(x, axis, mult):
        pad = (-x.shape[axis]) % mult
        if pad == 0:
            return x
        cfg = [(0, 0)] * x.ndim
        cfg[axis] = (0, pad)
        return jnp.pad(x, cfg)

    qp = pad_to(q, 2, block_q)
    kp = pad_to(k, 2, block_k)
    vp = pad_to(v, 2, block_k)
    Sp, Skvp = qp.shape[2], kp.shape[2]
    grid = (B, H, Sp // block_q, Skvp // block_k)

    kernel = functools.partial(
        _fa_kernel, scale=1.0 / math.sqrt(hd), causal=causal, window=window,
        logit_cap=logit_cap, block_q=block_q, block_k=block_k,
        seq_q=S, seq_k=Skv)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :S]
