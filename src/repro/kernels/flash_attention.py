"""Fused flash attention for TPU (Pallas), with GQA, causal masking,
sliding-window ("local") attention, and Gemma-2 logit soft-capping.

TPU adaptation notes (vs the CUDA flash-attention algorithm):
  * tiling targets VMEM: one (block_q × head_dim) query tile and one
    (block_k × head_dim) K/V tile resident per grid step; the MXU consumes
    (block_q × head_dim) @ (head_dim × block_k) matmuls, so block sizes are
    multiples of 128 and head_dim is the contracting dim;
  * the online-softmax running state (m, l, acc) lives in VMEM scratch and
    is carried across the innermost grid dimension (TPU grid steps execute
    sequentially, which replaces CUDA's per-CTA shared-memory loop);
  * causal/window block skipping is a `pl.when` guard on whole tiles (the
    TPU equivalent of warp-level early exit).

Backward pass (FlashAttention-2 style recompute): the forward additionally
emits the per-row LSE (logsumexp of the masked logits), and the backward
kernels rebuild each attention tile from (q, k, lse) — never materialising
the (S × Skv) score matrix — to produce dq (one kernel, kv blocks innermost)
and dk/dv (a second kernel, query blocks innermost, accumulating over the
H//Hkv GQA query-head group in VMEM scratch).  Soft-capping contributes the
tanh-derivative factor (1 - (z/cap)²) to dS.

Layout: q (B, H, S, hd); k, v (B, Hkv, Skv, hd).  `ops.flash_attention`
wraps the (B, S, H, hd) public layout.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _tile_mask(q_start, k_start, *, causal, window, block_q, block_k, seq_k):
    """The (block_q, block_k) validity mask of one attention tile."""
    qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < seq_k                             # padding
    if causal:
        mask = jnp.logical_and(mask, kpos <= qpos)
    if window is not None:
        mask = jnp.logical_and(mask, kpos > qpos - window)
    return mask


def _tile_relevant(q_start, k_start, *, causal, window, block_q, block_k):
    """Whole-tile skip predicate: False iff every entry is masked by the
    causal/window structure (padding is handled by the entry mask)."""
    relevant = jnp.bool_(True)
    if causal:
        relevant = jnp.logical_and(relevant, k_start <= q_start + block_q - 1)
    if window is not None:
        relevant = jnp.logical_and(
            relevant, k_start + block_k - 1 > q_start - window)
    return relevant


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: int | None,
               logit_cap: float | None, block_q: int, block_k: int,
               seq_q: int, seq_k: int):
    i = pl.program_id(2)          # query block
    j = pl.program_id(3)          # kv block
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = i * block_q
    k_start = j * block_k

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if logit_cap is not None:
            s = logit_cap * jnp.tanh(s / logit_cap)

        mask = _tile_mask(q_start, k_start, causal=causal, window=window,
                          block_q=block_q, block_k=block_k, seq_k=seq_k)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)                 # NEG_INF-safe: exp(-inf)≈0
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    # tile-level skip: in causal/window mode many (i, j) tiles are fully
    # masked — skip their compute entirely (TPU analogue of early exit).
    if causal or window is not None:
        pl.when(_tile_relevant(q_start, k_start, causal=causal, window=window,
                               block_q=block_q, block_k=block_k))(_compute)
    else:
        _compute()

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)
        # LSE of the masked row; fully-masked rows keep NEG_INF so the
        # backward's exp(z - lse) stays mask-zeroed rather than NaN.
        lse_ref[0, 0] = jnp.where(l > 0.0, m_scr[...] + jnp.log(safe),
                                  NEG_INF)


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def flash_attention_fwd_bhsd(q, k, v, *, causal=True, window=None,
                             logit_cap=None, block_q=128, block_k=128,
                             interpret=False):
    """q: (B, H, S, hd); k, v: (B, Hkv, Skv, hd).
    Returns (out (B, H, S, hd), lse (B, H, S) float32)."""
    B, H, S, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    group = H // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, Skv)

    # pad sequences to block multiples (mask handles the tail)
    qp = _pad_to(q, 2, block_q)
    kp = _pad_to(k, 2, block_k)
    vp = _pad_to(v, 2, block_k)
    Sp, Skvp = qp.shape[2], kp.shape[2]
    if Sp % block_q or Skvp % block_k:
        raise ValueError(
            f"padded seq lengths ({Sp}, {Skvp}) not divisible by blocks "
            f"({block_q}, {block_k}); the grid would drop the tail")
    grid = (B, H, Sp // block_q, Skvp // block_k)

    kernel = functools.partial(
        _fa_kernel, scale=1.0 / math.sqrt(hd), causal=causal, window=window,
        logit_cap=logit_cap, block_q=block_q, block_k=block_k,
        seq_q=S, seq_k=Skv)

    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sp, hd), q.dtype),
            jax.ShapeDtypeStruct((B, H, Sp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :S], lse[:, :, :S]


def flash_attention_bhsd(q, k, v, *, causal=True, window=None, logit_cap=None,
                         block_q=128, block_k=128, interpret=False):
    """Forward-only convenience wrapper: returns just (B, H, S, hd)."""
    out, _ = flash_attention_fwd_bhsd(
        q, k, v, causal=causal, window=window, logit_cap=logit_cap,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return out


# ---------------------------------------------------------------------------
# Backward kernels (recompute from q, k, v, lse — FlashAttention-2 schedule)
# ---------------------------------------------------------------------------

def _tile_p_ds(q, k, v, do, lse_row, delta_row, mask, *, scale, logit_cap):
    """Rebuild one attention tile's probabilities p and logit-gradient dS.

    z = softcap(scale·qkᵀ); p = exp(z - lse); dS = p·(doᵀv - Δ) with the
    tanh-derivative factor (1 - (z/cap)²) when soft-capped.  Fully-masked
    rows carry lse = NEG_INF; the mask zeroes p there before any use.
    """
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if logit_cap is not None:
        z = logit_cap * jnp.tanh(s / logit_cap)
    else:
        z = s
    p = jnp.exp(z - lse_row[:, None])
    p = jnp.where(mask, p, 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta_row[:, None])
    if logit_cap is not None:
        ds = ds * (1.0 - jnp.square(z / logit_cap))    # d softcap / d s
    return p, ds


def _fa_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                      dq_ref, dq_scr, *, scale: float, causal: bool,
                      window: int | None, logit_cap: float | None,
                      block_q: int, block_k: int, seq_k: int):
    """dq = Σ_j dS_ij · K_j · scale; kv blocks innermost, dq in VMEM."""
    i = pl.program_id(2)
    j = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q_start = i * block_q
    k_start = j * block_k

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        mask = _tile_mask(q_start, k_start, causal=causal, window=window,
                          block_q=block_q, block_k=block_k, seq_k=seq_k)
        _, ds = _tile_p_ds(q, k, v, do, lse_ref[0, 0], delta_ref[0, 0], mask,
                           scale=scale, logit_cap=logit_cap)
        dq_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    if causal or window is not None:
        pl.when(_tile_relevant(q_start, k_start, causal=causal, window=window,
                               block_q=block_q, block_k=block_k))(_compute)
    else:
        _compute()

    @pl.when(j == nk - 1)
    def _finalize():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


def _fa_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                       dk_ref, dv_ref, dk_scr, dv_scr, *, scale: float,
                       causal: bool, window: int | None,
                       logit_cap: float | None, block_q: int, block_k: int,
                       seq_k: int, group: int):
    """dk = Σ_i dS_ijᵀ · Q_i · scale, dv = Σ_i P_ijᵀ · dO_i; query blocks
    innermost, accumulating over the GQA query-head group g in VMEM —
    grid (B, Hkv, nk, group, nq), so one (kv head, kv block) owns its
    dk/dv tile across all g·nq sequential steps."""
    j = pl.program_id(2)
    g = pl.program_id(3)
    i = pl.program_id(4)
    nq = pl.num_programs(4)

    @pl.when(jnp.logical_and(g == 0, i == 0))
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q_start = i * block_q
    k_start = j * block_k

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        mask = _tile_mask(q_start, k_start, causal=causal, window=window,
                          block_q=block_q, block_k=block_k, seq_k=seq_k)
        p, ds = _tile_p_ds(q, k, v, do, lse_ref[0, 0], delta_ref[0, 0], mask,
                           scale=scale, logit_cap=logit_cap)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    if causal or window is not None:
        pl.when(_tile_relevant(q_start, k_start, causal=causal, window=window,
                               block_q=block_q, block_k=block_k))(_compute)
    else:
        _compute()

    @pl.when(jnp.logical_and(g == group - 1, i == nq - 1))
    def _finalize():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd_bhsd(q, k, v, o, lse, do, *, causal=True, window=None,
                             logit_cap=None, block_q=128, block_k=128,
                             interpret=False):
    """Recompute backward.  q/o/do: (B, H, S, hd); k, v: (B, Hkv, Skv, hd);
    lse: (B, H, S).  Returns (dq, dk, dv) in float32."""
    B, H, S, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    group = H // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, Skv)
    scale = 1.0 / math.sqrt(hd)

    # Δ = rowsum(dO ⊙ O): the softmax-normalisation term of dS (the cheap
    # "preprocess" pass; padded rows are zero because dO pads with zeros).
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    qp, op, dop = (_pad_to(t, 2, block_q) for t in (q, o, do))
    kp, vp = (_pad_to(t, 2, block_k) for t in (k, v))
    lsep = _pad_to(lse, 2, block_q)
    deltap = _pad_to(delta, 2, block_q)
    Sp, Skvp = qp.shape[2], kp.shape[2]
    if Sp % block_q or Skvp % block_k:
        raise ValueError(
            f"padded seq lengths ({Sp}, {Skvp}) not divisible by blocks "
            f"({block_q}, {block_k}); the grid would drop the tail")
    nq, nk = Sp // block_q, Skvp // block_k
    del op  # o only feeds Δ

    common = dict(scale=scale, causal=causal, window=window,
                  logit_cap=logit_cap, block_q=block_q, block_k=block_k,
                  seq_k=Skv)

    dq = pl.pallas_call(
        functools.partial(_fa_bwd_dq_kernel, **common),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        interpret=interpret,
    )(qp, kp, vp, dop, lsep, deltap)

    dk, dv = pl.pallas_call(
        functools.partial(_fa_bwd_dkv_kernel, **common, group=group),
        grid=(B, Hkv, nk, group, nq),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, kh, j, g, i, gr=group: (b, kh * gr + g, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, kh, j, g, i: (b, kh, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, kh, j, g, i: (b, kh, j, 0)),
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, kh, j, g, i, gr=group: (b, kh * gr + g, i, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b, kh, j, g, i, gr=group: (b, kh * gr + g, i)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b, kh, j, g, i, gr=group: (b, kh * gr + g, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, kh, j, g, i: (b, kh, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, kh, j, g, i: (b, kh, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, Skvp, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, Skvp, hd), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, hd), jnp.float32),
            pltpu.VMEM((block_k, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp, dop, lsep, deltap)

    return dq[:, :, :S], dk[:, :, :Skv], dv[:, :, :Skv]
