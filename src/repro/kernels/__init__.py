from . import ops, ref
