"""Capability-tier heterogeneity sampling (fleet emulation).

Real fleets are capability-skewed, not four equal speed groups: a few
server-class boxes, a band of mid-range phones, a long tail of
constrained devices (the Apodotiko heterogeneous-environment picture).
:class:`DeviceProfile` describes one capability tier as lognormal
flops/bandwidth distributions around a median; :func:`sample_cluster`
draws a seeded K-device :class:`~repro.core.simulation.SimCluster` from a
weighted tier mix, replacing the single uniform
``heterogeneous_cluster`` helper as the way fleets are built (that
helper now lives here too, as the deterministic paper-Table-3 special
case, and stays re-exported from ``core.simulation`` unchanged).

Tier specs are strings so they ride CLIs and JSON: ``"low,mid,high"``
(equal weights) or ``"low:3,premium:1"`` (3:1 mix).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DeviceProfile:
    """One capability tier: lognormal flops/bandwidth around a median."""
    name: str
    flops: float                # median device compute, FLOP/s
    bw: float                   # median link bandwidth, bytes/s
    flops_sigma: float = 0.0    # lognormal sigma (0 = every device exact)
    bw_sigma: float = 0.0

    def sample(self, n: int, rng: np.random.Generator):
        """(flops, bw) arrays for n devices of this tier."""
        f = self.flops * np.exp(rng.normal(0.0, self.flops_sigma, n)) \
            if self.flops_sigma else np.full(n, float(self.flops))
        b = self.bw * np.exp(rng.normal(0.0, self.bw_sigma, n)) \
            if self.bw_sigma else np.full(n, float(self.bw))
        return f, b


#: Built-in tiers, spanning the REFL/Apodotiko capability spread: a ~13x
#: flops range low -> premium, with wider spread at the low end (cheap
#: hardware varies more) and bandwidth growing with tier.
TIERS = {
    "low": DeviceProfile("low", 1.5e9, 25e6 / 8,
                         flops_sigma=0.35, bw_sigma=0.40),
    "mid": DeviceProfile("mid", 5e9, 50e6 / 8,
                         flops_sigma=0.25, bw_sigma=0.30),
    "high": DeviceProfile("high", 1.2e10, 100e6 / 8,
                          flops_sigma=0.20, bw_sigma=0.25),
    "premium": DeviceProfile("premium", 2e10, 200e6 / 8,
                             flops_sigma=0.15, bw_sigma=0.20),
}

DEFAULT_TIERS = "low,mid,high,premium"


def parse_tiers(spec) -> list[tuple[DeviceProfile, float]]:
    """Parse a tier spec into (profile, weight) pairs.

    ``spec`` is a comma-separated list of ``name`` or ``name:weight``
    entries (names from :data:`TIERS`), or an already-parsed list of
    (DeviceProfile, weight) pairs, passed through."""
    if not isinstance(spec, str):
        return [(p, float(w)) for p, w in spec]
    out = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        name, _, w = part.partition(":")
        if name not in TIERS:
            raise ValueError(f"unknown device tier {name!r}; "
                             f"choose from {sorted(TIERS)}")
        weight = float(w) if w else 1.0
        if weight <= 0:
            raise ValueError(f"tier weight must be > 0, got {part!r}")
        out.append((TIERS[name], weight))
    if not out:
        raise ValueError(f"empty tier spec {spec!r}")
    return out


def tier_counts(K: int, tiers) -> list[int]:
    """Largest-remainder apportionment of K devices over the tier weights
    (deterministic: ties break toward earlier tiers)."""
    pairs = parse_tiers(tiers)
    w = np.asarray([weight for _, weight in pairs], float)
    quota = K * w / w.sum()
    counts = np.floor(quota).astype(int)
    rest = quota - counts
    order = sorted(range(len(rest)), key=lambda j: (-rest[j], j))
    for i in order[:K - int(counts.sum())]:
        counts[i] += 1
    return [int(c) for c in counts]


def sample_cluster(K: int, tiers=DEFAULT_TIERS, *, srv_ratio: float = 50.0,
                   seed: int = 0):
    """Draw a K-device SimCluster from a weighted capability-tier mix.

    Devices are laid out tier-by-tier (slowest first, mirroring the old
    helper's grouped layout); per-device flops/bandwidth are sampled from
    each tier's lognormals under one seeded RNG, so the same (K, tiers,
    seed) always yields the same cluster.  The server is ``srv_ratio`` x
    the fastest sampled device."""
    from repro.core.simulation import SimCluster

    pairs = parse_tiers(tiers)
    counts = tier_counts(K, pairs)
    rng = np.random.default_rng(seed)
    flops, bw = [], []
    for (profile, _), n in zip(pairs, counts):
        f, b = profile.sample(n, rng)
        flops.append(f)
        bw.append(b)
    dev_flops = np.concatenate(flops)
    dev_bw = np.concatenate(bw)
    return SimCluster(dev_flops=dev_flops, dev_bw=dev_bw,
                      srv_flops=float(dev_flops.max()) * srv_ratio)


def heterogeneous_cluster(K: int, base_flops: float = 5e9,
                          speed_groups=(1.0, 1.33, 2.67, 3.84),
                          bw: float = 100e6 / 8, srv_ratio: float = 50.0,
                          seed: int = 0):
    """Paper Table 3-style cluster: 4 equal-size speed groups; server is
    srv_ratio x the fastest device.  The deterministic special case of
    :func:`sample_cluster` (zero-sigma tiers), kept verbatim for every
    existing benchmark/test."""
    from repro.core.simulation import SimCluster

    groups = np.array([speed_groups[i * len(speed_groups) // K]
                       for i in range(K)])
    return SimCluster(dev_flops=base_flops * groups,
                      dev_bw=np.full(K, bw),
                      srv_flops=base_flops * max(speed_groups) * srv_ratio)
