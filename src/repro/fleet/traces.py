"""Seeded, deterministic device-availability traces (fleet emulation).

A :class:`FleetTrace` is a reusable scenario artifact: a (T, K) grid of
per-device availability + bandwidth, sampled every ``interval`` simulated
seconds.  The same trace drives FedOptima and every baseline protocol, so
scenario comparisons are identical-population by construction (REFL-style
availability realism; see PAPERS.md).  Traces are:

* **deterministic** — every generator is seeded; the same (kind, params,
  seed) always yields the same grid, and the grid itself (not the
  generator) is what the simulators consume;
* **serializable** — ``save``/``load`` round-trip the grid through JSON,
  so a trace is a shareable experiment input, not a code path;
* **periodic** — reading past the horizon wraps (tick ``i`` maps to row
  ``i % T``), so a day-long trace drives a week-long run.

Generators: :func:`uniform_trace` (always-on control), :func:`diurnal_trace`
(phase-shifted on/off day windows), :func:`weibull_sessions_trace`
(alternating Weibull-length up/down sessions — heavy-tailed device
attendance), :func:`flaky_trace` (memoryless per-tick drop/rejoin with
bandwidth re-draws — the §6.4 unstable-environment protocol as a trace).
Legacy ``churn=`` callers are materialized onto the same grid by
:meth:`FleetTrace.from_churn`, which replays the ChurnModel's RNG in tick
order — bit-for-bit the draws the old per-protocol closures consumed.

:func:`install_fleet` is the single trace-event API the event simulators
drive membership from: one tick per interval, per-device ``on_leave`` /
``on_rejoin`` transition callbacks, and an ``after_tick`` hook (participant
re-selection).  A static trace with no ``after_tick`` schedules nothing —
an always-on trace is event-free, keeping uniform runs bit-for-bit
identical to tracefree ones.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field

import numpy as np

TRACE_FORMAT = "fleet-trace-v1"

#: default sampling interval: the paper's §6.4 re-draw cadence (10 sim-min)
DEFAULT_INTERVAL = 600.0


@dataclass
class FleetTrace:
    interval: float              # seconds between consecutive rows
    active: np.ndarray           # (T, K) bool availability grid
    bw: np.ndarray               # (T, K) bytes/s link bandwidth
    meta: dict = field(default_factory=dict)   # generator provenance

    def __post_init__(self):
        self.active = np.asarray(self.active, bool)
        self.bw = np.asarray(self.bw, float)
        if self.active.ndim != 2 or self.active.shape != self.bw.shape:
            raise ValueError(
                f"active/bw must be matching (T, K) grids, got "
                f"{self.active.shape} vs {self.bw.shape}")
        if self.active.shape[0] < 1:
            raise ValueError("a trace needs at least one row")
        if self.interval <= 0:
            raise ValueError(f"interval must be > 0, got {self.interval}")

    # -- geometry --------------------------------------------------------
    @property
    def K(self) -> int:
        return self.active.shape[1]

    @property
    def T(self) -> int:
        return self.active.shape[0]

    @property
    def horizon(self) -> float:
        return self.T * self.interval

    @property
    def is_static(self) -> bool:
        """True when every row equals row 0 — the trace fires no events."""
        return bool(np.all(self.active == self.active[0]) and
                    np.all(self.bw == self.bw[0]))

    def row(self, tick: int):
        """(active, bw) rows for tick ``tick`` (periodic past the horizon)."""
        i = int(tick) % self.T
        return self.active[i], self.bw[i]

    def roster(self, tick: int) -> np.ndarray:
        """Availability mask at tick ``tick`` (a copy; periodic)."""
        return self.active[int(tick) % self.T].copy()

    def state_at(self, t: float):
        """(active, bw) rows in effect at simulated time ``t``."""
        return self.row(int(t // self.interval))

    def apply(self, active: np.ndarray, bw: np.ndarray, tick: int = 0):
        """Write row ``tick`` into live (active, bw) views in place."""
        a, b = self.row(tick)
        active[:] = a
        bw[:] = b

    # -- uptime accounting ----------------------------------------------
    def availability(self) -> np.ndarray:
        """(K,) fraction of ticks each device is on."""
        return self.active.mean(axis=0)

    # -- JSON artifact ---------------------------------------------------
    def to_json(self) -> dict:
        return {"format": TRACE_FORMAT,
                "interval": float(self.interval),
                "active": self.active.astype(int).tolist(),
                "bw": self.bw.tolist(),
                "meta": self.meta}

    @classmethod
    def from_json(cls, d: dict) -> "FleetTrace":
        if d.get("format") != TRACE_FORMAT:
            raise ValueError(
                f"not a fleet trace: format={d.get('format')!r} "
                f"(expected {TRACE_FORMAT!r})")
        return cls(interval=float(d["interval"]),
                   active=np.asarray(d["active"], bool),
                   bw=np.asarray(d["bw"], float),
                   meta=dict(d.get("meta", {})))

    def save(self, path: str) -> str:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh)
        return path

    @classmethod
    def load(cls, path: str) -> "FleetTrace":
        with open(path) as fh:
            return cls.from_json(json.load(fh))

    # -- constructors ----------------------------------------------------
    @classmethod
    def always_on(cls, K: int, horizon: float, *,
                  interval: float = DEFAULT_INTERVAL,
                  bw=100e6 / 8) -> "FleetTrace":
        """``bw`` is a scalar or a (K,) per-device base bandwidth."""
        T = _n_rows(horizon, interval)
        base = np.broadcast_to(np.asarray(bw, float), (K,))
        return cls(interval=interval, active=np.ones((T, K), bool),
                   bw=np.tile(base, (T, 1)),
                   meta={"kind": "uniform", "bw": _bw_meta(bw)})

    @classmethod
    def from_cluster(cls, cluster, horizon: float, *,
                     interval: float = DEFAULT_INTERVAL) -> "FleetTrace":
        """Always-on trace carrying the cluster's own per-device bandwidth
        (the identity scenario: trace-driven ≡ tracefree)."""
        T = _n_rows(horizon, interval)
        bw = np.tile(np.asarray(cluster.dev_bw, float), (T, 1))
        return cls(interval=interval,
                   active=np.ones((T, cluster.K), bool), bw=bw,
                   meta={"kind": "uniform", "bw": "cluster"})

    @classmethod
    def from_churn(cls, churn, horizon: float, *, bw0) -> "FleetTrace":
        """Materialize a legacy ``ChurnModel`` onto the trace grid.

        Row 0 is the pre-first-tick state (all devices on, at the caller's
        ``bw0`` — the cluster bandwidth); rows 1.. replay ``churn.draw`` in
        tick order, consuming the SAME RNG sequence the old per-protocol
        churn closures did — a converted run is bit-for-bit the legacy
        ``churn=`` run."""
        K = churn.n_devices
        n_ticks = int(math.ceil(horizon / churn.interval))
        rows_a = [np.ones(K, bool)]
        rows_b = [np.asarray(bw0, float).copy()]
        for i in range(n_ticks):
            a, b = churn.draw((i + 1) * churn.interval)
            rows_a.append(np.asarray(a, bool).copy())
            rows_b.append(np.asarray(b, float).copy())
        return cls(interval=float(churn.interval),
                   active=np.stack(rows_a), bw=np.stack(rows_b),
                   meta={"kind": "churn", "p_drop": float(churn.p_drop),
                         "seed": int(churn.seed)})


def _n_rows(horizon: float, interval: float) -> int:
    if horizon <= 0 or interval <= 0:
        raise ValueError(f"need horizon > 0 and interval > 0, got "
                         f"horizon={horizon}, interval={interval}")
    return max(1, int(math.ceil(horizon / interval)))


# ---------------------------------------------------------------------------
# Generators
# ---------------------------------------------------------------------------

def uniform_trace(K: int, horizon: float, *,
                  interval: float = DEFAULT_INTERVAL,
                  bw: float = 100e6 / 8, seed: int = 0) -> "FleetTrace":
    """Always-on fleet at constant bandwidth (the control scenario)."""
    del seed  # deterministic by construction; kept for a uniform signature
    return FleetTrace.always_on(K, horizon, interval=interval, bw=bw)


def diurnal_trace(K: int, horizon: float, *,
                  interval: float = DEFAULT_INTERVAL, day: float = 86400.0,
                  on_frac: float = 0.5, bw: float = 100e6 / 8,
                  bw_jitter: float = 0.0, seed: int = 0) -> "FleetTrace":
    """Phase-shifted diurnal windows: device k is on while its local time
    of day falls inside an ``on_frac`` window (phase ~ U[0, 1) per device,
    so the fleet's aggregate availability stays near ``on_frac`` while
    individual devices churn on a daily rhythm)."""
    if not 0.0 < on_frac <= 1.0:
        raise ValueError(f"on_frac must be in (0, 1], got {on_frac}")
    rng = np.random.default_rng(seed)
    T = _n_rows(horizon, interval)
    t = np.arange(T, dtype=float)[:, None] * interval
    phase = rng.uniform(0.0, 1.0, size=K)[None, :]
    active = ((t / day + phase) % 1.0) < on_frac
    bw_grid = _bw_grid(rng, T, K, bw, bw_jitter)
    return FleetTrace(interval=interval, active=active, bw=bw_grid,
                      meta={"kind": "diurnal", "day": float(day),
                            "on_frac": float(on_frac), "bw": _bw_meta(bw),
                            "bw_jitter": float(bw_jitter), "seed": int(seed)})


def weibull_sessions_trace(K: int, horizon: float, *,
                           interval: float = DEFAULT_INTERVAL,
                           shape: float = 0.9, on_scale: float = 3600.0,
                           off_scale: float = 1800.0, p_start: float = 0.7,
                           bw: float = 100e6 / 8, bw_jitter: float = 0.0,
                           seed: int = 0) -> "FleetTrace":
    """Alternating up/down sessions with Weibull-distributed lengths
    (shape < 1 = heavy-tailed attendance: many short sessions, a few very
    long ones — the REFL availability picture)."""
    rng = np.random.default_rng(seed)
    T = _n_rows(horizon, interval)
    active = np.zeros((T, K), bool)
    for k in range(K):
        t, on = 0.0, bool(rng.random() < p_start)
        while t < T * interval:
            scale = on_scale if on else off_scale
            length = max(interval, scale * float(rng.weibull(shape)))
            i0 = int(t // interval)
            i1 = min(T, int(math.ceil((t + length) / interval)))
            active[i0:i1, k] = on
            t += length
            on = not on
    bw_grid = _bw_grid(rng, T, K, bw, bw_jitter)
    return FleetTrace(interval=interval, active=active, bw=bw_grid,
                      meta={"kind": "weibull", "shape": float(shape),
                            "on_scale": float(on_scale),
                            "off_scale": float(off_scale),
                            "p_start": float(p_start), "bw": _bw_meta(bw),
                            "bw_jitter": float(bw_jitter), "seed": int(seed)})


def flaky_trace(K: int, horizon: float, *,
                interval: float = DEFAULT_INTERVAL, p_drop: float = 0.1,
                bw_lo: float = 25e6 / 8, bw_hi: float = 50e6 / 8,
                seed: int = 0) -> "FleetTrace":
    """Memoryless per-tick drop/rejoin with per-tick bandwidth re-draws —
    the paper's §6.4 unstable-environment protocol, materialized."""
    rng = np.random.default_rng(seed)
    T = _n_rows(horizon, interval)
    active = rng.random((T, K)) >= p_drop
    bw_grid = rng.uniform(bw_lo, bw_hi, size=(T, K))
    return FleetTrace(interval=interval, active=active, bw=bw_grid,
                      meta={"kind": "flaky", "p_drop": float(p_drop),
                            "bw_lo": float(bw_lo), "bw_hi": float(bw_hi),
                            "seed": int(seed)})


def _bw_grid(rng, T, K, bw, bw_jitter):
    """``bw`` is a scalar or a (K,) per-device base (e.g. a tier-sampled
    cluster's ``dev_bw``, so capability bandwidth heterogeneity survives
    trace generation); jitter multiplies per tick around that base."""
    base = np.broadcast_to(np.asarray(bw, float), (K,))
    if bw_jitter:
        return base[None, :] * rng.uniform(1.0 - bw_jitter, 1.0 + bw_jitter,
                                           size=(T, K))
    return np.tile(base, (T, 1))


def _bw_meta(bw):
    arr = np.asarray(bw, float)
    return float(arr) if arr.ndim == 0 else [float(v) for v in arr]


GENERATORS = {
    "uniform": uniform_trace,
    "diurnal": diurnal_trace,
    "weibull": weibull_sessions_trace,
    "flaky": flaky_trace,
}


def make_trace(kind: str, K: int, horizon: float, *,
               interval: float = DEFAULT_INTERVAL, seed: int = 0,
               **kw) -> FleetTrace:
    """Build a trace by generator name (the CLI entry point)."""
    if kind not in GENERATORS:
        raise ValueError(f"unknown trace kind {kind!r}; "
                         f"choose from {sorted(GENERATORS)}")
    return GENERATORS[kind](K, horizon, interval=interval, seed=seed, **kw)


# ---------------------------------------------------------------------------
# The single trace-event API the event simulators drive membership from
# ---------------------------------------------------------------------------

def resolve_fleet(fleet, churn, cluster, duration) -> FleetTrace | None:
    """Normalize a protocol's (fleet=, churn=) pair onto one trace.

    ``fleet=`` wins; a legacy ``churn=`` ChurnModel is materialized onto
    the trace grid (same draws, bit-for-bit).  Returns None when neither
    is given — the tracefree fast path."""
    if fleet is not None and churn is not None:
        raise ValueError("pass fleet= or churn=, not both — convert the "
                         "ChurnModel with FleetTrace.from_churn")
    if fleet is not None:
        if fleet.K != cluster.K:
            raise ValueError(f"trace describes {fleet.K} devices, "
                             f"cluster has {cluster.K}")
        return fleet
    if churn is not None:
        return FleetTrace.from_churn(churn, duration,
                                     bw0=np.asarray(cluster.dev_bw, float))
    return None


def install_fleet(sim, trace: FleetTrace | None, active: np.ndarray,
                  bw: np.ndarray, *, on_leave=None, on_rejoin=None,
                  after_tick=None) -> None:
    """Drive live (active, bw) views from the trace inside an event sim.

    Schedules one tick per ``trace.interval`` (the first at t=interval —
    row 0 is the initial state, applied by the caller via ``trace.apply``
    before starting its devices).  Each tick writes the row in per-device
    order, firing ``on_leave(k)`` / ``on_rejoin(k)`` on transitions, then
    ``after_tick()`` (participant re-selection).  A static trace with no
    ``after_tick`` schedules nothing at all — an always-on trace leaves
    the event heap untouched (bit-for-bit the tracefree run)."""
    if trace is None or (trace.is_static and after_tick is None):
        return
    if trace.K != len(active):
        raise ValueError(f"trace describes {trace.K} devices, the live "
                         f"views hold {len(active)}")

    def tick(i):
        row_a, row_b = trace.row(i)
        for k in range(trace.K):
            was = bool(active[k])
            active[k] = bool(row_a[k])
            bw[k] = float(row_b[k])
            if was and not row_a[k] and on_leave is not None:
                on_leave(k)
            if not was and row_a[k] and on_rejoin is not None:
                on_rejoin(k)
        if after_tick is not None:
            after_tick()
        sim.after(trace.interval, tick, i + 1)

    sim.after(trace.interval, tick, 1)
