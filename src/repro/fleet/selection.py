"""Pluggable participant-selection policies (fleet emulation).

Each trace tick the server picks a cohort from the currently-available
devices; only cohort members run local rounds and ship activations.
Policies are fed the Task Scheduler's Alg. 3 consumption counters and the
control plane's staleness accounting, so selection composes with
FedOptima's balanced-contribution machinery instead of bypassing it:

``random``   uniform cohort (FedAvg-style client sampling; the control).
``refl``     availability/staleness-aware (REFL, Abdelmoniem et al.):
             prioritize devices whose local model is most stale — the
             ones whose scarce availability the round should exploit —
             tie-broken toward the least-consumed counters.
``score``    score-based (Apodotiko, Chadha et al.): rank by a weighted
             score of capability (fast devices finish rounds), balance
             (1 - consumption share: underserved devices catch up) and
             staleness, and take the top of the ranking.

All policies are deterministic under their seed: ``random`` consumes its
own RNG (and consumes nothing when the cohort is the whole fleet, so
full-participation runs stay bit-for-bit tracefree); ``refl``/``score``
are pure functions of the selection context.

Also home to the per-device contribution-balance metric
(:func:`balance_summary` — variance / CV / Gini of consumed counts),
reported by ``Metrics.contribution_balance`` and ``bench_fleet``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Mapping

import numpy as np


@dataclass
class SelectionContext:
    """What a policy may look at when picking the cohort."""
    t: float                               # simulated time / round index
    counters: Mapping[int, int]            # Alg. 3 consumption counters
    staleness: np.ndarray                  # (K,) global - local version
    capability: np.ndarray | None = None   # (K,) device FLOP/s (or None)


class SelectionPolicy:
    """Base: cohort sizing + seeded RNG; subclasses rank/draw members."""

    name = "base"

    def __init__(self, *, fraction: float = 1.0, cohort: int | None = None,
                 seed: int = 0):
        if not 0.0 < fraction <= 1.0:
            raise ValueError(f"fraction must be in (0, 1], got {fraction}")
        if cohort is not None and cohort < 1:
            raise ValueError(f"cohort must be >= 1, got {cohort}")
        self.fraction = float(fraction)
        self.cohort = cohort
        self.seed = int(seed)
        self._rng = np.random.default_rng(seed)

    @property
    def trivial(self) -> bool:
        """True when the policy always selects every available device —
        the identity cohort, needing no re-selection ticks."""
        return self.cohort is None and self.fraction >= 1.0

    def cohort_size(self, n_available: int) -> int:
        if n_available <= 0:
            return 0
        if self.cohort is not None:
            return min(self.cohort, n_available)
        return max(1, int(math.ceil(self.fraction * n_available)))

    def select(self, available, ctx: SelectionContext) -> np.ndarray:
        raise NotImplementedError

    def describe(self) -> str:
        size = f"cohort={self.cohort}" if self.cohort is not None else \
            f"frac={self.fraction:g}"
        return f"{self.name}({size})"


class RandomSelection(SelectionPolicy):
    """Uniform cohort sampling without replacement."""

    name = "random"

    def select(self, available, ctx: SelectionContext = None) -> np.ndarray:
        available = np.asarray(available, int)
        n = self.cohort_size(len(available))
        if n >= len(available):
            return available          # select-all: no RNG consumed
        return np.sort(self._rng.choice(available, size=n, replace=False))


class StalenessSelection(SelectionPolicy):
    """REFL-style: most-stale first, least-consumed on ties.

    A device that has been absent (or rejected) for many rounds carries
    the highest staleness — selecting it while it happens to be available
    maximizes the fleet coverage of the aggregate, which is the REFL
    resource-efficiency argument; the counter tie-break folds in Alg. 3's
    balanced-contribution objective."""

    name = "refl"

    def select(self, available, ctx: SelectionContext) -> np.ndarray:
        available = [int(k) for k in available]
        n = self.cohort_size(len(available))
        ranked = sorted(available,
                        key=lambda k: (-int(ctx.staleness[k]),
                                       int(ctx.counters.get(k, 0)), k))
        return np.sort(np.asarray(ranked[:n], int))


class ScoreSelection(SelectionPolicy):
    """Apodotiko-style weighted scoring over capability/balance/staleness.

    score_k = w_cap * cap_k/max(cap) + w_bal * (1 - share_k)
              + w_stale * stale_k/max(stale)

    where share_k is device k's share of all consumed contributions.  The
    top-``n`` scores form the cohort (deterministic: ties break toward
    smaller ids).  Without capability data the capability term is uniform
    (every device scores 1 on it)."""

    name = "score"

    def __init__(self, *, w_capability: float = 0.5, w_balance: float = 0.3,
                 w_staleness: float = 0.2, **kw):
        super().__init__(**kw)
        self.w_capability = float(w_capability)
        self.w_balance = float(w_balance)
        self.w_staleness = float(w_staleness)

    def select(self, available, ctx: SelectionContext) -> np.ndarray:
        available = np.asarray(available, int)
        n = self.cohort_size(len(available))
        if n == 0:
            return available        # nobody on this tick (all devices off)
        if ctx.capability is not None:
            cap = np.asarray(ctx.capability, float)[available]
            cap = cap / max(float(cap.max()), 1e-12)
        else:
            cap = np.ones(len(available))
        total = max(sum(int(v) for v in ctx.counters.values()), 1)
        share = np.asarray([ctx.counters.get(int(k), 0) / total
                            for k in available], float)
        stale = np.asarray(ctx.staleness, float)[available]
        stale = stale / max(float(stale.max()), 1.0)
        score = (self.w_capability * cap + self.w_balance * (1.0 - share)
                 + self.w_staleness * stale)
        order = sorted(range(len(available)),
                       key=lambda i: (-score[i], int(available[i])))
        return np.sort(available[order[:n]])


POLICIES = {
    "random": RandomSelection,
    "refl": StalenessSelection,
    "score": ScoreSelection,
}


def make_selection_policy(spec, *, seed: int = 0) -> SelectionPolicy | None:
    """Resolve a policy spec: None passes through, a SelectionPolicy is
    used as-is, and a string is ``name`` or ``name:fraction`` (e.g.
    ``"refl:0.25"`` selects the most-stale quarter of the fleet)."""
    if spec is None or isinstance(spec, SelectionPolicy):
        return spec
    name, _, frac = str(spec).partition(":")
    if name not in POLICIES:
        raise ValueError(f"unknown selection policy {name!r}; "
                         f"choose from {sorted(POLICIES)}")
    kw = {"seed": seed}
    if frac:
        kw["fraction"] = float(frac)
    return POLICIES[name](**kw)


# ---------------------------------------------------------------------------
# Contribution-balance metric (variance / CV / Gini of consumed counts)
# ---------------------------------------------------------------------------

def gini(counts) -> float:
    """Gini coefficient of a non-negative count vector (0 = perfectly
    balanced contributions, -> 1 = one device dominates)."""
    x = np.sort(np.asarray(counts, float))
    n = len(x)
    total = float(x.sum())
    if n == 0 or total <= 0.0:
        return 0.0
    cum = np.cumsum(x) / total
    return float((n + 1 - 2.0 * cum.sum()) / n)


def balance_summary(counts) -> dict:
    """JSON-able balance statistics over per-device contribution counts."""
    x = np.asarray(counts, float)
    mean = float(x.mean()) if len(x) else 0.0
    var = float(x.var()) if len(x) else 0.0
    return {"total": int(x.sum()), "mean": mean, "var": var,
            "cv": math.sqrt(var) / mean if mean > 0 else 0.0,
            "gini": gini(x),
            "participants": int((x > 0).sum())}
