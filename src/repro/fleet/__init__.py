"""Device fleet emulation plane: trace-driven availability, capability
heterogeneity sampling, and participant-selection policies.

Three modules, one scenario surface:

* :mod:`~repro.fleet.traces` — seeded, JSON-serializable availability
  traces (diurnal / Weibull-session / flaky-link / uniform) and the
  single trace-event API (`install_fleet`) every protocol simulation
  drives membership from;
* :mod:`~repro.fleet.devices` — capability tiers (`DeviceProfile`) and
  weighted-mix cluster sampling (`sample_cluster`);
* :mod:`~repro.fleet.selection` — participant-selection policies
  (`random` / REFL-style `refl` / Apodotiko-style `score`) fed by the
  Task Scheduler's Alg. 3 consumption counters, plus the
  contribution-balance metric (`balance_summary` / `gini`).

One `FleetTrace` drives `simulate_fedoptima` and all six baselines, so
every scenario comparison runs over an identical device population.
"""
from .devices import (DEFAULT_TIERS, DeviceProfile, TIERS,
                      heterogeneous_cluster, parse_tiers, sample_cluster,
                      tier_counts)
from .selection import (POLICIES, RandomSelection, ScoreSelection,
                        SelectionContext, SelectionPolicy,
                        StalenessSelection, balance_summary, gini,
                        make_selection_policy)
from .traces import (DEFAULT_INTERVAL, FleetTrace, GENERATORS, diurnal_trace,
                     flaky_trace, install_fleet, make_trace, resolve_fleet,
                     uniform_trace, weibull_sessions_trace)

__all__ = [
    "DEFAULT_INTERVAL", "DEFAULT_TIERS", "DeviceProfile", "FleetTrace",
    "GENERATORS", "POLICIES", "RandomSelection", "ScoreSelection",
    "SelectionContext", "SelectionPolicy", "StalenessSelection", "TIERS",
    "balance_summary", "diurnal_trace", "flaky_trace", "gini",
    "heterogeneous_cluster", "install_fleet", "make_selection_policy",
    "make_trace", "parse_tiers", "resolve_fleet", "sample_cluster",
    "tier_counts", "uniform_trace", "weibull_sessions_trace",
]
