"""The blessed wall-clock for instrumented hot paths.

Hot-path modules (``core/``, ``memory/``, ``fleet/``, ``runtime/``,
``faults/``) must not call ``time.perf_counter``/``time.monotonic``
directly — lint rule RP002 enforces it — so that every interval a span
or a stats field reports was read from ONE clock, and tests can reason
about the tracer's time domain.  ``now()`` is that clock: monotonic,
seconds, float.  Simulated runs never call it (their clock is ``sim.t``,
passed to the tracer explicitly); only host-side pod code does.
"""
from __future__ import annotations

import time

__all__ = ["now"]


def now() -> float:
    """Monotonic wall-clock seconds (the only sanctioned hot-path read)."""
    return time.perf_counter()
