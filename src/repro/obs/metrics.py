"""Unified metrics registry: counters, gauges, fixed-bucket histograms.

One :class:`MetricsRegistry` per component (executor, activation store,
update gate, benchmark harness) replaces the scattered ad-hoc counter
attributes those components grew organically.  Design constraints:

* **no deps** — percentiles come from fixed exponential buckets with
  linear interpolation inside the bucket, not from kept samples;
* **pure bookkeeping** — instruments never feed control flow, so a
  registry-backed run is bit-identical to the ad-hoc-counter run it
  replaced (the components keep their legacy attribute names as
  read-only properties over the instruments);
* **JSON-able** — :meth:`MetricsRegistry.snapshot` is what
  ``BENCH_*.json`` writers embed, :meth:`dump_line` is the periodic
  ``--metrics-every`` one-liner, :meth:`write_jsonl` appends a final
  snapshot line for log scrapers.
"""
from __future__ import annotations

import json
import math

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


class Counter:
    """Monotone (float) counter."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter increments must be >= 0, got {n}")
        self.value += n

    def snapshot(self):
        v = self.value
        return int(v) if float(v).is_integer() else float(v)


class Gauge:
    """Set/adjustable level with peak tracking (high-water marks)."""

    __slots__ = ("value", "peak")

    def __init__(self):
        self.value = 0.0
        self.peak = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)
        if self.value > self.peak:
            self.peak = self.value

    def add(self, dv: float) -> None:
        self.set(self.value + dv)

    def snapshot(self) -> dict:
        return {"value": self.value, "peak": self.peak}


class Histogram:
    """Fixed exponential-bucket histogram with interpolated percentiles.

    Buckets span ``[lo, hi]`` with ``growth``× geometric spacing plus an
    underflow and an overflow bucket; exact count/sum/min/max ride along
    so means are exact and only the percentiles are bucket-quantized
    (relative error bounded by ``growth - 1`` per estimate).
    """

    __slots__ = ("bounds", "counts", "count", "sum", "min", "max")

    def __init__(self, lo: float = 1e-6, hi: float = 1e4,
                 growth: float = 1.6):
        if not (lo > 0 and hi > lo and growth > 1):
            raise ValueError(
                f"need 0 < lo < hi and growth > 1, got {lo}, {hi}, {growth}")
        n = int(math.ceil(math.log(hi / lo, growth))) + 1
        self.bounds = [lo * growth ** i for i in range(n)]   # upper edges
        self.counts = [0] * (n + 1)                          # + overflow
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def percentile(self, p: float) -> float:
        """p in [0, 100] — linear interpolation inside the landing bucket,
        clamped to the observed [min, max] envelope."""
        if self.count == 0:
            return 0.0
        target = (p / 100.0) * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if c and seen + c >= target:
                lo = 0.0 if i == 0 else self.bounds[i - 1]
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                frac = (target - seen) / c
                est = lo + (hi - lo) * frac
                return float(min(max(est, self.min), self.max))
            seen += c
        return float(self.max)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        if self.count == 0:
            return {"count": 0}
        return {"count": self.count, "sum": self.sum, "mean": self.mean,
                "min": self.min, "max": self.max,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99)}


class MetricsRegistry:
    """Named instruments, get-or-create, one flat namespace.

    Naming convention (see EXPERIMENTS.md §Observability):
    ``<component>.<noun>[_<unit>]`` — e.g. ``exec.hidden_host_s``,
    ``store.spills``, ``gate.rejected.norm_fence``, ``bench.us.fedoptima``.
    """

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    # -- get-or-create ----------------------------------------------------
    def counter(self, name: str) -> Counter:
        self._check_free(name, self._counters)
        return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        self._check_free(name, self._gauges)
        return self._gauges.setdefault(name, Gauge())

    def histogram(self, name: str, **kw) -> Histogram:
        self._check_free(name, self._histograms)
        if name not in self._histograms:
            self._histograms[name] = Histogram(**kw)
        return self._histograms[name]

    def _check_free(self, name: str, own: dict) -> None:
        for kind, d in (("counter", self._counters),
                        ("gauge", self._gauges),
                        ("histogram", self._histograms)):
            if d is not own and name in d:
                raise ValueError(
                    f"metric {name!r} already registered as a {kind}")

    # -- export -----------------------------------------------------------
    def snapshot(self) -> dict:
        out: dict = {}
        if self._counters:
            out["counters"] = {k: c.snapshot()
                               for k, c in sorted(self._counters.items())}
        if self._gauges:
            out["gauges"] = {k: g.snapshot()
                             for k, g in sorted(self._gauges.items())}
        if self._histograms:
            out["histograms"] = {k: h.snapshot()
                                 for k, h in sorted(self._histograms.items())}
        return out

    def dump_line(self, prefix: str = "") -> str:
        """Compact one-line ``k=v`` rendering (the --metrics-every dump)."""
        parts = []
        for k, c in sorted(self._counters.items()):
            parts.append(f"{k}={c.snapshot()}")
        for k, g in sorted(self._gauges.items()):
            parts.append(f"{k}={g.value:g}(peak={g.peak:g})")
        for k, h in sorted(self._histograms.items()):
            if h.count:
                parts.append(f"{k}:p50={h.percentile(50):.3g}"
                             f",p99={h.percentile(99):.3g},n={h.count}")
        return (f"{prefix} " if prefix else "") + " ".join(parts)

    def write_jsonl(self, path: str, extra: dict | None = None) -> None:
        """Append one JSON line: the final snapshot (+ caller context)."""
        rec = dict(extra or {})
        rec["metrics"] = self.snapshot()
        with open(path, "a") as f:
            f.write(json.dumps(rec) + "\n")
