"""Telemetry plane: span tracing, idle attribution, metrics registry.

Three pieces, no third-party deps:

* :mod:`repro.obs.trace` — span/instant tracing on the sanitizer's
  detached-seam pattern (one module-flag read per site when off), with
  Chrome trace-event JSON export (Perfetto / chrome://tracing).
* :mod:`repro.obs.idle` — per-lane gap classification into the paper's
  two idle classes (task-dependency vs straggler) plus pipeline-fill
  warmup, from a captured trace.
* :mod:`repro.obs.metrics` — counters / gauges / fixed-bucket histograms
  behind one :class:`MetricsRegistry`, replacing scattered ad-hoc
  accounting; snapshots ride ``BENCH_*.json`` records.
* :mod:`repro.obs.clock` — the blessed wall-clock (``now()``) for
  instrumented hot paths (lint rule RP002 requires it there).
"""
from .clock import now  # noqa: F401
from .metrics import Counter, Gauge, Histogram, MetricsRegistry  # noqa: F401
from .trace import (Tracer, attach, detach, emit_instant,  # noqa: F401
                    emit_span, span, traced, validate_chrome_trace)
from .idle import attribute_idle  # noqa: F401
