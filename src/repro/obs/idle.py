"""Idle-time attribution: classify every gap on every trace lane.

FedOptima's Table 3 reports idle-time *reductions*; this module makes the
underlying quantity first-class.  Given a captured :class:`~repro.obs
.trace.Tracer`, every non-busy second on each entity's timeline is
assigned to exactly one class:

``warmup``
    before the entity's first busy span — pipeline fill for the server,
    pre-selection wait for a device.  Kept separate so steady-state idle
    fractions are not diluted by startup.
``offline``
    (devices only) between a ``leave`` and the matching ``join`` instant
    — the device does not exist, so the time is excluded from its idle
    denominator rather than blamed on the protocol.
``task_dependency``
    idle forced by the protocol's dependency structure: a device waiting
    while the server aggregates/trains, or the server waiting with no
    device mid-task (nothing outstanding to wait *for*).
``straggler``
    idle forced by load imbalance: a device done while a peer is still
    computing, or the server blocked on outstanding slow devices while
    other finished devices sit idle.

Entities aggregate lanes: device *k* is every ``dev/<k>`` and
``dev/<k>/...`` lane (PiPar's overlapped-forward sub-lane counts as the
same device being busy); the server is ``srv``, ``srv/...`` and ``mesh``.
``net/`` and ``host/`` lanes are timeline detail, not compute, and are
ignored here.

The classifier is a single sweep over the union of interval boundaries,
so classes partition each entity's [0, duration] exactly — the output
rows sum back to the wall (asserted by the tests, not trusted).
"""
from __future__ import annotations

__all__ = ["attribute_idle"]


def _merge(intervals: list) -> list:
    """Sort + coalesce [t0, t1) intervals."""
    out: list = []
    for t0, t1 in sorted(intervals):
        if t1 <= t0:
            continue
        if out and t0 <= out[-1][1]:
            out[-1][1] = max(out[-1][1], t1)
        else:
            out.append([t0, t1])
    return out


def _clamp(intervals: list, duration: float) -> list:
    return [[max(0.0, a), min(duration, b)] for a, b in intervals
            if min(duration, b) > max(0.0, a)]


def _covered(intervals: list, a: float, b: float) -> bool:
    """True if [a, b) lies inside one of the (merged, sorted) intervals."""
    for t0, t1 in intervals:
        if t0 <= a and b <= t1:
            return True
        if t0 >= b:
            break
    return False


def _device_of(lane: str):
    if lane.startswith("dev/"):
        parts = lane.split("/")
        if len(parts) >= 2 and parts[1]:
            return parts[1]
    return None


def _is_server(lane: str) -> bool:
    return lane == "srv" or lane.startswith("srv/") or lane == "mesh"


def attribute_idle(tracer, duration: float | None = None) -> dict:
    """Classify idle time on a captured trace.

    ``duration`` is the run's horizon in the tracer's time domain;
    defaults to the last span end.  Returns a dict with ``server``,
    ``devices`` (fleet aggregate) and ``per_device`` sections; each
    carries ``busy_s`` / ``warmup_s`` / ``task_dependency_s`` /
    ``straggler_s`` (devices add ``offline_s``) plus fractions.  Server
    fractions are over ``duration``; device fractions are over the
    fleet's *online* time ``n_devices * duration - offline_s``.
    """
    dev_busy: dict = {}
    srv_busy: list = []
    for lane, _name, t0, t1, _args in tracer.spans:
        k = _device_of(lane)
        if k is not None:
            dev_busy.setdefault(k, []).append((t0, t1))
        elif _is_server(lane):
            srv_busy.append((t0, t1))

    if duration is None:
        ends = [s[3] for s in tracer.spans]
        duration = max(ends) if ends else 0.0
    duration = float(duration)
    if duration <= 0.0:
        raise ValueError("attribute_idle needs a positive duration "
                         "(or at least one recorded span)")

    # offline windows from leave/join instants, paired per device
    dev_offline: dict = {k: [] for k in dev_busy}
    pending_leave: dict = {}
    for lane, name, t, _args in sorted(tracer.instants, key=lambda i: i[2]):
        k = _device_of(lane)
        if k is None:
            continue
        if name == "leave":
            pending_leave.setdefault(k, t)
        elif name == "join" and k in pending_leave:
            dev_offline.setdefault(k, []).append(
                (pending_leave.pop(k), t))
    for k, t in pending_leave.items():     # left and never came back
        dev_offline.setdefault(k, []).append((t, duration))

    srv_busy = _clamp(_merge(srv_busy), duration)
    dev_busy = {k: _clamp(_merge(v), duration) for k, v in dev_busy.items()}
    dev_offline = {k: _clamp(_merge(v), duration)
                   for k, v in dev_offline.items()}
    devices = sorted(dev_busy, key=lambda k: (len(k), k))

    srv_start = srv_busy[0][0] if srv_busy else duration
    dev_start = {k: (dev_busy[k][0][0] if dev_busy[k] else duration)
                 for k in devices}

    # one sweep over the union of all interval boundaries
    cuts = {0.0, duration}
    for t0, t1 in srv_busy:
        cuts.update((t0, t1))
    for k in devices:
        for t0, t1 in dev_busy[k]:
            cuts.update((t0, t1))
        for t0, t1 in dev_offline.get(k, []):
            cuts.update((t0, t1))
    cuts = sorted(c for c in cuts if 0.0 <= c <= duration)

    srv = {"busy_s": 0.0, "warmup_s": 0.0,
           "task_dependency_s": 0.0, "straggler_s": 0.0}
    per_dev = {k: {"busy_s": 0.0, "warmup_s": 0.0, "offline_s": 0.0,
                   "task_dependency_s": 0.0, "straggler_s": 0.0}
               for k in devices}

    for a, b in zip(cuts, cuts[1:]):
        seg = b - a
        if seg <= 0.0:
            continue
        s_busy = _covered(srv_busy, a, b)
        d_busy = {k: _covered(dev_busy[k], a, b) for k in devices}
        d_off = {k: _covered(dev_offline.get(k, []), a, b) for k in devices}

        if s_busy:
            srv["busy_s"] += seg
        elif a < srv_start:
            srv["warmup_s"] += seg
        else:
            any_busy = any(d_busy[k] and not d_off[k] for k in devices)
            finished_waiting = any(
                (not d_busy[k]) and (not d_off[k]) and a >= dev_start[k]
                for k in devices)
            if any_busy and finished_waiting:
                srv["straggler_s"] += seg
            else:
                srv["task_dependency_s"] += seg

        for k in devices:
            row = per_dev[k]
            if d_off[k]:
                row["offline_s"] += seg
            elif d_busy[k]:
                row["busy_s"] += seg
            elif a < dev_start[k]:
                row["warmup_s"] += seg
            elif s_busy:
                row["task_dependency_s"] += seg
            elif any(d_busy[j] and not d_off[j]
                     for j in devices if j != k):
                row["straggler_s"] += seg
            else:
                row["task_dependency_s"] += seg

    def _fracs(row: dict, denom: float) -> dict:
        idle = row["task_dependency_s"] + row["straggler_s"]
        out = dict(row)
        out["idle_frac"] = idle / denom if denom > 0 else 0.0
        for cls in ("task_dependency", "straggler"):
            out[f"{cls}_frac"] = (row[f"{cls}_s"] / denom
                                  if denom > 0 else 0.0)
        return out

    fleet = {"busy_s": 0.0, "warmup_s": 0.0, "offline_s": 0.0,
             "task_dependency_s": 0.0, "straggler_s": 0.0}
    for row in per_dev.values():
        for key in fleet:
            fleet[key] += row[key]
    online = len(devices) * duration - fleet["offline_s"]

    return {
        "duration": duration,
        "warmup_end_s": srv_start,
        "server": _fracs(srv, duration),
        "devices": {"n": len(devices), **_fracs(fleet, online)},
        "per_device": {
            k: _fracs(row, duration - row["offline_s"])
            for k, row in per_dev.items()},
    }
