"""Span tracing on the sanitizer's detached-seam pattern.

Instrumented call sites guard on the module flag — ``if _obs.TRACING:
_obs.emit_span(...)`` — so a run with no tracer attached pays one global
read per site and is bit-identical to an uninstrumented build (the same
contract :mod:`repro.analysis.sanitize` established; the two seams are
independent and compose).  Attach explicitly::

    from repro.obs.trace import Tracer, traced

    with traced(Tracer(domain="sim")) as tr:
        simulate_fedoptima(...)
    tr.export_chrome("out.json")       # Perfetto / chrome://tracing

or run the drivers with ``--trace out.json``.

Lanes and time domains
----------------------

A *lane* is a string naming one timeline: ``dev/<k>`` (device compute),
``net/<k>`` (device uplink), ``srv`` (server compute), ``mesh`` (the pod
mesh), ``host/<phase>`` (pod host loop: plan, build, drain, memory,
capture, ckpt, control).  Chrome export maps lanes onto pid/tid rows:
pid 1 = server/host lanes, pid 2 = devices, pid 3 = network.

Every span carries explicit ``t0``/``t1`` in the tracer's ``domain``:
``"wall"`` (``repro.obs.clock.now()`` seconds — pod runs) or ``"sim"``
(simulated seconds — event-sim runs).  One trace must stay in one
domain; the drivers pick it by mode.  ``clip=True`` spans are clamped to
start at-or-after the lane's previous end (busy lanes stay physically
non-overlapping even when a simulator's cost accounting double-books).

``python -m repro.obs.trace out.json [...]`` validates exported files
against the schema (CI runs it on the smoke-lane artifacts).
"""
from __future__ import annotations

import json
from contextlib import contextmanager

from .clock import now as _now

__all__ = [
    "TRACING", "Tracer", "attach", "detach", "traced", "span",
    "emit_span", "emit_instant", "validate_chrome_trace",
]

#: Fast-path guard read by every instrumented call site.
TRACING = False

_STACK: list["Tracer"] = []


def attach(tracer: "Tracer") -> None:
    global TRACING
    _STACK.append(tracer)
    TRACING = True


def detach(tracer: "Tracer") -> None:
    global TRACING
    if tracer in _STACK:
        _STACK.remove(tracer)
    TRACING = bool(_STACK)


@contextmanager
def traced(tracer: "Tracer | None" = None, domain: str = "wall"):
    """Attach ``tracer`` (or a fresh one) for the block; yields it."""
    tr = tracer if tracer is not None else Tracer(domain=domain)
    attach(tr)
    try:
        yield tr
    finally:
        detach(tr)


def emit_span(lane: str, name: str, t0: float, t1: float,
              clip: bool = False, **args) -> None:
    for tr in _STACK:
        tr.add_span(lane, name, t0, t1, clip=clip, **args)


def emit_instant(lane: str, name: str, t: float, **args) -> None:
    for tr in _STACK:
        tr.add_instant(lane, name, t, **args)


@contextmanager
def span(lane: str, name: str, **args):
    """Wall-clock span context for host code (reads the obs clock).
    Near-free when detached, but hot per-round sites should prefer the
    guarded ``if TRACING: emit_span(...)`` form with explicit times."""
    if not TRACING:
        yield
        return
    t0 = _now()
    try:
        yield
    finally:
        emit_span(lane, name, t0, _now(), **args)


class Tracer:
    """Span/instant collector for one run.

    ``spans`` holds ``(lane, name, t0, t1, args|None)`` tuples and
    ``instants`` holds ``(lane, name, t, args|None)`` — both in emission
    order, times in the tracer's ``domain`` seconds.
    """

    def __init__(self, domain: str = "wall"):
        if domain not in ("wall", "sim"):
            raise ValueError(f"domain must be 'wall' or 'sim', got {domain!r}")
        self.domain = domain
        self.spans: list[tuple] = []
        self.instants: list[tuple] = []
        self._lane_end: dict[str, float] = {}

    # -- recording --------------------------------------------------------
    def add_span(self, lane: str, name: str, t0: float, t1: float,
                 clip: bool = False, **args) -> None:
        t0, t1 = float(t0), float(t1)
        if clip:
            t0 = max(t0, self._lane_end.get(lane, t0))
            if t1 <= t0:
                return          # fully shadowed by the lane's previous span
        end = self._lane_end.get(lane)
        self._lane_end[lane] = t1 if end is None else max(end, t1)
        self.spans.append((lane, name, t0, max(t1, t0), args or None))

    def add_instant(self, lane: str, name: str, t: float, **args) -> None:
        self.instants.append((lane, name, float(t), args or None))

    def lanes(self) -> list:
        return sorted({s[0] for s in self.spans} |
                      {i[0] for i in self.instants}, key=_lane_sort_key)

    # -- Chrome trace-event export ----------------------------------------
    def to_chrome(self) -> dict:
        lanes = self.lanes()
        pid_tid = {}
        next_tid = {1: 0, 2: 0, 3: 0}
        for lane in lanes:
            pid = _lane_pid(lane)
            pid_tid[lane] = (pid, next_tid[pid])
            next_tid[pid] += 1
        times = [s[2] for s in self.spans] + [i[2] for i in self.instants]
        t_origin = min(times) if times else 0.0

        def us(t: float) -> float:
            return round((t - t_origin) * 1e6, 3)

        events = []
        for pid, pname in ((1, "server"), (2, "devices"), (3, "network")):
            if any(p == pid for p, _ in pid_tid.values()):
                events.append({"name": "process_name", "ph": "M", "pid": pid,
                               "tid": 0, "args": {"name": pname}})
        for lane, (pid, tid) in pid_tid.items():
            events.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": tid, "args": {"name": _lane_label(lane)}})
        for lane, name, t0, t1, args in self.spans:
            pid, tid = pid_tid[lane]
            u0, u1 = us(t0), us(t1)
            # dur from the ROUNDED endpoints: ts+dur lands exactly on the
            # next span's rounded start, so clip-tight spans stay
            # non-overlapping after µs quantization
            ev = {"name": name, "ph": "X", "ts": u0,
                  "dur": max(round(u1 - u0, 3), 0.0),
                  "pid": pid, "tid": tid}
            if args:
                ev["args"] = args
            events.append(ev)
        for lane, name, t, args in self.instants:
            pid, tid = pid_tid[lane]
            ev = {"name": name, "ph": "i", "ts": us(t), "s": "t",
                  "pid": pid, "tid": tid}
            if args:
                ev["args"] = args
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"domain": self.domain,
                              "time_unit": "sim-seconds"
                              if self.domain == "sim" else "wall-seconds",
                              "tool": "repro.obs.trace"}}

    def export_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)


# ---------------------------------------------------------------------------
# lane → pid/tid mapping helpers
# ---------------------------------------------------------------------------

def _lane_pid(lane: str) -> int:
    if lane.startswith("dev/"):
        return 2
    if lane.startswith("net/"):
        return 3
    return 1


def _lane_sort_key(lane: str):
    parts = lane.split("/")
    num = int(parts[1]) if len(parts) > 1 and parts[1].isdigit() else -1
    return (_lane_pid(lane), parts[0], num, lane)


def _lane_label(lane: str) -> str:
    parts = lane.split("/")
    if lane.startswith("dev/") and len(parts) >= 2:
        tail = " ".join(parts[2:])
        return f"device {parts[1]}" + (f" ({tail})" if tail else "")
    if lane.startswith("net/") and len(parts) >= 2:
        return f"uplink {parts[1]}"
    return lane


# ---------------------------------------------------------------------------
# schema validation (CI smoke lane + tests)
# ---------------------------------------------------------------------------

#: tolerance for float-rounding overlap between adjacent spans (µs)
_OVERLAP_EPS_US = 1e-3


def validate_chrome_trace(doc: dict) -> list:
    """Check a Chrome trace-event document.  Returns a list of problem
    strings (empty = valid): required top-level shape, required per-phase
    fields, non-negative timestamps/durations, and — per (pid, tid) lane —
    monotonically ordered, non-overlapping complete ('X') spans."""
    problems: list[str] = []
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        return ["top level must be an object with a 'traceEvents' list"]
    by_lane: dict[tuple, list] = {}
    for i, ev in enumerate(doc["traceEvents"]):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "M"):
            problems.append(f"event {i}: unsupported ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str):
            problems.append(f"event {i}: missing/non-string 'name'")
        if not isinstance(ev.get("pid"), int):
            problems.append(f"event {i}: missing/non-int 'pid'")
        if ph == "M":
            continue
        if not isinstance(ev.get("tid"), int):
            problems.append(f"event {i}: missing/non-int 'tid'")
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i}: 'ts' must be a number >= 0")
            continue
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: 'X' event needs 'dur' >= 0")
                continue
            by_lane.setdefault((ev["pid"], ev["tid"]), []).append(
                (float(ts), float(dur), ev.get("name", ""), i))
    for (pid, tid), evs in sorted(by_lane.items()):
        evs.sort()
        end = -1.0
        for ts, dur, name, i in evs:
            if ts < end - _OVERLAP_EPS_US:
                problems.append(
                    f"lane pid={pid} tid={tid}: span {name!r} (event {i}) "
                    f"starts at {ts} before the previous span ended at "
                    f"{end} — overlapping spans on one lane")
            end = max(end, ts + dur)
    return problems


def _main(argv) -> int:
    if not argv:
        print("usage: python -m repro.obs.trace TRACE.json [...]")
        return 2
    rc = 0
    for path in argv:
        with open(path) as f:
            doc = json.load(f)
        problems = validate_chrome_trace(doc)
        evs = doc.get("traceEvents", []) if isinstance(doc, dict) else []
        n_x = sum(1 for e in evs if isinstance(e, dict)
                  and e.get("ph") == "X")
        lanes = {(e.get("pid"), e.get("tid")) for e in evs
                 if isinstance(e, dict) and e.get("ph") == "X"}
        if problems:
            rc = 1
            for p in problems:
                print(f"{path}: {p}")
        else:
            dom = (doc.get("otherData") or {}).get("domain", "?")
            print(f"{path}: OK — {n_x} spans on {len(lanes)} lanes "
                  f"(domain={dom})")
    return rc


if __name__ == "__main__":
    import sys
    raise SystemExit(_main(sys.argv[1:]))
