"""Seeded, deterministic fault schedules (the chaos plane's scenario input).

A :class:`FaultSchedule` is the fault-injection analogue of a
``repro.fleet.FleetTrace``: a reusable, JSON-serializable scenario
artifact — a list of timed :class:`FaultEvent`\\ s a run injects at named
seams — so the SAME adversarial scenario drives FedOptima, every baseline
protocol, and the pod executor.  Schedules are:

* **deterministic** — :func:`make_fault_schedule` is seeded; the same
  (classes, params, seed) always yields the same event list, and the list
  (not the generator) is what the injectors consume;
* **serializable** — ``save``/``load`` round-trip through JSON
  (``fault-schedule-v1``), so a chaos scenario is a shareable experiment
  input, not a code path;
* **path-agnostic** — the time axis is simulated seconds for the event
  simulators and the round index for the pod executor; the schema is the
  same either way.

Fault classes (the taxonomy; see EXPERIMENTS.md §Fault injection):

================  ===========================================================
corrupt_act       the device's next ACTIVATION upload carries a poisoned
                  payload (``kind``: nan | inf | huge | bitflip)
corrupt_model     the device's next MODEL upload is poisoned (same kinds)
duplicate         the device's next activation upload arrives twice — the
                  copy delayed by ``param`` seconds (reordered arrivals)
delay             the device's next model upload is delayed by ``param``
                  seconds (stale arrivals, possibly past ``max_delay``)
timeout           the device goes dark at ``t`` for ``param`` seconds
                  (sim) / rounds (pod) — mid-round, without a trace event
server_crash      the server crashes at ``t`` and is down for ``param``
                  seconds (sim); in the pod the executor aborts at the
                  round-``t`` boundary (the crash-consistent restart path)
torn_checkpoint   the snapshot committed at round ``t`` is torn afterwards
                  (``kind``: truncate | bitflip | manifest) — resume must
                  fall back to the newest VERIFIED snapshot
================  ===========================================================
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field

import numpy as np

FAULT_FORMAT = "fault-schedule-v1"

#: the full taxonomy, in canonical order
CLASSES = ("corrupt_act", "corrupt_model", "duplicate", "delay",
           "timeout", "server_crash", "torn_checkpoint")

#: corruption payload kinds (corrupt_act / corrupt_model)
CORRUPT_KINDS = ("nan", "inf", "huge", "bitflip")

#: torn-checkpoint damage modes
TEAR_MODES = ("truncate", "bitflip", "manifest")

#: classes the event simulators inject (sim time axis = seconds)
SIM_CLASSES = ("corrupt_act", "corrupt_model", "duplicate", "delay",
               "timeout", "server_crash")

#: classes the baseline protocols inject (full-model methods have no
#: activation stream / flow control; the server is a modeled cost only)
BASELINE_CLASSES = ("corrupt_model", "delay", "timeout")

#: classes the pod executor injects (time axis = round index)
POD_CLASSES = ("corrupt_act", "timeout", "server_crash", "torn_checkpoint")


@dataclass(frozen=True, order=True)
class FaultEvent:
    t: float                 # sim seconds (sim path) / round index (pod)
    cls: str                 # one of CLASSES
    device: int = -1         # target device/group; -1 = server-scoped
    kind: str = ""           # corruption payload / tear mode
    param: float = 0.0       # class-specific: extra delay / outage length

    def __post_init__(self):
        if self.cls not in CLASSES:
            raise ValueError(f"unknown fault class {self.cls!r}; "
                             f"choose from {CLASSES}")
        if self.cls.startswith("corrupt") and self.kind not in CORRUPT_KINDS:
            raise ValueError(f"{self.cls} needs kind in {CORRUPT_KINDS}, "
                             f"got {self.kind!r}")
        if self.cls == "torn_checkpoint" and self.kind not in TEAR_MODES:
            raise ValueError(f"torn_checkpoint needs kind in {TEAR_MODES}, "
                             f"got {self.kind!r}")


@dataclass
class FaultSchedule:
    horizon: float                        # run length the schedule targets
    events: tuple = ()                    # FaultEvents, sorted by t
    meta: dict = field(default_factory=dict)

    def __post_init__(self):
        self.events = tuple(sorted(self.events))
        if self.horizon <= 0:
            raise ValueError(f"horizon must be > 0, got {self.horizon}")
        late = [e for e in self.events if e.t >= self.horizon]
        if late:
            raise ValueError(
                f"{len(late)} event(s) at/after the horizon "
                f"{self.horizon} (first: {late[0]}) would never fire")

    def __len__(self) -> int:
        return len(self.events)

    def by_class(self, cls: str) -> tuple:
        return tuple(e for e in self.events if e.cls == cls)

    def counts(self) -> dict:
        out: dict[str, int] = {}
        for e in self.events:
            out[e.cls] = out.get(e.cls, 0) + 1
        return out

    # -- JSON artifact ----------------------------------------------------
    def to_json(self) -> dict:
        return {"format": FAULT_FORMAT,
                "horizon": float(self.horizon),
                "events": [[float(e.t), e.cls, int(e.device), e.kind,
                            float(e.param)] for e in self.events],
                "meta": self.meta}

    @classmethod
    def from_json(cls, d: dict) -> "FaultSchedule":
        if d.get("format") != FAULT_FORMAT:
            raise ValueError(
                f"not a fault schedule: format={d.get('format')!r} "
                f"(expected {FAULT_FORMAT!r})")
        events = tuple(FaultEvent(t=float(t), cls=c, device=int(k),
                                  kind=kind, param=float(p))
                       for t, c, k, kind, p in d["events"])
        return cls(horizon=float(d["horizon"]), events=events,
                   meta=dict(d.get("meta", {})))

    def save(self, path: str) -> str:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh)
        return path

    @classmethod
    def load(cls, path: str) -> "FaultSchedule":
        with open(path) as fh:
            return cls.from_json(json.load(fh))


def make_fault_schedule(K: int, horizon: float, *, seed: int = 0,
                        classes=SIM_CLASSES, density: float = 1.0,
                        n_per_class: int | None = None) -> FaultSchedule:
    """Seeded fault-schedule generator.

    Per class, ``n_per_class`` events (default: ``ceil(density * K / 4)``,
    so ``density=1`` stresses ~a quarter of the fleet per class and the
    benchmark's "dense" scenario uses ``density=4`` — every device hit)
    are drawn at uniform times over the first 80% of the horizon (outage
    durations always END inside the run, so every injected fault can be
    matched to its recovery counter).  Targets, payload kinds and
    class-specific params all come from one seeded Generator — the same
    (K, horizon, classes, density, seed) is bit-for-bit the same schedule.
    """
    if K < 1:
        raise ValueError(f"need K >= 1, got {K}")
    if horizon <= 0:
        raise ValueError(f"need horizon > 0, got {horizon}")
    unknown = [c for c in classes if c not in CLASSES]
    if unknown:
        raise ValueError(f"unknown fault class(es) {unknown}; "
                         f"choose from {CLASSES}")
    rng = np.random.default_rng(seed)
    n = n_per_class if n_per_class is not None \
        else max(1, int(math.ceil(density * K / 4.0)))
    events = []
    for cls in classes:
        times = rng.uniform(0.0, 0.8 * horizon, size=n)
        for t in times:
            t = float(t)
            device = int(rng.integers(0, K)) \
                if cls not in ("server_crash", "torn_checkpoint") else -1
            kind, param = "", 0.0
            if cls.startswith("corrupt"):
                kind = CORRUPT_KINDS[int(rng.integers(len(CORRUPT_KINDS)))]
            elif cls == "torn_checkpoint":
                kind = TEAR_MODES[int(rng.integers(len(TEAR_MODES)))]
            if cls == "duplicate":
                param = float(rng.uniform(0.0, horizon / 50.0))
            elif cls == "delay":
                param = float(rng.uniform(horizon / 50.0, horizon / 8.0))
            elif cls in ("timeout", "server_crash"):
                hi = min(horizon / 10.0, 0.95 * horizon - t)
                param = float(rng.uniform(horizon / 100.0,
                                          max(hi, horizon / 50.0)))
            events.append(FaultEvent(t=t, cls=cls, device=device,
                                     kind=kind, param=param))
    return FaultSchedule(
        horizon=horizon, events=tuple(events),
        meta={"K": int(K), "seed": int(seed), "density": float(density),
              "n_per_class": int(n), "classes": list(classes)})
