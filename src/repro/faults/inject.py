"""Fault injectors: play a :class:`FaultSchedule` into the named seams.

Two injector flavors share the schedule format and the accounting
contract:

* :class:`FaultInjector` — the event-simulator side (``simulate_fedoptima``
  and the six baselines).  The simulator calls ``tag_*_upload`` at send
  seams, ``act_dedupe``/``act_validate``/``model_validate`` at arrival
  seams, and schedules the injector's ``timeouts()``/``crashes()`` windows
  itself.  Time axis: simulated seconds.
* :class:`PodFaultInjector` — the pod-mode :class:`RoundExecutor` side.
  ``on_round_start`` raises :class:`InjectedCrash` at a scheduled round
  boundary (the crash-consistent restart path), ``mask_active`` opens
  timeout windows (the timed-out group's slot is reclaimed and its state
  retained for α-rejoin via the PR 3 retention path), ``mask_produce``
  quarantines poisoned groups, and ``on_checkpoint`` tears a
  just-committed snapshot (``tear_snapshot``).  Time axis: round index.

Accounting contract (checked by tests and the faults benchmark): every
fault is counted as **injected** at the seam where its effect lands (not
when scheduled or armed), and every injected fault must be matched by a
**recovered** count from the armor that absorbed it — quarantine,
α-staleness weighting, dedupe, timeout rejoin, crash restart.  Events a
run never reaches are **unfired** (``scheduled - injected``).  With the
gate disabled, poisoned updates flow through unrecovered (disposition
``consumed_poisoned_*``) — the benchmark's no-armor leg — and
``report()["matched"]`` is honestly False.
"""
from __future__ import annotations

import os

import numpy as np

from repro.obs import trace as _tr

from .quarantine import UpdateGate, make_payload
from .schedule import (BASELINE_CLASSES, POD_CLASSES, SIM_CLASSES,
                       FaultSchedule)

#: schedule classes that arm a device's NEXT upload (consumed one-shot,
#: per device, in time order)
_UPLOAD_CLASSES = ("corrupt_act", "corrupt_model", "duplicate", "delay")


class InjectedCrash(RuntimeError):
    """A scheduled server crash at a round boundary (pod path).  The
    driver persists the fired boundary, then dies; the resumed process
    passes it back via ``fired_crashes`` so the crash fires exactly once."""

    def __init__(self, round_index: int):
        super().__init__(
            f"injected server crash at round boundary {round_index}")
        self.round_index = int(round_index)


class _Accounting:
    """Shared injected/recovered/disposition bookkeeping."""

    def __init__(self, schedule: FaultSchedule, gate, supported):
        self.schedule = schedule
        self.gate = gate
        self.supported = frozenset(supported)
        self.injected: dict[str, int] = {}
        self.recovered: dict[str, int] = {}
        self.disposition: dict[str, int] = {}

    @staticmethod
    def _bump(d: dict, key: str, n: int = 1):
        d[key] = d.get(key, 0) + n

    def note_injected(self, cls: str):
        self._bump(self.injected, cls)

    def note_recovered(self, cls: str, disposition: str = ""):
        self._bump(self.recovered, cls)
        if disposition:
            self._bump(self.disposition, disposition)

    def note_disposition(self, key: str):
        self._bump(self.disposition, key)

    def report(self) -> dict:
        scheduled = {c: n for c, n in self.schedule.counts().items()
                     if c in self.supported}
        unfired = {c: scheduled.get(c, 0) - self.injected.get(c, 0)
                   for c in scheduled}
        classes = set(self.injected) | set(self.recovered)
        return {"scheduled": scheduled,
                "injected": dict(self.injected),
                "recovered": dict(self.recovered),
                "disposition": dict(self.disposition),
                "unfired": unfired,
                "matched": all(self.injected.get(c, 0) ==
                               self.recovered.get(c, 0) for c in classes),
                "gate": self.gate.summary() if self.gate else None}


# ---------------------------------------------------------------------------
# Event-simulator injector
# ---------------------------------------------------------------------------

class FaultInjector(_Accounting):
    """Schedule player for the event simulators (time axis: sim seconds).

    Upload-scoped classes (corrupt/duplicate/delay) arm a device's next
    upload at/after their ``t`` — consumed one-shot in time order.
    Window classes (timeout/server_crash) are exposed via ``timeouts()`` /
    ``crashes()`` for the simulator to schedule as begin/end events.
    """

    def __init__(self, schedule: FaultSchedule, gate: UpdateGate | None = None,
                 supported=SIM_CLASSES):
        super().__init__(schedule, gate, supported)
        self._pending: dict[str, dict[int, list]] = \
            {c: {} for c in _UPLOAD_CLASSES}
        for e in schedule.events:          # already sorted by t
            if e.cls in self._pending and e.cls in self.supported:
                self._pending[e.cls].setdefault(int(e.device), []).append(e)
        self._seq = 0
        self._delivered: set[int] = set()   # duplicate-tagged seqs seen once

    @classmethod
    def for_baseline(cls, schedule, gate=None) -> "FaultInjector":
        """Injector restricted to what full-model baselines can express
        (no activation stream / flow control; server cost is modeled)."""
        return cls(schedule, gate=gate, supported=BASELINE_CLASSES)

    # -- window events for the simulator to schedule ----------------------
    def timeouts(self) -> tuple:
        return self.schedule.by_class("timeout") \
            if "timeout" in self.supported else ()

    def crashes(self) -> tuple:
        return self.schedule.by_class("server_crash") \
            if "server_crash" in self.supported else ()

    # -- upload tagging (send seams) ---------------------------------------
    def _pop(self, cls: str, k: int, t: float):
        q = self._pending[cls].get(int(k))
        if q and q[0].t <= t:
            return q.pop(0)
        return None

    def may_send(self, k: int, t: float) -> bool:
        """Quarantine backoff: a struck device's sends stay paused."""
        return self.gate is None or self.gate.may_send(k, t)

    def tag_act_upload(self, k: int, t: float) -> dict | None:
        """Consume faults armed for device k's next activation upload."""
        e_c = self._pop("corrupt_act", k, t)
        e_d = self._pop("duplicate", k, t)
        if e_c is None and e_d is None:
            return None
        self._seq += 1
        return {"seq": self._seq,
                "kind": e_c.kind if e_c is not None else "",
                "dup_extra": e_d.param if e_d is not None else None}

    def tag_model_upload(self, k: int, t: float) -> tuple:
        """(extra_delay_s, corrupt_kind) for device k's next model upload."""
        e_d = self._pop("delay", k, t)
        e_c = self._pop("corrupt_model", k, t)
        return ((e_d.param if e_d is not None else 0.0),
                (e_c.kind if e_c is not None else ""))

    # -- arrival seams -------------------------------------------------------
    def act_dedupe(self, seq: int) -> bool:
        """True for the first delivery of a duplicate-tagged upload; the
        second delivery is the injected fault, recovered by the drop."""
        if seq in self._delivered:
            self.note_injected("duplicate")
            self.note_recovered("duplicate", "dedup_dropped")
            return False
        self._delivered.add(seq)
        return True

    def act_validate(self, k: int, tag: dict | None, t: float) -> bool:
        """Validation gate for one arriving activation batch.  True →
        admit (poisoned-if-unarmored); False → quarantined, and the CALLER
        must withdraw the flow token (``FlowController.on_quarantined``)
        and not enqueue."""
        kind = tag.get("kind", "") if tag else ""
        if not kind:
            return True
        self.note_injected("corrupt_act")
        if self.gate is None:
            self.note_disposition("admitted_poisoned_act")
            return True
        ok, _ = self.gate.validate(make_payload(kind, seed=tag["seq"]))
        if ok:
            self.note_disposition("gate_missed_act")
            return True
        self.gate.note_reject(k, t)
        self.note_recovered("corrupt_act", "quarantined_act")
        if _tr.TRACING:
            _tr.emit_instant(f"dev/{k}", "fault.quarantine_act", t,
                             kind=kind)
        return False

    def note_accept(self, k: int):
        """A clean admitted update forgives one strike (gate healing)."""
        if self.gate is not None:
            self.gate.note_accept(k)

    def model_validate(self, k: int, kind: str, t: float) -> tuple:
        """(admit, backoff) for one arriving model update.  On quarantine
        the caller skips aggregation and releases the device after
        ``backoff`` (re-sync without consuming the poisoned update)."""
        if not kind:
            return True, 0.0
        self.note_injected("corrupt_model")
        if self.gate is None:
            self.note_disposition("consumed_poisoned_model")
            return True, 0.0
        self._seq += 1
        ok, _ = self.gate.validate(make_payload(kind, seed=self._seq))
        if ok:
            self.note_disposition("gate_missed_model")
            return True, 0.0
        backoff = self.gate.note_reject(k, t)
        self.note_recovered("corrupt_model", "quarantined_model")
        if _tr.TRACING:
            _tr.emit_instant(f"dev/{k}", "fault.quarantine_model", t,
                             kind=kind, backoff=backoff)
        return False, backoff

    def note_delayed_arrival(self):
        """A delay-tagged model arrived: Alg. 4's staleness weighting is
        the armor (weight 0 past max_delay), applied by the control plane
        at aggregation — injected and recovered at the same seam."""
        self.note_injected("delay")
        self.note_recovered("delay", "late_arrival")

    # -- run end ---------------------------------------------------------
    def finalize(self, t_end: float):
        """Close outage windows still open when the run ends (an end event
        scheduled past ``duration`` never fires — the run finishing IS the
        recovery)."""
        del t_end
        for cls in ("timeout", "server_crash"):
            gap = self.injected.get(cls, 0) - self.recovered.get(cls, 0)
            for _ in range(gap):
                self.note_recovered(cls, f"{cls}_closed_at_end")


def install_timeouts(sim, inj: FaultInjector | None, active, trace, *,
                     on_leave=None, on_rejoin=None):
    """Schedule an injector's device-timeout windows into an event sim.

    A timeout is a mid-round blackout, NOT a trace event: the device goes
    dark at the scheduled instant (``on_leave`` fires the protocol's own
    departure handling — chain kill, token reclaim, counter purge) and
    comes back when the window closes, unless a trace tick already brought
    it back ("already_back") or still holds it down ("deferred_to_trace" —
    the trace's own rejoin tick recovers it later).  Shared by
    ``simulate_fedoptima`` and all six baselines so the window accounting
    is one code path."""
    if inj is None:
        return

    def timeout_begin(k, outage_s):
        if not active[k]:
            inj.note_disposition("timeout_noop")     # already away
            return
        inj.note_injected("timeout")
        if _tr.TRACING:
            _tr.emit_instant(f"dev/{k}", "fault.timeout_begin", sim.t,
                             outage_s=outage_s)
        active[k] = False
        if on_leave is not None:
            on_leave(k)
        sim.after(outage_s, timeout_end, k)

    def timeout_end(k):
        if active[k]:
            inj.note_recovered("timeout", "timeout_already_back")
            return
        if trace is not None and not bool(trace.state_at(sim.t)[0][k]):
            inj.note_recovered("timeout", "timeout_deferred_to_trace")
            return
        active[k] = True
        inj.note_recovered("timeout", "timeout_rejoined")
        if _tr.TRACING:
            _tr.emit_instant(f"dev/{k}", "fault.timeout_end", sim.t)
        if on_rejoin is not None:
            on_rejoin(k)

    for ev in inj.timeouts():
        sim.at(ev.t, timeout_begin, int(ev.device), float(ev.param))


# ---------------------------------------------------------------------------
# Pod-mode injector
# ---------------------------------------------------------------------------

class PodFaultInjector(_Accounting):
    """Schedule player for the pod executor (time axis: round index).

    ``fired_crashes`` carries the boundaries already crashed at across
    process restarts (run_pod persists them to ``FAULTS_FIRED.json``), so
    a resumed run counts them recovered instead of re-crashing forever.
    """

    def __init__(self, schedule: FaultSchedule, gate: UpdateGate | None = None,
                 fired_crashes=()):
        super().__init__(schedule, gate, supported=POD_CLASSES)
        self.fired_crashes = {int(x) for x in fired_crashes}
        self._crashes = []
        for e in schedule.by_class("server_crash"):
            if int(e.t) in self.fired_crashes:
                self.note_injected("server_crash")
                self.note_recovered("server_crash", "crash_resumed")
            else:
                self._crashes.append(e)
        self._timeouts = list(schedule.by_class("timeout"))
        self._corrupt = list(schedule.by_class("corrupt_act"))
        self._tears = list(schedule.by_class("torn_checkpoint"))
        self._down_until: dict[int, int] = {}

    # -- round boundary ----------------------------------------------------
    def on_round_start(self, r: int):
        """Raise at a scheduled crash boundary (exactly once per boundary
        across restarts).  The caller persists ``fired_crashes`` BEFORE
        letting the exception kill the process."""
        due = [e for e in self._crashes if int(e.t) <= r]
        if not due:
            return
        self._crashes = [e for e in self._crashes if int(e.t) > r]
        boundary = int(due[0].t)
        self.fired_crashes.add(boundary)
        self.note_injected("server_crash")
        for _ in due[1:]:       # boundaries merged into one restart
            self.note_injected("server_crash")
            self.note_recovered("server_crash", "crash_merged")
            self.fired_crashes.add(int(_.t))
        raise InjectedCrash(r)

    def mask_active(self, r: int, active: np.ndarray) -> np.ndarray:
        """Open/close timeout windows: a timed-out group reads as inactive,
        so the plan retires it (slot reclaimed, state retained) and its
        window end rejoins it through the α-rejoin restore path."""
        active = np.array(active, bool, copy=True)
        still = []
        for e in self._timeouts:
            k = int(e.device)
            if e.t <= r and active[k] and k not in self._down_until:
                self.note_injected("timeout")
                self._down_until[k] = r + max(1, int(round(e.param)))
            else:
                still.append(e)
        self._timeouts = still
        for k, until in list(self._down_until.items()):
            if r < until:
                active[k] = False
            else:
                self.note_recovered("timeout", "timeout_rejoined")
                del self._down_until[k]
        return active

    def mask_produce(self, r: int, produce: np.ndarray,
                     active: np.ndarray) -> np.ndarray:
        """Quarantine poisoned groups for round ``r``: with the gate on, a
        corrupt-upload group's produce column is zeroed (its activations
        never reach the ring — the slot does no-op work this round);
        without the gate the poison flows into server training."""
        due = [e for e in self._corrupt
               if e.t <= r and active[int(e.device)]]
        if not due:
            return produce
        self._corrupt = [e for e in self._corrupt
                         if not any(e is d for d in due)]
        produce = np.array(produce, bool, copy=True)
        for e in due:
            k = int(e.device)
            self.note_injected("corrupt_act")
            if self.gate is None:
                self.note_disposition("admitted_poisoned_act")
                continue
            ok, _ = self.gate.validate(make_payload(e.kind, seed=k + 1))
            if ok:
                self.note_disposition("gate_missed_act")
                continue
            self.gate.note_reject(k, float(r))
            produce[:, k] = False
            self.note_recovered("corrupt_act", "quarantined_act")
        return produce

    def on_checkpoint(self, r: int, directory: str, step: int):
        """Tear the snapshot just committed at round ``r`` (if scheduled).
        Recovery — resume falling back to the newest VERIFIED snapshot —
        is owned by ``checkpoint.store.latest_verified_step``; the tear is
        counted recovered here because the torn snapshot can never be
        half-loaded (checksums/commit markers make it detectable)."""
        due = [e for e in self._tears if int(e.t) <= r]
        if not due:
            return
        self._tears = [e for e in self._tears if int(e.t) > r]
        for e in due:
            tear_snapshot(directory, step, e.kind)
            self.note_injected("torn_checkpoint")
            self.note_recovered("torn_checkpoint", f"torn_{e.kind}")

    def finalize(self, r_end: int):
        del r_end
        for k in list(self._down_until):
            self.note_recovered("timeout", "timeout_closed_at_end")
            del self._down_until[k]


# ---------------------------------------------------------------------------
# Snapshot tearing (the torn_checkpoint fault body)
# ---------------------------------------------------------------------------

def tear_snapshot(directory: str, step: int, mode: str) -> str:
    """Damage a COMMITTED snapshot in place.

    ``truncate`` cuts ``arrays.npz`` in half (load fails), ``bitflip``
    flips one bit mid-file (loads fine — only the per-array checksums
    catch it), ``manifest`` mangles ``tree.json`` (parse fails).  Returns
    the snapshot directory."""
    snap = os.path.join(directory, f"step_{step:08d}")
    arrays = os.path.join(snap, "arrays.npz")
    if mode == "truncate":
        size = os.path.getsize(arrays)
        with open(arrays, "r+b") as fh:
            fh.truncate(max(1, size // 2))
    elif mode == "bitflip":
        size = os.path.getsize(arrays)
        with open(arrays, "r+b") as fh:
            fh.seek(size // 2)
            byte = fh.read(1)
            fh.seek(size // 2)
            fh.write(bytes([byte[0] ^ 0x40]))
    elif mode == "manifest":
        with open(os.path.join(snap, "tree.json"), "w") as fh:
            fh.write("{ torn")
    else:
        raise ValueError(f"unknown tear mode {mode!r}; "
                         "choose truncate | bitflip | manifest")
    return snap
