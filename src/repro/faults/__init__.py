"""Chaos plane: seeded fault injection + the recovery machinery's tests.

``schedule`` — the deterministic fault-scenario artifact
(``fault-schedule-v1``); ``quarantine`` — the poison-update validation
gate; ``inject`` — the schedule players for the event simulators and the
pod executor; ``crash_harness`` — the kill-at-every-round-boundary
SIGKILL sweep proving crash-consistent, bit-exact resume.
"""
from .inject import (FaultInjector, InjectedCrash, PodFaultInjector,
                     tear_snapshot)
from .quarantine import UpdateGate, make_payload
from .schedule import (BASELINE_CLASSES, CLASSES, CORRUPT_KINDS,
                       FAULT_FORMAT, POD_CLASSES, SIM_CLASSES, TEAR_MODES,
                       FaultEvent, FaultSchedule, make_fault_schedule)

__all__ = [
    "FAULT_FORMAT", "CLASSES", "CORRUPT_KINDS", "TEAR_MODES",
    "SIM_CLASSES", "BASELINE_CLASSES", "POD_CLASSES",
    "FaultEvent", "FaultSchedule", "make_fault_schedule",
    "UpdateGate", "make_payload",
    "FaultInjector", "PodFaultInjector", "InjectedCrash", "tear_snapshot",
]
