"""Poison-update quarantine: the validation gate in front of aggregation.

The gate sits between arrival and ``TaskScheduler.put`` / Alg. 4
aggregation.  Every device payload is checked for finiteness and an
absolute norm fence; a failing update is QUARANTINED — dropped before it
touches scheduler counters, the ω ring, or the global model — and the
device takes a strike.  Strikes drive exponential re-admission backoff
(``quarantined_until``), so a persistently-poisoning device is throttled
out of the send path without ever being hard-removed (it heals: each
accepted update forgives one strike).

The gate itself is pure policy — callers own the conservation side
(withdrawing flow tokens via ``FlowController.on_quarantined`` and NOT
calling ``sched.put``), which is what keeps Eq. 3 and the Alg. 3
counters exact under injection (the sanitizer checks this).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

#: fence on ||update||_inf — generous vs. real gradients (~O(1)) yet far
#: below the 1e12-scaled "huge" poison payload
DEFAULT_NORM_FENCE = 1e6


@dataclass
class UpdateGate:
    norm_fence: float = DEFAULT_NORM_FENCE
    strike_limit: int = 3          # strikes at/after which backoff applies
    backoff: float = 30.0          # base re-admission delay (s / rounds)
    backoff_growth: float = 2.0    # delay multiplier per extra strike
    strikes: dict = field(default_factory=dict)
    quarantined_until: dict = field(default_factory=dict)
    n_checked: int = 0
    n_rejected: int = 0
    reject_reasons: dict = field(default_factory=dict)

    # -- payload validation ------------------------------------------------
    def validate(self, payload) -> tuple:
        """(ok, reason) for one update payload (any array-like)."""
        self.n_checked += 1
        arr = np.asarray(payload, dtype=np.float64)
        if not np.all(np.isfinite(arr)):
            return self._reject("non_finite")
        if arr.size and float(np.max(np.abs(arr))) > self.norm_fence:
            return self._reject("norm_fence")
        return True, ""

    def _reject(self, reason: str) -> tuple:
        self.n_rejected += 1
        self.reject_reasons[reason] = self.reject_reasons.get(reason, 0) + 1
        return False, reason

    # -- per-device strike / backoff policy ---------------------------------
    def note_reject(self, k: int, t: float) -> float:
        """Record a strike for device ``k`` at time ``t``.

        Returns the re-admission delay: 0 while under ``strike_limit``,
        then ``backoff * growth**(strikes - strike_limit)``.
        """
        k = int(k)
        self.strikes[k] = self.strikes.get(k, 0) + 1
        over = self.strikes[k] - self.strike_limit
        if over < 0:
            return 0.0
        delay = self.backoff * self.backoff_growth ** over
        self.quarantined_until[k] = max(
            self.quarantined_until.get(k, 0.0), t + delay)
        return delay

    def note_accept(self, k: int) -> None:
        """A clean accepted update forgives one strike."""
        k = int(k)
        if self.strikes.get(k, 0) > 0:
            self.strikes[k] -= 1

    def may_send(self, k: int, t: float) -> bool:
        return t >= self.quarantined_until.get(int(k), 0.0)

    def summary(self) -> dict:
        return {"n_checked": int(self.n_checked),
                "n_rejected": int(self.n_rejected),
                "reject_reasons": dict(self.reject_reasons),
                "devices_struck": sum(1 for v in self.strikes.values() if v),
                "max_strikes": max(self.strikes.values(), default=0)}


def make_payload(kind: str, seed: int = 0, size: int = 8) -> np.ndarray:
    """Materialize a tiny update payload, optionally poisoned.

    ``kind``: "" (clean) | nan | inf | huge | bitflip.  The simulators
    carry these stand-in arrays through the gate instead of real tensors —
    validation cost stays negligible while exercising every reject path.
    """
    arr = np.random.default_rng(seed).standard_normal(size) * 0.1
    if kind == "nan":
        arr[0] = np.nan
    elif kind == "inf":
        arr[0] = np.inf
    elif kind == "huge":
        arr *= 1e12
    elif kind == "bitflip":
        bits = arr.view(np.uint64)
        bits[0] ^= np.uint64(1) << np.uint64(62)
    elif kind:
        raise ValueError(f"unknown corruption kind {kind!r}")
    return arr
