"""Poison-update quarantine: the validation gate in front of aggregation.

The gate sits between arrival and ``TaskScheduler.put`` / Alg. 4
aggregation.  Every device payload is checked for finiteness and an
absolute norm fence; a failing update is QUARANTINED — dropped before it
touches scheduler counters, the ω ring, or the global model — and the
device takes a strike.  Strikes drive exponential re-admission backoff
(``quarantined_until``), so a persistently-poisoning device is throttled
out of the send path without ever being hard-removed (it heals: each
accepted update forgives one strike).

The gate itself is pure policy — callers own the conservation side
(withdrawing flow tokens via ``FlowController.on_quarantined`` and NOT
calling ``sched.put``), which is what keeps Eq. 3 and the Alg. 3
counters exact under injection (the sanitizer checks this).
"""
from __future__ import annotations

import numpy as np

from repro.obs.metrics import MetricsRegistry

#: fence on ||update||_inf — generous vs. real gradients (~O(1)) yet far
#: below the 1e12-scaled "huge" poison payload
DEFAULT_NORM_FENCE = 1e6


class UpdateGate:
    """Validation gate with registry-backed check/reject accounting (the
    legacy ``n_checked``/``n_rejected``/``reject_reasons`` attributes are
    read-only views over the instruments; strike state stays plain)."""

    def __init__(self, norm_fence: float = DEFAULT_NORM_FENCE,
                 strike_limit: int = 3, backoff: float = 30.0,
                 backoff_growth: float = 2.0, metrics=None):
        self.norm_fence = norm_fence
        self.strike_limit = strike_limit    # strikes at/after which backoff
        self.backoff = backoff              # base re-admission delay
        self.backoff_growth = backoff_growth
        self.strikes: dict = {}
        self.quarantined_until: dict = {}
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._c_checked = self.metrics.counter("gate.checked")
        self._c_rejected = self.metrics.counter("gate.rejected")
        self._g_struck = self.metrics.gauge("gate.devices_struck")

    # legacy counter names, read-only over the registry instruments
    @property
    def n_checked(self) -> int:
        return int(self._c_checked.value)

    @property
    def n_rejected(self) -> int:
        return int(self._c_rejected.value)

    @property
    def reject_reasons(self) -> dict:
        prefix = "gate.rejected."
        return {name[len(prefix):]: int(c.value)
                for name, c in self.metrics._counters.items()
                if name.startswith(prefix) and c.value}

    # -- payload validation ------------------------------------------------
    def validate(self, payload) -> tuple:
        """(ok, reason) for one update payload (any array-like)."""
        self._c_checked.inc()
        arr = np.asarray(payload, dtype=np.float64)
        if not np.all(np.isfinite(arr)):
            return self._reject("non_finite")
        if arr.size and float(np.max(np.abs(arr))) > self.norm_fence:
            return self._reject("norm_fence")
        return True, ""

    def _reject(self, reason: str) -> tuple:
        self._c_rejected.inc()
        self.metrics.counter(f"gate.rejected.{reason}").inc()
        return False, reason

    # -- per-device strike / backoff policy ---------------------------------
    def note_reject(self, k: int, t: float) -> float:
        """Record a strike for device ``k`` at time ``t``.

        Returns the re-admission delay: 0 while under ``strike_limit``,
        then ``backoff * growth**(strikes - strike_limit)``.
        """
        k = int(k)
        self.strikes[k] = self.strikes.get(k, 0) + 1
        self._g_struck.set(sum(1 for v in self.strikes.values() if v))
        over = self.strikes[k] - self.strike_limit
        if over < 0:
            return 0.0
        delay = self.backoff * self.backoff_growth ** over
        self.quarantined_until[k] = max(
            self.quarantined_until.get(k, 0.0), t + delay)
        return delay

    def note_accept(self, k: int) -> None:
        """A clean accepted update forgives one strike."""
        k = int(k)
        if self.strikes.get(k, 0) > 0:
            self.strikes[k] -= 1
            self._g_struck.set(sum(1 for v in self.strikes.values() if v))

    def may_send(self, k: int, t: float) -> bool:
        return t >= self.quarantined_until.get(int(k), 0.0)

    def summary(self) -> dict:
        return {"n_checked": int(self.n_checked),
                "n_rejected": int(self.n_rejected),
                "reject_reasons": dict(self.reject_reasons),
                "devices_struck": sum(1 for v in self.strikes.values() if v),
                "max_strikes": max(self.strikes.values(), default=0)}


def make_payload(kind: str, seed: int = 0, size: int = 8) -> np.ndarray:
    """Materialize a tiny update payload, optionally poisoned.

    ``kind``: "" (clean) | nan | inf | huge | bitflip.  The simulators
    carry these stand-in arrays through the gate instead of real tensors —
    validation cost stays negligible while exercising every reject path.
    """
    arr = np.random.default_rng(seed).standard_normal(size) * 0.1
    if kind == "nan":
        arr[0] = np.nan
    elif kind == "inf":
        arr[0] = np.inf
    elif kind == "huge":
        arr *= 1e12
    elif kind == "bitflip":
        bits = arr.view(np.uint64)
        bits[0] ^= np.uint64(1) << np.uint64(62)
    elif kind:
        raise ValueError(f"unknown corruption kind {kind!r}")
    return arr
