"""Kill-at-every-round-boundary crash sweep for the pod driver.

The acceptance harness for crash-consistent recovery: for each round
boundary, a child training process is SIGKILLed at the checkpoint seam —
either just *after* a snapshot commits (``after``: the classic crash
between rounds) or *mid-write* (``mid``: the process dies with a partial
temp dir on disk and no commit, exercising the atomic temp+rename path) —
then restarted.  The restarted run must

* resume from the newest **verified** snapshot (a mid-write kill leaves
  only uncommitted garbage, so it falls back one boundary),
* finish sanitizer-clean (the child runs under ``--sanitize``; any
  protocol invariant violation is a non-zero exit), and
* reach a **bit-exact** final state: the final snapshot's per-array CRC32
  manifest and the host-loop continuation state (batch RNG) must equal an
  uninterrupted same-seed reference run's.  Checksums cover every leaf of
  the train state, so manifest equality *is* array equality.

Run directly (``python -m repro.faults.crash_harness --rounds 6``) or
from pytest via :func:`sweep`.  ``--child`` is the internal re-exec mode:
it monkeypatches ``checkpoint.store.save`` to SIGKILL itself at the
target step, then drives ``launch.train.main``.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile

from repro.checkpoint import store

_SIGKILLED = -signal.SIGKILL


def _child_main(a) -> None:
    """Re-exec target: run pod training, dying at the kill step."""
    from repro.launch import train

    real_save = store.save

    def killing_save(directory, step, tree, metadata=None, retain=3,
                     extras=None):
        if a.kill_mode == "mid" and step == a.kill_step:
            # die mid-write: a temp dir exists, nothing was committed —
            # exactly what a power cut during np.savez leaves behind
            os.makedirs(directory, exist_ok=True)
            tmp = tempfile.mkdtemp(dir=directory,
                                   prefix=f".tmp_step_{step:08d}_")
            with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
                f.write(b"partial write, never committed")
                f.flush()
                os.fsync(f.fileno())
            os.kill(os.getpid(), signal.SIGKILL)
        path = real_save(directory, step, tree, metadata=metadata,
                         retain=retain, extras=extras)
        if a.kill_mode == "after" and step == a.kill_step:
            os.kill(os.getpid(), signal.SIGKILL)
        return path

    store.save = killing_save
    sys.argv = ["train", "--mode", "pod", "--rounds", str(a.rounds),
                "--ckpt-dir", a.ckpt_dir, "--ckpt-every", str(a.ckpt_every),
                "--batch", "4", "--seq-len", "32", "--seed", str(a.seed),
                "--window", str(a.window),
                "--log-every", "1000000", "--sanitize"]
    if a.ckpt_flush:
        sys.argv.append("--ckpt-flush")
    train.main()


def _run_child(ckpt_dir: str, rounds: int, ckpt_every: int, seed: int,
               kill_step: int = -1, kill_mode: str = "after",
               timeout: float = 600.0, window: int = 2,
               ckpt_flush: bool = False) -> subprocess.CompletedProcess:
    cmd = [sys.executable, "-m", "repro.faults.crash_harness", "--child",
           "--ckpt-dir", ckpt_dir, "--rounds", str(rounds),
           "--ckpt-every", str(ckpt_every), "--seed", str(seed),
           "--kill-step", str(kill_step), "--kill-mode", kill_mode,
           "--window", str(window)]
    if ckpt_flush:
        cmd.append("--ckpt-flush")
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env["PYTHONPATH"] = root + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(cmd, env=env, timeout=timeout,
                          capture_output=True, text=True)
    if proc.returncode not in (0, _SIGKILLED):
        raise RuntimeError(
            f"crash-sweep child failed unexpectedly (exit "
            f"{proc.returncode}, kill_step={kill_step}, "
            f"kill_mode={kill_mode}):\n{proc.stdout}\n{proc.stderr}")
    return proc


def _final_fingerprint(ckpt_dir: str, rounds: int) -> dict:
    """Bit-exactness witness: the final snapshot's CRC32 manifest plus the
    host-loop RNG continuation state."""
    step, skipped = store.latest_verified_step(ckpt_dir)
    if step != rounds:
        raise RuntimeError(f"expected a verified final snapshot at step "
                           f"{rounds} in {ckpt_dir}, found {step} "
                           f"(skipped: {skipped})")
    meta = store._load_manifest(ckpt_dir, step)
    return {"checksums": meta["checksums"],
            "extra_checksums": meta.get("extra_checksums"),
            "rng_state": json.loads(json.dumps(
                meta["metadata"].get("rng_state")))}


def _assert_no_flush(proc: subprocess.CompletedProcess, case: str) -> None:
    """No-flush contract witness: the driver reports its save counters
    (``checkpoints: flush_saves=N noflush_saves=M``) — a run configured
    for checkpoint-without-flush must never have drained the pipeline
    for a save."""
    if "flush_saves=0 " not in proc.stdout:
        raise RuntimeError(
            f"{case}: expected checkpoint-without-flush (flush_saves=0) "
            f"but the driver reported otherwise:\n{proc.stdout}")


def sweep(boundaries=None, *, rounds: int = 4, ckpt_every: int = 1,
          seed: int = 0, kill_modes=("after", "mid"),
          workdir: str | None = None, verbose: bool = False,
          window: int = 2, ckpt_flush: bool = False) -> dict:
    """Kill a pod run at each checkpoint boundary, resume it, and verify
    bit-exact, sanitizer-clean continuation against an uninterrupted
    reference.  Returns the per-case results dict (raises on any
    divergence).

    ``window`` sets the child's pipeline depth; with the default
    ``ckpt_flush=False`` the children save via checkpoint-without-flush
    (the sweep asserts no full-drain save point ever happened), so a
    window=4 sweep is the acceptance run for deferred handle saves."""
    if boundaries is None:
        boundaries = list(range(ckpt_every, rounds + 1, ckpt_every))
    tmp_ctx = tempfile.TemporaryDirectory() if workdir is None else None
    base = workdir if workdir is not None else tmp_ctx.name
    try:
        ref_dir = os.path.join(base, "reference")
        ref_proc = _run_child(ref_dir, rounds, ckpt_every, seed,
                              window=window, ckpt_flush=ckpt_flush)
        if ref_proc.returncode != 0:
            raise RuntimeError(
                f"reference run exited {ref_proc.returncode}")
        if not ckpt_flush:
            _assert_no_flush(ref_proc, "reference")
        ref = _final_fingerprint(ref_dir, rounds)
        results = {}
        for mode in kill_modes:
            for s in boundaries:
                case = f"{mode}@{s}"
                d = os.path.join(base, f"kill_{mode}_{s}")
                killed = _run_child(d, rounds, ckpt_every, seed,
                                    kill_step=s, kill_mode=mode,
                                    window=window, ckpt_flush=ckpt_flush)
                if killed.returncode != _SIGKILLED:
                    raise RuntimeError(
                        f"{case}: child was not SIGKILLed (exit "
                        f"{killed.returncode}) — the kill step never fired")
                resumed = _run_child(d, rounds, ckpt_every, seed,
                                     window=window, ckpt_flush=ckpt_flush)
                if resumed.returncode != 0:
                    raise RuntimeError(f"{case}: resumed run exited "
                                       f"{resumed.returncode} (sanitizer "
                                       "violation or crash)")
                if not ckpt_flush:
                    _assert_no_flush(resumed, case)
                got = _final_fingerprint(d, rounds)
                if got != ref:
                    raise RuntimeError(
                        f"{case}: resumed run is NOT bit-exact with the "
                        f"reference —\n  ref: {ref}\n  got: {got}")
                results[case] = "bit-exact"
                if verbose:
                    print(f"crash sweep {case}: resumed bit-exact, "
                          "sanitizer-clean")
        return {"rounds": rounds, "boundaries": list(boundaries),
                "kill_modes": list(kill_modes), "window": window,
                "ckpt_flush": ckpt_flush, "cases": results}
    finally:
        if tmp_ctx is not None:
            tmp_ctx.cleanup()


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--child", action="store_true",
                   help="internal: run one (possibly self-killing) child")
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--rounds", type=int, default=4)
    p.add_argument("--ckpt-every", type=int, default=1)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--kill-step", type=int, default=-1,
                   help="checkpoint step to SIGKILL at (-1: never)")
    p.add_argument("--kill-mode", default="after", choices=("after", "mid"))
    p.add_argument("--window", type=int, default=2,
                   help="pipeline window for the child runs (4+ exercises "
                        "deferred checkpoint-without-flush saves)")
    p.add_argument("--ckpt-flush", action="store_true", dest="ckpt_flush",
                   help="children drain the pipeline at every save (the "
                        "legacy flush saver) instead of the default "
                        "checkpoint-without-flush")
    p.add_argument("--boundaries", default=None,
                   help="comma-separated kill boundaries (default: every "
                        "checkpoint step)")
    a = p.parse_args()
    if a.child:
        if not a.ckpt_dir:
            raise SystemExit("--child requires --ckpt-dir")
        _child_main(a)
        return
    boundaries = [int(x) for x in a.boundaries.split(",")] \
        if a.boundaries else None
    out = sweep(boundaries, rounds=a.rounds, ckpt_every=a.ckpt_every,
                seed=a.seed, verbose=True, window=a.window,
                ckpt_flush=a.ckpt_flush)
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
