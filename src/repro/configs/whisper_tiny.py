"""whisper-tiny — enc-dec audio; conv frontend is a STUB.

[arXiv:2212.04356; unverified]
4L d_model=384 6H d_ff=1536 vocab=51865; decoder mirrors the encoder.
input_specs() supplies precomputed mel-frame embeddings (frontend_len).
"""
from repro.models.api import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio", n_layers=4, d_model=384,
    n_heads=6, n_kv_heads=6, d_ff=1536, vocab=51865,
    n_decoder_layers=4, frontend_len=1500, activation="gelu",
    tie_embeddings=True)
