"""mamba2-780m — attention-free SSM (state-space duality).

[arXiv:2405.21060; unverified]
48L d_model=1536 ssm_state=128 vocab=50280
"""
from repro.models.api import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m", family="ssm", n_layers=48, d_model=1536,
    n_heads=24, n_kv_heads=24, d_ff=0, vocab=50280,
    pattern=(("mamba", "none"),), ssm_state=128, ssm_head_dim=64,
    tie_embeddings=True)
