"""qwen3-32b — dense GQA decoder with qk_norm.

[hf:Qwen/Qwen3-8B; hf]
64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936
"""
from repro.models.api import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b", family="lm", n_layers=64, d_model=5120,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=25600, vocab=151936,
    qk_norm=True, activation="swiglu", tie_embeddings=False)
