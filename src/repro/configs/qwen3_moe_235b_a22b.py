"""qwen3-moe-235b-a22b — 128-expert top-8 MoE with qk_norm.

[hf:Qwen/Qwen3-30B-A3B; hf]
94L d_model=4096 64H (GQA kv=4) d_ff=1536(per-expert) vocab=151936
"""
from repro.models.api import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe", n_layers=94, d_model=4096,
    n_heads=64, n_kv_heads=4, head_dim=128, d_ff=1536, vocab=151936,
    pattern=(("attn", "moe"),), n_experts=128, top_k=8, qk_norm=True,
    activation="swiglu", tie_embeddings=False)
