"""llama4-maverick-400b-a17b — MoE top-1, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, 128e top-1,
MoE interleaved every other layer.
"""
from repro.models.api import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, head_dim=128, d_ff=8192, vocab=202048,
    pattern=(("attn", "moe"), ("attn", "dense")), n_experts=128, top_k=1,
    activation="swiglu", tie_embeddings=False)
