"""llama-3.2-vision-90b — VLM backbone, cross-attn image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256
Frontend (vision tower) is a STUB: input_specs() supplies precomputed
patch embeddings; the cross-attention layers consume them.
"""
from repro.models.api import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm", n_layers=100, d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=28672, vocab=128256,
    pattern=(("attn", "dense"), ("attn", "dense"), ("attn", "dense"),
             ("attn", "dense"), ("cross", "dense")),
    frontend_len=1024, activation="swiglu", tie_embeddings=False)
