"""Architecture registry: --arch <id> resolves here.

Each assigned architecture is an ArchConfig (full size, exercised only via
the dry-run) plus a smoke_config() reduction (same family/pattern, tiny
dims, runnable on CPU).  Shapes are the assignment's four cells.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.models.api import ArchConfig


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


ARCHS: dict[str, ArchConfig] = {}


def _register(cfg: ArchConfig) -> ArchConfig:
    ARCHS[cfg.name] = cfg
    return cfg


# One module per assigned architecture (``--arch <id>`` resolves to the
# CONFIG defined there); the registry just aggregates them.
from . import (command_r_plus_104b, gemma2_27b, jamba_1_5_large_398b,  # noqa: E402
               llama4_maverick_400b_a17b, llama_3_2_vision_90b,
               mamba2_780m, qwen3_32b, qwen3_moe_235b_a22b, smollm_135m,
               whisper_tiny)

for _mod in (command_r_plus_104b, qwen3_32b, smollm_135m, gemma2_27b,
             llama_3_2_vision_90b, mamba2_780m, whisper_tiny,
             jamba_1_5_large_398b, qwen3_moe_235b_a22b,
             llama4_maverick_400b_a17b):
    _register(_mod.CONFIG)


# ---------------------------------------------------------------------------
# Smoke reductions: same family/pattern, tiny dims
# ---------------------------------------------------------------------------

def smoke_config(name: str) -> ArchConfig:
    cfg = ARCHS[name]
    period = cfg.period
    kw = dict(
        n_layers=2 * period, d_model=64,
        n_heads=4, n_kv_heads=max(1, 4 * cfg.n_kv_heads // cfg.n_heads),
        head_dim=16, d_ff=0 if cfg.d_ff == 0 else 96, vocab=211,
        frontend_len=8 if cfg.frontend_len else 0,
        window=8 if cfg.window else None,
        aux_dim=32, ce_chunk=64,
    )
    if cfg.n_experts:
        kw.update(n_experts=8, top_k=min(cfg.top_k, 2))
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
    if cfg.n_decoder_layers:
        kw.update(n_decoder_layers=2)
    return cfg.scaled(**kw)


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(ARCHS)}")
    return ARCHS[name]


def skip_reason(arch_name: str, shape_name: str) -> str | None:
    """Why an (arch × shape) cell is skipped (None = runnable)."""
    cfg = ARCHS[arch_name]
    if shape_name == "long_500k" and not cfg.long_context_ok:
        return ("full-attention arch: long_500k requires a sub-quadratic "
                "mixer (see DESIGN.md §Arch-applicability)")
    return None


def cells():
    """All assigned (arch × shape) cells, with skip annotations."""
    return [(name, sname, skip_reason(name, sname))
            for name in ARCHS for sname in SHAPES]
