"""gemma2-27b — local+global alternating attention, logit softcap.

[arXiv:2408.00118; hf]
46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000
"""
from repro.models.api import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b", family="lm", n_layers=46, d_model=4608,
    n_heads=32, n_kv_heads=16, head_dim=128, d_ff=36864, vocab=256000,
    attn_softcap=50.0, final_softcap=30.0, window=4096,
    pattern=(("local", "dense"), ("attn", "dense")),
    activation="geglu", tie_embeddings=True)
