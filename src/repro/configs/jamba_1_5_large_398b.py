"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7, MoE 16e top-2.

[arXiv:2403.19887; hf]
72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536
Period: 8 layers, attention at position 0, MoE on odd positions.
"""
from repro.models.api import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid", n_layers=72, d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=24576, vocab=65536,
    pattern=(("attn", "dense"), ("mamba", "moe"), ("mamba", "dense"),
             ("mamba", "moe"), ("mamba", "dense"), ("mamba", "moe"),
             ("mamba", "dense"), ("mamba", "moe")),
    n_experts=16, top_k=2, ssm_state=128, ssm_head_dim=64,
    activation="swiglu", tie_embeddings=False)
