"""Server memory manager: tiered activation store + eviction policies.

The paper's third pillar ("an efficient memory management mechanism on
the server increases the scalability of the number of participating
devices") as a subsystem: the on-mesh ω-ring is tier 0 (a cache), a
host-side spill pool (optionally int8-quantized) is tier 1, and a
swappable eviction/admission policy decides what lives where.  ω stops
being a hard correctness ceiling and becomes a performance knob: the
control plane plans spill/fill moves instead of refusing sends, and the
flow controller admits against the TOTAL tiered budget ω + pool_cap.
"""
from .policy import (ConsumptionShareEviction, LRUEviction, POLICIES,
                     make_eviction_policy)
from .store import ActivationStore

__all__ = ["ActivationStore", "ConsumptionShareEviction", "LRUEviction",
           "POLICIES", "make_eviction_policy"]
