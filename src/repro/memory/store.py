"""Tiered server activation store: the host spill tier behind the ω-ring.

The on-mesh activation ring (``fedopt_step`` ``state["act_buf"]``, ω
slots) becomes tier 0 — a cache.  :class:`ActivationStore` is tier 1: a
host-side pool of up to ``pool_cap`` spilled ring slots, optionally
int8-quantized (per-tensor, reusing the ``_quant``/``_dequant``
machinery from ``core/fedopt_step.py`` — integer leaves such as labels
and tokens are stored verbatim; only float activations quantize).

Division of labor: the :class:`~repro.core.control_plane.ControlPlane`
plans WHICH logical slots move between tiers (``RoundPlan.spill`` /
``RoundPlan.fill`` + per-entry contributor bookkeeping); this store owns
the actual host arrays, the byte accounting per tier, and the
checkpoint riding (``meta_dict``/``arrays`` mirror the RetentionStore
protocol: JSON metadata in ``tree.json``, payloads in ``extras.npz``).
The :class:`~repro.core.executor.RoundExecutor` bridges the two, moving
payloads host↔mesh at round boundaries inside the in-flight window.
"""
from __future__ import annotations

import numpy as np

from repro.analysis import sanitize as _san
from repro.obs import trace as _tr
from repro.obs.clock import now as _now
from repro.obs.metrics import MetricsRegistry


def _quant_leaf(x: np.ndarray) -> dict:
    """Per-tensor int8 spill encoding (fedopt_step's aggregation quant)."""
    from repro.core.fedopt_step import _quant
    q, scale = _quant(x)
    return {"q": np.asarray(q), "scale": np.asarray(scale, np.float32)}


def _is_quant_leaf(x) -> bool:
    return isinstance(x, dict) and set(x) == {"q", "scale"}


def _dequant_leaf(e: dict, dtype=np.float32) -> np.ndarray:
    from repro.core.fedopt_step import _dequant
    return np.asarray(_dequant((e["q"], e["scale"]))).astype(dtype)


def _encode(payload: dict, quant: bool) -> dict:
    out = {}
    for k, v in payload.items():
        v = np.asarray(v)
        if quant and np.issubdtype(v.dtype, np.floating):
            out[k] = _quant_leaf(v)
        else:
            out[k] = np.array(v, copy=True)
    return out


def _decode(stored: dict, dtypes: dict | None = None) -> dict:
    out = {}
    for k, v in stored.items():
        if _is_quant_leaf(v):
            out[k] = _dequant_leaf(
                v, (dtypes or {}).get(k, np.float32))
        else:
            out[k] = v
    return out


def _nbytes(tree: dict) -> int:
    total = 0
    for v in tree.values():
        if _is_quant_leaf(v):
            total += int(v["q"].nbytes) + int(v["scale"].nbytes)
        else:
            total += int(np.asarray(v).nbytes)
    return total


class ActivationStore:
    """Host pool of spilled ring slots, with per-tier byte accounting.

    Entries are keyed by the control plane's monotone pool keys; the
    stored form is what rides checkpoints (int8 + scale for quantized
    float leaves — the snapshot stays small), and :meth:`fill`
    dequantizes on the way back to the mesh.
    """

    def __init__(self, pool_cap: int, *, quant: bool = False,
                 metrics=None):
        if pool_cap < 0:
            raise ValueError(f"pool_cap must be >= 0, got {pool_cap}")
        self.pool_cap = pool_cap
        self.quant = quant
        self._pool: dict[int, dict] = {}   # key -> {"payload", "quant",
                                           #         "dtypes", "staged"?}
        # registry-backed accounting (the legacy counter names below are
        # read-only properties over these instruments)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._c_spills = self.metrics.counter("store.spills")
        self._c_fills = self.metrics.counter("store.fills")
        self._g_pool_bytes = self.metrics.gauge("store.pool_bytes")
        self._g_entries = self.metrics.gauge("store.entries")
        self._c_prefetched = self.metrics.counter("store.prefetched")
        self._c_prefetch_hits = self.metrics.counter("store.prefetch_hits")
        self._g_staged_bytes = self.metrics.gauge("store.staged_bytes")

    # legacy counter names, read-only over the registry instruments
    @property
    def n_spills(self) -> int:
        return int(self._c_spills.value)

    @property
    def n_fills(self) -> int:
        return int(self._c_fills.value)

    @property
    def pool_bytes(self) -> int:
        return int(self._g_pool_bytes.value)

    @property
    def peak_pool_bytes(self) -> int:
        return int(self._g_pool_bytes.peak)

    @property
    def peak_entries(self) -> int:
        return int(self._g_entries.peak)

    @property
    def n_prefetched(self) -> int:
        return int(self._c_prefetched.value)

    @property
    def prefetch_hits(self) -> int:
        return int(self._c_prefetch_hits.value)

    @property
    def staged_bytes(self) -> int:
        return int(self._g_staged_bytes.value)

    @property
    def peak_staged_bytes(self) -> int:
        return int(self._g_staged_bytes.peak)

    def __len__(self) -> int:
        return len(self._pool)

    def __contains__(self, key) -> bool:
        return int(key) in self._pool

    @property
    def keys(self) -> list[int]:
        return sorted(self._pool)

    # ------------------------------------------------------------------
    # tier transfers
    # ------------------------------------------------------------------

    def spill(self, key: int, payload: dict) -> None:
        """Admit one gathered ring slot (a flat dict of host arrays)."""
        key = int(key)
        if key in self._pool:
            raise KeyError(f"pool key {key} already holds a spilled slot")
        if len(self._pool) >= self.pool_cap:
            raise RuntimeError(
                f"spill pool full ({len(self._pool)}/{self.pool_cap} "
                f"slots): the control plane planned a spill past pool_cap")
        stored = _encode(payload, self.quant)
        dtypes = {k: np.asarray(v).dtype for k, v in payload.items()}
        self._pool[key] = {"payload": stored, "quant": self.quant,
                           "dtypes": dtypes}
        self._c_spills.inc()
        self._g_pool_bytes.add(_nbytes(stored))
        self._g_entries.set(len(self._pool))
        if _san.TRACING:
            _san.emit("store.spill", store=self, key=key,
                      entries=len(self._pool))
        if _tr.TRACING:
            _tr.emit_instant("host/memory", "spill", _now(), key=key,
                             entries=len(self._pool))

    def fill(self, key: int) -> dict:
        """Pop one entry, dequantized, ready to scatter back on-mesh.
        A prefetch-staged entry returns its staged decode (bit-identical
        to decoding now: ``_decode`` is pure in the stored payload)."""
        e = self._pool.pop(int(key))
        self._c_fills.inc()
        self._g_pool_bytes.add(-_nbytes(e["payload"]))
        self._g_entries.set(len(self._pool))
        staged = e.get("staged")
        if staged is not None:
            self._c_prefetch_hits.inc()
            self._g_staged_bytes.add(-_nbytes(staged))
        if _san.TRACING:
            _san.emit("store.fill", store=self, key=int(key),
                      entries=len(self._pool))
        if _tr.TRACING:
            _tr.emit_instant("host/memory", "fill", _now(), key=int(key),
                             entries=len(self._pool))
        return staged if staged is not None \
            else _decode(e["payload"], e["dtypes"])

    def prefetch(self, key: int) -> None:
        """Pre-decode one pooled entry into a staged host payload (the
        plan's lookahead hint): the eventual :meth:`fill` returns the
        staged decode instead of dequantizing on the critical boundary.
        Advisory and idempotent — unknown keys and payload-less entries
        (post-restore, pre-load_arrays) are ignored; staging never
        changes what ``fill`` returns, only when the decode work runs."""
        e = self._pool.get(int(key))
        if e is None or e.get("payload") is None or \
                e.get("staged") is not None:
            return
        e["staged"] = _decode(e["payload"], e["dtypes"])
        self._c_prefetched.inc()
        self._g_staged_bytes.add(_nbytes(e["staged"]))

    # ------------------------------------------------------------------
    # checkpoint riding (RetentionStore protocol)
    # ------------------------------------------------------------------

    def meta_dict(self) -> dict:
        """JSON-able part: held keys + per-entry quantization flag."""
        return {"pool_cap": self.pool_cap, "quant_default": self.quant,
                "entries": {str(k): {"quant": bool(e["quant"])}
                            for k, e in self._pool.items()}}

    def load_meta(self, meta: dict) -> None:
        """Restore held-key metadata; payloads arrive via load_arrays."""
        entries = meta.get("entries", {})
        if len(entries) > self.pool_cap:
            raise ValueError(
                f"snapshot holds {len(entries)} spilled slots but this "
                f"store has pool_cap={self.pool_cap}; resume with "
                f"--pool-cap >= {len(entries)}")
        self._pool = {int(k): {"payload": None, "quant": bool(e["quant"]),
                               "dtypes": None}
                      for k, e in entries.items()}
        self._g_pool_bytes.set(0)
        self._g_entries.set(len(self._pool))

    def arrays(self) -> dict:
        """Stored (possibly quantized) payloads keyed by pool key — the
        checkpoint extras payload; empty dict when nothing is held."""
        return {str(k): e["payload"] for k, e in self._pool.items()}

    def load_arrays(self, tree: dict, dtypes: dict | None = None) -> None:
        """Restore payloads for held keys (``load_meta`` first).
        ``dtypes`` optionally maps leaf name -> dtype for dequantized
        fills (defaults to float32 for quantized leaves)."""
        for k, payload in tree.items():
            if int(k) not in self._pool:
                raise KeyError(
                    f"spill arrays for pool key {k} have no matching "
                    "metadata entry — load_meta first")
            e = self._pool[int(k)]
            e["payload"] = {name: dict(v) if _is_quant_leaf(v) else
                            np.asarray(v) for name, v in payload.items()}
            e["dtypes"] = dict(dtypes) if dtypes else None
            self._g_pool_bytes.add(_nbytes(e["payload"]))
        self._g_entries.set(len(self._pool))

    def like_tree(self, slot_like: dict) -> dict:
        """Restore templates for ``checkpoint.store.restore_extras``:
        per held key, the stored-form structure (int8 q + scale for
        quantized float leaves) shaped like one ring slot."""
        import jax

        def leaf_like(x, quant):
            sds = jax.ShapeDtypeStruct
            if quant and np.issubdtype(np.dtype(x.dtype), np.floating):
                return {"q": sds(x.shape, np.int8),
                        "scale": sds((), np.float32)}
            return sds(x.shape, x.dtype)

        return {str(k): {name: leaf_like(x, e["quant"])
                         for name, x in slot_like.items()}
                for k, e in self._pool.items()}

    def slot_dtypes(self, slot_like: dict) -> dict:
        """Leaf-name -> dtype map for :meth:`load_arrays` after restore."""
        return {name: np.dtype(x.dtype) for name, x in slot_like.items()}

    # ------------------------------------------------------------------

    def summary(self) -> dict:
        """JSON-able accounting for logs / benchmark records."""
        return {"pool_cap": self.pool_cap, "spill_quant": self.quant,
                "pool_entries": len(self._pool),
                "peak_pool_entries": self.peak_entries,
                "pool_bytes": int(self.pool_bytes),
                "peak_pool_bytes": int(self.peak_pool_bytes),
                "store_spills": self.n_spills, "store_fills": self.n_fills,
                "n_prefetched": self.n_prefetched,
                "prefetch_hits": self.prefetch_hits,
                "peak_staged_bytes": int(self.peak_staged_bytes)}
