"""Eviction/admission policies for the tiered activation store.

When the on-mesh ω-ring is full and a write wants a slot, the control
plane evicts (spills) one live slot to the host pool; when mesh slots
free up, pooled entries are filled back.  Which slot to evict and which
pool entry to fill first is a *policy* decision, decoupled here so the
trade-off is swappable and benchmarkable:

``lru``
    Classic recency: evict the ring slot least recently written/filled,
    fill pool entries oldest-first (FIFO).  Scheduler-oblivious — cheap,
    but can evict exactly the contribution the Alg. 3 counter policy
    wants to consume next.

``share`` (default)
    Scheduler-aware "least-consumption-share" protection: the counter
    policy (Alg. 3) always serves the *least-consumed* group next, so a
    slot holding a low-consumption-share contributor is scheduler-hot
    and must stay on-mesh.  The victim is the slot whose best-priority
    contributor has the HIGHEST consumption share (its content will be
    scheduled last); fills promote the pool entry whose contributors
    have the LOWEST share (the scheduler's next picks) first.

Both policies are pure functions of host bookkeeping (touch ticks,
consumption counters), so plans stay deterministic and checkpoint-
resumable.  Ties break on slot id / pool key for run-to-run stability.
"""
from __future__ import annotations


def _min_share(groups, share) -> float:
    """Best (lowest) consumption share among a slot's contributors —
    the Alg. 3 priority of its most-wanted contribution."""
    return min((share(g) for g in groups), default=float("inf"))


class LRUEviction:
    """Recency policy: evict least-recently-touched, fill oldest-first."""

    name = "lru"

    def victim(self, slots, *, groups_of, share, touch) -> int:
        return min(slots, key=lambda s: (touch[s], s))

    def fill_order(self, keys, *, groups_of, share) -> list:
        return sorted(keys)          # pool keys are monotone: FIFO

    def __repr__(self):
        return f"{type(self).__name__}()"


class ConsumptionShareEviction(LRUEviction):
    """Scheduler-aware policy driven by ``ControlPlane.consumption_share``.

    Evicts the slot whose contributors are already best-served (highest
    minimum share — the counter policy will schedule them last), keeping
    least-consumption-share contributions on-mesh; fills restore the
    most-underserved pool entry first.  Falls back to LRU recency as the
    tie-break so equal-share slots rotate instead of thrashing.
    """

    name = "share"

    def victim(self, slots, *, groups_of, share, touch) -> int:
        return max(slots,
                   key=lambda s: (_min_share(groups_of(s), share),
                                  -touch[s], -s))

    def fill_order(self, keys, *, groups_of, share) -> list:
        return sorted(keys, key=lambda k: (_min_share(groups_of(k), share), k))


POLICIES = {p.name: p for p in (LRUEviction, ConsumptionShareEviction)}


def make_eviction_policy(name: str):
    """Build an eviction policy by name ("lru" | "share")."""
    try:
        return POLICIES[name]()
    except KeyError:
        raise ValueError(
            f"unknown eviction policy {name!r}; choose from "
            f"{sorted(POLICIES)}") from None
