"""Optimizers as pure pytree transforms (no optax dependency).

Interface::

    state = <opt>_init(params)
    params, state = <opt>_update(params, grads, state, lr, ...)

``make_optimizer(name, **hyper)`` returns an (init, update) pair with
hyperparameters bound; update signature is (params, grads, state, lr).
Optimizer states inherit the sharding of their parameters (ZeRO-style when
parameters are sharded over the data axis).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

OptState = Any


# ---------------------------------------------------------------------------
# SGD (+ momentum) — the paper's device/server optimizer
# ---------------------------------------------------------------------------

def sgd_init(params, momentum: float = 0.0) -> OptState:
    if momentum == 0.0:
        return {"step": jnp.zeros((), jnp.int32)}
    return {"step": jnp.zeros((), jnp.int32),
            "velocity": jax.tree.map(jnp.zeros_like, params)}


def sgd_update(params, grads, state: OptState, lr, momentum: float = 0.0,
               weight_decay: float = 0.0):
    if weight_decay:
        grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
    if momentum == 0.0:
        new_params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        return new_params, {"step": state["step"] + 1}
    vel = jax.tree.map(lambda v, g: momentum * v + g, state["velocity"], grads)
    new_params = jax.tree.map(lambda p, v: p - lr * v, params, vel)
    return new_params, {"step": state["step"] + 1, "velocity": vel}


# ---------------------------------------------------------------------------
# AdamW — used for the LM-scale training steps
# ---------------------------------------------------------------------------

def adamw_init(params) -> OptState:
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def adamw_update(params, grads, state: OptState, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                      state["nu"], grads)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return (p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)
                ).astype(p.dtype)

    return jax.tree.map(upd, params, mu, nu), {"step": step, "mu": mu, "nu": nu}


def make_optimizer(name: str, **hyper) -> tuple[Callable, Callable]:
    if name == "sgd":
        momentum = hyper.pop("momentum", 0.0)
        return (lambda p: sgd_init(p, momentum),
                lambda p, g, s, lr: sgd_update(p, g, s, lr, momentum, **hyper))
    if name == "adamw":
        return (adamw_init,
                lambda p, g, s, lr: adamw_update(p, g, s, lr, **hyper))
    raise ValueError(f"unknown optimizer {name}")
