from .optimizers import (OptState, adamw_init, adamw_update, sgd_init,
                         sgd_update, make_optimizer)
from .schedule import constant_schedule, cosine_schedule, warmup_cosine
from .clip import clip_by_global_norm, global_norm
