"""Baseline FL protocols as event simulations (paper §5.2 baselines).

  classic FL [McMahan'17]  — full model on device, synchronous FedAvg
  FedAsync   [Xie'23]      — full model, asynchronous aggregation
  FedBuff    [Nguyen'22]   — full model, buffered async aggregation (Z)
  SplitFed   [Thapa'22]    — offloading, per-iteration grad return, sync agg
  PiPar      [Zhang'24]    — SplitFed + pipeline overlap on the device
  OAFL       (§2.2)        — SplitFed protocol + FedAsync aggregation

All share the Metrics structure of `simulation.py`, so figures compare
like-for-like.  Server compute is serialized (single accelerator); links
are full-duplex.  hooks objects (optional) drive real JAX training in
event order — see core/learning.py.

Every protocol accepts ``fleet=`` (a ``repro.fleet.FleetTrace``): device
join/leave and bandwidth follow the trace's tick grid through the single
trace-event API (``repro.fleet.traces.install_fleet``), so FedOptima and
all six baselines can be compared under one identical device population.
Legacy ``churn=`` ChurnModels are materialized onto the same grid
(``FleetTrace.from_churn`` — identical draws, bit-for-bit).

Every protocol also accepts ``faults=`` (a ``repro.faults.FaultSchedule``
or prebuilt injector): the subset of the chaos taxonomy a full-model
protocol can express — corrupted model uploads, delayed arrivals, device
timeouts mid-round (``repro.faults.BASELINE_CLASSES``) — is injected at
the same named seams as FedOptima's, so clean-vs-faulted degradation is
compared like-for-like.  ``fault_gate`` mirrors ``simulate_fedoptima``:
None = default UpdateGate, False = no armor (poison flows into
aggregation), an instance = used as-is.
"""
from __future__ import annotations

import numpy as np

from repro.analysis import sanitize as _san
from repro.faults.inject import FaultInjector, install_timeouts
from repro.faults.quarantine import UpdateGate
from repro.fleet.traces import install_fleet, resolve_fleet
from repro.obs import trace as _tr

from .simulation import Metrics, Sim, SimCluster, SimModel


def _resolve_injector(faults, fault_gate) -> FaultInjector | None:
    if faults is None:
        return None
    if isinstance(faults, FaultInjector):
        return faults
    gate = UpdateGate() if fault_gate is None else (fault_gate or None)
    return FaultInjector.for_baseline(faults, gate=gate)




# ---------------------------------------------------------------------------
# Full-model methods: classic FL / FedAsync / FedBuff
# ---------------------------------------------------------------------------

def simulate_classic_fl(model: SimModel, cluster: SimCluster, *,
                        duration: float, H: int = 10, hooks=None,
                        churn=None, fleet=None, seed: int = 0,
                        faults=None, fault_gate=None) -> Metrics:
    sim = Sim()
    K = cluster.K
    m = Metrics(K=K, duration=duration)
    inj = _resolve_injector(faults, fault_gate)
    t_iter = [3 * model.full_fwd_flops / cluster.dev_flops[k] for k in range(K)]
    trace = resolve_fleet(fleet, churn, cluster, duration)
    active = np.ones(K, bool)
    bw = cluster.dev_bw.astype(float).copy()
    if trace is not None:
        trace.apply(active, bw)
    pending = {"n": 0}

    def start_round():
        m.rounds += 1
        expected = [k for k in range(K) if active[k]]
        if not expected:
            sim.after(1.0, start_round)
            return
        pending["n"] = len(expected)
        for k in expected:
            tx = model.full_model_bytes / bw[k]
            m.bytes_down += model.full_model_bytes
            sim.after(tx, dev_train, k, H)

    def dev_train(k, h_left):
        if not active[k]:
            arrive(None)
            return
        start = sim.t

        def done():
            m.note_dev_busy(k, start, sim.t, name="train",
                            samples=model.batch_size)
            if hooks:
                hooks.device_iter(k, False)
            if h_left > 1:
                dev_train(k, h_left - 1)
            else:
                tx = model.full_model_bytes / bw[k]
                m.bytes_up += model.full_model_bytes
                extra, ckind = inj.tag_model_upload(k, sim.t) \
                    if inj is not None else (0.0, "")
                sim.after(tx + extra, arrive, k, ckind, extra > 0.0)
        sim.after(t_iter[k], done)

    def arrive(k, ckind="", delayed=False):
        ok = True
        if inj is not None and k is not None:
            if delayed:
                # sync FL has no staleness machinery: the barrier simply
                # waited — the delay is absorbed as round latency
                inj.note_delayed_arrival()
            if ckind:
                # quarantined contribution is dropped, but its barrier
                # slot must still release (a sync round can't wait on a
                # poisoned update forever)
                ok, _ = inj.model_validate(k, ckind, sim.t)
        if k is not None and ok:
            m.note_contribution(k)
        pending["n"] -= 1
        if pending["n"] <= 0:
            start = sim.t
            m.note_warmup_end(start)
            dt = model.agg_flops * max(1, K) / cluster.srv_flops

            def agg_done():
                m.note_srv_busy(start, sim.t, name="aggregate")
                m.aggregations += 1
                if hooks:
                    hooks.sync_aggregate()
                start_round()
            sim.after(dt, agg_done)

    install_fleet(sim, trace, active, bw)
    install_timeouts(sim, inj, active, trace)
    start_round()
    sim.run(duration)
    if inj is not None:
        inj.finalize(duration)
        m.faults = inj.report()
    return m


def _simulate_async_full(model: SimModel, cluster: SimCluster, *, duration,
                         H, buffer_size, hooks, churn, fleet, seed,
                         faults=None, fault_gate=None) -> Metrics:
    """Shared core of FedAsync (buffer_size=1) and FedBuff (buffer_size=Z)."""
    sim = Sim()
    K = cluster.K
    m = Metrics(K=K, duration=duration)
    inj = _resolve_injector(faults, fault_gate)
    t_iter = [3 * model.full_fwd_flops / cluster.dev_flops[k] for k in range(K)]
    trace = resolve_fleet(fleet, churn, cluster, duration)
    active = np.ones(K, bool)
    bw = cluster.dev_bw.astype(float).copy()
    if trace is not None:
        trace.apply(active, bw)
    srv = {"busy": False, "buffer": 0}
    queue: list[tuple] = []          # (device, chain epoch)
    # per-device chain discipline (same as simulate_fedoptima): a leave
    # bumps the epoch so the dead chain's pending callbacks can't revive
    # alongside the chain on_rejoin starts — without it one off->on flap
    # inside an iteration forks two concurrent chains forever
    running = np.zeros(K, bool)
    epoch = np.zeros(K, np.int64)

    def on_leave(k):
        running[k] = False
        epoch[k] += 1
        if _san.TRACING:
            _san.emit("sim.device_left", sim=sim, device=int(k),
                      epoch=int(epoch[k]))
        if _tr.TRACING:
            _tr.emit_instant(f"dev/{k}", "leave", sim.t)

    def on_rejoin(k):
        if _san.TRACING:
            _san.emit("sim.device_join", sim=sim, device=int(k),
                      epoch=int(epoch[k]))
        if _tr.TRACING:
            _tr.emit_instant(f"dev/{k}", "join", sim.t)
        dev_round(k)

    def dev_round(k):
        if not active[k] or running[k]:
            return
        running[k] = True
        if _san.TRACING:
            _san.emit("sim.chain_start", sim=sim, device=int(k),
                      epoch=int(epoch[k]))
        dev_train(k, H, epoch[k])

    def dev_train(k, h_left, e):
        if not active[k] or epoch[k] != e:
            return
        start = sim.t

        def done():
            if not active[k] or epoch[k] != e:
                return
            m.note_dev_busy(k, start, sim.t, name="train",
                            samples=model.batch_size)
            if hooks:
                hooks.device_iter(k, False)
            if h_left > 1:
                dev_train(k, h_left - 1, e)
            else:
                tx = model.full_model_bytes / bw[k]
                m.bytes_up += model.full_model_bytes
                extra, ckind = inj.tag_model_upload(k, sim.t) \
                    if inj is not None else (0.0, "")
                sim.after(tx + extra, arrive, k, e, ckind, extra > 0.0)
        sim.after(t_iter[k], done)

    def arrive(k, e, ckind="", delayed=False):
        if inj is not None and delayed:
            # async aggregation absorbs stale arrivals by design (FedAsync
            # α-decay / FedBuff buffer mixing)
            inj.note_delayed_arrival()
        if inj is not None and ckind:
            ok, backoff = inj.model_validate(k, ckind, sim.t)
            if not ok:
                # quarantined before the buffer: the device re-downloads
                # the current global after its strike backoff
                tx = model.full_model_bytes / bw[k] if active[k] else 0.0
                m.bytes_down += model.full_model_bytes if active[k] else 0.0
                sim.after(backoff + tx, model_back, k, e)
                return
        queue.append((k, e))
        srv["buffer"] += 1
        kick()

    def kick():
        if srv["busy"] or srv["buffer"] < buffer_size or not queue:
            return
        srv["busy"] = True
        start = sim.t
        m.note_warmup_end(start)
        batch = queue[:buffer_size]
        del queue[:buffer_size]
        srv["buffer"] -= len(batch)
        dt = model.agg_flops * len(batch) / cluster.srv_flops

        def agg_done():
            m.note_srv_busy(start, sim.t, name="aggregate")
            m.aggregations += 1
            for kk, _ in batch:
                m.note_contribution(kk)
            if hooks:
                for kk, _ in batch:
                    hooks.aggregate(kk)
            for kk, e in batch:
                tx = model.full_model_bytes / bw[kk] if active[kk] else 0.0
                m.bytes_down += model.full_model_bytes if active[kk] else 0.0
                sim.after(tx, model_back, kk, e)
            srv["busy"] = False
            kick()
        sim.after(dt, agg_done)

    def model_back(k, e):
        if epoch[k] != e:
            return      # pre-departure round: the live chain owns the device
        if _san.TRACING:
            _san.emit("sim.chain_end", sim=sim, device=int(k), epoch=int(e))
        running[k] = False
        dev_round(k)

    install_fleet(sim, trace, active, bw, on_leave=on_leave,
                  on_rejoin=on_rejoin)
    install_timeouts(sim, inj, active, trace, on_leave=on_leave,
                     on_rejoin=on_rejoin)
    for k in range(K):
        dev_round(k)
    sim.run(duration)
    if inj is not None:
        inj.finalize(duration)
        m.faults = inj.report()
    return m


def simulate_fedasync(model, cluster, *, duration, H=10, hooks=None,
                      churn=None, fleet=None, seed=0,
                      faults=None, fault_gate=None) -> Metrics:
    return _simulate_async_full(model, cluster, duration=duration, H=H,
                                buffer_size=1, hooks=hooks, churn=churn,
                                fleet=fleet, seed=seed, faults=faults,
                                fault_gate=fault_gate)


def simulate_fedbuff(model, cluster, *, duration, H=10, buffer_size=None,
                     hooks=None, churn=None, fleet=None, seed=0,
                     faults=None, fault_gate=None) -> Metrics:
    Z = buffer_size or max(2, cluster.K // 4)
    return _simulate_async_full(model, cluster, duration=duration, H=H,
                                buffer_size=Z, hooks=hooks, churn=churn,
                                fleet=fleet, seed=seed, faults=faults,
                                fault_gate=fault_gate)


# ---------------------------------------------------------------------------
# Offloading methods: SplitFed / PiPar / OAFL
# ---------------------------------------------------------------------------

def _simulate_split(model: SimModel, cluster: SimCluster, *, duration, H,
                    sync_agg: bool, pipeline: bool, hooks, churn, fleet,
                    seed, faults=None, fault_gate=None) -> Metrics:
    """Split-training protocol: per iteration the device sends activations,
    the server trains that device's server-side model and returns gradients.

    sync_agg=True  -> SplitFed/PiPar (round barrier across devices)
    pipeline=True  -> PiPar (device overlaps next fwd while waiting)
    sync_agg=False -> OAFL (async aggregation at round end, no barrier)
    """
    sim = Sim()
    K = cluster.K
    m = Metrics(K=K, duration=duration)
    inj = _resolve_injector(faults, fault_gate)
    trace = resolve_fleet(fleet, churn, cluster, duration)
    active = np.ones(K, bool)
    bw = cluster.dev_bw.astype(float).copy()
    if trace is not None:
        trace.apply(active, bw)
    srv = {"busy": False}
    srv_queue: list[tuple] = []
    barrier = {"n": 0}
    t_fwd = [model.dev_fwd_flops / cluster.dev_flops[k] for k in range(K)]
    t_bwd = [model.dev_bwd_flops / cluster.dev_flops[k] for k in range(K)]
    # chain discipline for the async (OAFL) restart path, mirroring
    # _simulate_async_full; under sync_agg there is no on_leave so epochs
    # stay 0 and the guards are inert (the barrier replays old behavior)
    running = np.zeros(K, bool)
    epoch = np.zeros(K, np.int64)

    def on_leave(k):
        running[k] = False
        epoch[k] += 1
        if _san.TRACING:
            _san.emit("sim.device_left", sim=sim, device=int(k),
                      epoch=int(epoch[k]))
        if _tr.TRACING:
            _tr.emit_instant(f"dev/{k}", "leave", sim.t)

    def on_rejoin(k):
        if _san.TRACING:
            _san.emit("sim.device_join", sim=sim, device=int(k),
                      epoch=int(epoch[k]))
        if _tr.TRACING:
            _tr.emit_instant(f"dev/{k}", "join", sim.t)
        dev_round(k)

    def dev_round(k):
        if not active[k] or running[k]:
            return
        running[k] = True
        # chain events only under async restarts: the sync barrier resets
        # ``running`` wholesale, which is a different (round, not chain)
        # discipline the single-live-chain invariant does not describe
        if not sync_agg and _san.TRACING:
            _san.emit("sim.chain_start", sim=sim, device=int(k),
                      epoch=int(epoch[k]))
        dev_fwd(k, H, epoch[k])

    def dev_fwd(k, h_left, e):
        if not active[k] or epoch[k] != e:
            return
        start = sim.t

        def fwd_done():
            if not active[k] or epoch[k] != e:
                return
            m.note_dev_busy(k, start, sim.t, name="fwd")
            tx = model.act_bytes / bw[k]
            m.bytes_up += model.act_bytes
            if _tr.TRACING:
                _tr.emit_span(f"net/{k}", "act_upload", sim.t, sim.t + tx,
                              clip=True)
            sim.after(tx, srv_request, k, h_left, e)
            # PiPar: overlap — start next microbatch fwd while waiting
            if pipeline and h_left > 1:
                start2 = sim.t

                def fwd2_done():
                    # overlapped fwd rides a pipeline sub-lane: the device
                    # is genuinely busy twice over, which one lane cannot
                    # render without overlap
                    m.note_dev_busy(k, start2, sim.t, name="fwd_overlap",
                                    lane=f"dev/{k}/pipe")
                sim.after(t_fwd[k], fwd2_done)
        sim.after(t_fwd[k], fwd_done)

    def srv_request(k, h_left, e):
        srv_queue.append((k, h_left, e))
        kick()

    def kick():
        if srv["busy"] or not srv_queue:
            return
        srv["busy"] = True
        k, h_left, e = srv_queue.pop(0)
        start = sim.t
        m.note_warmup_end(start)
        dt = model.srv_flops_per_batch / cluster.srv_flops

        def done():
            m.note_srv_busy(start, sim.t, name="train_batch")
            m.srv_batches += 1
            m.note_contribution(k)
            if hooks:
                hooks.server_train(k)
            tx = model.act_bytes / bw[k] if active[k] else 0.0  # gradients back
            m.bytes_down += model.act_bytes if active[k] else 0.0
            sim.after(tx, dev_bwd, k, h_left, e)
            srv["busy"] = False
            kick()
        sim.after(dt, done)

    def dev_bwd(k, h_left, e):
        if not active[k] or epoch[k] != e:
            if sync_agg:
                barrier_arrive()
            return
        start = sim.t

        def bwd_done():
            if not active[k] or epoch[k] != e:
                if sync_agg:
                    barrier_arrive()
                return
            # PiPar already accounted the overlapped fwd busy time
            m.note_dev_busy(k, start, sim.t, name="bwd",
                            samples=model.batch_size)
            if hooks:
                hooks.device_iter(k, True)
            if h_left > 1:
                if pipeline:
                    # fwd of next batch already ran; go straight to upload
                    tx = model.act_bytes / bw[k]
                    m.bytes_up += model.act_bytes
                    sim.after(tx, srv_request, k, h_left - 1, e)
                else:
                    dev_fwd(k, h_left - 1, e)
            else:
                tx = model.dev_model_bytes / bw[k]
                m.bytes_up += model.dev_model_bytes
                extra, ckind = inj.tag_model_upload(k, sim.t) \
                    if inj is not None else (0.0, "")
                sim.after(tx + extra, model_arrive, k, e, ckind,
                          extra > 0.0)
        sim.after(t_bwd[k], bwd_done)

    def model_arrive(k, e, ckind="", delayed=False):
        if inj is not None and delayed:
            inj.note_delayed_arrival()
        if inj is not None and ckind:
            ok, backoff = inj.model_validate(k, ckind, sim.t)
            if not ok:
                if sync_agg:
                    # quarantined: the contribution is dropped but the
                    # barrier slot still releases
                    barrier_arrive()
                else:
                    # OAFL: skip aggregation; the device re-syncs after
                    # its strike backoff
                    tx = model.dev_model_bytes / bw[k] if active[k] else 0.0
                    m.bytes_down += model.dev_model_bytes \
                        if active[k] else 0.0
                    sim.after(backoff + tx, model_back, k, e)
                return
        if sync_agg:
            barrier_arrive()
        else:
            # OAFL: async aggregation immediately (serialized on server)
            start = sim.t
            m.note_warmup_end(start)
            dt = model.agg_flops / cluster.srv_flops

            def agg_done():
                m.note_srv_busy(start, sim.t, name="aggregate")
                m.aggregations += 1
                if hooks:
                    hooks.aggregate(k)
                tx = model.dev_model_bytes / bw[k] if active[k] else 0.0
                m.bytes_down += model.dev_model_bytes if active[k] else 0.0
                sim.after(tx, model_back, k, e)
            sim.after(dt, agg_done)

    def model_back(k, e):
        if epoch[k] != e:
            return      # pre-departure round: the live chain owns the device
        if _san.TRACING:
            _san.emit("sim.chain_end", sim=sim, device=int(k), epoch=int(e))
        running[k] = False
        dev_round(k)

    def barrier_arrive():
        barrier["n"] -= 1
        if barrier["n"] <= 0:
            start = sim.t
            m.note_warmup_end(start)
            dt = model.agg_flops * K / cluster.srv_flops

            def agg_done():
                m.note_srv_busy(start, sim.t, name="aggregate")
                m.aggregations += 1
                m.rounds += 1
                if hooks:
                    hooks.sync_aggregate()
                start_round()
            sim.after(dt, agg_done)

    def start_round():
        # the barrier owns round starts: no chain is outstanding here, so
        # every roster member begins fresh (running is a per-chain flag)
        running[:] = False
        expected = [k for k in range(K) if active[k]]
        if not expected:
            sim.after(1.0, start_round)
            return
        barrier["n"] = len(expected)
        for k in expected:
            tx = model.dev_model_bytes / bw[k]
            m.bytes_down += model.dev_model_bytes
            sim.after(tx, dev_round, k)

    install_fleet(sim, trace, active, bw,
                  on_leave=None if sync_agg else on_leave,
                  on_rejoin=None if sync_agg else on_rejoin)
    install_timeouts(sim, inj, active, trace,
                     on_leave=None if sync_agg else on_leave,
                     on_rejoin=None if sync_agg else on_rejoin)
    if sync_agg:
        start_round()
    else:
        for k in range(K):
            dev_round(k)
    sim.run(duration)
    if inj is not None:
        inj.finalize(duration)
        m.faults = inj.report()
    return m


def simulate_splitfed(model, cluster, *, duration, H=10, hooks=None,
                      churn=None, fleet=None, seed=0,
                      faults=None, fault_gate=None) -> Metrics:
    return _simulate_split(model, cluster, duration=duration, H=H,
                           sync_agg=True, pipeline=False, hooks=hooks,
                           churn=churn, fleet=fleet, seed=seed,
                           faults=faults, fault_gate=fault_gate)


def simulate_pipar(model, cluster, *, duration, H=10, hooks=None,
                   churn=None, fleet=None, seed=0,
                   faults=None, fault_gate=None) -> Metrics:
    return _simulate_split(model, cluster, duration=duration, H=H,
                           sync_agg=True, pipeline=True, hooks=hooks,
                           churn=churn, fleet=fleet, seed=seed,
                           faults=faults, fault_gate=fault_gate)


def simulate_oafl(model, cluster, *, duration, H=10, hooks=None,
                  churn=None, fleet=None, seed=0,
                  faults=None, fault_gate=None) -> Metrics:
    return _simulate_split(model, cluster, duration=duration, H=H,
                           sync_agg=False, pipeline=False, hooks=hooks,
                           churn=churn, fleet=fleet, seed=seed,
                           faults=faults, fault_gate=fault_gate)


REGISTRY = {
    "fl": simulate_classic_fl,
    "fedasync": simulate_fedasync,
    "fedbuff": simulate_fedbuff,
    "splitfed": simulate_splitfed,
    "pipar": simulate_pipar,
    "oafl": simulate_oafl,
}
