"""Baseline FL protocols as event simulations (paper §5.2 baselines).

  classic FL [McMahan'17]  — full model on device, synchronous FedAvg
  FedAsync   [Xie'23]      — full model, asynchronous aggregation
  FedBuff    [Nguyen'22]   — full model, buffered async aggregation (Z)
  SplitFed   [Thapa'22]    — offloading, per-iteration grad return, sync agg
  PiPar      [Zhang'24]    — SplitFed + pipeline overlap on the device
  OAFL       (§2.2)        — SplitFed protocol + FedAsync aggregation

All share the Metrics structure of `simulation.py`, so figures compare
like-for-like.  Server compute is serialized (single accelerator); links
are full-duplex.  hooks objects (optional) drive real JAX training in
event order — see core/learning.py.
"""
from __future__ import annotations

import numpy as np

from .simulation import Metrics, Sim, SimCluster, SimModel


# ---------------------------------------------------------------------------
# Full-model methods: classic FL / FedAsync / FedBuff
# ---------------------------------------------------------------------------

def simulate_classic_fl(model: SimModel, cluster: SimCluster, *,
                        duration: float, H: int = 10, hooks=None,
                        churn=None, seed: int = 0) -> Metrics:
    sim = Sim()
    K = cluster.K
    m = Metrics(K=K, duration=duration)
    t_iter = [3 * model.full_fwd_flops / cluster.dev_flops[k] for k in range(K)]
    active = np.ones(K, bool)
    bw = cluster.dev_bw.astype(float).copy()
    pending = {"n": 0}

    def start_round():
        m.rounds += 1
        expected = [k for k in range(K) if active[k]]
        if not expected:
            sim.after(1.0, start_round)
            return
        pending["n"] = len(expected)
        for k in expected:
            tx = model.full_model_bytes / bw[k]
            m.bytes_down += model.full_model_bytes
            sim.after(tx, dev_train, k, H)

    def dev_train(k, h_left):
        if not active[k]:
            arrive(None)
            return
        start = sim.t

        def done():
            m.dev_busy[k] += sim.t - start
            m.dev_samples += model.batch_size
            if hooks:
                hooks.device_iter(k, False)
            if h_left > 1:
                dev_train(k, h_left - 1)
            else:
                tx = model.full_model_bytes / bw[k]
                m.bytes_up += model.full_model_bytes
                sim.after(tx, arrive, k)
        sim.after(t_iter[k], done)

    def arrive(k):
        pending["n"] -= 1
        if pending["n"] <= 0:
            start = sim.t
            dt = model.agg_flops * max(1, K) / cluster.srv_flops

            def agg_done():
                m.srv_busy += sim.t - start
                m.aggregations += 1
                if hooks:
                    hooks.sync_aggregate()
                start_round()
            sim.after(dt, agg_done)

    _install_churn(sim, churn, active, bw, K, on_rejoin=None)
    start_round()
    sim.run(duration)
    return m


def _simulate_async_full(model: SimModel, cluster: SimCluster, *, duration,
                         H, buffer_size, hooks, churn, seed) -> Metrics:
    """Shared core of FedAsync (buffer_size=1) and FedBuff (buffer_size=Z)."""
    sim = Sim()
    K = cluster.K
    m = Metrics(K=K, duration=duration)
    t_iter = [3 * model.full_fwd_flops / cluster.dev_flops[k] for k in range(K)]
    active = np.ones(K, bool)
    bw = cluster.dev_bw.astype(float).copy()
    srv = {"busy": False, "buffer": 0}
    queue: list[int] = []

    def dev_round(k):
        if not active[k]:
            return
        dev_train(k, H)

    def dev_train(k, h_left):
        if not active[k]:
            return
        start = sim.t

        def done():
            if not active[k]:
                return
            m.dev_busy[k] += sim.t - start
            m.dev_samples += model.batch_size
            if hooks:
                hooks.device_iter(k, False)
            if h_left > 1:
                dev_train(k, h_left - 1)
            else:
                tx = model.full_model_bytes / bw[k]
                m.bytes_up += model.full_model_bytes
                sim.after(tx, arrive, k)
        sim.after(t_iter[k], done)

    def arrive(k):
        queue.append(k)
        srv["buffer"] += 1
        kick()

    def kick():
        if srv["busy"] or srv["buffer"] < buffer_size or not queue:
            return
        srv["busy"] = True
        start = sim.t
        batch = queue[:buffer_size]
        del queue[:buffer_size]
        srv["buffer"] -= len(batch)
        dt = model.agg_flops * len(batch) / cluster.srv_flops

        def agg_done():
            m.srv_busy += sim.t - start
            m.aggregations += 1
            if hooks:
                for kk in batch:
                    hooks.aggregate(kk)
            for kk in batch:
                tx = model.full_model_bytes / bw[kk] if active[kk] else 0.0
                m.bytes_down += model.full_model_bytes if active[kk] else 0.0
                sim.after(tx, dev_round, kk)
            srv["busy"] = False
            kick()
        sim.after(dt, agg_done)

    _install_churn(sim, churn, active, bw, K, on_rejoin=dev_round)
    for k in range(K):
        dev_round(k)
    sim.run(duration)
    return m


def simulate_fedasync(model, cluster, *, duration, H=10, hooks=None,
                      churn=None, seed=0) -> Metrics:
    return _simulate_async_full(model, cluster, duration=duration, H=H,
                                buffer_size=1, hooks=hooks, churn=churn, seed=seed)


def simulate_fedbuff(model, cluster, *, duration, H=10, buffer_size=None,
                     hooks=None, churn=None, seed=0) -> Metrics:
    Z = buffer_size or max(2, cluster.K // 4)
    return _simulate_async_full(model, cluster, duration=duration, H=H,
                                buffer_size=Z, hooks=hooks, churn=churn, seed=seed)


# ---------------------------------------------------------------------------
# Offloading methods: SplitFed / PiPar / OAFL
# ---------------------------------------------------------------------------

def _simulate_split(model: SimModel, cluster: SimCluster, *, duration, H,
                    sync_agg: bool, pipeline: bool, hooks, churn, seed) -> Metrics:
    """Split-training protocol: per iteration the device sends activations,
    the server trains that device's server-side model and returns gradients.

    sync_agg=True  -> SplitFed/PiPar (round barrier across devices)
    pipeline=True  -> PiPar (device overlaps next fwd while waiting)
    sync_agg=False -> OAFL (async aggregation at round end, no barrier)
    """
    sim = Sim()
    K = cluster.K
    m = Metrics(K=K, duration=duration)
    active = np.ones(K, bool)
    bw = cluster.dev_bw.astype(float).copy()
    srv = {"busy": False}
    srv_queue: list[tuple] = []
    barrier = {"n": 0}
    t_fwd = [model.dev_fwd_flops / cluster.dev_flops[k] for k in range(K)]
    t_bwd = [model.dev_bwd_flops / cluster.dev_flops[k] for k in range(K)]

    def dev_round(k):
        if not active[k]:
            return
        dev_fwd(k, H)

    def dev_fwd(k, h_left):
        if not active[k]:
            return
        start = sim.t

        def fwd_done():
            if not active[k]:
                return
            m.dev_busy[k] += sim.t - start
            tx = model.act_bytes / bw[k]
            m.bytes_up += model.act_bytes
            sim.after(tx, srv_request, k, h_left)
            # PiPar: overlap — start next microbatch fwd while waiting
            if pipeline and h_left > 1:
                start2 = sim.t

                def fwd2_done():
                    m.dev_busy[k] += sim.t - start2
                sim.after(t_fwd[k], fwd2_done)
        sim.after(t_fwd[k], fwd_done)

    def srv_request(k, h_left):
        srv_queue.append((k, h_left))
        kick()

    def kick():
        if srv["busy"] or not srv_queue:
            return
        srv["busy"] = True
        k, h_left = srv_queue.pop(0)
        start = sim.t
        dt = model.srv_flops_per_batch / cluster.srv_flops

        def done():
            m.srv_busy += sim.t - start
            m.srv_batches += 1
            if hooks:
                hooks.server_train(k)
            tx = model.act_bytes / bw[k] if active[k] else 0.0  # gradients back
            m.bytes_down += model.act_bytes if active[k] else 0.0
            sim.after(tx, dev_bwd, k, h_left)
            srv["busy"] = False
            kick()
        sim.after(dt, done)

    def dev_bwd(k, h_left):
        if not active[k]:
            if sync_agg:
                barrier_arrive()
            return
        start = sim.t

        def bwd_done():
            if not active[k]:
                if sync_agg:
                    barrier_arrive()
                return
            # PiPar already accounted the overlapped fwd busy time
            m.dev_busy[k] += sim.t - start
            m.dev_samples += model.batch_size
            if hooks:
                hooks.device_iter(k, True)
            if h_left > 1:
                if pipeline:
                    # fwd of next batch already ran; go straight to upload
                    tx = model.act_bytes / bw[k]
                    m.bytes_up += model.act_bytes
                    sim.after(tx, srv_request, k, h_left - 1)
                else:
                    dev_fwd(k, h_left - 1)
            else:
                tx = model.dev_model_bytes / bw[k]
                m.bytes_up += model.dev_model_bytes
                sim.after(tx, model_arrive, k)
        sim.after(t_bwd[k], bwd_done)

    def model_arrive(k):
        if sync_agg:
            barrier_arrive()
        else:
            # OAFL: async aggregation immediately (serialized on server)
            start = sim.t
            dt = model.agg_flops / cluster.srv_flops

            def agg_done():
                m.srv_busy += sim.t - start
                m.aggregations += 1
                if hooks:
                    hooks.aggregate(k)
                tx = model.dev_model_bytes / bw[k] if active[k] else 0.0
                m.bytes_down += model.dev_model_bytes if active[k] else 0.0
                sim.after(tx, dev_round, k)
            sim.after(dt, agg_done)

    def barrier_arrive():
        barrier["n"] -= 1
        if barrier["n"] <= 0:
            start = sim.t
            dt = model.agg_flops * K / cluster.srv_flops

            def agg_done():
                m.srv_busy += sim.t - start
                m.aggregations += 1
                m.rounds += 1
                if hooks:
                    hooks.sync_aggregate()
                start_round()
            sim.after(dt, agg_done)

    def start_round():
        expected = [k for k in range(K) if active[k]]
        if not expected:
            sim.after(1.0, start_round)
            return
        barrier["n"] = len(expected)
        for k in expected:
            tx = model.dev_model_bytes / bw[k]
            m.bytes_down += model.dev_model_bytes
            sim.after(tx, dev_round, k)

    _install_churn(sim, churn, active, bw, K,
                   on_rejoin=None if sync_agg else dev_round)
    if sync_agg:
        start_round()
    else:
        for k in range(K):
            dev_round(k)
    sim.run(duration)
    return m


def simulate_splitfed(model, cluster, *, duration, H=10, hooks=None,
                      churn=None, seed=0) -> Metrics:
    return _simulate_split(model, cluster, duration=duration, H=H,
                           sync_agg=True, pipeline=False, hooks=hooks,
                           churn=churn, seed=seed)


def simulate_pipar(model, cluster, *, duration, H=10, hooks=None,
                   churn=None, seed=0) -> Metrics:
    return _simulate_split(model, cluster, duration=duration, H=H,
                           sync_agg=True, pipeline=True, hooks=hooks,
                           churn=churn, seed=seed)


def simulate_oafl(model, cluster, *, duration, H=10, hooks=None,
                  churn=None, seed=0) -> Metrics:
    return _simulate_split(model, cluster, duration=duration, H=H,
                           sync_agg=False, pipeline=False, hooks=hooks,
                           churn=churn, seed=seed)


# ---------------------------------------------------------------------------

def _install_churn(sim, churn, active, bw, K, on_rejoin):
    if churn is None:
        return

    def tick(i):
        act, new_bw = churn.draw(sim.t)
        for k in range(K):
            was = active[k]
            active[k] = act[k]
            bw[k] = new_bw[k]
            if not was and act[k] and on_rejoin is not None:
                on_rejoin(k)
        sim.after(churn.interval, tick, i + 1)
    sim.after(churn.interval, tick, 0)


REGISTRY = {
    "fl": simulate_classic_fl,
    "fedasync": simulate_fedasync,
    "fedbuff": simulate_fedbuff,
    "splitfed": simulate_splitfed,
    "pipar": simulate_pipar,
    "oafl": simulate_oafl,
}
