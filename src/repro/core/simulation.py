"""Deterministic event-driven FL cluster simulator.

Models a server + K heterogeneous devices with per-device compute rates
o_k (FLOP/s) and bandwidths b_k (bytes/s), full-duplex links, a serialized
server compute engine, and (for FedOptima) the Task Scheduler + activation
flow control.  Produces the paper's system metrics — idle time (Fig. 8/9),
throughput (Fig. 10/11), communication volume (Fig. 2), resilience under
churn (Fig. 12/13) — and, when a ``hooks`` object is supplied, drives real
JAX training in event order so accuracy experiments (Table 2, Fig. 6/7,
14/15) use genuine learning dynamics.

Simulated time is in seconds; nothing here sleeps.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field

import numpy as np

from repro.analysis import sanitize as _san
from repro.faults.inject import FaultInjector, install_timeouts
from repro.obs import trace as _tr
from repro.faults.quarantine import UpdateGate
from repro.fleet.devices import heterogeneous_cluster  # noqa: F401 re-export
from repro.fleet.selection import (SelectionContext, balance_summary,
                                   make_selection_policy)
from repro.fleet.traces import FleetTrace, install_fleet, resolve_fleet

from .control_plane import ControlPlane
from .executor import StragglerProfiles
from .scheduler import Message

# test-only mutation hook: True re-introduces PR 5's churn-flap bug — the
# per-device epoch check in ``model_return`` is skipped, so a pre-departure
# round's return restarts the device on top of its rejoined chain and the
# sanitizer's single-live-chain invariant must fire.  Never set outside
# tests.
_TEST_SKIP_EPOCH_CHECK = False


# ---------------------------------------------------------------------------
# Workload + cluster description
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SimModel:
    """Per-iteration compute/communication costs (batch granularity)."""
    dev_fwd_flops: float        # device-side block forward, per batch
    dev_bwd_flops: float        # device-side backward (incl. aux for FedOptima)
    full_fwd_flops: float       # full model forward, per batch (classic FL)
    srv_flops_per_batch: float  # server-side fwd+bwd per activation batch
    act_bytes: float            # one activation batch
    dev_model_bytes: float      # device-side (+aux) model
    full_model_bytes: float
    batch_size: int
    agg_flops: float = 1e7      # aggregation cost on server per model


@dataclass
class SimCluster:
    dev_flops: np.ndarray       # (K,) FLOP/s
    dev_bw: np.ndarray          # (K,) bytes/s
    srv_flops: float
    signal_latency: float = 1e-3   # control messages (turn-on etc.)

    @property
    def K(self) -> int:
        return len(self.dev_flops)


# ``heterogeneous_cluster`` (paper Table 3's 4 equal speed groups) now
# lives in ``repro.fleet.devices`` as the deterministic special case of
# tier-sampled fleets; it is re-exported above unchanged.


# ---------------------------------------------------------------------------
# Engine + metrics
# ---------------------------------------------------------------------------

class Sim:
    def __init__(self):
        self.t = 0.0
        self._heap: list = []
        self._seq = 0

    def at(self, t: float, fn, *args):
        heapq.heappush(self._heap, (t, self._seq, fn, args))
        self._seq += 1

    def after(self, dt: float, fn, *args):
        self.at(self.t + dt, fn, *args)

    def run(self, until: float):
        while self._heap and self._heap[0][0] <= until:
            self.t, _, fn, args = heapq.heappop(self._heap)
            fn(*args)
        self.t = until


@dataclass
class Metrics:
    K: int
    duration: float = 0.0
    dev_busy: np.ndarray = None
    srv_busy: float = 0.0
    bytes_up: float = 0.0
    bytes_down: float = 0.0
    dev_samples: int = 0          # samples trained on devices
    srv_batches: int = 0          # activation batches consumed by the server
    aggregations: int = 0
    rounds: int = 0
    max_buffered: int = 0         # peak Σ|Q_act| (memory check)
    trace: list = field(default_factory=list)
    profiles: StragglerProfiles = None   # measured per-device EMAs (if kept)
    dev_consumed: np.ndarray = None      # (K,) per-device contributions the
                                         # server consumed (activation batches
                                         # for split methods, model updates
                                         # for full-model methods)
    registry: object = None              # ElasticRegistry mirroring trace
                                         # join/leave events (fleet runs)
    faults: dict = None                  # FaultInjector.report() for runs
                                         # under a fault schedule: per-class
                                         # injected/recovered/disposition
                                         # counters + gate summary
    # -- steady-state (warmup-excluded) accounting: the sim-mode mirror of
    #    the executor's hidden_host_frac_steady.  warmup ends at the
    #    server's first dequeue (pipeline fill); see note_warmup_end.
    warmup_t: float = None
    dev_busy_steady: np.ndarray = None
    srv_busy_steady: float = 0.0
    dev_samples_steady: int = 0

    def __post_init__(self):
        if self.dev_busy is None:
            self.dev_busy = np.zeros(self.K)
        if self.dev_consumed is None:
            self.dev_consumed = np.zeros(self.K, np.int64)
        if self.dev_busy_steady is None:
            self.dev_busy_steady = np.zeros(self.K)

    # -- derived --
    @property
    def dev_idle_frac(self) -> float:
        return float(np.mean(1.0 - self.dev_busy / max(self.duration, 1e-9)))

    @property
    def srv_idle_frac(self) -> float:
        return 1.0 - self.srv_busy / max(self.duration, 1e-9)

    @property
    def throughput(self) -> float:
        return self.dev_samples / max(self.duration, 1e-9)

    def comm_per_round(self, total_dataset: int) -> float:
        if self.dev_samples == 0:
            return 0.0
        rounds = self.dev_samples / total_dataset
        return (self.bytes_up + self.bytes_down) / max(rounds, 1e-9)

    # -- per-device contribution balance (Alg. 3's fairness objective) --
    def note_contribution(self, k: int):
        """The server consumed one contribution of device k."""
        self.dev_consumed[k] += 1

    def contribution_balance(self) -> dict:
        """Variance / CV / Gini of per-device consumed counts (0-Gini =
        perfectly balanced contributions across the fleet)."""
        return balance_summary(self.dev_consumed)

    # -- busy-interval accounting (one mechanism for every protocol) ----
    #
    # Simulators call these instead of touching dev_busy/srv_busy
    # directly: the interval feeds (a) the legacy totals bit-for-bit,
    # (b) the steady-state accumulators, and (c) — only when a tracer is
    # attached — a span on the device/server lane.
    def note_warmup_end(self, t: float):
        """The server started real work: everything before is pipeline
        fill.  Idempotent; note_srv_busy calls it defensively."""
        if self.warmup_t is None:
            self.warmup_t = float(t)

    def note_dev_busy(self, k: int, start: float, end: float, *,
                      name: str = "step", lane: str | None = None,
                      samples: int = 0):
        self.dev_busy[k] += end - start
        if samples:
            self.dev_samples += samples
        if self.warmup_t is not None:
            self.dev_busy_steady[k] += max(0.0,
                                           end - max(start, self.warmup_t))
            if samples and end >= self.warmup_t:
                self.dev_samples_steady += samples
        if _tr.TRACING:
            _tr.emit_span(lane if lane is not None else f"dev/{k}",
                          name, start, end, clip=True)

    def note_srv_busy(self, start: float, end: float, *,
                      name: str = "train_batch", lane: str = "srv"):
        self.note_warmup_end(start)
        self.srv_busy += end - start
        self.srv_busy_steady += end - max(start, self.warmup_t)
        if _tr.TRACING:
            _tr.emit_span(lane, name, start, end, clip=True)

    def steady_summary(self) -> dict:
        """Warmup-excluded idle/throughput stats (the executor's
        ``*_steady`` keys, sim-side)."""
        w = self.warmup_t if self.warmup_t is not None else self.duration
        steady = max(self.duration - w, 0.0)
        if steady <= 0.0:
            return {"warmup_s": w, "steady_s": 0.0,
                    "srv_idle_frac_steady": 0.0,
                    "dev_idle_frac_steady": 0.0,
                    "throughput_steady": 0.0}
        return {
            "warmup_s": w,
            "steady_s": steady,
            "srv_idle_frac_steady": 1.0 - self.srv_busy_steady / steady,
            "dev_idle_frac_steady":
                float(np.mean(1.0 - self.dev_busy_steady / steady)),
            "throughput_steady": self.dev_samples_steady / steady,
        }

    def to_registry(self, reg=None, at: float | None = None):
        """Mirror the run's accounting into a MetricsRegistry (fresh one
        by default).  ``at`` overrides the horizon for mid-run dumps."""
        from repro.obs.metrics import MetricsRegistry
        if reg is None:
            reg = MetricsRegistry()
        horizon = max(self.duration if at is None else at, 1e-9)
        for name, v in (("sim.dev_busy_s", float(self.dev_busy.sum())),
                        ("sim.srv_busy_s", self.srv_busy),
                        ("sim.bytes_up", self.bytes_up),
                        ("sim.bytes_down", self.bytes_down),
                        ("sim.dev_samples", self.dev_samples),
                        ("sim.srv_batches", self.srv_batches),
                        ("sim.aggregations", self.aggregations)):
            inst = reg.counter(name)
            inst.inc(max(v - inst.value, 0.0))
        reg.gauge("sim.max_buffered").set(self.max_buffered)
        reg.gauge("sim.srv_idle_frac").set(
            1.0 - self.srv_busy / horizon)
        reg.gauge("sim.dev_idle_frac").set(
            float(np.mean(1.0 - self.dev_busy / horizon)))
        reg.gauge("sim.throughput").set(self.dev_samples / horizon)
        if self.warmup_t is not None and at is None:
            ss = self.steady_summary()
            for key in ("srv_idle_frac_steady", "dev_idle_frac_steady",
                        "throughput_steady", "warmup_s"):
                reg.gauge(f"sim.{key}").set(ss[key])
        return reg


# ---------------------------------------------------------------------------
# FedOptima simulation (paper §3.3, Alg. 1–4, Fig. 1(d))
# ---------------------------------------------------------------------------

def simulate_fedoptima(model: SimModel, cluster: SimCluster, *,
                       duration: float, omega: int = 8, H: int = 10,
                       max_delay: int = 16, policy: str = "counter",
                       pool_cap: int = 0,
                       hooks=None, churn=None, fleet=None, selection=None,
                       registry=None, seed: int = 0,
                       control: ControlPlane | None = None,
                       profiles: StragglerProfiles | None = None,
                       faults=None, fault_gate=None,
                       metrics_every: float = 0.0) -> Metrics:
    """Event simulation of FedOptima.

    hooks (optional): object with callbacks driving real training:
        device_iter(k, send: bool) -> None   (one local SGD iteration;
                                              if send, its activations ship)
        server_train(k) -> None              (server consumes one batch of k)
        aggregate(k) -> None                 (async aggregation of device k)
    churn (optional): legacy ChurnModel — materialized onto the fleet
        trace grid (same draws, bit-for-bit); mutually exclusive with
        ``fleet``.
    fleet (optional): a repro.fleet.FleetTrace driving per-device
        availability + bandwidth from its tick grid (diurnal windows,
        Weibull sessions, flaky links, ...).  Row 0 is the initial state;
        join/leave transitions reclaim flow tokens, purge scheduler
        counters (§3.4.2 fresh-history rejoin) and are mirrored into an
        ElasticRegistry (returned on ``Metrics.registry``).  A static
        always-on trace schedules no events — bit-for-bit the tracefree
        run.
    selection (optional): participant-selection policy (repro.fleet:
        "random" | "refl" | "score", optionally ":fraction", or a
        SelectionPolicy).  Each trace tick the policy picks a cohort from
        the available devices — fed the Task Scheduler's Alg. 3
        consumption counters and the staleness accounting — and only
        cohort members start rounds; deselected devices finish their
        in-flight round, then idle.  The default (None, or a
        full-fraction "random") runs every available device.
    registry (optional): an ElasticRegistry to mirror trace events into;
        by default one is created for fleet runs.
    control (optional): a ControlPlane supplying the scheduler, flow
        controller and staleness accounting; by default one is built with
        per-device flow units (Eq. 3: Σ_k |Q_k^act| ≤ ω strict).  Passing
        it in lets callers inspect peak buffers / counters afterwards.
    pool_cap: host spill-tier budget in device activation batches
        (server memory manager, repro.memory): admission runs against
        the total tiered budget ω + pool_cap, so up to pool_cap batches
        beyond the ω mesh tier may buffer (counted by the flow
        controller's n_spilled/n_filled).  0 = the strict Eq. 3 cap.
    profiles (optional): a StragglerProfiles fed with MEASURED per-device
        iteration/transfer durations and server batch times as they
        complete (EMA).  By default one is created; it is returned on
        ``Metrics.profiles`` so callers can feed its ``produce``/``reads``
        patterns into ``ControlPlane.plan_round`` (real straggler
        profiles, not host-supplied placeholders).
    faults (optional): a repro.faults.FaultSchedule (or a prebuilt
        FaultInjector) played into the run's seams — upload corruption,
        duplicate/delayed arrivals, device timeouts, server crashes.
        Every injected fault is matched by a recovery counter on
        ``Metrics.faults`` (quarantine, dedupe, α-weighting, rejoin,
        restart; see repro.faults.inject).
    fault_gate: the poison-update validation gate paired with ``faults``:
        None builds a default UpdateGate, an UpdateGate instance is used
        as-is, and False disables the gate entirely (the no-armor
        benchmark leg: poisoned updates flow into training unrecovered).
    metrics_every: simulated-seconds cadence for a one-line metrics dump
        (stdout); 0 disables.  Pure print — scheduling it perturbs no
        run state.
    """
    sim = Sim()
    K = cluster.K
    m = Metrics(K=K, duration=duration)
    if control is not None and \
            (control.G, control.omega, control.flow.omega,
             control.flow.pool_cap, control.scheduler.policy,
             control.max_delay) != \
            (K, omega, omega, pool_cap, policy, max_delay):
        raise ValueError(
            f"supplied ControlPlane (n={control.G}, omega={control.omega}, "
            f"flow budget={control.flow.omega}+{control.flow.pool_cap}, "
            f"policy={control.scheduler.policy!r}, "
            f"max_delay={control.max_delay}) disagrees with the run "
            f"(n={K}, omega={omega}, pool_cap={pool_cap}, "
            f"policy={policy!r}, max_delay={max_delay}); build it with "
            "ControlPlane.for_sim so the flow budget is the per-device "
            "Eq. 3 cap (tiered by pool_cap)")
    cp = control if control is not None else \
        ControlPlane.for_sim(K, omega, policy=policy, max_delay=max_delay,
                             pool_cap=pool_cap)
    prof = profiles if profiles is not None else StragglerProfiles(K)
    if prof.G != K:
        raise ValueError(f"profiles track {prof.G} groups, cluster has {K}")
    m.profiles = prof
    sched = cp.scheduler
    flow = cp.flow

    inj = None
    if faults is not None:
        if isinstance(faults, FaultInjector):
            inj = faults
        else:
            gate = UpdateGate() if fault_gate is None else \
                (fault_gate or None)
            inj = FaultInjector(faults, gate=gate)

    trace = resolve_fleet(fleet, churn, cluster, duration)
    sel = make_selection_policy(selection, seed=seed)
    if sel is not None and sel.trivial:
        sel = None        # select-all ≡ no selection (cohort = available)
    if sel is not None and trace is None:
        # selection needs a re-draw cadence even over an always-on fleet:
        # a static identity trace supplies the tick grid (no churn
        # events), at a duration-derived interval so short runs still
        # re-draw the cohort (>= 12 ticks; the §6.4 cadence for long runs)
        trace = FleetTrace.from_cluster(
            cluster, duration,
            interval=max(min(600.0, duration / 12.0), 1e-3))
    reg = registry
    if reg is None and trace is not None:
        from repro.runtime.elastic import ElasticRegistry
        reg = ElasticRegistry()
    if reg is not None and not reg.devices:
        for k in range(K):
            reg.join(float(cluster.dev_flops[k]), float(cluster.dev_bw[k]))
    m.registry = reg

    active = np.ones(K, bool)
    bw = cluster.dev_bw.astype(float).copy()
    if trace is not None:
        trace.apply(active, bw)              # row 0: the initial roster
        for k in np.flatnonzero(~active):
            flow.on_device_left(int(k))      # reclaim the pre-granted token
            if reg is not None:
                reg.leave(int(k), t=0.0)
    selected = np.ones(K, bool)              # current selection cohort
    running = np.zeros(K, bool)              # device has a round in flight
    epoch = np.zeros(K, np.int64)            # bumped per departure: pending
                                             # callbacks of the pre-leave
                                             # chain see a stale epoch and
                                             # die, so a rejoin can never
                                             # run two chains concurrently
    versions = cp.versions            # local model version t_k
    srv_state = {"busy": False, "down": 0, "cur": None, "epoch": 0}

    t_iter = [(model.dev_fwd_flops + model.dev_bwd_flops) / cluster.dev_flops[k]
              for k in range(K)]

    # ---------------- device state machine ----------------
    def device_start_round(k, h_left):
        if not active[k] or not selected[k] or running[k]:
            return
        running[k] = True
        if _san.TRACING:
            _san.emit("sim.chain_start", sim=sim, device=int(k),
                      epoch=int(epoch[k]))
        device_iter(k, h_left, epoch[k])

    def device_iter(k, h_left, e):
        if not active[k] or epoch[k] != e:
            return
        start = sim.t
        sim.after(t_iter[k], device_iter_done, k, h_left, start, e)

    def device_iter_done(k, h_left, start, e):
        if not active[k] or epoch[k] != e:
            return
        m.note_dev_busy(k, start, sim.t, samples=model.batch_size)
        prof.observe_group(k, step_s=sim.t - start)
        send = flow.can_send(k) and \
            (inj is None or inj.may_send(k, sim.t))
        if send:
            flow.mark_sent(k)
            tx = model.act_bytes / bw[k]
            prof.observe_group(k, transfer_s=tx)
            m.bytes_up += model.act_bytes
            if _tr.TRACING:
                _tr.emit_span(f"net/{k}", "act_upload", sim.t, sim.t + tx,
                              clip=True)
            tag = inj.tag_act_upload(k, sim.t) if inj is not None else None
            sim.after(tx, act_arrive, k, tag)
            if tag is not None and tag["dup_extra"] is not None:
                # injected duplicate: the copy ships too, delayed — it may
                # land reordered past other devices' arrivals
                m.bytes_up += model.act_bytes
                sim.after(tx + tag["dup_extra"], act_arrive, k, tag)
        if hooks:
            hooks.device_iter(k, send)
        if h_left > 1:
            device_iter(k, h_left - 1, e)
        else:
            # end of round: ship device model for aggregation (Alg. 1 l.13)
            tx = model.dev_model_bytes / bw[k]
            m.bytes_up += model.dev_model_bytes
            if _tr.TRACING:
                _tr.emit_span(f"net/{k}", "model_upload", sim.t, sim.t + tx,
                              clip=True)
            extra, ckind = inj.tag_model_upload(k, sim.t) \
                if inj is not None else (0.0, "")
            sim.after(tx + extra, model_arrive, k, e, ckind, extra > 0.0)

    def act_arrive(k, tag=None):
        if tag is not None and tag["dup_extra"] is not None and \
                not inj.act_dedupe(tag["seq"]):
            return              # second delivery of a duplicated upload
        if not active[k]:
            flow.on_device_left(k)
            return
        poisoned = bool(tag and tag["kind"])
        if inj is not None and not inj.act_validate(k, tag, sim.t):
            # quarantined before it touches a queue: withdraw the in-flight
            # unit so Eq. 3 and the Alg. 3 counters stay conserved
            flow.on_quarantined(k)
            return
        if not flow.on_enqueue(k):
            # zombie packet: the sender dropped (its in-flight budget was
            # reclaimed) and rejoined before this arrival — reject it so
            # the ω cap stays strict
            return
        if inj is not None and not poisoned:
            inj.note_accept(k)          # clean update: forgive one strike
        sched.put(Message("activation", k,
                          content="poison" if poisoned else None,
                          size_bytes=model.act_bytes,
                          enqueued_at=sim.t))
        m.max_buffered = max(m.max_buffered, sched.total_buffered)
        cp.note_buffered(sched.total_buffered)
        if not flow.within_cap:
            raise RuntimeError(
                f"flow-control cap violated in simulation at t={sim.t}: "
                f"device {k} admitted with buffered={flow.buffered}, "
                f"promised={flow.promised} of cap={flow.cap}")
        kick_server()

    def model_arrive(k, e, ckind="", delayed=False):
        if inj is not None and delayed:
            # late arrival (possibly past max_delay): Alg. 4's staleness
            # weighting at aggregation is the armor — nothing to drop here
            inj.note_delayed_arrival()
        if inj is not None and ckind:
            ok, backoff = inj.model_validate(k, ckind, sim.t)
            if not ok:
                # quarantined: the poisoned update never reaches Q_model;
                # re-sync the device after its strike backoff so the chain
                # survives without consuming the update
                tx = model.dev_model_bytes / bw[k] if active[k] else 0.0
                m.bytes_down += model.dev_model_bytes if active[k] else 0.0
                sim.after(backoff + tx, model_return, k, e)
                return
        # the shipping chain's epoch rides the message so the eventual
        # model_return can tell a pre-departure upload from a live one
        sched.put(Message("model", k, content=(int(versions[k]), int(e))))
        kick_server()

    # ---------------- server engine ----------------
    def kick_server():
        if srv_state["busy"] or srv_state["down"]:
            return
        msg = sched.get()
        if msg is None:
            return
        m.note_warmup_end(sim.t)
        srv_state["busy"] = True
        srv_state["cur"] = msg
        if msg.kind == "model":
            dt = model.agg_flops / cluster.srv_flops
            sim.after(dt, server_agg_done, msg.origin, sim.t,
                      msg.content[1], srv_state["epoch"])
        else:
            flow.on_dequeue(msg.origin)
            dt = model.srv_flops_per_batch / cluster.srv_flops
            sim.after(dt, server_train_done, msg.origin, sim.t,
                      msg.content == "poison", srv_state["epoch"])

    def server_agg_done(k, start, e, se=0):
        if se != srv_state["epoch"]:
            return                      # in-service work lost to a crash
        srv_state["cur"] = None
        m.note_srv_busy(start, sim.t, name="aggregate")
        m.aggregations += 1
        if cp.aggregate_arrival(k, versions[k]) > 0.0 and hooks:
            hooks.aggregate(k)
        # return global model to device (Alg. 4 l.20)
        tx = model.dev_model_bytes / bw[k] if active[k] else 0.0
        m.bytes_down += model.dev_model_bytes if active[k] else 0.0
        sim.after(tx, model_return, k, e)
        srv_state["busy"] = False
        kick_server()

    def model_return(k, e):
        cp.device_synced(k)
        if epoch[k] != e and not _TEST_SKIP_EPOCH_CHECK:
            # a pre-departure round's model came back after the device
            # left (and possibly rejoined with a live chain): syncing is
            # fine, but this return must not restart the device
            return
        if _san.TRACING:
            _san.emit("sim.chain_end", sim=sim, device=int(k), epoch=int(e))
        running[k] = False
        device_start_round(k, H)

    def server_train_done(k, start, poisoned=False, se=0):
        if se != srv_state["epoch"]:
            return                      # in-service work lost to a crash
        srv_state["cur"] = None
        m.note_srv_busy(start, sim.t, name="train_batch")
        m.srv_batches += 1
        m.note_contribution(k)
        prof.observe_server(sim.t - start)
        if poisoned:
            # no-gate leg: the poison reached server training (badput —
            # the faults benchmark subtracts these from goodput)
            inj.note_disposition("consumed_poisoned_act")
        if hooks:
            hooks.server_train(k)
        srv_state["busy"] = False
        kick_server()

    # ---------------- fleet membership (trace ticks) ----------------
    def on_leave(k):
        running[k] = False
        epoch[k] += 1                 # kill the chain's pending callbacks
        if _san.TRACING:
            _san.emit("sim.device_left", sim=sim, device=int(k),
                      epoch=int(epoch[k]))
        if _tr.TRACING:
            _tr.emit_instant(f"dev/{k}", "leave", sim.t)
        flow.on_device_left(k)
        # purge the consumption counter (§3.4.2: a rejoin starts with
        # fresh history); buffered activations still train
        sched.remove_device(k)
        if reg is not None:
            reg.leave(k, t=sim.t)

    def on_rejoin(k):
        flow.register(k)
        if reg is not None:
            reg.rejoin(k, t=sim.t)
            reg.set_bandwidth(k, float(bw[k]))
        if _san.TRACING:
            _san.emit("sim.device_join", sim=sim, device=int(k),
                      epoch=int(epoch[k]))
        if _tr.TRACING:
            _tr.emit_instant(f"dev/{k}", "join", sim.t)
        device_start_round(k, H)

    # ---------------- injected fault windows ----------------
    def crash_begin(outage_s):
        inj.note_injected("server_crash")
        if _tr.TRACING:
            _tr.emit_instant("srv", "fault.crash_begin", sim.t,
                             outage_s=outage_s)
        srv_state["down"] += 1
        srv_state["epoch"] += 1         # pending completions die stale
        cur = srv_state["cur"]
        if srv_state["busy"] and cur is not None:
            if cur.kind == "model":
                # a lost model update would strand its device (model_return
                # never fires): requeue it — durable Q_model survives the
                # outage, only in-service compute is lost
                sched.put(cur)
                inj.note_disposition("lost_model_requeued")
            else:
                # the batch's flow token was released at dequeue: dropping
                # it keeps Eq. 3 conserved, the work is simply lost
                inj.note_disposition("lost_act_batch")
        srv_state["cur"] = None
        srv_state["busy"] = False
        sim.after(outage_s, crash_end)

    def crash_end():
        srv_state["down"] -= 1
        inj.note_recovered("server_crash", "crash_restart")
        if _tr.TRACING:
            _tr.emit_instant("srv", "fault.crash_end", sim.t)
        if not srv_state["down"]:
            kick_server()

    def reselect():
        """Re-draw the participation cohort from the available devices
        (fed the live Alg. 3 counters + staleness accounting).  Devices
        leaving the cohort finish their in-flight round, then idle; new
        cohort members start immediately."""
        ctx = SelectionContext(t=sim.t, counters=sched.counters,
                               staleness=cp.version - versions,
                               capability=cluster.dev_flops)
        chosen = sel.select(np.flatnonzero(active), ctx)
        selected[:] = False
        selected[np.asarray(chosen, int)] = True
        for k in np.flatnonzero(selected & active & ~running):
            device_start_round(int(k), H)

    # ---------------- go ----------------
    if sel is not None:
        reselect()
    else:
        for k in range(K):
            device_start_round(k, H)
    install_fleet(sim, trace, active, bw, on_leave=on_leave,
                  on_rejoin=on_rejoin,
                  after_tick=reselect if sel is not None else None)
    if inj is not None:
        install_timeouts(sim, inj, active, trace,
                         on_leave=on_leave, on_rejoin=on_rejoin)
        for ev in inj.crashes():
            sim.at(ev.t, crash_begin, float(ev.param))
    if metrics_every and metrics_every > 0.0:
        def _dump_metrics():
            print(m.to_registry(at=sim.t).dump_line(
                prefix=f"[sim t={sim.t:.1f}s]"))
            sim.after(metrics_every, _dump_metrics)
        sim.after(metrics_every, _dump_metrics)
    sim.run(duration)
    m.duration = duration
    if inj is not None:
        inj.finalize(duration)
        m.faults = inj.report()
    return m
