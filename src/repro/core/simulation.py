"""Deterministic event-driven FL cluster simulator.

Models a server + K heterogeneous devices with per-device compute rates
o_k (FLOP/s) and bandwidths b_k (bytes/s), full-duplex links, a serialized
server compute engine, and (for FedOptima) the Task Scheduler + activation
flow control.  Produces the paper's system metrics — idle time (Fig. 8/9),
throughput (Fig. 10/11), communication volume (Fig. 2), resilience under
churn (Fig. 12/13) — and, when a ``hooks`` object is supplied, drives real
JAX training in event order so accuracy experiments (Table 2, Fig. 6/7,
14/15) use genuine learning dynamics.

Simulated time is in seconds; nothing here sleeps.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field

import numpy as np

from .control_plane import ControlPlane
from .executor import StragglerProfiles
from .scheduler import Message


# ---------------------------------------------------------------------------
# Workload + cluster description
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SimModel:
    """Per-iteration compute/communication costs (batch granularity)."""
    dev_fwd_flops: float        # device-side block forward, per batch
    dev_bwd_flops: float        # device-side backward (incl. aux for FedOptima)
    full_fwd_flops: float       # full model forward, per batch (classic FL)
    srv_flops_per_batch: float  # server-side fwd+bwd per activation batch
    act_bytes: float            # one activation batch
    dev_model_bytes: float      # device-side (+aux) model
    full_model_bytes: float
    batch_size: int
    agg_flops: float = 1e7      # aggregation cost on server per model


@dataclass
class SimCluster:
    dev_flops: np.ndarray       # (K,) FLOP/s
    dev_bw: np.ndarray          # (K,) bytes/s
    srv_flops: float
    signal_latency: float = 1e-3   # control messages (turn-on etc.)

    @property
    def K(self) -> int:
        return len(self.dev_flops)


def heterogeneous_cluster(K: int, base_flops: float = 5e9,
                          speed_groups=(1.0, 1.33, 2.67, 3.84),
                          bw: float = 100e6 / 8, srv_ratio: float = 50.0,
                          seed: int = 0) -> SimCluster:
    """Paper Table 3-style cluster: 4 equal-size speed groups; server is
    srv_ratio× the fastest device."""
    groups = np.array([speed_groups[i * len(speed_groups) // K] for i in range(K)])
    return SimCluster(dev_flops=base_flops * groups,
                      dev_bw=np.full(K, bw),
                      srv_flops=base_flops * max(speed_groups) * srv_ratio)


# ---------------------------------------------------------------------------
# Engine + metrics
# ---------------------------------------------------------------------------

class Sim:
    def __init__(self):
        self.t = 0.0
        self._heap: list = []
        self._seq = 0

    def at(self, t: float, fn, *args):
        heapq.heappush(self._heap, (t, self._seq, fn, args))
        self._seq += 1

    def after(self, dt: float, fn, *args):
        self.at(self.t + dt, fn, *args)

    def run(self, until: float):
        while self._heap and self._heap[0][0] <= until:
            self.t, _, fn, args = heapq.heappop(self._heap)
            fn(*args)
        self.t = until


@dataclass
class Metrics:
    K: int
    duration: float = 0.0
    dev_busy: np.ndarray = None
    srv_busy: float = 0.0
    bytes_up: float = 0.0
    bytes_down: float = 0.0
    dev_samples: int = 0          # samples trained on devices
    srv_batches: int = 0          # activation batches consumed by the server
    aggregations: int = 0
    rounds: int = 0
    max_buffered: int = 0         # peak Σ|Q_act| (memory check)
    trace: list = field(default_factory=list)
    profiles: StragglerProfiles = None   # measured per-device EMAs (if kept)

    def __post_init__(self):
        if self.dev_busy is None:
            self.dev_busy = np.zeros(self.K)

    # -- derived --
    @property
    def dev_idle_frac(self) -> float:
        return float(np.mean(1.0 - self.dev_busy / max(self.duration, 1e-9)))

    @property
    def srv_idle_frac(self) -> float:
        return 1.0 - self.srv_busy / max(self.duration, 1e-9)

    @property
    def throughput(self) -> float:
        return self.dev_samples / max(self.duration, 1e-9)

    def comm_per_round(self, total_dataset: int) -> float:
        if self.dev_samples == 0:
            return 0.0
        rounds = self.dev_samples / total_dataset
        return (self.bytes_up + self.bytes_down) / max(rounds, 1e-9)


# ---------------------------------------------------------------------------
# FedOptima simulation (paper §3.3, Alg. 1–4, Fig. 1(d))
# ---------------------------------------------------------------------------

def simulate_fedoptima(model: SimModel, cluster: SimCluster, *,
                       duration: float, omega: int = 8, H: int = 10,
                       max_delay: int = 16, policy: str = "counter",
                       pool_cap: int = 0,
                       hooks=None, churn=None, seed: int = 0,
                       control: ControlPlane | None = None,
                       profiles: StragglerProfiles | None = None) -> Metrics:
    """Event simulation of FedOptima.

    hooks (optional): object with callbacks driving real training:
        device_iter(k, send: bool) -> None   (one local SGD iteration;
                                              if send, its activations ship)
        server_train(k) -> None              (server consumes one batch of k)
        aggregate(k) -> None                 (async aggregation of device k)
    churn (optional): ChurnModel — devices drop/rejoin, bandwidth re-drawn.
    control (optional): a ControlPlane supplying the scheduler, flow
        controller and staleness accounting; by default one is built with
        per-device flow units (Eq. 3: Σ_k |Q_k^act| ≤ ω strict).  Passing
        it in lets callers inspect peak buffers / counters afterwards.
    pool_cap: host spill-tier budget in device activation batches
        (server memory manager, repro.memory): admission runs against
        the total tiered budget ω + pool_cap, so up to pool_cap batches
        beyond the ω mesh tier may buffer (counted by the flow
        controller's n_spilled/n_filled).  0 = the strict Eq. 3 cap.
    profiles (optional): a StragglerProfiles fed with MEASURED per-device
        iteration/transfer durations and server batch times as they
        complete (EMA).  By default one is created; it is returned on
        ``Metrics.profiles`` so callers can feed its ``produce``/``reads``
        patterns into ``ControlPlane.plan_round`` (real straggler
        profiles, not host-supplied placeholders).
    """
    sim = Sim()
    K = cluster.K
    m = Metrics(K=K, duration=duration)
    if control is not None and \
            (control.G, control.omega, control.flow.omega,
             control.flow.pool_cap, control.scheduler.policy,
             control.max_delay) != \
            (K, omega, omega, pool_cap, policy, max_delay):
        raise ValueError(
            f"supplied ControlPlane (n={control.G}, omega={control.omega}, "
            f"flow budget={control.flow.omega}+{control.flow.pool_cap}, "
            f"policy={control.scheduler.policy!r}, "
            f"max_delay={control.max_delay}) disagrees with the run "
            f"(n={K}, omega={omega}, pool_cap={pool_cap}, "
            f"policy={policy!r}, max_delay={max_delay}); build it with "
            "ControlPlane.for_sim so the flow budget is the per-device "
            "Eq. 3 cap (tiered by pool_cap)")
    cp = control if control is not None else \
        ControlPlane.for_sim(K, omega, policy=policy, max_delay=max_delay,
                             pool_cap=pool_cap)
    prof = profiles if profiles is not None else StragglerProfiles(K)
    if prof.G != K:
        raise ValueError(f"profiles track {prof.G} groups, cluster has {K}")
    m.profiles = prof
    sched = cp.scheduler
    flow = cp.flow
    rng = np.random.default_rng(seed)

    active = np.ones(K, bool)
    bw = cluster.dev_bw.astype(float).copy()
    versions = cp.versions            # local model version t_k
    srv_state = {"busy": False}

    t_iter = [(model.dev_fwd_flops + model.dev_bwd_flops) / cluster.dev_flops[k]
              for k in range(K)]

    # ---------------- device state machine ----------------
    def device_start_round(k, h_left):
        if not active[k]:
            return
        device_iter(k, h_left)

    def device_iter(k, h_left):
        if not active[k]:
            return
        start = sim.t
        sim.after(t_iter[k], device_iter_done, k, h_left, start)

    def device_iter_done(k, h_left, start):
        if not active[k]:
            return
        m.dev_busy[k] += sim.t - start
        m.dev_samples += model.batch_size
        prof.observe_group(k, step_s=sim.t - start)
        send = flow.can_send(k)
        if send:
            flow.mark_sent(k)
            tx = model.act_bytes / bw[k]
            prof.observe_group(k, transfer_s=tx)
            m.bytes_up += model.act_bytes
            sim.after(tx, act_arrive, k)
        if hooks:
            hooks.device_iter(k, send)
        if h_left > 1:
            device_iter(k, h_left - 1)
        else:
            # end of round: ship device model for aggregation (Alg. 1 l.13)
            tx = model.dev_model_bytes / bw[k]
            m.bytes_up += model.dev_model_bytes
            sim.after(tx, model_arrive, k)

    def act_arrive(k):
        if not active[k]:
            flow.on_device_left(k)
            return
        if not flow.on_enqueue(k):
            # zombie packet: the sender dropped (its in-flight budget was
            # reclaimed) and rejoined before this arrival — reject it so
            # the ω cap stays strict
            return
        sched.put(Message("activation", k, size_bytes=model.act_bytes,
                          enqueued_at=sim.t))
        m.max_buffered = max(m.max_buffered, sched.total_buffered)
        cp.note_buffered(sched.total_buffered)
        assert flow.within_cap, "flow-control cap violated in simulation"
        kick_server()

    def model_arrive(k):
        sched.put(Message("model", k, content=versions[k]))
        kick_server()

    # ---------------- server engine ----------------
    def kick_server():
        if srv_state["busy"]:
            return
        msg = sched.get()
        if msg is None:
            return
        srv_state["busy"] = True
        if msg.kind == "model":
            dt = model.agg_flops / cluster.srv_flops
            sim.after(dt, server_agg_done, msg.origin, sim.t)
        else:
            flow.on_dequeue(msg.origin)
            dt = model.srv_flops_per_batch / cluster.srv_flops
            sim.after(dt, server_train_done, msg.origin, sim.t)

    def server_agg_done(k, start):
        m.srv_busy += sim.t - start
        m.aggregations += 1
        if cp.aggregate_arrival(k, versions[k]) > 0.0 and hooks:
            hooks.aggregate(k)
        # return global model to device (Alg. 4 l.20)
        tx = model.dev_model_bytes / bw[k] if active[k] else 0.0
        m.bytes_down += model.dev_model_bytes if active[k] else 0.0
        sim.after(tx, model_return, k)
        srv_state["busy"] = False
        kick_server()

    def model_return(k):
        cp.device_synced(k)
        if active[k]:
            device_start_round(k, H)

    def server_train_done(k, start):
        m.srv_busy += sim.t - start
        m.srv_batches += 1
        prof.observe_server(sim.t - start)
        if hooks:
            hooks.server_train(k)
        srv_state["busy"] = False
        kick_server()

    # ---------------- churn ----------------
    def churn_tick(idx):
        if churn is None:
            return
        act, new_bw = churn.draw(sim.t)
        for k in range(K):
            was = active[k]
            active[k] = act[k]
            bw[k] = new_bw[k]
            if not was and act[k]:
                flow.register(k)
                device_start_round(k, H)
            if was and not act[k]:
                flow.on_device_left(k)
                # purge the consumption counter (§3.4.2: a rejoin starts
                # with fresh history); buffered activations still train
                sched.remove_device(k)
        sim.after(churn.interval, churn_tick, idx + 1)

    # ---------------- go ----------------
    for k in range(K):
        device_start_round(k, H)
    if churn is not None:
        sim.after(churn.interval, churn_tick, 0)
    sim.run(duration)
    m.duration = duration
    return m
