"""Donation-safe per-round state handles for the deep pipeline.

``jit_train_step`` donates its state argument (``donate_argnums=(0,)``):
the round-r output buffers are aliased into round r+1's inputs, so any
Python reference the driver keeps into round r's state is INVALID the
moment round r+1 dispatches.  That is what pinned the executor at
shallow windows — checkpoints had to drain the whole pipeline, and every
retention/spill gather had to run against the live (about-to-be-donated)
state.

A :class:`RoundHandle` makes a round's state outlive donation without
turning donation off:

* **on-device copy at dispatch** — ``jnp.copy`` on each captured leaf
  enqueues a copy program *after* round r's step and *before* round
  r+1's; in-order execution guarantees the copy reads round r's output
  before the donated write clobbers it.  The copies are fresh buffers
  (never donated), so they stay valid for as long as the handle lives.
* **async device→host staging** — ``copy_to_host_async`` starts the D2H
  transfer without blocking the dispatch thread (the orbax async-
  checkpoint idiom); ``ready()`` polls completion via ``is_ready`` and
  ``host_tree()`` materializes numpy copies, blocking only on the
  transfers themselves, never on unrelated in-flight rounds.
* **lazy slicing** — retention/spill consumers read one group or ring
  slot; ``group_state``/``act_slot`` slice the on-device copy and
  transfer just that slice, so light per-round handles (captured with
  ``to_host=False``) cost one fused copy and pay D2H per needed slice.

A :class:`HandleRing` keeps the last ``depth`` per-round handles with
byte accounting, so the executor can resolve "state as of round r" for
any round still inside the in-flight window.

This module is dependency-light on purpose (numpy + jax only, imported
lazily) so benchmark stubs and unit tests can use it without pulling in
the model stack.
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np


def _is_jax_array(x) -> bool:
    return hasattr(x, "is_ready") and hasattr(x, "copy_to_host_async")


_jit_copy = None


def _fused_copy(leaves: list):
    """One jitted copy program over all jax leaves — a single dispatch
    per snapshot (per-leaf ``jnp.copy`` calls cost one host dispatch
    each, which is real overhead on the pipelined hot path)."""
    global _jit_copy
    if _jit_copy is None:
        import jax
        import jax.numpy as jnp
        _jit_copy = jax.jit(lambda xs: [jnp.copy(x) for x in xs])
    return _jit_copy(leaves)


def snapshot_tree(tree, *, to_host: bool = False):
    """Donation-safe copy of a pytree: jax leaves go through one fused
    jitted copy (fresh, never-donated device buffers, enqueued in
    dispatch order), numpy leaves are copied host-side, scalars pass
    through.  ``to_host`` starts the async D2H transfer on every jax
    leaf immediately."""
    import jax

    leaves, treedef = jax.tree.flatten(tree)
    idx = [i for i, x in enumerate(leaves) if _is_jax_array(x)]
    if idx:
        for i, y in zip(idx, _fused_copy([leaves[i] for i in idx])):
            if to_host:
                y.copy_to_host_async()
            leaves[i] = y
    jax_idx = set(idx)
    leaves = [np.array(x, copy=True)
              if i not in jax_idx and isinstance(x, np.ndarray) else x
              for i, x in enumerate(leaves)]
    return jax.tree.unflatten(treedef, leaves)


def _tree_nbytes(tree) -> int:
    import jax

    return sum(int(x.nbytes) for x in jax.tree.leaves(tree)
               if hasattr(x, "nbytes"))


class RoundHandle:
    """One round's captured state: donation-safe device copies plus any
    dispatch-time metadata the eventual consumer (checkpoint saver,
    retention gather, spill gather) needs.

    ``meta`` carries host-side bookkeeping snapshotted at the SAME
    dispatch point as the arrays (e.g. the ControlPlane state_dict and
    RNG state for checkpoint-without-flush), so arrays and metadata
    always describe the same round.
    """

    def __init__(self, round_: int, tree, *, meta=None):
        self.round = int(round_)
        self.tree = tree
        self.meta = meta
        self._host = None

    @classmethod
    def capture(cls, round_: int, state, *, keys=None, meta=None,
                copy: bool = True, to_host: bool = False) -> "RoundHandle":
        """Snapshot ``state`` (or the ``keys`` subset of a dict state) at
        dispatch time.  ``copy=False`` wraps the live tree without
        copying — only safe when the pipeline is already drained and the
        handle is consumed before the next donating dispatch (the legacy
        flush path)."""
        src = state
        if keys is not None and isinstance(state, dict):
            src = {k: state[k] for k in keys if k in state}
        tree = snapshot_tree(src, to_host=to_host) if copy else src
        return cls(round_, tree, meta=meta)

    # -- readiness / materialization ------------------------------------
    def ready(self) -> bool:
        """True when every captured device leaf has materialized (the
        copy programs and any staged D2H transfers completed) — a save
        can proceed without stalling the dispatch thread."""
        import jax

        return all(x.is_ready() for x in jax.tree.leaves(self.tree)
                   if _is_jax_array(x))

    def host_tree(self):
        """Numpy copies of the captured tree (blocks only on this
        handle's own transfers); cached after the first call."""
        import jax

        if self._host is None:
            self._host = jax.tree.map(np.asarray, self.tree)
        return self._host

    # -- lazy slicing for retention / spill consumers -------------------
    def has(self, key: str) -> bool:
        return isinstance(self.tree, dict) and key in self.tree

    def group_state(self, g: int) -> dict:
        """One group's dev/aux slices from the captured stacks (the
        retention-gather payload), transferring only the slices."""
        import jax

        take = lambda tree: jax.tree.map(lambda x: np.asarray(x[g]), tree)
        return {"dev": take(self.tree["dev"]), "aux": take(self.tree["aux"])}

    def act_slot(self, s: int) -> dict:
        """One activation-ring slot from the captured ring (the spill
        payload), transferring only the slice."""
        import jax

        return jax.tree.map(lambda x: np.asarray(x[s]),
                            self.tree["act_buf"])

    @property
    def nbytes(self) -> int:
        return _tree_nbytes(self.tree)

    def __repr__(self) -> str:
        return (f"RoundHandle(round={self.round}, "
                f"nbytes={self.nbytes}, ready={self.ready()})")


class HandleRing:
    """Bounded ring of the last ``depth`` per-round handles.

    Eviction is purely positional (oldest round out); dropping a handle
    releases its device copies to the allocator.  ``peak_bytes`` tracks
    the high-water mark of simultaneously-held handle bytes — the
    pipeline-depth memory cost the benchmarks report.
    """

    def __init__(self, depth: int):
        if depth < 1:
            raise ValueError(f"need depth >= 1, got {depth}")
        self.depth = depth
        self._ring: OrderedDict[int, RoundHandle] = OrderedDict()
        self.n_captured = 0
        self.peak_bytes = 0

    def push(self, handle: RoundHandle) -> None:
        self._ring[handle.round] = handle
        self._ring.move_to_end(handle.round)
        while len(self._ring) > self.depth:
            self._ring.popitem(last=False)
        self.n_captured += 1
        self.peak_bytes = max(self.peak_bytes, self.nbytes)

    def get(self, round_: int) -> RoundHandle | None:
        return self._ring.get(int(round_))

    def __len__(self) -> int:
        return len(self._ring)

    @property
    def nbytes(self) -> int:
        return sum(h.nbytes for h in self._ring.values())

    def summary(self) -> dict:
        return {"depth": self.depth, "held": len(self._ring),
                "captured": self.n_captured,
                "bytes": int(self.nbytes),
                "peak_bytes": int(self.peak_bytes)}
