"""Asynchronous staleness-weighted aggregation (paper Alg. 4, lines 12–19).

FedAsync-style: when a local device-side model (θ_dk, θ̃_dk, t_k) arrives,

    if t - t_k > D:  skip (too stale)
    α   = 1 / (t - t_k + 1)
    θ_d  ← α θ_dk + (1-α) θ_d
    θ̃_d  ← α θ̃_dk + (1-α) θ̃_d
    t   ← t + 1

All state is a plain pytree; the update is jit-able and is reused both by
the event simulator and by the datacenter hybrid step (where it runs as an
on-mesh collective update).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax

from repro.models.common import tree_lerp


@dataclass
class AsyncAggregator:
    """Host-side aggregator holding the global device-side model."""
    theta_d: Any                     # global device-side params
    theta_aux: Any                   # global auxiliary params
    max_delay: int = 16              # D
    version: int = 0                 # t
    n_accepted: int = 0
    n_rejected: int = 0
    alpha_power: float = 1.0         # α = (t - t_k + 1)^-alpha_power

    def aggregate(self, theta_dk: Any, theta_aux_k: Any, t_k: int) -> bool:
        """Alg. 4 lines 12–19.  Returns True if the update was applied."""
        alpha = staleness_weight(self.version - t_k, self.max_delay,
                                 self.alpha_power)
        if alpha == 0.0:
            self.n_rejected += 1
            return False
        self.theta_d = tree_lerp(self.theta_d, theta_dk, alpha)
        self.theta_aux = tree_lerp(self.theta_aux, theta_aux_k, alpha)
        self.version += 1
        self.n_accepted += 1
        return True

    def snapshot(self):
        """(θ_d, θ̃_d, t) sent back to a device (Alg. 4 line 20)."""
        return self.theta_d, self.theta_aux, self.version


def staleness_weight(staleness: int, max_delay: int = 16,
                     alpha_power: float = 1.0) -> float:
    """Alg. 4's per-update weight: α = (staleness + 1)^-alpha_power, or 0
    when the update is older than the staleness cap D (line 13's skip).
    Shared by the host-side aggregator, the event simulator, and the
    control plane that feeds ``agg_weight`` into the jit'd hybrid step."""
    if staleness > max_delay:
        return 0.0
    return (1.0 / (staleness + 1.0)) ** alpha_power


def fedasync_update(global_tree, local_tree, staleness, alpha_power: float = 1.0):
    """Pure functional form (used inside jit for the datacenter step)."""
    alpha = (1.0 / (staleness + 1.0)) ** alpha_power
    return tree_lerp(global_tree, local_tree, alpha)
