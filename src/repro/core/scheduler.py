"""Task Scheduler (paper Alg. 2 & 3): model/activation queues + counters.

put():  models -> Q_model; activations -> Q_act[k]   (Alg. 2)
get():  models first (priority); else the activation queue of the device
        with the smallest consumption counter c_k      (Alg. 3)

The counter-based policy prevents fast devices from dominating server-side
training (Challenge 3).  A FIFO policy is included for the §6.5.2 ablation.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any


@dataclass
class Message:
    kind: str              # "model" | "activation"
    origin: int            # device id
    content: Any = None
    size_bytes: float = 0.0
    enqueued_at: float = 0.0


class TaskScheduler:
    """Counter-based scheduler (default) or FIFO (ablation)."""

    def __init__(self, n_devices: int, policy: str = "counter"):
        assert policy in ("counter", "fifo")
        self.policy = policy
        self.q_model: deque[Message] = deque()
        self.q_act: dict[int, deque[Message]] = {k: deque() for k in range(n_devices)}
        self.counters: dict[int, int] = {k: 0 for k in range(n_devices)}
        self._fifo_seq = 0
        self._arrival: deque[int] = deque()   # device order of activation arrivals

    # -- dynamic device membership (elastic) --
    def add_device(self, k: int):
        self.q_act.setdefault(k, deque())
        self.counters.setdefault(k, 0)

    def remove_device(self, k: int):
        # keep already-buffered activations (they still train); stop counters
        pass

    # -- Alg. 2 --
    def put(self, m: Message):
        if m.kind == "model":
            self.q_model.append(m)
        else:
            self.add_device(m.origin)
            self.q_act[m.origin].append(m)
            self._arrival.append(m.origin)

    # -- Alg. 3 --
    def get(self) -> Message | None:
        if self.q_model:
            return self.q_model.popleft()
        if self.policy == "fifo":
            while self._arrival:
                k = self._arrival.popleft()
                if self.q_act[k]:
                    self.counters[k] += 1
                    return self.q_act[k].popleft()
            return None
        # counter policy: argmin_k c_k over devices with pending activations
        pending = [k for k, q in self.q_act.items() if q]
        if not pending:
            return None
        k = min(pending, key=lambda d: (self.counters[d], d))
        self.counters[k] += 1
        # drop stale arrival-order entries lazily
        return self.q_act[k].popleft()

    # -- introspection --
    @property
    def total_buffered(self) -> int:
        return sum(len(q) for q in self.q_act.values())

    def buffered(self, k: int) -> int:
        return len(self.q_act.get(k, ()))

    @property
    def has_model(self) -> bool:
        return bool(self.q_model)

    @property
    def has_activation(self) -> bool:
        return any(self.q_act.values())
