"""Task Scheduler (paper Alg. 2 & 3): model/activation queues + counters.

put():  models -> Q_model; activations -> Q_act[k]   (Alg. 2)
get():  models first (priority); else the activation queue of the device
        with the smallest consumption counter c_k      (Alg. 3)

The counter-based policy prevents fast devices from dominating server-side
training (Challenge 3).  A FIFO policy is included for the §6.5.2 ablation.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any

from repro.analysis import sanitize as _san


@dataclass
class Message:
    kind: str              # "model" | "activation"
    origin: int            # device id
    content: Any = None
    size_bytes: float = 0.0
    enqueued_at: float = 0.0


class TaskScheduler:
    """Counter-based scheduler (default) or FIFO (ablation)."""

    def __init__(self, n_devices: int, policy: str = "counter"):
        if policy not in ("counter", "fifo"):
            raise ValueError(
                f"unknown scheduler policy {policy!r}; expected 'counter' "
                "or 'fifo'")
        self.policy = policy
        self.q_model: deque[Message] = deque()
        self.q_act: dict[int, deque[Message]] = {k: deque() for k in range(n_devices)}
        self.counters: dict[int, int] = {k: 0 for k in range(n_devices)}
        self._fifo_seq = 0
        self._arrival: deque[int] = deque()   # device order of activation arrivals
        self._removed: set[int] = set()       # departed, backlog still draining

    # -- dynamic device membership (elastic) --
    def add_device(self, k: int):
        if k in self._removed:                # rejoin starts with fresh history
            self._removed.discard(k)
            self.counters[k] = 0
        self.q_act.setdefault(k, deque())
        self.counters.setdefault(k, 0)
        if _san.TRACING:
            _san.emit("sched.add", sched=self, device=k)

    def remove_device(self, k: int):
        """Departure (§3.4.2): buffered activations are kept — they are
        valid training data and still drain through ``get`` — and while
        they drain the device keeps competing under its accumulated counter
        (zeroing it would hand the departed backlog top priority under the
        argmin policy).  Counter and queue are purged once drained; a
        rejoin (``add_device``) always restarts with fresh history."""
        drained = not self.q_act.get(k)
        if drained:
            self.q_act.pop(k, None)
            self.counters.pop(k, None)
            self._removed.discard(k)
        else:
            self._removed.add(k)
        if _san.TRACING:
            _san.emit("sched.remove", sched=self, device=k, drained=drained)

    # -- Alg. 2 --
    def put(self, m: Message):
        if m.kind == "model":
            self.q_model.append(m)
        else:
            self.add_device(m.origin)
            self.q_act[m.origin].append(m)
            if self.policy == "fifo":
                # only the FIFO policy replays arrival order; appending
                # under the counter policy would grow without bound
                self._arrival.append(m.origin)

    def _serve(self, k: int) -> Message:
        """Pop one activation of device k, count it, and fully purge a
        departed device once its backlog has drained."""
        msg = self.q_act[k].popleft()
        if k in self.counters:
            self.counters[k] += 1
        self._purge_if_drained(k)
        return msg

    def _purge_if_drained(self, k: int):
        if k in self._removed and not self.q_act.get(k):
            self.q_act.pop(k, None)
            self.counters.pop(k, None)
            self._removed.discard(k)
            if _san.TRACING:
                _san.emit("sched.purge", sched=self, device=k)

    # -- Alg. 3 --
    def get(self) -> Message | None:
        if self.q_model:
            return self.q_model.popleft()
        if self.policy == "fifo":
            while self._arrival:
                k = self._arrival.popleft()   # lazily drains stale entries
                if self.q_act.get(k):
                    return self._serve(k)
            return None
        # counter policy: argmin_k c_k over devices with pending activations
        pending = [k for k, q in self.q_act.items() if q]
        if not pending:
            return None
        k = min(pending, key=lambda d: (self.counters.get(d, 0), d))
        return self._serve(k)

    def drain_slot(self, s: Any, groups) -> None:
        """Datacenter slot-granular consumption: the mesh reads a whole ring
        slot, so every listed group's buffered contribution to slot ``s``
        is served in one go — popped, counted, and (under FIFO) its arrival
        entry retired.  Used by the control plane for co-resident
        contributions after ``get()`` picked the slot."""
        for g in groups:
            q = self.q_act.get(g)
            if not q:
                continue
            for m in list(q):
                if m.content == s:
                    q.remove(m)
                    if g in self.counters:
                        self.counters[g] += 1
                    if self.policy == "fifo":
                        # this is g's oldest live message (earlier arrivals
                        # are consumed once slot s reaches the front), so
                        # its entry is g's first in the arrival log
                        try:
                            self._arrival.remove(g)
                        except ValueError:
                            pass
                    break
            self._purge_if_drained(g)

    def withdraw_slot(self, s: Any, groups) -> None:
        """Spill-tier withdrawal: each listed group's buffered contribution
        to ring slot ``s`` leaves the scheduler's queues WITHOUT being
        counted as consumed — the payload moves to the host spill pool and
        its messages are re-``put`` on fill.  Under FIFO the arrival-log
        entry retired is the one MATCHING the withdrawn message (a group's
        arrival entries appear in its queue order, and eviction — unlike
        consumption — may take a newer message than the group's oldest),
        so unspilled contributions keep their arrival position; the
        spill/fill round-trip itself re-enqueues at the back of the
        arrival order (an approximation the counter policy, which orders
        by consumption alone, is immune to)."""
        for g in groups:
            q = self.q_act.get(g)
            if not q:
                continue
            for idx, m in enumerate(list(q)):
                if m.content == s:
                    q.remove(m)
                    if self.policy == "fifo":
                        self._drop_arrival(g, idx)
                    break
            self._purge_if_drained(g)

    def _drop_arrival(self, g: int, nth: int) -> None:
        """Delete the (nth+1)-th occurrence of ``g`` from the arrival log
        (the entry for g's queue position ``nth``)."""
        seen = 0
        for j, a in enumerate(self._arrival):
            if a == g:
                if seen == nth:
                    del self._arrival[j]
                    return
                seen += 1

    # -- introspection --
    @property
    def total_buffered(self) -> int:
        return sum(len(q) for q in self.q_act.values())

    def buffered(self, k: int) -> int:
        return len(self.q_act.get(k, ()))

    @property
    def has_model(self) -> bool:
        return bool(self.q_model)

    @property
    def has_activation(self) -> bool:
        return any(self.q_act.values())
