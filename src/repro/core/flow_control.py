"""Memory-bounded activation flow control (paper §3.4.1).

Server-side: a **global** buffering cap ω bounds Σ_k |Q_k^act| ≤ ω,
decoupling server memory from the number of devices (Eq. 3:
μ = μ_model + ω·μ_act, versus OAFL's Eq. 2: μ = (K+1)μ_model + K·μ_act).

Device-side: each device holds a Sender Status token.  After sending one
activation batch the Sender deactivates until the server grants a
'turn-on'.  The server grants tokens whenever the buffer (plus everything
already promised: in-flight sends and granted-but-unused tokens) is below
ω — so the cap holds as a **strict invariant**, never just in expectation::

    buffered + inflight + active_tokens <= omega        (always)

Grants are issued round-robin for fairness.  The controller is transport-
agnostic: the event simulator and the datacenter driver both drive it via
``can_send`` / ``mark_sent`` / ``on_enqueue`` / ``on_dequeue``.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class FlowController:
    omega: int                              # global activation cap ω
    sender_active: dict = field(default_factory=dict)   # device -> bool
    buffered: int = 0                       # Σ_k |Q_k^act| (server view)
    inflight: int = 0                       # sent-but-not-enqueued
    grants: list = field(default_factory=list)  # grant log (for tests)
    _rr: list = field(default_factory=list)     # round-robin order

    def register(self, k: int):
        """New device: sender starts inactive; a token is granted if the
        cap allows (so at most ω senders are ever simultaneously armed)."""
        if k in self.sender_active:
            return
        self.sender_active[k] = False
        self._rr.append(k)
        self._maybe_grant()

    def unregister(self, k: int):
        self.sender_active.pop(k, None)
        if k in self._rr:
            self._rr.remove(k)

    # -- device side --
    def can_send(self, k: int) -> bool:
        return self.sender_active.get(k, False)

    def mark_sent(self, k: int):
        """Device consumed its token -> becomes an in-flight send."""
        assert self.sender_active.get(k, False), f"device {k} sent without token"
        self.sender_active[k] = False
        self.inflight += 1

    # -- server side --
    def on_enqueue(self, k: int):
        self.inflight = max(0, self.inflight - 1)
        self.buffered += 1
        self._maybe_grant()

    def on_dequeue(self, k: int):
        self.buffered = max(0, self.buffered - 1)
        self._maybe_grant()

    def on_device_left(self, k: int):
        """A device dropped with a token or in-flight send: reclaim."""
        if self.sender_active.pop(k, None):
            pass
        if k in self._rr:
            self._rr.remove(k)
        self._maybe_grant()

    # -- invariant-preserving grant --
    @property
    def active_tokens(self) -> int:
        return sum(1 for v in self.sender_active.values() if v)

    @property
    def promised(self) -> int:
        return self.buffered + self.inflight + self.active_tokens

    def _maybe_grant(self):
        if not self._rr:
            return
        n = len(self._rr)
        scanned = 0
        while self.promised < self.omega and scanned < n:
            k = self._rr.pop(0)      # true round-robin: a scanned device
            self._rr.append(k)       # moves to the back of the grant queue
            scanned += 1
            if not self.sender_active.get(k, False):
                self.sender_active[k] = True
                self.grants.append(k)
                scanned = 0  # re-scan: more room may remain

    @property
    def within_cap(self) -> bool:
        return self.buffered <= self.omega and self.promised <= self.omega
