"""Memory-bounded activation flow control (paper §3.4.1).

Server-side: a **global** buffering cap ω bounds Σ_k |Q_k^act| ≤ ω,
decoupling server memory from the number of devices (Eq. 3:
μ = μ_model + ω·μ_act, versus OAFL's Eq. 2: μ = (K+1)μ_model + K·μ_act).

Device-side: each device holds a Sender Status token.  After sending one
activation batch the Sender deactivates until the server grants a
'turn-on'.  The server grants tokens whenever the buffer (plus everything
already promised: in-flight sends and granted-but-unused tokens) is below
ω — so the cap holds as a **strict invariant**, never just in expectation::

    buffered + inflight + active_tokens <= omega        (always)

Grants are issued round-robin for fairness.  The controller is transport-
agnostic: the event simulator and the datacenter driver both drive it via
``can_send`` / ``mark_sent`` / ``on_enqueue`` / ``on_dequeue``.

Tiered budget (server memory manager, ``repro.memory``): with
``pool_cap > 0`` the server backs the ω mesh-resident slots with a host
spill pool, so admission is accounted against the TOTAL tiered budget::

    buffered + inflight + active_tokens <= omega + pool_cap      (always)

``omega`` stays the mesh (tier-0) capacity; admissions beyond it are
spill-tier residents (counted by ``n_spilled``; ``n_filled`` counts the
dequeues that promote a spilled unit back toward the mesh tier).  With
``pool_cap == 0`` behavior is bit-for-bit the strict Eq. 3 controller.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

from repro.analysis import sanitize as _san


@dataclass
class FlowController:
    omega: int                              # mesh-tier activation cap ω
    pool_cap: int = 0                       # spill-tier budget (flow units)
    sender_active: dict = field(default_factory=dict)   # device -> bool
    buffered: int = 0                       # Σ_k |Q_k^act| (server view)
    inflight_by: dict = field(default_factory=dict)  # device -> in-flight sends
    n_spilled: int = 0                      # admissions beyond the mesh tier
    n_filled: int = 0                       # spilled units promoted on dequeue
    # bounded debug log of recent grants (unbounded growth would be the
    # same leak class as the scheduler's arrival log on long runs)
    grants: deque = field(default_factory=lambda: deque(maxlen=256))
    _rr: list = field(default_factory=list)     # round-robin order

    # test-only mutation hook (no annotation -> NOT a dataclass field):
    # True re-introduces PR 1's leak — on_device_left stops reclaiming the
    # departed device's token/in-flight budget, so the sanitizer's
    # flow-token-conservation invariant must fire.  Never set outside tests.
    _test_skip_reclaim = False

    @property
    def cap(self) -> int:
        """Total tiered admission budget: mesh ring + host spill pool."""
        return self.omega + self.pool_cap

    def register(self, k: int):
        """New device: sender starts inactive; a token is granted if the
        cap allows (so at most ω + pool_cap senders are ever
        simultaneously armed — exactly ω with the spill tier off)."""
        if k in self.sender_active:
            return
        self.sender_active[k] = False
        self._rr.append(k)
        self._maybe_grant()
        if _san.TRACING:
            _san.emit("flow.register", flow=self, device=k)

    def unregister(self, k: int):
        self.on_device_left(k)

    # -- device side --
    def can_send(self, k: int) -> bool:
        return self.sender_active.get(k, False)

    def mark_sent(self, k: int):
        """Device consumed its token -> becomes an in-flight send."""
        if not self.sender_active.get(k, False):
            raise RuntimeError(
                f"device {k} sent without a token (buffered={self.buffered}, "
                f"inflight={self.inflight}, tokens={self.active_tokens}, "
                f"cap={self.cap})")
        self.sender_active[k] = False
        self.inflight_by[k] = self.inflight_by.get(k, 0) + 1
        if _san.TRACING:
            _san.emit("flow.sent", flow=self, device=k)

    def inflight_of(self, k: int) -> int:
        return self.inflight_by.get(k, 0)

    # -- server side --
    def on_enqueue(self, k: int) -> bool:
        """Admit an arriving activation batch.  Returns False for an
        unaccounted arrival — the sender dropped (its in-flight budget was
        reclaimed) and the packet landed anyway; the caller must drop it,
        otherwise the ω cap would be violated retroactively."""
        n = self.inflight_by.get(k, 0)
        accepted = n > 0
        if accepted:
            if n == 1:
                self.inflight_by.pop(k)
            else:
                self.inflight_by[k] = n - 1
            self.buffered += 1
            if self.buffered > self.omega:
                self.n_spilled += 1    # admitted into the spill tier
            self._maybe_grant()
        if _san.TRACING:
            _san.emit("flow.enqueue", flow=self, device=k, accepted=accepted,
                      registered=k in self.sender_active)
        return accepted

    def on_dequeue(self, k: int):
        if self.buffered > self.omega:
            self.n_filled += 1         # a spilled unit moves up a tier
        self.buffered = max(0, self.buffered - 1)
        self._maybe_grant()
        if _san.TRACING:
            _san.emit("flow.dequeue", flow=self, device=k)

    def on_quarantined(self, k: int):
        """An arriving batch failed validation (poison quarantine): the
        send happened — ``mark_sent`` moved a token into in-flight — but
        the payload must never be buffered.  Withdraw exactly one in-flight
        unit and re-grant, so Eq. 3 conservation holds with the quarantined
        unit simply returned to the budget (``buffered`` is untouched: a
        quarantined batch never entered a tier, so the spill/fill counters
        stay exact)."""
        n = self.inflight_by.get(k, 0)
        if n == 1:
            self.inflight_by.pop(k)
        elif n > 1:
            self.inflight_by[k] = n - 1
        self._maybe_grant()
        if _san.TRACING:
            _san.emit("flow.quarantine", flow=self, device=k,
                      withdrawn=n > 0)

    def on_device_left(self, k: int):
        """A device dropped with a token or an in-flight send: reclaim both,
        so ``promised`` never stays inflated under churn (otherwise grants
        starve as departed devices permanently eat into ω)."""
        if not self._test_skip_reclaim:
            self.sender_active.pop(k, None)
            self.inflight_by.pop(k, None)
            if k in self._rr:
                self._rr.remove(k)
        self._maybe_grant()
        if _san.TRACING:
            _san.emit("flow.device_left", flow=self, device=k)

    # -- invariant-preserving grant --
    @property
    def inflight(self) -> int:
        return sum(self.inflight_by.values())

    @property
    def active_tokens(self) -> int:
        return sum(1 for v in self.sender_active.values() if v)

    @property
    def promised(self) -> int:
        return self.buffered + self.inflight + self.active_tokens

    def _maybe_grant(self):
        if not self._rr:
            return
        n = len(self._rr)
        scanned = 0
        while self.promised < self.cap and scanned < n:
            k = self._rr.pop(0)      # true round-robin: a scanned device
            self._rr.append(k)       # moves to the back of the grant queue
            scanned += 1
            if not self.sender_active.get(k, False):
                self.sender_active[k] = True
                self.grants.append(k)
                scanned = 0  # re-scan: more room may remain
                if _san.TRACING:
                    _san.emit("flow.grant", flow=self, device=k)

    @property
    def within_cap(self) -> bool:
        """Σ buffered (and everything promised) within the TOTAL tiered
        budget ω + pool_cap; with pool_cap=0 this is the strict Eq. 3 ω."""
        return self.buffered <= self.cap and self.promised <= self.cap
