"""Host control plane: Alg. 2–4 driving the jit'd hybrid step.

This module is the bridge between the paper's three host-side algorithms —
the Task Scheduler (Alg. 2/3, ``scheduler.py``), memory-bounded activation
flow control (§3.4.1, ``flow_control.py``) and staleness-weighted async
aggregation (Alg. 4, ``aggregator.py``) — and the datacenter-scale pjit
program in ``fedopt_step.py``.  The on-mesh step is pure and shape-static;
everything data-dependent (who may send, which buffered batch the server
consumes, how stale each group's model is) is planned here on the host and
shipped into the step as small dense batch fields.

Datacenter mapping
------------------
An FL "device" is a *device group* (one dp index of the mesh).  One jit
step is one round of H micro-iterations.  The activation hand-off is an
ω-deep ring of **slots**; one slot holds one scheduled activation batch
(the combined emission of all groups for one micro-iteration — μ_act in
Eq. 3 is measured at this granularity, so server activation memory is
exactly ω slots regardless of the number of groups, versus OAFL's K-linear
growth).  Within a slot, each group's rows are an individually flow-
controlled contribution: a group needs a sender token to refresh its rows
(budget ω slots × G rows-groups), and the Task Scheduler's counters track
per-group server consumption for the Alg. 3 fairness policy.

Per round, :meth:`ControlPlane.plan_round` emits a :class:`RoundPlan`:

    read_slot[h]    slot the server trains on at micro-iteration h —
                    chosen by the counter policy (argmin consumption over
                    groups with live contributions, Alg. 3) or FIFO
    write_slot[h]   slot the devices' emission lands in (a free ring slot)
    send_mask[h,g]  1 if group g holds a token and ships its rows
    agg_weight[g]   α_g = (staleness_g + 1)^-alpha_power, 0 beyond the
                    staleness cap D or for inactive groups (Alg. 4 l.13/16)
    bcast_mask[g]   1 if group g receives the aggregated global model back
                    (Alg. 4 line 20 — participants only; dropped groups
                    keep their retained per-group state instead of being
                    resynced by the broadcast)

plus ``retire``/``restore`` group lists: a group that just dropped must
have its dev/aux params gathered into the host :class:`RetentionStore`
(``retire``) and a rejoining group's retained params scattered back
on-mesh (``restore``) before the round is dispatched — the round executor
(``core/executor.py``) performs the actual transfers.

Tiered memory (``repro.memory``): with ``pool_cap > 0`` the ω-ring is
tier 0 of a two-tier store — when every ring slot holds unconsumed
contributions, ``plan_round`` no longer gates all sends; it plans an
eviction (policy-chosen victim slot → host spill pool) so the write can
land, and fills pooled entries back into free slots at the next round
boundary.  The moves ride the plan as ``spill``/``fill`` lists (slot ↔
pool-key pairs); the executor performs the actual host↔mesh transfers
against an :class:`~repro.memory.store.ActivationStore` BEFORE the round
is dispatched, so every spill reflects pre-round ring content (a slot
written this round can never be a victim — its content does not exist at
the boundary).  Flow-control admission runs against the TOTAL tiered
budget ω + pool_cap, so Σ buffered ≤ (ω + pool_cap) · units is the new
``within_cap`` invariant; with ``pool_cap == 0`` every path reduces
bit-for-bit to the hard-ω behavior.

Knobs: ``omega`` (ring depth / Eq. 3 cap), ``pool_cap`` (host spill tier
depth in slots), ``eviction`` ("share" | "lru", see ``repro.memory``),
``policy`` ("counter" | "fifo"), ``max_delay`` (D), ``alpha_power``
(staleness exponent).

The same class also fronts the event simulator (``simulation.py``): there
the scheduler/flow units are per-device activation batches and the
simulator drives them in event order; :func:`ControlPlane.for_sim` builds
that configuration.  Benchmarks assert ``peak_buffered <= omega`` through
either path.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass

import numpy as np

from repro.analysis import sanitize as _san
from repro.memory.policy import make_eviction_policy
from repro.obs import trace as _tr
from repro.obs.clock import now as _now

from .aggregator import staleness_weight
from .flow_control import FlowController
from .scheduler import Message, TaskScheduler


@dataclass(frozen=True)
class RoundPlan:
    """One round's host-planned schedule, consumed by the jit'd step."""
    read_slot: np.ndarray    # (H,) int32
    write_slot: np.ndarray   # (H,) int32
    send_mask: np.ndarray    # (H, G) float32
    agg_weight: np.ndarray   # (G,) float32
    bcast_mask: np.ndarray = None   # (G,) float32; None -> all receive
    retire: tuple = ()       # groups that just dropped: gather to retention
    restore: tuple = ()      # rejoining groups: scatter retained state back
    # tiered-store moves, performed by the executor at the round boundary
    # (fills BEFORE spills, so the pool never transiently exceeds its cap)
    fill: tuple = ()         # (pool_key, slot): pool entry -> free ring slot
    spill: tuple = ()        # (slot, pool_key): evicted ring slot -> pool
    # prefetch is PLAN-NEUTRAL: pool keys the fill policy would pick next
    # (the executor's in-flight window is the lookahead horizon) — the
    # store pre-decodes their payloads so the eventual fill is a copy,
    # not a decode.  No bookkeeping moves; plans are bit-identical with
    # lookahead on or off.
    prefetch: tuple = ()     # pool keys to pre-stage host-side

    def batch_fields(self) -> dict:
        """The plan as jit-step batch fields (see fedopt_step.SCHEDULE_KEYS
        + the per-group ``agg_weight``/``bcast_mask``)."""
        import jax.numpy as jnp
        bcast = self.bcast_mask if self.bcast_mask is not None else \
            np.ones(self.send_mask.shape[1], np.float32)
        return {"read_slot": jnp.asarray(self.read_slot, jnp.int32),
                "write_slot": jnp.asarray(self.write_slot, jnp.int32),
                "send_mask": jnp.asarray(self.send_mask, jnp.float32),
                "agg_weight": jnp.asarray(self.agg_weight, jnp.float32),
                "bcast_mask": jnp.asarray(bcast, jnp.float32)}


class RetentionStore:
    """Host-side per-group dev/aux retention for dropped groups (§3.4.2).

    When a group leaves mid-run its last-synced device-side params are held
    here (host copies) together with the model version they correspond to,
    so the group rejoins from its OWN state at its recorded staleness
    instead of being resynced by the aggregation broadcast.  Metadata
    (which groups, at what version) is JSON-able and rides the checkpoint
    store's ``tree.json``; the params themselves ride the snapshot's
    ``extras.npz`` (see ``checkpoint/store.py``).
    """

    def __init__(self):
        self._held: dict[int, dict] = {}   # g -> {"params": pytree|None,
                                           #       "version": int}

    def retain(self, g: int, params, version: int):
        self._held[int(g)] = {"params": params, "version": int(version)}

    def release(self, g: int) -> dict:
        return self._held.pop(int(g))

    def __contains__(self, g) -> bool:
        return int(g) in self._held

    def __len__(self) -> int:
        return len(self._held)

    @property
    def groups(self) -> list[int]:
        return sorted(self._held)

    def version_of(self, g: int) -> int:
        return self._held[int(g)]["version"]

    def params_of(self, g: int):
        return self._held[int(g)]["params"]

    # -- checkpoint riding --
    def meta_dict(self) -> dict:
        """JSON-able part: which groups are held, at what version."""
        return {"versions": {str(g): e["version"]
                             for g, e in self._held.items()}}

    def load_meta(self, meta: dict):
        """Restore held-group metadata; params arrive via load_arrays."""
        self._held = {int(g): {"params": None, "version": int(v)}
                      for g, v in meta.get("versions", {}).items()}

    def arrays(self) -> dict:
        """The retained params as one pytree keyed by group (checkpoint
        extras payload); empty dict when nothing is held."""
        return {str(g): e["params"] for g, e in self._held.items()}

    def load_arrays(self, tree: dict):
        for g, params in tree.items():
            if int(g) not in self._held:
                raise KeyError(
                    f"retention arrays for group {g} have no matching "
                    "metadata entry — load_meta/load_state_dict first")
            self._held[int(g)]["params"] = params


class ControlPlane:
    """TaskScheduler + FlowController + staleness accounting, round-planned.

    ``unit`` is the flow-control granularity: "group" for the pod path
    (one unit = one group's rows in a slot; token budget ω·G) and "device"
    for the event simulator (one unit = one device activation batch;
    budget ω, the paper's strict Eq. 3 bookkeeping).
    """

    def __init__(self, n_groups: int, omega: int, H: int = 1, *,
                 policy: str = "counter", max_delay: int = 16,
                 alpha_power: float = 1.0, unit: str = "group",
                 pool_cap: int = 0, eviction: str = "share"):
        if omega < 1 or n_groups < 1:
            raise ValueError(
                f"need omega >= 1 and n_groups >= 1, got omega={omega}, "
                f"n_groups={n_groups} (ω is the Eq. 3 activation cap)")
        if pool_cap < 0:
            raise ValueError(f"pool_cap must be >= 0, got {pool_cap}")
        if unit not in ("group", "device"):
            raise ValueError(
                f"unknown flow unit {unit!r}; expected 'group' (pod path) "
                "or 'device' (event simulator)")
        self.G = n_groups
        self.omega = omega
        self.H = H
        self.max_delay = max_delay
        self.alpha_power = alpha_power
        self.unit = unit
        self.pool_cap = pool_cap
        self.mem_policy = make_eviction_policy(eviction)
        self.scheduler = TaskScheduler(n_groups, policy=policy)
        per_unit = n_groups if unit == "group" else 1
        self.flow = FlowController(omega=omega * per_unit,
                                   pool_cap=pool_cap * per_unit)
        for g in range(n_groups):
            self.flow.register(g)
        self.versions = np.zeros(n_groups, np.int64)   # t_g
        self.version = 0                               # t (global model)
        self.retention = RetentionStore()
        self.prev_active = np.ones(n_groups, bool)     # last round's roster
        self.n_accepted = 0
        self.n_rejected = 0
        self.peak_buffered = 0        # peak Σ|Q_act| in flow units
        self.peak_live_slots = 0      # peak occupied ring slots (pod path)
        self._slot_groups = [set() for _ in range(omega)]
        self._next_write = 0
        self._last_read = 0
        # -- spill tier (pod path; slot granularity) --
        self._pool: dict[int, tuple] = {}   # pool key -> contributor groups
        self._next_pool_key = 0
        self._slot_touch = [0] * omega      # last tick written/filled (LRU)
        self._tick = 0
        self.n_spills = 0
        self.n_fills = 0
        self.peak_pool = 0                  # peak occupied pool entries

    @classmethod
    def for_sim(cls, n_devices: int, omega: int, **kw):
        """Control plane for the event simulator: per-device flow units so
        Σ_k |Q_k^act| ≤ ω holds exactly as written in Eq. 3."""
        return cls(n_devices, omega, unit="device", **kw)

    # ------------------------------------------------------------------
    # pod path: plan one round of H micro-iterations
    # ------------------------------------------------------------------

    def plan_round(self, active=None, produce=None, reads=None, *,
                   lookahead: int = 0) -> RoundPlan:
        """Plan H micro-iterations and commit the bookkeeping.

        active : (G,) bool — groups participating in this round (drive
            aggregation weights; inactive groups neither send nor count).
        produce : (H, G) bool — which groups have a fresh emission at each
            micro-iteration (straggler profile); default: active every h.
        reads : (H,) bool — micro-iterations at which the server consumes a
            new scheduled batch; default all (lockstep server).  A False
            entry re-reads the last consumed slot (the server never idles —
            Fig. 1(d) — but consumes no new buffered batch).
        lookahead : int — prefetch horizon (the executor's in-flight
            window): the ``lookahead`` pool entries the fill policy ranks
            highest AFTER this round's fills ride the plan as
            ``prefetch`` so the store can pre-decode them.  Strictly
            plan-neutral: schedules, moves and bookkeeping are
            bit-identical for any value.

        The plan is deterministic, and the bookkeeping (scheduler counters,
        flow tokens, peak buffers) is committed immediately: in the lockstep
        datacenter mapping the mesh executes exactly this schedule.
        """
        tp0 = _now() if _tr.TRACING else 0.0
        G, H = self.G, self.H
        active = np.ones(G, bool) if active is None else \
            np.asarray(active, bool)
        produce = np.tile(active, (H, 1)) if produce is None else \
            np.asarray(produce, bool) & active[None, :]
        reads = np.ones(H, bool) if reads is None else np.asarray(reads, bool)

        # roster transitions: a group that just left must be retained (its
        # current dev/aux gathered to the host store) and a returning group
        # restored from retention, both BEFORE the round is dispatched
        retire = tuple(int(g)
                       for g in np.flatnonzero(self.prev_active & ~active))
        restore = tuple(int(g)
                        for g in np.flatnonzero(~self.prev_active & active)
                        if int(g) in self.retention)
        self.prev_active = active.copy()

        # -- tiered store: round-boundary moves.  Fills first (pooled
        #    entries return to free ring slots, scheduler-priority order);
        #    spills are planned lazily by _plan_write when the ring is
        #    full.  Both are executed host↔mesh BEFORE dispatch, so only
        #    pre-round ring content may spill (see _spill_for_write).
        self._tick += 1
        fill = self._plan_fills()
        self._round_filled = {s for _, s in fill}
        self._round_written: set[int] = set()
        self._round_spills: list[tuple[int, int]] = []

        read_slot = np.zeros(H, np.int32)
        write_slot = np.zeros(H, np.int32)
        send_mask = np.zeros((H, G), np.float32)

        for h in range(H):
            # -- server read first: the mesh consumes the ring state from
            #    before this micro-iteration's write --
            read_slot[h] = self._plan_read(consume=bool(reads[h]))
            # -- then the device emission lands --
            write_slot[h] = self._plan_write(produce[h], send_mask[h])

        plan = RoundPlan(read_slot=read_slot, write_slot=write_slot,
                         send_mask=send_mask,
                         agg_weight=self.agg_weights(active),
                         bcast_mask=active.astype(np.float32),
                         retire=retire, restore=restore,
                         fill=fill, spill=tuple(self._round_spills),
                         prefetch=self._plan_prefetch(lookahead))
        if _san.TRACING:
            _san.emit("cp.plan", cp=self, plan=plan,
                      version=int(self.version),
                      live_slots=self.live_slots, pool_live=self.pool_live)
        if _tr.TRACING:
            _tr.emit_span("host/control", "plan_round", tp0, _now(),
                          version=int(self.version))
        return plan

    def retain_group(self, g: int, params):
        """Hold a dropped group's dev/aux params at its last-synced version
        (the executor supplies the gathered host copies)."""
        self.retention.retain(g, params, version=int(self.versions[g]))

    def release_group(self, g: int) -> dict:
        """Pop a rejoining group's retained entry ({"params", "version"})."""
        return self.retention.release(g)

    def _plan_read(self, consume: bool) -> int:
        """Pick the slot the server trains on (Alg. 3 at slot granularity:
        the slot containing the least-served group's contribution)."""
        if not consume or not self.scheduler.has_activation:
            # cold start or a stalled server tick: replay stale (already
            # consumed or zero) content — scan for a slot with no live
            # contributions so unconsumed rows are not trained unaccounted
            for d in range(self.omega):
                s = (self._last_read + d) % self.omega
                if not self._slot_groups[s]:
                    return s
            # ring fully live (stall long enough for writes to fill all ω
            # slots): replay the last consumed position; its rows are also
            # trained when actually consumed — a bounded pipeline-bubble
            # duplicate, not a consumption event (counters record Alg. 3
            # scheduling decisions, not stalled re-processing)
            return self._last_read
        msg = self.scheduler.get()           # counter/FIFO policy pick
        s = msg.content
        # the mesh consumes the whole slot: dequeue every co-resident
        # contribution and count it against its group
        contributors = sorted(self._slot_groups[s])
        self.scheduler.drain_slot(s, [g for g in contributors
                                      if g != msg.origin])
        for g in contributors:
            self.flow.on_dequeue(g)
        self._slot_groups[s].clear()
        self._last_read = s
        return s

    def _plan_write(self, offer: np.ndarray, mask_row: np.ndarray) -> int:
        """Allocate a free ring slot and grant sends into it.  When every
        slot still holds unconsumed contributions (buffer full) and the
        spill pool has room, a policy-chosen victim slot is evicted to the
        host tier so the write can land; only when the TOTAL tiered budget
        is exhausted does nobody send — the write is then a masked no-op
        on the mesh, which is exactly the ω + pool_cap cap."""
        # token-holding offering groups ship their rows, least-served first
        # (counter order, so scarcity favors underserved groups — Alg. 3)
        order = [int(g) for g in
                 sorted(np.flatnonzero(offer),
                        key=lambda g: (self.scheduler.counters.get(g, 0), g))
                 if self.flow.can_send(g)]
        w = self._free_slot()
        if w is None and order:
            w = self._spill_for_write()      # evict to the host tier
        if w is None:
            return int(self._next_write)     # all-zero mask row: no-op write
        for g in order:
            self.flow.mark_sent(g)
            self.flow.on_enqueue(g)          # lockstep: arrival is immediate
            self.scheduler.put(Message("activation", g, content=w))
            self._slot_groups[w].add(g)
            mask_row[g] = 1.0
        if self._slot_groups[w]:
            self._next_write = (w + 1) % self.omega
            self._round_written.add(w)
            self._slot_touch[w] = self._tick
        self.peak_buffered = max(self.peak_buffered, self.flow.buffered)
        self.peak_live_slots = max(self.peak_live_slots, self.live_slots)
        return w

    def _free_slot(self) -> int | None:
        for d in range(self.omega):
            s = (self._next_write + d) % self.omega
            if not self._slot_groups[s]:
                return s
        return None

    # ------------------------------------------------------------------
    # tiered store planning (repro.memory; pod path, slot granularity)
    # ------------------------------------------------------------------

    def _plan_fills(self) -> tuple:
        """Move pooled entries back into free ring slots at the round
        boundary, most-scheduler-wanted first (policy ``fill_order``).
        Re-``put`` each contribution so Alg. 3 can serve it this round."""
        if not self._pool:
            return ()
        free = [s for s in range(self.omega) if not self._slot_groups[s]]
        if not free:
            # a stalled full ring is the pool's steady state — skip the
            # O(pool·G) policy ranking when nothing could be filled anyway
            return ()
        order = self.mem_policy.fill_order(
            list(self._pool), groups_of=lambda k: self._pool[k],
            share=self.consumption_share)
        moves = []
        for key, s in zip(order, free):
            groups = self._pool.pop(key)
            self._slot_groups[s] = set(groups)
            self._slot_touch[s] = self._tick
            for g in groups:
                self.scheduler.put(Message("activation", int(g),
                                           content=int(s)))
            moves.append((int(key), int(s)))
            self.n_fills += 1
        return tuple(moves)

    def _plan_prefetch(self, lookahead: int) -> tuple:
        """The ``lookahead`` pool entries the fill policy ranks highest in
        the post-round pool — what the next boundaries' fills will want.
        Ranking reuses the SAME pure ``fill_order`` the fills use, and
        nothing here mutates scheduler/flow/pool state: prefetch is
        advisory staging, never a planning decision."""
        if lookahead <= 0 or not self._pool:
            return ()
        order = self.mem_policy.fill_order(
            list(self._pool), groups_of=lambda k: self._pool[k],
            share=self.consumption_share)
        return tuple(int(k) for k in order[:lookahead])

    def _spill_for_write(self) -> int | None:
        """Evict one live ring slot to the host pool, freeing it for this
        write.  Victims must hold PRE-round content (the physical spill
        happens before dispatch): slots written this round are ineligible;
        slots filled this round are eligible only as a last resort (the
        executor runs fills before spills, so the round trip is
        consistent, just wasted bandwidth the policies avoid)."""
        if len(self._pool) >= self.pool_cap:
            return None
        live = [s for s in range(self.omega)
                if self._slot_groups[s] and s not in self._round_written]
        candidates = [s for s in live if s not in self._round_filled] or live
        if not candidates:
            return None
        s = self.mem_policy.victim(
            candidates, groups_of=lambda t: self._slot_groups[t],
            share=self.consumption_share, touch=self._slot_touch)
        key = self._next_pool_key
        self._next_pool_key += 1
        groups = tuple(sorted(self._slot_groups[s]))
        # the buffered contributions follow the payload to the host tier:
        # withdrawn from the scheduler (no consumption counted), re-put on
        # fill; flow budget stays held — they are still buffered server-side
        self.scheduler.withdraw_slot(s, groups)
        self._pool[key] = groups
        self._slot_groups[s].clear()
        self._round_spills.append((int(s), int(key)))
        self.n_spills += 1
        self.peak_pool = max(self.peak_pool, len(self._pool))
        return s

    # ------------------------------------------------------------------
    # staleness-weighted aggregation bookkeeping (Alg. 4)
    # ------------------------------------------------------------------

    def agg_weights(self, active=None) -> np.ndarray:
        """Per-group α from real staleness counters (Alg. 4 lines 13/16).
        May be all-zero (every update rejected as too stale / absent); the
        on-mesh aggregation treats that as "keep current params", matching
        Alg. 4's skip."""
        active = np.ones(self.G, bool) if active is None else \
            np.asarray(active, bool)
        return np.array([staleness_weight(self.version - int(self.versions[g]),
                                          self.max_delay, self.alpha_power)
                         if active[g] else 0.0 for g in range(self.G)],
                        np.float32)

    def finish_round(self, active=None):
        """End-of-round aggregation accounting: in the lockstep mapping all
        participating groups' models arrive together, so one round = one
        aggregation event (version +1).  Accepted groups (staleness ≤ D)
        sync to the new global model; rejected/absent ones drift further
        (Alg. 4 lines 12–20 telescoped per round)."""
        tf0 = _now() if _tr.TRACING else 0.0
        active = np.ones(self.G, bool) if active is None else \
            np.asarray(active, bool)
        t = self.version
        accepted = [g for g in np.flatnonzero(active)
                    if staleness_weight(t - int(self.versions[g]),
                                        self.max_delay,
                                        self.alpha_power) > 0.0]
        self.n_accepted += len(accepted)
        self.n_rejected += int(active.sum()) - len(accepted)
        if not accepted:
            # every update rejected: no aggregation event happened on-mesh
            # (all-zero weights keep current params), nobody resyncs
            if _san.TRACING:
                _san.emit("cp.finish", cp=self, version_before=int(t),
                          version_after=int(t), n_accepted=0)
            if _tr.TRACING:
                _tr.emit_span("host/control", "finish_round", tf0, _now(),
                              n_accepted=0)
            return
        self.version = t + 1
        for g in np.flatnonzero(active):
            # Alg. 4 line 20: every participant receives the global model
            # back, so even a rejected (too-stale) group restarts fresh —
            # its delta was dropped (weight 0), not its membership
            self.versions[g] = self.version
        if _san.TRACING:
            _san.emit("cp.finish", cp=self, version_before=int(t),
                      version_after=int(self.version),
                      n_accepted=len(accepted))
        if _tr.TRACING:
            _tr.emit_span("host/control", "finish_round", tf0, _now(),
                          n_accepted=len(accepted))

    # -- event-simulator staleness hooks (per-arrival, version always
    #    advances: the simulator counts every aggregation event) --
    def aggregate_arrival(self, k: int, t_k: int) -> float:
        """One device model arrived (sim path): returns its α (0 =
        rejected as too stale, Alg. 4 line 13)."""
        t = self.version
        w = staleness_weight(t - int(t_k), self.max_delay,
                             self.alpha_power)
        if w > 0.0:
            self.n_accepted += 1
        else:
            self.n_rejected += 1
        self.version = t + 1
        if _san.TRACING:
            _san.emit("cp.arrival", cp=self, device=int(k), t_k=int(t_k),
                      weight=float(w), version_before=int(t))
        return w

    def device_synced(self, k: int):
        """Device k received the global model back (Alg. 4 line 20)."""
        self.versions[k] = self.version
        if _san.TRACING:
            _san.emit("cp.synced", cp=self, device=int(k),
                      version=int(self.version))

    # ------------------------------------------------------------------
    # introspection / invariants
    # ------------------------------------------------------------------

    @property
    def live_slots(self) -> int:
        return sum(1 for s in self._slot_groups if s)

    @property
    def slot_occupancy(self) -> list[list[int]]:
        """Per-ring-slot live contributions (group ids), slot order."""
        return [sorted(s) for s in self._slot_groups]

    @property
    def consumption(self) -> dict[int, int]:
        """Per-group server-consumption counters (Alg. 3 state)."""
        return dict(self.scheduler.counters)

    def consumption_share(self, g: int) -> float:
        total = sum(self.scheduler.counters.values())
        return self.scheduler.counters.get(g, 0) / max(total, 1)

    @property
    def pool_live(self) -> int:
        """Occupied host spill-pool entries (pod path)."""
        return len(self._pool)

    @property
    def pool_occupancy(self) -> dict:
        """Pool key -> contributor groups, key order."""
        return {k: list(self._pool[k]) for k in sorted(self._pool)}

    @property
    def within_cap(self) -> bool:
        """Σ|Q_act| ≤ ω + pool_cap in flow units AND live ring slots ≤ ω
        AND occupied pool entries ≤ pool_cap (the tiered Eq. 3)."""
        return (self.flow.within_cap and self.live_slots <= self.omega
                and len(self._pool) <= self.pool_cap)

    def note_buffered(self, n: int):
        """Record an externally-observed buffer occupancy (sim path)."""
        self.peak_buffered = max(self.peak_buffered, n)

    def memory_summary(self) -> dict:
        """JSON-able tier accounting: spill/fill/eviction counts + peaks.

        Pod path counts at SLOT granularity (one spill = one ring slot of
        all its contributions); the event-simulator path has no ring, so
        its counts come from the flow controller at unit granularity
        (one spill = one device activation batch admitted past ω)."""
        out = {"omega": self.omega, "pool_cap": self.pool_cap,
               "eviction": self.mem_policy.name,
               "peak_buffered": int(self.peak_buffered)}
        if self.unit == "group":
            # every pod-path spill IS a victim selection, so evictions
            # is derived, not a second counter to keep in sync
            out.update(spills=self.n_spills, fills=self.n_fills,
                       evictions=self.n_spills,
                       pool_live=len(self._pool),
                       peak_pool=int(self.peak_pool),
                       peak_live_slots=int(self.peak_live_slots))
        else:
            out.update(spills=self.flow.n_spilled, fills=self.flow.n_filled,
                       evictions=0)
        return out

    # ------------------------------------------------------------------
    # checkpointing: the host plan must survive restarts together with the
    # on-mesh ring it describes, or staleness history and slot occupancy
    # silently reset on resume
    # ------------------------------------------------------------------

    def state_dict(self) -> dict:
        """JSON-able snapshot of the planning state (pod path).  v3: adds
        the spill-tier bookkeeping (pool occupancy, eviction policy, tier
        counters); the spilled payloads themselves ride the checkpoint's
        ``extras.npz`` via the driver's ActivationStore, exactly like the
        retention params."""
        return {
            "version_tag": 3,
            "policy": self.scheduler.policy,
            "versions": [int(v) for v in self.versions],
            "version": int(self.version),
            "counters": {str(k): int(v)
                         for k, v in self.scheduler.counters.items()},
            "queues": {str(g): [None if m.content is None else int(m.content)
                                for m in q]
                       for g, q in self.scheduler.q_act.items()},
            "arrival": [int(g) for g in self.scheduler._arrival],
            "slot_groups": [sorted(s) for s in self._slot_groups],
            "tokens": {str(g): bool(v)
                       for g, v in self.flow.sender_active.items()},
            "rr": [int(g) for g in self.flow._rr],
            "next_write": int(self._next_write),
            "last_read": int(self._last_read),
            "n_accepted": int(self.n_accepted),
            "n_rejected": int(self.n_rejected),
            "peak_buffered": int(self.peak_buffered),
            "peak_live_slots": int(self.peak_live_slots),
            "prev_active": [bool(a) for a in self.prev_active],
            "retention": self.retention.meta_dict(),
            "pool_cap": int(self.pool_cap),
            "eviction": self.mem_policy.name,
            "pool": {str(k): [int(g) for g in gs]
                     for k, gs in self._pool.items()},
            "next_pool_key": int(self._next_pool_key),
            "slot_touch": [int(t) for t in self._slot_touch],
            "tick": int(self._tick),
            "n_spills": int(self.n_spills),
            "n_fills": int(self.n_fills),
            "peak_pool": int(self.peak_pool),
        }

    def load_state_dict(self, sd: dict):
        """Restore a :meth:`state_dict` snapshot: queue contents (exact
        order), counters, slot occupancy, staleness versions, and the flow
        budget implied by the live contributions."""
        if len(sd["slot_groups"]) != self.omega:
            raise ValueError(
                f"snapshot has {len(sd['slot_groups'])} ring slots, "
                f"this ControlPlane has omega={self.omega}")
        if sd.get("policy", self.scheduler.policy) != self.scheduler.policy:
            raise ValueError(
                f"snapshot was taken under policy={sd['policy']!r}, this "
                f"ControlPlane uses {self.scheduler.policy!r}; the arrival "
                "log is policy-specific — resume with the same --policy")
        pool = {int(k): tuple(int(g) for g in gs)
                for k, gs in sd.get("pool", {}).items()}
        if len(pool) > self.pool_cap:
            raise ValueError(
                f"snapshot holds {len(pool)} spilled slots but this "
                f"ControlPlane has pool_cap={self.pool_cap}; resume with "
                f"--pool-cap >= {len(pool)}")
        if pool and sd.get("eviction", self.mem_policy.name) != \
                self.mem_policy.name:
            raise ValueError(
                f"snapshot was taken under eviction={sd['eviction']!r}, "
                f"this ControlPlane uses {self.mem_policy.name!r}; spill "
                "plans are policy-specific — resume with the same "
                "--eviction")
        self.versions[:] = np.asarray(sd["versions"], np.int64)
        self.version = sd["version"]
        self.n_accepted = sd["n_accepted"]
        self.n_rejected = sd["n_rejected"]
        self.peak_buffered = sd["peak_buffered"]
        self.peak_live_slots = sd["peak_live_slots"]
        self._next_write = sd["next_write"]
        self._last_read = sd["last_read"]
        self.scheduler.counters = {int(k): v
                                   for k, v in sd["counters"].items()}
        self._slot_groups = [set(gs) for gs in sd["slot_groups"]]
        # replay queues verbatim and restore the flow controller's exact
        # token/round-robin state (re-granting from fresh registration
        # order could arm different groups than the original under a tight
        # budget, diverging a resumed run from an uninterrupted one)
        self.scheduler.q_act = {
            int(g): deque(Message("activation", int(g), content=s)
                          for s in slots)
            for g, slots in sd["queues"].items()}
        self.scheduler._arrival = deque(sd["arrival"])
        if "prev_active" in sd:      # older snapshots predate retention
            self.prev_active = np.asarray(sd["prev_active"], bool)
        if "retention" in sd:
            # metadata only: the params ride the checkpoint's extras.npz —
            # the driver must call retention.load_arrays with the restored
            # tree before any held group can rejoin
            self.retention.load_meta(sd["retention"])
        # spill-tier bookkeeping (v3; older snapshots have no pool — the
        # defaults from __init__ already describe an empty tier)
        self._pool = pool
        self._next_pool_key = sd.get("next_pool_key", 0)
        self._slot_touch = [int(t) for t in
                            sd.get("slot_touch", [0] * self.omega)]
        self._tick = sd.get("tick", 0)
        self.n_spills = sd.get("n_spills", 0)
        self.n_fills = sd.get("n_fills", 0)
        self.peak_pool = sd.get("peak_pool", len(pool))
        self.flow.inflight_by.clear()
        # pooled contributions still hold flow budget: they are buffered
        # server-side, just in the host tier rather than scheduler queues
        self.flow.buffered = sum(
            len(q) for q in self.scheduler.q_act.values()) + \
            sum(len(gs) for gs in self._pool.values())
        if "tokens" in sd:
            self.flow.sender_active = {int(g): v
                                       for g, v in sd["tokens"].items()}
            self.flow._rr = [int(g) for g in sd["rr"]]
        else:   # snapshot predates token serialization: re-grant in the cap
            for g in list(self.flow.sender_active):
                self.flow.sender_active[g] = False
            self.flow._maybe_grant()
