"""Pre-processor: DNN profiling + split-point selection (paper §3.2.1).

The Pre-processor profiles the model M to obtain per-layer FLOPs {O_l} and
output sizes {S_l}, then picks the split point (Eq. 6–8)::

    t_train_k(l)    = sum_{i<=l} O_i / o_k                      (6)
    t_transfer_k(l) = S_l / b_k                                 (7)
    l* = argmin_l max_k max(t_train_k(l), t_transfer_k(l))      (8)

Profiles are analytic (no tracing): exact MAC counts for convs/matmuls.
For transformers the unit "layer" is one *period* of the pattern so splits
never cut an alternation motif (gemma2 local/global, jamba 1:7).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.models.api import ArchConfig
from repro.models.cnn import CnnConfig


@dataclass(frozen=True)
class LayerProfile:
    """Per-splittable-unit profile, plus totals."""
    flops: tuple           # O_l: forward FLOPs per sample for unit l
    out_bytes: tuple       # S_l: activation bytes per sample at unit l output
    names: tuple
    total_flops: float     # full forward FLOPs per sample
    head_flops: float      # final head/classifier FLOPs per sample
    param_bytes_cum: tuple # cumulative parameter bytes through unit l

    @property
    def n_units(self) -> int:
        return len(self.flops)


# ---------------------------------------------------------------------------
# Transformer profiles (per period, per sample = per sequence)
# ---------------------------------------------------------------------------

def _attn_flops(cfg: ArchConfig, seq: int, window: int | None) -> float:
    hd = cfg.hd
    proj = 2 * seq * cfg.d_model * (cfg.n_heads * hd)            # q
    proj += 2 * 2 * seq * cfg.d_model * (cfg.n_kv_heads * hd)    # k, v
    proj += 2 * seq * (cfg.n_heads * hd) * cfg.d_model           # o
    ctx = min(seq, window) if window else seq
    scores = 2 * 2 * seq * ctx * cfg.n_heads * hd                # qk^T + pv
    return float(proj + scores)


def _mlp_flops(cfg: ArchConfig, seq: int) -> float:
    mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
    return float(mats * 2 * seq * cfg.d_model * cfg.d_ff)


def _moe_flops(cfg: ArchConfig, seq: int) -> float:
    mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
    router = 2 * seq * cfg.d_model * cfg.n_experts
    return float(router + cfg.top_k * mats * 2 * seq * cfg.d_model * cfg.d_ff)


def _mamba_flops(cfg: ArchConfig, seq: int) -> float:
    m = cfg.mamba_cfg()
    di, N, H, P = m.d_inner, m.d_state, m.n_heads, m.head_dim
    proj = 2 * seq * cfg.d_model * (2 * di + 2 * m.n_groups * N + H)
    proj += 2 * seq * di * cfg.d_model
    conv = 2 * seq * m.conv_dim * m.conv_kernel
    Q = min(m.chunk, seq)
    # SSD: intra-chunk (seq*Q per head: CB^T scores + weighted sum) + states
    intra = 2 * 2 * seq * Q * H * (N + P) / 2 * 2  # scores (N) + apply (P)
    states = 2 * 2 * seq * H * N * P               # state build + read
    return float(proj + conv + intra + states)


def _period_flops(cfg: ArchConfig, seq: int, frontend_len: int = 0) -> float:
    total = 0.0
    for mixer, ffn in cfg.pattern:
        if mixer in ("attn",):
            total += _attn_flops(cfg, seq, None)
        elif mixer == "local":
            total += _attn_flops(cfg, seq, cfg.window)
        elif mixer == "cross":
            hd = cfg.hd
            fl = frontend_len or cfg.frontend_len or seq
            total += 2 * seq * cfg.d_model * cfg.n_heads * hd * 2      # q,o
            total += 2 * 2 * fl * cfg.d_model * cfg.n_kv_heads * hd    # k,v
            total += 2 * 2 * seq * fl * cfg.n_heads * hd
        elif mixer == "mamba":
            total += _mamba_flops(cfg, seq)
        if ffn == "dense":
            total += _mlp_flops(cfg, seq)
        elif ffn == "moe":
            total += _moe_flops(cfg, seq)
    return total


def _period_param_bytes(cfg: ArchConfig, dtype_bytes: int = 4) -> float:
    n = 0
    hd = cfg.hd
    for mixer, ffn in cfg.pattern:
        if mixer in ("attn", "local", "cross"):
            n += cfg.d_model * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) \
                + cfg.n_heads * hd * cfg.d_model
        elif mixer == "mamba":
            m = cfg.mamba_cfg()
            n += cfg.d_model * (2 * m.d_inner + 2 * m.n_groups * m.d_state + m.n_heads)
            n += m.d_inner * cfg.d_model + m.conv_dim * m.conv_kernel
        if ffn == "dense":
            mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
            n += mats * cfg.d_model * cfg.d_ff
        elif ffn == "moe":
            mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
            n += cfg.n_experts * mats * cfg.d_model * cfg.d_ff + cfg.d_model * cfg.n_experts
    return float(n * dtype_bytes)


def transformer_profile(cfg: ArchConfig, seq: int, dtype_bytes: int = 4) -> LayerProfile:
    per_period = _period_flops(cfg, seq)
    embed = 0.0  # lookup, negligible FLOPs
    head = 2 * seq * cfg.d_model * cfg.vocab
    act_bytes = seq * cfg.d_model * dtype_bytes
    n = cfg.n_periods
    pbytes = _period_param_bytes(cfg, dtype_bytes)
    return LayerProfile(
        flops=tuple([per_period] * n),
        out_bytes=tuple([act_bytes] * n),
        names=tuple(f"period_{i}" for i in range(n)),
        total_flops=embed + per_period * n + head,
        head_flops=head,
        param_bytes_cum=tuple(cfg.vocab * cfg.d_model * dtype_bytes + pbytes * (i + 1)
                              for i in range(n)),
    )


# ---------------------------------------------------------------------------
# CNN profiles (per sample)
# ---------------------------------------------------------------------------

def cnn_profile(cfg: CnnConfig, dtype_bytes: int = 4) -> LayerProfile:
    flops, out_bytes, names, pbytes_cum = [], [], [], []
    cin, hw, pbytes = cfg.in_channels, cfg.img_size, 0.0
    for spec in cfg.layers:
        kind = spec["kind"]
        if kind == "conv":
            s = spec.get("stride", 1)
            hw_out = hw // s
            f = 2 * spec["k"] ** 2 * cin * spec["cout"] * hw_out * hw_out
            pbytes += spec["k"] ** 2 * cin * spec["cout"] * dtype_bytes
            cin, hw = spec["cout"], hw_out // (2 if spec.get("pool") else 1)
        elif kind == "bneck":
            ce = int(round(cin * spec["expand"]))
            s = spec.get("stride", 1)
            hw_out = hw // s
            f = (2 * cin * ce * hw * hw               # expand 1x1
                 + 2 * spec["k"] ** 2 * ce * hw_out * hw_out   # depthwise
                 + 2 * ce * spec["cout"] * hw_out * hw_out)    # project
            pbytes += (cin * ce + spec["k"] ** 2 * ce + ce * spec["cout"]) * dtype_bytes
            cin, hw = spec["cout"], hw_out
        elif kind in ("flatten", "gap"):
            f = 0.0
            cin = cin * hw * hw if kind == "flatten" else cin
            hw = 1
        elif kind == "fc":
            f = 2 * cin * spec["dout"]
            pbytes += cin * spec["dout"] * dtype_bytes
            cin = spec["dout"]
        flops.append(float(f))
        out_bytes.append(float(cin * hw * hw * dtype_bytes))
        names.append(f"{kind}_{len(names)}")
        pbytes_cum.append(pbytes)
    total = sum(flops)
    return LayerProfile(tuple(flops), tuple(out_bytes), tuple(names),
                        total, flops[-1], tuple(pbytes_cum))


# ---------------------------------------------------------------------------
# Split-point selection (Eq. 6–8)
# ---------------------------------------------------------------------------

def select_split(profile: LayerProfile, device_flops: list[float],
                 bandwidths: list[float], min_server_units: int = 1,
                 batch: int = 1) -> int:
    """Returns l* in [1, n_units - min_server_units].

    device_flops o_k in FLOP/s; bandwidths b_k in bytes/s; batch scales the
    per-iteration compute/transfer identically (so it cancels in the argmax
    structure but keeps units honest)."""
    n = profile.n_units
    lo, hi = 1, n - min_server_units
    best_l, best_cost = lo, float("inf")
    cum = np.cumsum(profile.flops)
    for l in range(lo, hi + 1):
        cost = 0.0
        for o_k, b_k in zip(device_flops, bandwidths):
            t_train = batch * cum[l - 1] / o_k
            t_tx = batch * profile.out_bytes[l - 1] / b_k
            cost = max(cost, max(t_train, t_tx))
        if cost < best_cost:
            best_cost, best_l = cost, l
    return best_l


def split_costs(profile: LayerProfile, device_flops: list[float],
                bandwidths: list[float], batch: int = 1) -> np.ndarray:
    """Full cost curve over l (for the partition benchmark/figure)."""
    cum = np.cumsum(profile.flops)
    out = []
    for l in range(1, profile.n_units + 1):
        cost = max(max(batch * cum[l - 1] / o, batch * profile.out_bytes[l - 1] / b)
                   for o, b in zip(device_flops, bandwidths))
        out.append(cost)
    return np.array(out)
