"""Learning executors: real JAX training driven by the event simulators.

The simulators (simulation.py / baselines.py) call hook methods in event
order; these classes do the actual math, so accuracy experiments (Table 2,
Fig. 6/7, 14/15) reflect genuine non-IID learning dynamics — staleness,
imbalance, scheduling effects and all.

A `ModelAdapter` abstracts over layer-list models (cnn.py,
text_classifier.py): both expose forward/split/aux/ce with the same
signatures, so one adapter class serves VGG-5, MobileNetV3ish and
Transformer-6/12.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DeviceDataset
from repro.models.common import tree_lerp
from .aggregator import AsyncAggregator


@dataclass(frozen=True)
class ModelAdapter:
    """Bundles a layer-list model module (cnn / text_classifier) + config."""
    module: Any
    cfg: Any

    def init(self, rng):
        return self.module.init_params(rng, self.cfg)

    def split(self, params, l):
        return self.module.split_params(params, l)

    def make_aux(self, rng, l, variant="default"):
        """Returns (aux_params, aux_spec) — params are pure array pytrees;
        the spec (layer kinds, pooling) is static metadata."""
        return self.module.make_aux_params(rng, self.cfg, l, variant)

    def full_loss(self, params, x, y):
        return self.module.loss_fn(params, self.cfg, x, y)

    def accuracy(self, params, x, y):
        return float(self.module.accuracy(params, self.cfg, x, y))

    def device_forward(self, dev, x, l):
        return self.module.forward(dev, self.cfg, x, upto=l)

    def aux_loss(self, aux, aux_spec, acts, y):
        if self.module.__name__.endswith("cnn"):
            return self.module.aux_head_loss(aux, aux_spec, acts, y)
        return self.module.aux_head_loss(aux, aux_spec, self.cfg, acts, y)

    def server_loss(self, srv, acts, y, l):
        return self.module.server_forward_loss(srv, self.cfg, acts, y, l)


def _sgd(tree, grads, lr):
    return jax.tree.map(lambda p, g: p - lr * g, tree, grads)


# ---------------------------------------------------------------------------
# FedOptima learner
# ---------------------------------------------------------------------------

class FedOptimaLearner:
    """Implements Alg. 1 (device) + Alg. 4 (server) math.

    Device k: one local iteration = fwd device block -> aux loss -> SGD on
    (θ_dk, θ̃_dk).  Activations ship to the server only when the simulator's
    flow control granted a token (send=True).  The server trains a single
    θ_s on scheduled activation batches; device blocks aggregate per
    FedAsync with staleness cap D.

    ``consumed[k]`` counts the batches the server actually trained on per
    device — the learner-side mirror of the ControlPlane's TaskScheduler
    counters (Alg. 3), so fairness claims can be cross-checked against the
    real training stream.
    """

    def __init__(self, adapter: ModelAdapter, datasets: list[DeviceDataset],
                 l_split: int, *, lr_d=0.05, lr_s=0.05, max_delay=16,
                 aux_variant="default", seed=0, max_queue=64):
        self.a = adapter
        self.l = l_split
        self.lr_d, self.lr_s = lr_d, lr_s
        self.datasets = datasets
        K = len(datasets)
        rng = jax.random.PRNGKey(seed)
        kf, ka = jax.random.split(rng)
        full = adapter.init(kf)
        dev0, srv = adapter.split(full, l_split)
        aux0, aux_spec = adapter.make_aux(ka, l_split, aux_variant)
        self.aux_spec = aux_spec
        self.dev = [jax.tree.map(jnp.copy, dev0) for _ in range(K)]
        self.aux = [jax.tree.map(jnp.copy, aux0) for _ in range(K)]
        self.srv = srv
        self.versions = [0] * K
        self.agg = AsyncAggregator(theta_d=dev0, theta_aux=aux0, max_delay=max_delay)
        self.act_queues: list[deque] = [deque(maxlen=max_queue) for _ in range(K)]
        self.srv_steps = 0
        self.dev_steps = 0
        self.consumed = {k: 0 for k in range(K)}   # server batches per device

        l_cap = l_split

        @jax.jit
        def dev_step(dev, aux, x, y):
            def loss_fn(dv, au):
                acts = self.a.device_forward(dv, x, l_cap)
                return self.a.aux_loss(au, aux_spec, acts, y), acts
            (loss, acts), grads = jax.value_and_grad(loss_fn, argnums=(0, 1),
                                                     has_aux=True)(dev, aux)
            dev = _sgd(dev, grads[0], self.lr_d)
            aux = _sgd(aux, grads[1], self.lr_d)
            return dev, aux, acts, loss

        @jax.jit
        def srv_step(srv, acts, y):
            loss, grads = jax.value_and_grad(
                lambda s: self.a.server_loss(s, acts, y, l_cap))(srv)
            return _sgd(srv, grads, self.lr_s), loss

        self._dev_step = dev_step
        self._srv_step = srv_step

    # --- hooks ---
    def device_iter(self, k: int, send: bool):
        x, y = self.datasets[k].next_batch()
        self.dev[k], self.aux[k], acts, _ = self._dev_step(
            self.dev[k], self.aux[k], x, y)
        self.dev_steps += 1
        if send:
            self.act_queues[k].append((np.asarray(acts), y))

    def server_train(self, k: int):
        if not self.act_queues[k]:
            return
        acts, y = self.act_queues[k].popleft()
        self.srv, _ = self._srv_step(self.srv, acts, y)
        self.srv_steps += 1
        self.consumed[k] = self.consumed.get(k, 0) + 1

    def aggregate(self, k: int):
        ok = self.agg.aggregate(self.dev[k], self.aux[k], self.versions[k])
        theta_d, theta_aux, t = self.agg.snapshot()
        self.dev[k] = jax.tree.map(jnp.copy, theta_d)
        self.aux[k] = jax.tree.map(jnp.copy, theta_aux)
        self.versions[k] = t

    def sync_aggregate(self):  # unused in FedOptima; here for API parity
        pass

    # --- evaluation: merged global model ---
    def eval_accuracy(self, x, y) -> float:
        params = list(self.agg.theta_d) + list(self.srv)
        return self.a.accuracy(params, x, y)


# ---------------------------------------------------------------------------
# Full-model learner (classic FL / FedAsync / FedBuff)
# ---------------------------------------------------------------------------

class FullModelLearner:
    def __init__(self, adapter: ModelAdapter, datasets: list[DeviceDataset], *,
                 lr=0.05, max_delay=16, seed=0):
        self.a = adapter
        self.datasets = datasets
        K = len(datasets)
        g = adapter.init(jax.random.PRNGKey(seed))
        self.global_params = g
        self.dev = [jax.tree.map(jnp.copy, g) for _ in range(K)]
        self.versions = [0] * K
        self.version = 0
        self.max_delay = max_delay
        self.lr = lr
        self.dev_steps = 0

        @jax.jit
        def step(params, x, y):
            loss, grads = jax.value_and_grad(
                lambda p: self.a.full_loss(p, x, y))(params)
            return _sgd(params, grads, self.lr), loss

        self._step = step

    def device_iter(self, k: int, _send: bool):
        x, y = self.datasets[k].next_batch()
        self.dev[k], _ = self._step(self.dev[k], x, y)
        self.dev_steps += 1

    def aggregate(self, k: int):
        staleness = self.version - self.versions[k]
        if staleness <= self.max_delay:
            alpha = 1.0 / (staleness + 1.0)
            self.global_params = tree_lerp(self.global_params, self.dev[k], alpha)
            self.version += 1
        self.dev[k] = jax.tree.map(jnp.copy, self.global_params)
        self.versions[k] = self.version

    def sync_aggregate(self):
        self.global_params = jax.tree.map(
            lambda *xs: sum(xs) / len(xs), *self.dev)
        self.version += 1
        for k in range(len(self.dev)):
            self.dev[k] = jax.tree.map(jnp.copy, self.global_params)
            self.versions[k] = self.version

    def server_train(self, k: int):
        pass

    def eval_accuracy(self, x, y) -> float:
        return self.a.accuracy(self.global_params, x, y)


# ---------------------------------------------------------------------------
# Split learner (SplitFed / PiPar / OAFL)
# ---------------------------------------------------------------------------

class SplitLearner:
    """Split training with gradient return.  The simulator calls
    server_train(k) (server fwd/bwd on device k's activations, producing
    ∂loss/∂acts) *before* device_iter(k) (device-side VJP + SGD), matching
    the wire protocol.  SplitFed keeps one server-side model per device;
    sync_aggregate averages device and server halves each round; OAFL
    aggregates asynchronously (α-weighted) per arriving device."""

    def __init__(self, adapter: ModelAdapter, datasets: list[DeviceDataset],
                 l_split: int, *, lr=0.05, max_delay=16, seed=0):
        self.a = adapter
        self.l = l_split
        self.lr = lr
        self.datasets = datasets
        K = len(datasets)
        full = adapter.init(jax.random.PRNGKey(seed))
        dev0, srv0 = adapter.split(full, l_split)
        self.dev = [jax.tree.map(jnp.copy, dev0) for _ in range(K)]
        self.srv = [jax.tree.map(jnp.copy, srv0) for _ in range(K)]
        self.g_dev = dev0
        self.g_srv = srv0
        self.versions = [0] * K
        self.version = 0
        self.max_delay = max_delay
        self._pending: dict[int, tuple] = {}
        self.dev_steps = 0
        l_cap = l_split

        @jax.jit
        def srv_step(srv, acts, y):
            def loss_fn(s, a):
                return self.a.server_loss(s, a, y, l_cap)
            (loss, (g_srv, g_acts)) = jax.value_and_grad(
                loss_fn, argnums=(0, 1))(srv, acts)
            return _sgd(srv, g_srv, self.lr), g_acts, loss

        @jax.jit
        def dev_step(dev, x, g_acts):
            acts, vjp_fn = jax.vjp(lambda d: self.a.device_forward(d, x, l_cap), dev)
            (g_dev,) = vjp_fn(g_acts)
            return _sgd(dev, g_dev, self.lr)

        self._srv_step = srv_step
        self._dev_step = dev_step

    def server_train(self, k: int):
        x, y = self.datasets[k].next_batch()
        acts = self.a.device_forward(self.dev[k], x, self.l)
        self.srv[k], g_acts, _ = self._srv_step(self.srv[k], acts, y)
        self._pending[k] = (x, np.asarray(g_acts))

    def device_iter(self, k: int, _send: bool):
        if k not in self._pending:
            return
        x, g_acts = self._pending.pop(k)
        self.dev[k] = self._dev_step(self.dev[k], x, g_acts)
        self.dev_steps += 1

    def sync_aggregate(self):
        K = len(self.dev)
        self.g_dev = jax.tree.map(lambda *xs: sum(xs) / K, *self.dev)
        self.g_srv = jax.tree.map(lambda *xs: sum(xs) / K, *self.srv)
        self.version += 1
        for k in range(K):
            self.dev[k] = jax.tree.map(jnp.copy, self.g_dev)
            self.srv[k] = jax.tree.map(jnp.copy, self.g_srv)
            self.versions[k] = self.version

    def aggregate(self, k: int):  # OAFL: async α-weighted
        staleness = self.version - self.versions[k]
        if staleness <= self.max_delay:
            alpha = 1.0 / (staleness + 1.0)
            self.g_dev = tree_lerp(self.g_dev, self.dev[k], alpha)
            self.g_srv = tree_lerp(self.g_srv, self.srv[k], alpha)
            self.version += 1
        self.dev[k] = jax.tree.map(jnp.copy, self.g_dev)
        self.srv[k] = jax.tree.map(jnp.copy, self.g_srv)
        self.versions[k] = self.version

    def eval_accuracy(self, x, y) -> float:
        params = list(self.g_dev) + list(self.g_srv)
        return self.a.accuracy(params, x, y)
