"""Datacenter-scale FedOptima: the paper's split-training pipeline as one
pjit program per (arch × shape × mesh).

Mapping (DESIGN.md §3): an FL "device" becomes a *device group* — one index
of the mesh's data-parallel axes (pod × data), owning a ``model``-axis (TP)
slice of chips.  Each group trains its own copy of the device-side block
(params stacked on a leading group axis, sharded over dp) on its local
non-IID shard, with gradients from the *auxiliary network* only — no
gradient ever flows server→device (``stop_gradient`` on the activation
hand-off).  The server-side block is ONE centrally-trained model (TP over
``model``, FSDP over dp) consuming the activation stream.

Idle-time elimination carries over: with ``pipeline_acts=True`` (the
paper's queue semantics) the server trains on *previously scheduled*
activations, so the device half and the server half of the XLA program
have no data dependency — the latency-hiding scheduler overlaps them,
which is Fig. 1(d) at pod scale.

The activation hand-off is an ω-deep ring of scheduled batches (Eq. 3's
bounded buffer realized on-mesh): ``state["act_buf"]`` holds ω slots, each
one micro-iteration's combined (all-groups) activation batch.  The *host
control plane* (core/control_plane.py — TaskScheduler + FlowController +
staleness accounting) plans each round and feeds the jit'd step three
schedule fields per micro-iteration:

    read_slot[h]    which ring slot the server trains on (Alg. 3's pick)
    write_slot[h]   which slot this iteration's emission lands in
    send_mask[h,g]  which groups' rows refresh in that slot (flow-control
                    token grants; unsent rows keep the slot's old content)

plus two per-group fields: ``agg_weight`` derived from real staleness
counters (Alg. 4 line 16) instead of placeholder ones, and ``bcast_mask``
gating which groups receive the aggregated global model back (Alg. 4
line 20 applies to *participants*; a dropped group's rows keep their
current params so it can rejoin from its host-retained state at its
recorded staleness — see ``ControlPlane``'s RetentionStore).  With ω=1, an
identity schedule, uniform weights and an all-ones ``bcast_mask`` this
reduces bit-for-bit to the original single-buffer pipeline.

Structure of one hybrid step::

    devices (vmapped over G groups)          server (centralized)
    ───────────────────────────────          ─────────────────────
    fwd device block + aux head              train on act_buf (prev step)
    local SGD on (θ_dk, θ̃_dk)               SGD/AdamW on θ_s
    emit activations ──────────────▶ act_buf (next step)
    every H steps: staleness-weighted async aggregation over groups
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field, replace
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import transformer as tfm
from repro.models.api import ArchConfig
from repro.optim.optimizers import make_optimizer
from repro.parallel.sharding import Parallelism, param_specs, _param_spec, _validate

Params = Any


# ---------------------------------------------------------------------------
# Step configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FedStepConfig:
    arch: ArchConfig
    l_split: int                      # device-side periods (split point, Eq. 8)
    n_groups: int                     # FL device groups (= mesh dp size)
    seq_len: int
    per_group_batch: int              # sequences per group
    H: int = 8                        # local iterations per round (Alg. 1);
                                      # one jit step = one round of H
                                      # micro-iterations + aggregation
    lr_d: float = 0.05
    lr_s: float = 0.05
    server_opt: str = "sgd"           # paper Alg. 4 line 10 (adamw optional)
    param_dtype: Any = jnp.float32
    # --- pipeline/perf options (see EXPERIMENTS.md §Perf) ---
    pipeline_acts: bool = True        # server trains on prev-step activations
    omega: int = 1                    # activation-ring depth in scheduled
                                      # batches (Eq. 3 cap ω); slots are
                                      # read/written per the host schedule
    remat: Any = "selective"          # True | False | "selective" (§Perf it.4:
                                      # save post-TP-collective outputs only)
    act_sharding: str = "seq"         # "seq" (Megatron-SP carries) | "none"
    use_kernel: bool = False          # Pallas kernels for attn/SSD hot spots
                                      # (differentiable: custom_vjp backward
                                      # kernels, so both halves' value_and_grad
                                      # run through the fused path; composes
                                      # with remat="selective", which saves
                                      # the kernels' (o, lse)/state residuals)
    agg_compress: bool = False        # int8 aggregation payload (cross-pod)
    # Server gradient accumulation: apply the server optimizer once per
    # round (grads summed over the H scheduled batches) instead of per
    # batch (Alg. 4 line 10).  Keeps θ_s loop-invariant inside the round
    # scan, so the FSDP weight all-gathers hoist out of the H-loop —
    # collective traffic / H.  A beyond-paper systems trade-off: same data,
    # one optimizer step per round.
    server_accum: bool = False
    ep_interior: bool = False         # pin MoE expert tensors to EP axis
                                      # (§Perf it.6: refuted — forces
                                      # redundant compute under GSPMD)
    # Explicit shard_map expert parallelism for the server block: each
    # ``model`` shard routes its dp-shard's tokens to its LOCAL experts and
    # partial outputs psum over ``model``.  Avoids GSPMD's unsharded
    # gather/scatter dispatch tables (the MoE cells' dominant traffic).
    ep_shard_map: bool = True         # (§Perf it.7: 7x on MoE cells)

    @property
    def seq_shard_acts(self) -> bool:
        return self.act_sharding == "seq"

    @property
    def global_batch(self) -> int:
        return self.n_groups * self.per_group_batch

    @property
    def micro_batch(self) -> int:
        """Sequences per group per local iteration (Alg. 1 line 4)."""
        if self.per_group_batch % self.H != 0:
            raise ValueError(
                f"per_group_batch={self.per_group_batch} is not divisible "
                f"by H={self.H}; Alg. 1 consumes per_group_batch/H "
                "sequences per local iteration")
        return self.per_group_batch // self.H

    @property
    def frontend_dtype(self):
        return self.param_dtype


def default_l_split(arch: ArchConfig) -> int:
    """Paper Eq. 8 with edge-device profiles puts the split early (devices
    are weak); at pod scale we default to 1/8 of the periods on the device
    side, clamped to a valid boundary."""
    return max(1, min(arch.n_periods - 1, arch.n_periods // 8))


# ---------------------------------------------------------------------------
# State construction
# ---------------------------------------------------------------------------

def _init_one_group(rng, arch: ArchConfig, l_split: int, dtype):
    full = tfm.init_params(rng, arch, dtype)
    dev, srv = tfm.split_params(full, arch, l_split)
    aux = tfm.make_aux_params(jax.random.fold_in(rng, 1), arch, dtype,
                              regression=bool(arch.n_decoder_layers))
    return dev, aux, srv


def init_train_state(rng, cfg: FedStepConfig) -> Params:
    """Concrete training state (smoke-scale; full configs use eval_shape)."""
    dev1, aux1, srv = _init_one_group(rng, cfg.arch, cfg.l_split,
                                      cfg.param_dtype)
    G = cfg.n_groups
    stack = lambda t: jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (G,) + x.shape), t)
    s_init, _ = make_optimizer(cfg.server_opt)
    state = {
        "dev": stack(dev1),
        "aux": stack(aux1),
        "srv": srv,
        "srv_opt": s_init(srv),
        "step": jnp.zeros((), jnp.int32),
        "version": jnp.zeros((), jnp.int32),
    }
    if cfg.pipeline_acts:
        state["act_buf"] = _empty_act_buf(cfg)
    return state


def _empty_act_slot(cfg: FedStepConfig) -> Params:
    """One scheduled activation batch (one micro-iteration's output)."""
    arch = cfg.arch
    B = cfg.n_groups * cfg.micro_batch
    S = arch.frontend_len if arch.n_decoder_layers else cfg.seq_len
    buf = {"acts": jnp.zeros((B, S, arch.d_model), cfg.param_dtype),
           "labels": jnp.zeros((B, cfg.seq_len), jnp.int32)}
    if arch.n_decoder_layers:
        buf["tokens"] = jnp.zeros((B, cfg.seq_len), jnp.int32)
    if arch.family == "vlm":
        buf["frontend"] = jnp.zeros((B, arch.frontend_len, arch.d_model),
                                    cfg.frontend_dtype)
    return buf


def _empty_act_buf(cfg: FedStepConfig) -> Params:
    """ω-deep ring of scheduled activation batches: Σ|Q_act| ≤ ω on-mesh."""
    return jax.tree.map(
        lambda x: jnp.zeros((cfg.omega,) + x.shape, x.dtype),
        _empty_act_slot(cfg))


def abstract_train_state(cfg: FedStepConfig) -> Params:
    """ShapeDtypeStruct state — no allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_train_state(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs for every model input)
# ---------------------------------------------------------------------------

#: Host-control-plane fields: per-micro-iteration ring schedule (leading H
#: axis, NOT per-group) + per-group staleness weights (leading G axis).
SCHEDULE_KEYS = ("read_slot", "write_slot", "send_mask")

#: Per-group (G,) control fields consumed once per round (not scanned over
#: the H micro-iterations): aggregation weights + broadcast receive mask.
PER_GROUP_KEYS = ("agg_weight", "bcast_mask")


def train_input_specs(cfg: FedStepConfig) -> dict:
    """Batch stand-ins: tokens/labels per group per local iteration (one
    round = H micro-iterations); agg weights + the activation-ring schedule
    from the host control plane (staleness-derived, §Alg. 4 line 16)."""
    arch = cfg.arch
    G, H, b, S = cfg.n_groups, cfg.H, cfg.micro_batch, cfg.seq_len
    sds = jax.ShapeDtypeStruct
    batch = {"tokens": sds((G, H, b, S), jnp.int32),
             "labels": sds((G, H, b, S), jnp.int32),
             "agg_weight": sds((G,), jnp.float32),
             "bcast_mask": sds((G,), jnp.float32),
             "read_slot": sds((H,), jnp.int32),
             "write_slot": sds((H,), jnp.int32),
             "send_mask": sds((H, G), jnp.float32)}
    if arch.frontend_len:
        batch["frontend"] = sds((G, H, b, arch.frontend_len, arch.d_model),
                                cfg.frontend_dtype)
    return batch


def identity_schedule(cfg: FedStepConfig) -> dict:
    """The uncontrolled default plan: every group sends every iteration and
    slot h%ω is consumed then overwritten — with ω=1 this is exactly the
    original single-buffer pipeline."""
    slots = jnp.arange(cfg.H, dtype=jnp.int32) % max(cfg.omega, 1)
    return {"read_slot": slots, "write_slot": slots,
            "send_mask": jnp.ones((cfg.H, cfg.n_groups), jnp.float32)}


def _stable_fold(rng, name: str):
    """fold_in with a process-stable salt (builtin hash() varies with
    PYTHONHASHSEED, breaking run-to-run benchmark reproducibility)."""
    return jax.random.fold_in(rng, zlib.crc32(name.encode()) % 97)


def concrete_train_batch(rng, cfg: FedStepConfig) -> dict:
    arch = cfg.arch
    out = dict(identity_schedule(cfg))
    for k, s in train_input_specs(cfg).items():
        if k in out:
            continue
        if k in PER_GROUP_KEYS:
            out[k] = jnp.ones(s.shape, s.dtype)
        elif s.dtype == jnp.int32:
            out[k] = jax.random.randint(_stable_fold(rng, k),
                                        s.shape, 0, arch.vocab, jnp.int32)
        else:
            out[k] = jax.random.normal(_stable_fold(rng, k), s.shape, s.dtype)
    return out


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

def _stacked_specs(params: Params, par: Parallelism) -> Params:
    """Specs for group-stacked device/aux params: leading G axis over the
    dp axes; inner dims per the standard rules (FSDP off — dp is taken).

    Exception: the device-side *input* embedding shards d_model (not
    vocab) over ``model`` — the token gather and the scatter-add of its
    gradient are then chip-local (no all-reduce of a (V, D) table per
    micro-iteration).  Vocab-sharding only pays off on the logits path,
    which the device block doesn't have (the aux head is factorized)."""
    inner_par = replace(par, fsdp=False)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key.endswith("embed") and leaf.ndim == 3:
            inner = _validate(P(None, par.tp_axis), leaf.shape[1:], inner_par)
        else:
            inner = _param_spec(key, leaf.shape[1:], inner_par)
            inner = _validate(inner, leaf.shape[1:], inner_par)
        specs.append(P(tuple(par.dp_axes), *tuple(inner)))
    return jax.tree_util.tree_unflatten(treedef, specs)


def _act_buf_specs(buf: Params, par: Parallelism, seq_shard: bool,
                   ring: bool = False) -> Params:
    """Slot-shaped activation specs; ``ring=True`` for the ω-stacked state
    buffer (leading slot axis replicated, inner dims as one slot)."""
    dp = tuple(par.dp_axes)
    tp = par.tp_axis
    tp_size = par.mesh.shape[tp]

    def spec(k, leaf):
        shape = leaf.shape[1:] if ring else leaf.shape
        b = dp if shape[0] % par.dp_size == 0 else None
        if len(shape) == 3:     # (B, S, D) or (B, F, D)
            s = tp if (seq_shard and shape[1] % tp_size == 0) else None
            inner = (b, s, None)
        else:
            inner = (b, None)   # (B, S) int labels/tokens
        return P(None, *inner) if ring else P(*inner)
    return {k: spec(k, v) for k, v in buf.items()}


def state_specs(state: Params, cfg: FedStepConfig, par: Parallelism) -> Params:
    specs = {
        "dev": _stacked_specs(state["dev"], par),
        "aux": _stacked_specs(state["aux"], par),
        "srv": param_specs(state["srv"], par),
        "step": P(),
        "version": P(),
    }
    # optimizer state mirrors its parameters (ZeRO); scalars replicated
    so = {}
    for k, v in state["srv_opt"].items():
        so[k] = specs["srv"] if k in ("mu", "nu", "velocity") else P()
    specs["srv_opt"] = so
    if "act_buf" in state:
        specs["act_buf"] = _act_buf_specs(state["act_buf"], par,
                                          cfg.seq_shard_acts, ring=True)
    return specs


def batch_specs(cfg: FedStepConfig, par: Parallelism) -> dict:
    dp = tuple(par.dp_axes)
    out = {"tokens": P(dp, None, None, None),
           "labels": P(dp, None, None, None),
           "agg_weight": P(dp),
           "bcast_mask": P(dp),
           # ring schedule: tiny host-planned control tensors, replicated
           "read_slot": P(None),
           "write_slot": P(None),
           "send_mask": P(None, dp)}
    if cfg.arch.frontend_len:
        out["frontend"] = P(dp, None, None, None, None)
    return out


def to_named(specs: Params, mesh) -> Params:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# The hybrid train step
# ---------------------------------------------------------------------------

def make_train_step(cfg: FedStepConfig, par: Parallelism):
    """Returns step(state, batch) -> (state, metrics), pure & jit-ready.

    One step = one FL round: a ``lax.scan`` over H local micro-iterations
    (Alg. 1 lines 3-12 on every device group in parallel; Alg. 4 lines 5-10
    on the server against the *previous* iteration's scheduled activation
    batch, so the two halves have no data dependency and overlap), followed
    by the end-of-round asynchronous aggregation (Alg. 4 lines 12-19).
    Micro-iterating also bounds activation memory to one iteration's worth.
    """
    arch = cfg.arch
    s_init, s_update = make_optimizer(cfg.server_opt)
    # Activation-sharding policy.  Inside the vmapped device half the group
    # axis has consumed dp, so act_batch=None there; the server half (not
    # vmapped) shards batch over dp.  "seq" adds Megatron-SP carries.
    constraints = cfg.act_sharding != "none"
    seq = cfg.act_sharding == "seq"
    dev_par = replace(par, ep=False, constraints=constraints, seq_shard=seq,
                      act_batch=None, moe_interior=cfg.ep_interior)
    srv_par = replace(par, ep=cfg.ep_shard_map, constraints=constraints,
                      seq_shard=seq, act_batch=tuple(par.dp_axes),
                      moe_interior=cfg.ep_interior)
    kw = dict(use_kernel=cfg.use_kernel, remat=cfg.remat)

    def device_half(dev, aux, batch_g):
        """One FL device group: local-loss training (Alg. 1 lines 3-12).
        Runs under vmap over the group axis — no cross-group collectives."""
        if arch.n_decoder_layers:        # whisper: encoder on frame stubs
            inputs, aux_labels = batch_g["frontend"], batch_g["frontend"]
        else:
            inputs, aux_labels = batch_g["tokens"], batch_g["labels"]
        frontend = batch_g.get("frontend") if arch.family == "vlm" else None

        def loss_fn(d, a):
            loss, acts = tfm.device_train_loss(d, a, arch, inputs, aux_labels,
                                               frontend=frontend,
                                               parallelism=dev_par, **kw)
            return loss, acts

        (d_loss, acts), (gd, ga) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(dev, aux)
        dev = jax.tree.map(lambda p, g: p - cfg.lr_d * g.astype(p.dtype),
                           dev, gd)
        aux = jax.tree.map(lambda p, g: p - cfg.lr_d * g.astype(p.dtype),
                           aux, ga)
        return dev, aux, acts, d_loss

    def server_grads(srv, buf):
        """One server iteration's loss + grads on the scheduled activation
        batch (Alg. 4 lines 5-9) — the single global model, never stale."""
        def loss_fn(s):
            if arch.n_decoder_layers:
                return tfm.server_encdec_loss(s, arch, buf["acts"],
                                              buf["tokens"], buf["labels"],
                                              parallelism=srv_par, **kw)
            return tfm.server_forward_loss(s, arch, buf["acts"],
                                           buf["labels"],
                                           frontend=buf.get("frontend"),
                                           parallelism=srv_par, **kw)
        return jax.value_and_grad(loss_fn)(srv)

    def server_half(srv, srv_opt, buf):
        """Per-batch server SGD (Alg. 4 line 10)."""
        s_loss, gs = server_grads(srv, buf)
        srv, srv_opt = s_update(srv, gs, srv_opt, cfg.lr_s)
        return srv, srv_opt, s_loss

    def aggregate(dev_aux, weights, recv_mask):
        """Async staleness-weighted aggregation over the group axis (Alg. 4
        lines 12-19 telescoped: the sequential α-lerps over one round equal
        a normalized weighted average with per-group staleness weights
        supplied by the host control plane).  All-zero weights mean every
        update was rejected (too stale / absent — Alg. 4 line 13): the
        groups keep their current params instead of being zeroed.  The
        broadcast back (Alg. 4 line 20) is masked by ``recv_mask``:
        dropped groups do NOT receive the global model — their rows keep
        current params so a rejoin scatters their host-retained state in,
        preserving true per-group staleness."""
        w_sum = jnp.sum(weights)
        w = weights / jnp.maximum(w_sum, 1e-9)

        def mean_bcast(x):
            xw = x.astype(jnp.float32) if cfg.agg_compress is False else \
                _dequant(_quant(x))
            g = jnp.tensordot(w, xw, axes=1).astype(x.dtype)
            rows = (recv_mask > 0.5).reshape((-1,) + (1,) * (x.ndim - 1))
            out = jnp.where(rows, jnp.broadcast_to(g[None], x.shape), x)
            return jnp.where(w_sum > 0, out, x)

        return jax.tree.map(mean_bcast, dev_aux)

    def step(state, batch):
        srv_const = state["srv"] if cfg.server_accum else None

        def body(carry, batch_h):
            if cfg.server_accum:
                dev, aux, srv_acc, *rest = carry
            else:
                dev, aux, srv, srv_opt, *rest = carry
            ring = rest[0] if cfg.pipeline_acts else None
            batch_g = {k: v for k, v in batch_h.items()
                       if k not in SCHEDULE_KEYS}

            dev, aux, acts, d_loss = jax.vmap(device_half)(dev, aux, batch_g)
            G, b = acts.shape[0], acts.shape[1]
            new_buf = {"acts": acts.reshape((G * b,) + acts.shape[2:]),
                       "labels": batch_g["labels"].reshape(G * b, -1)}
            if arch.n_decoder_layers:
                new_buf["tokens"] = batch_g["tokens"].reshape(G * b, -1)
            if arch.family == "vlm":
                new_buf["frontend"] = batch_g["frontend"].reshape(
                    (G * b,) + batch_g["frontend"].shape[2:])
            if cfg.seq_shard_acts:
                spec = _act_buf_specs({"acts": new_buf["acts"]}, par,
                                      True)["acts"]
                new_buf["acts"] = jax.lax.with_sharding_constraint(
                    new_buf["acts"], NamedSharding(par.mesh, spec))

            if cfg.pipeline_acts:
                # server consumes the host-scheduled slot (ring state from
                # BEFORE this iteration's write, matching the control
                # plane's read-then-write bookkeeping) ...
                read_slot = batch_h["read_slot"]
                train_buf = jax.tree.map(
                    lambda x: jax.lax.dynamic_index_in_dim(
                        x, read_slot, 0, keepdims=False), ring)
                # ... while token-holding groups' rows refresh the written
                # slot; groups without a flow-control grant keep the slot's
                # previous content (their emission is not shipped)
                write_slot = batch_h["write_slot"]
                keep = batch_h["send_mask"] > 0.5            # (G,)
                rows = jnp.repeat(keep, b)                   # (G*b,) grouped
                old = jax.tree.map(
                    lambda x: jax.lax.dynamic_index_in_dim(
                        x, write_slot, 0, keepdims=False), ring)
                merged = jax.tree.map(
                    lambda n, o: jnp.where(
                        rows.reshape((-1,) + (1,) * (n.ndim - 1)), n, o),
                    new_buf, old)
                ring = jax.tree.map(
                    lambda r, m: jax.lax.dynamic_update_index_in_dim(
                        r, m, write_slot, 0), ring, merged)
            else:
                train_buf = new_buf

            if cfg.server_accum:
                # θ_s loop-invariant: grads accumulate, FSDP gathers hoist
                s_loss, gs = server_grads(srv_const, train_buf)
                srv_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), srv_acc, gs)
                carry = (dev, aux, srv_acc)
            else:
                srv, srv_opt, s_loss = server_half(srv, srv_opt, train_buf)
                carry = (dev, aux, srv, srv_opt)
            if cfg.pipeline_acts:
                carry = carry + (ring,)
            return carry, (jnp.mean(d_loss), s_loss)

        # (G, H, ...) -> scan-major (H, G, ...); the schedule fields already
        # carry H on the leading axis and pass through unchanged; the
        # per-group (G,) control fields are consumed once after the scan
        xs = {k: v if k in SCHEDULE_KEYS else jnp.moveaxis(v, 1, 0)
              for k, v in batch.items() if k not in PER_GROUP_KEYS}
        if cfg.server_accum:
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["srv"])
            carry = (state["dev"], state["aux"], zeros)
        else:
            carry = (state["dev"], state["aux"], state["srv"],
                     state["srv_opt"])
        if cfg.pipeline_acts:
            carry = carry + (state["act_buf"],)
        carry, (d_losses, s_losses) = jax.lax.scan(body, carry, xs)
        if cfg.server_accum:
            dev, aux, srv_acc = carry[:3]
            gs = jax.tree.map(lambda a, p: (a / cfg.H).astype(p.dtype),
                              srv_acc, state["srv"])
            srv, srv_opt = s_update(state["srv"], gs, state["srv_opt"],
                                    cfg.lr_s)
        else:
            dev, aux, srv, srv_opt = carry[:4]

        # ---- end-of-round async aggregation (Alg. 1 l.13, Alg. 4 l.12-19)
        dev, aux = aggregate((dev, aux), batch["agg_weight"],
                             batch["bcast_mask"])

        new_state = dict(state, dev=dev, aux=aux, srv=srv, srv_opt=srv_opt,
                         step=state["step"] + 1,
                         version=state["version"] + 1)
        if cfg.pipeline_acts:
            new_state["act_buf"] = carry[-1]
        metrics = {"d_loss": jnp.mean(d_losses), "s_loss": jnp.mean(s_losses)}
        return new_state, metrics

    return step


def _quant(x):
    """Per-tensor int8 quantization of the aggregation payload (cross-pod
    model upload compression; see parallel/compression.py for the
    error-feedback gradient variant).  Also reused by the tiered
    activation store (repro.memory.store) for int8 spill encoding of
    float activation leaves."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    return jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8), scale


def _dequant(qs):
    q, scale = qs
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# Jit assembly (train)
# ---------------------------------------------------------------------------

def jit_train_step(cfg: FedStepConfig, mesh, *, donate: bool = True):
    """jit(step) with explicit in/out shardings for the given mesh.
    Returns (jitted, abstract_state, state_shardings, batch_shardings)."""
    dp = tuple(a for a in mesh.axis_names if a != "model")
    par = Parallelism(mesh=mesh, dp_axes=dp)
    step = make_train_step(cfg, par)
    state = abstract_train_state(cfg)
    s_spec = to_named(state_specs(state, cfg, par), mesh)
    b_spec = to_named(batch_specs(cfg, par), mesh)
    m_spec = {"d_loss": NamedSharding(mesh, P()),
              "s_loss": NamedSharding(mesh, P())}
    jitted = jax.jit(step, in_shardings=(s_spec, b_spec),
                     out_shardings=(s_spec, m_spec),
                     donate_argnums=(0,) if donate else ())
    return jitted, state, s_spec, b_spec


# ---------------------------------------------------------------------------
# Per-group state retention (dropped groups — §3.4.2)
# ---------------------------------------------------------------------------

def snapshot_state(state: Params, keys=None, *, to_host: bool = False) \
        -> Params:
    """Donation-safe snapshot of the train state (or the ``keys`` subset):
    ``jnp.copy`` per device leaf enqueues fresh, never-donated buffers in
    dispatch order, so the copy reads the current round's output before
    the next donated dispatch aliases it (see ``core/handles.py`` for the
    full contract).  With ``to_host=True`` every leaf also starts its
    async D2H transfer immediately (checkpoint staging).

    This is THE way to keep a reference into a past round's state under
    ``jit_train_step(..., donate=True)`` at window > 1 — a plain Python
    reference is invalid the moment the next round dispatches."""
    from repro.core.handles import snapshot_tree
    src = state if keys is None else \
        {k: state[k] for k in keys if k in state}
    return snapshot_tree(src, to_host=to_host)


def gather_act_slot(state: Params, s: int) -> dict:
    """Host copies of activation-ring slot ``s`` (spill path of the tiered
    store, ``repro.memory``): one scheduled batch — acts, labels and any
    tokens/frontend leaves — lifted off the mesh for the host pool.

    Blocks only until the act_buf leaves are materialized: under
    pipelined dispatch this waits for the rounds already in flight, and
    only on the ring (one slot's read is sliced host-side), never on the
    model params.  With donation at window > 1 the executor gathers from
    a :class:`~repro.core.handles.RoundHandle` (``handle.act_slot``)
    instead — this live-state sync remains the window=1 / unwired
    fallback, where the values are identical."""
    return jax.tree.map(lambda x: np.asarray(x[s]), state["act_buf"])


def scatter_act_slot(state: Params, s: int, payload: dict,
                     state_shardings=None) -> Params:
    """Functionally write one spilled slot's payload back into the on-mesh
    ring (fill path).  ``state_shardings`` (the jit step's state spec
    dict) re-pins the updated ring so the next dispatch sees the same
    shardings it was compiled for."""
    spec = None if state_shardings is None else state_shardings["act_buf"]

    def one(x, v, sh=None):
        y = x.at[s].set(jnp.asarray(v, x.dtype))
        return jax.device_put(y, sh) if sh is not None else y

    new = dict(state)
    new["act_buf"] = jax.tree.map(one, state["act_buf"], payload) \
        if spec is None else jax.tree.map(one, state["act_buf"], payload,
                                          spec)
    return new


def gather_group_state(state: Params, g: int) -> dict:
    """Host copies of one group's dev/aux slices for the retention store.

    Blocks until those leaves are materialized (a targeted device→host
    sync): under pipelined dispatch this waits only for the rounds already
    in flight, and only on the small device-side block, not the server
    params.  With donation at window > 1 the executor gathers from a
    :class:`~repro.core.handles.RoundHandle` (``handle.group_state``)
    instead — this live-state sync remains the window=1 / unwired
    fallback, where the values are identical."""
    take = lambda tree: jax.tree.map(lambda x: np.asarray(x[g]), tree)
    return {"dev": take(state["dev"]), "aux": take(state["aux"])}


def scatter_group_state(state: Params, g: int, retained: dict,
                        state_shardings=None) -> Params:
    """Functionally write one group's retained dev/aux slices back into the
    stacked state (rejoin path).  ``state_shardings`` (the jit step's state
    spec dict) re-pins the updated stacks so the next dispatch sees the
    same shardings it was compiled for."""
    def put(stacked, sl, spec):
        def one(x, v, s=None):
            y = x.at[g].set(jnp.asarray(v, x.dtype))
            return jax.device_put(y, s) if s is not None else y
        if spec is None:
            return jax.tree.map(one, stacked, sl)
        return jax.tree.map(one, stacked, sl, spec)

    new = dict(state)
    for key in ("dev", "aux"):
        spec = None if state_shardings is None else state_shardings[key]
        new[key] = put(state[key], retained[key], spec)
    return new


# ---------------------------------------------------------------------------
# Serving steps (prefill / decode) — single merged global model
# ---------------------------------------------------------------------------

def serve_param_specs(params: Params, par: Parallelism) -> Params:
    return param_specs(params, par)


def _cache_specs(caches, par: Parallelism) -> list:
    """Decode caches: batch over dp when divisible; the long axis (KV slots
    for attention, heads for SSM states) over ``model``.  KV-slot sharding
    is the flash-decoding layout — each model shard scores its slice of the
    context and the partial softmax reduces over ``model``."""
    dp = tuple(par.dp_axes)
    tp = par.tp_axis
    dp_size = par.dp_size
    tp_size = par.mesh.shape[tp]

    def spec_leaf(path_key: str, leaf):
        # leaves are stacked (n_periods, B, ...)
        s = [None] * leaf.ndim
        if leaf.ndim >= 2 and leaf.shape[1] % dp_size == 0:
            s[1] = dp
        if "conv" in path_key:                      # (n, B, K-1, Cd)
            if leaf.ndim == 4 and leaf.shape[3] % tp_size == 0:
                s[3] = tp
        elif "ssm" in path_key:                     # (n, B, H, N, P)
            if leaf.ndim == 5 and leaf.shape[2] % tp_size == 0:
                s[2] = tp
        elif leaf.ndim >= 3 and leaf.shape[2] % tp_size == 0:
            s[2] = tp                               # (n, B, L, Hkv, hd): L
        return P(*s)

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    specs = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        specs.append(spec_leaf(key, leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)


def jit_prefill(arch: ArchConfig, mesh, *, batch: int, seq_len: int,
                param_dtype=jnp.float32, use_kernel: bool = False,
                seq_shard: bool = True):
    """Lowerable prefill: tokens (B, S) -> (last logits, primed caches)."""
    dp = tuple(a for a in mesh.axis_names if a != "model")
    par = Parallelism(mesh=mesh, dp_axes=dp)
    b_div = batch % par.dp_size == 0
    # ep=b_div: prefill MoE layers use shard_map expert parallelism too
    # (§Perf it.7 — GSPMD materialises unsharded dispatch tables otherwise)
    run_par = replace(par, ep=b_div, constraints=True, seq_shard=seq_shard,
                      act_batch=dp if b_div else None, moe_interior=False)
    sds = jax.ShapeDtypeStruct

    params = jax.eval_shape(
        lambda: tfm.init_params(jax.random.PRNGKey(0), arch, param_dtype))
    p_spec = to_named(param_specs(params, par), mesh)
    tokens = sds((batch, seq_len), jnp.int32)
    t_spec = NamedSharding(mesh, P(dp if batch % par.dp_size == 0 else None,
                                   None))
    args = [params, tokens]
    in_shardings = [p_spec, t_spec]
    if arch.frontend_len:
        args.append(sds((batch, arch.frontend_len, arch.d_model),
                        param_dtype))
        in_shardings.append(NamedSharding(
            mesh, P(dp if batch % par.dp_size == 0 else None, None, None)))

    def prefill_fn(params, tokens, frontend=None):
        return tfm.prefill(params, arch, tokens, max_len=seq_len,
                           frontend=frontend, use_kernel=use_kernel,
                           parallelism=run_par, remat=True)

    jitted = jax.jit(prefill_fn, in_shardings=tuple(in_shardings))
    return jitted, tuple(args)


def jit_decode(arch: ArchConfig, mesh, *, batch: int, cache_len: int,
               param_dtype=jnp.float32):
    """Lowerable decode: one new token against a KV cache of ``cache_len``."""
    dp = tuple(a for a in mesh.axis_names if a != "model")
    par = Parallelism(mesh=mesh, dp_axes=dp)
    sds = jax.ShapeDtypeStruct

    params = jax.eval_shape(
        lambda: tfm.init_params(jax.random.PRNGKey(0), arch, param_dtype))
    caches = jax.eval_shape(
        lambda: tfm.init_serve_state(arch, batch, cache_len, param_dtype))
    p_spec = to_named(param_specs(params, par), mesh)
    c_spec = to_named(_cache_specs(caches, par), mesh)
    b_ok = batch % par.dp_size == 0
    tok_spec = NamedSharding(mesh, P(dp if b_ok else None, None))

    def decode_fn(params, caches, token, position):
        return tfm.serve_decode_step(params, arch, caches, token, position)

    jitted = jax.jit(
        decode_fn,
        in_shardings=(p_spec, c_spec, tok_spec, NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, P(dp if b_ok else None, None)),
                       c_spec),
        donate_argnums=(1,))
    args = (params, caches, sds((batch, 1), jnp.int32),
            sds((), jnp.int32))
    return jitted, args
