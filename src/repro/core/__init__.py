from .partition import (LayerProfile, cnn_profile, transformer_profile,
                        select_split, split_costs)
from .aggregator import AsyncAggregator, fedasync_update, staleness_weight
from .scheduler import Message, TaskScheduler
from .flow_control import FlowController
from .control_plane import ControlPlane, RetentionStore, RoundPlan
from .executor import RoundExecutor, RoundStats, StragglerProfiles
from .simulation import (Metrics, Sim, SimCluster, SimModel,
                         heterogeneous_cluster, simulate_fedoptima)
from .baselines import (REGISTRY, simulate_classic_fl, simulate_fedasync,
                        simulate_fedbuff, simulate_oafl, simulate_pipar,
                        simulate_splitfed)
from .learning import (FedOptimaLearner, FullModelLearner, ModelAdapter,
                       SplitLearner)
