"""Pipelined round executor: overlap host planning with device execution.

The paper eliminates dependency idle time *on the mesh* (the device and
server halves of the jit'd step have no data dependency), but a naive
driver reintroduces it on the HOST: plan round r, build its batch,
dispatch, then block on the metrics fetch before planning r+1 — the host
and the mesh strictly alternate.  :class:`RoundExecutor` removes that
alternation with a double-buffered loop riding JAX's async dispatch:

* ``step(state, batch)`` returns *futures* immediately; nothing blocks
  until a concrete value is read.  The executor keeps up to ``window``
  dispatched rounds in flight and fetches each round's metrics lazily,
  one drain behind the dispatch frontier — so the host plans round r+1
  and assembles its batch while round r executes on the mesh.
* ``window=1`` drains immediately after every dispatch, which is exactly
  the old synchronous loop — same plans, same batches, same metrics, bit
  for bit.  ``window=2`` is classic double buffering; deeper windows
  trade checkpoint/retention latency for more slack.  Planning consumes
  only host state (ControlPlane bookkeeping + the driver's RNG), never
  device values, and the profile patterns are pure functions of the
  profile seeds (``observe_round`` rescales without perturbing ratios),
  so metric *values* are window-invariant; only wall time changes.

The executor also owns the two host↔mesh consistency duties that the
round loop used to interleave by hand:

* **measured straggler profiles** — each drained round updates a
  :class:`StragglerProfiles` EMA from the measured wall time; the
  resulting ``produce``/``reads`` patterns feed the next
  ``ControlPlane.plan_round`` instead of host-supplied placeholders
  (REFL/Apodotiko-style: schedule from observed speeds, not assumed).
* **per-group state retention** — when a plan retires a dropped group,
  the executor gathers its dev/aux slices into the ControlPlane's
  RetentionStore before dispatch; when a group rejoins, its retained
  params are scattered back on-mesh so it resumes from its OWN state at
  its recorded staleness (the aggregation broadcast is masked via
  ``bcast_mask``, so the dropped rows were never resynced).

The ω-cap invariant is enforced with a real ``RuntimeError`` (asserts
are stripped under ``python -O``), surfacing the violating ring-slot
occupancy.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.analysis import sanitize as _san
from repro.core.handles import HandleRing, RoundHandle
from repro.obs import trace as _tr
from repro.obs.clock import now as _now
from repro.obs.metrics import MetricsRegistry


# ---------------------------------------------------------------------------
# Measured straggler profiles
# ---------------------------------------------------------------------------

class StragglerProfiles:
    """EMA over *measured* per-group step/transfer times + server batch time.

    The profile is observed, never assumed: the event simulator feeds it
    per-device iteration/transfer durations as they complete, and the pod
    executor feeds it each drained round's wall time (SimModel-style cost
    accounting sets the relative per-group speeds; the measurement sets
    the absolute scale — on a lockstep mesh the slowest group binds the
    micro-iteration).  From the EMAs it derives the two patterns
    ``ControlPlane.plan_round`` consumes:

    ``produce(H)`` — (H, G) bool: group g emits at micro-iteration h when
    its cumulative progress at its measured speed crosses a new whole
    batch (the fastest group emits every iteration; a group at half speed
    every other one).

    ``reads(H)`` — (H,) bool: the server consumes a new scheduled batch at
    iteration h when its measured per-batch time keeps up with the
    micro-iteration cadence; a slower server consumes on a strided
    subset (the skipped iterations replay the last slot — Fig. 1(d)'s
    never-idle server, without phantom consumption events).

    Unseeded profiles yield all-true patterns — identical to the
    placeholder defaults, so homogeneous runs are bit-for-bit unchanged.
    """

    def __init__(self, n_groups: int, *, beta: float = 0.25,
                 step_s=None, transfer_s=None, server_s: float | None = None):
        if n_groups < 1:
            raise ValueError(f"need n_groups >= 1, got {n_groups}")
        self.G = n_groups
        self.beta = beta
        self.step_s = None if step_s is None else \
            np.asarray(step_s, float).copy()        # (G,) s / micro-iter
        self.transfer_s = None if transfer_s is None else \
            np.asarray(transfer_s, float).copy()    # (G,) s / act batch
        self.server_s = server_s                    # s / scheduled batch
        self.n_obs = 0

    @classmethod
    def from_sim_model(cls, model, cluster, **kw) -> "StragglerProfiles":
        """Seed from SimModel-style cost accounting (FLOPs / rates); the
        measured observations then correct the seeds in place."""
        step = (model.dev_fwd_flops + model.dev_bwd_flops) / \
            np.asarray(cluster.dev_flops, float)
        transfer = model.act_bytes / np.asarray(cluster.dev_bw, float)
        server = model.srv_flops_per_batch / float(cluster.srv_flops)
        return cls(cluster.K, step_s=step, transfer_s=transfer,
                   server_s=server, **kw)

    # -- observations ---------------------------------------------------
    def _ema(self, old, new):
        return new if old is None else (1.0 - self.beta) * old + \
            self.beta * new

    def observe_group(self, g: int, *, step_s: float | None = None,
                      transfer_s: float | None = None):
        """One measured device event (simulator path): an iteration took
        ``step_s`` and/or an activation upload took ``transfer_s``."""
        if step_s is not None:
            if self.step_s is None:
                self.step_s = np.full(self.G, float(step_s))
            else:
                self.step_s[g] = self._ema(self.step_s[g], float(step_s))
        if transfer_s is not None:
            if self.transfer_s is None:
                self.transfer_s = np.full(self.G, float(transfer_s))
            else:
                self.transfer_s[g] = self._ema(self.transfer_s[g],
                                               float(transfer_s))
        self.n_obs += 1

    def observe_server(self, batch_s: float):
        self.server_s = self._ema(self.server_s, float(batch_s))
        self.n_obs += 1

    def observe_round(self, wall_s: float, H: int):
        """Pod path: one lockstep round of H micro-iterations measured at
        ``wall_s`` on the mesh.  The slowest group binds the lockstep
        cadence, so the measurement rescales the profile to put the
        slowest group at ``wall_s/H`` while preserving the relative
        speeds already observed/seeded (uniform when unseeded).

        ``step_s`` and ``server_s`` are rescaled by the SAME cadence
        factor, so every ratio the derived patterns depend on is an exact
        invariant of the seeds — ``produce``/``reads`` are pure functions
        of the profile's relative speeds, never of wall-clock noise.
        That is what makes pod plans deterministic and window-invariant
        even for heterogeneously seeded profiles."""
        per_iter = max(wall_s / max(H, 1), 1e-12)
        if self.step_s is None:
            self.step_s = np.full(self.G, per_iter)
        else:
            cadence = max(float(self.step_s.max()), 1e-12)
            self.step_s = self._ema(self.step_s,
                                    self.step_s / cadence * per_iter)
            if self.server_s is not None:
                self.server_s = self._ema(self.server_s,
                                          self.server_s / cadence * per_iter)
        if self.server_s is None:
            # the fused step trains the server every micro-iteration: its
            # per-batch time IS the (post-update) cadence, keeping rho=1
            # exactly for any seeding combination
            self.server_s = float(self.step_s.max())
        self.n_obs += 1

    # -- derived patterns ------------------------------------------------
    @staticmethod
    def _stride(rate: np.ndarray, H: int) -> np.ndarray:
        """(H, ...) bool: True at h when cumulative progress at ``rate``
        (batches per micro-iteration, in (0, 1]) crosses a whole batch."""
        h = np.arange(H, dtype=float)[:, None] if rate.ndim else \
            np.arange(H, dtype=float)
        return np.floor((h + 1.0) * rate) > np.floor(h * rate)

    def produce(self, H: int) -> np.ndarray:
        """(H, G) bool straggler emission pattern for plan_round."""
        if self.step_s is None:
            return np.ones((H, self.G), bool)
        t = np.maximum(self.step_s, 1e-12)
        speed = t.min() / t                       # (G,) relative, in (0, 1]
        return self._stride(speed[None, :], H)

    def reads(self, H: int) -> np.ndarray:
        """(H,) bool server-consumption pattern for plan_round."""
        if self.server_s is None or self.step_s is None:
            return np.ones(H, bool)
        cadence = max(float(self.step_s.max()), 1e-12)
        rho = np.asarray(min(1.0, cadence / max(self.server_s, 1e-12)))
        return self._stride(rho, H)

    def summary(self) -> dict:
        """JSON-able snapshot for logs / benchmark records."""
        out = {"n_obs": int(self.n_obs), "beta": self.beta}
        if self.step_s is not None:
            out["step_s"] = [float(v) for v in self.step_s]
        if self.transfer_s is not None:
            out["transfer_s"] = [float(v) for v in self.transfer_s]
        if self.server_s is not None:
            out["server_s"] = float(self.server_s)
        return out


# ---------------------------------------------------------------------------
# The executor
# ---------------------------------------------------------------------------

@dataclass
class RoundStats:
    """Per-round host/device accounting (times in seconds)."""
    round: int
    plan_s: float = 0.0          # plan_round + retention transfers
    build_s: float = 0.0         # host batch assembly
    in_flight_at_dispatch: int = 0
    hidden_host_s: float = 0.0   # host work done while the mesh was busy
                                 # (set at drain: clamped by the in-flight
                                 # round's observed completion)
    round_wall_s: float = 0.0    # measured device wall (set at drain)
    plan: object = None          # the RoundPlan this round ran under —
                                 # available in the on_metrics drain hook,
                                 # dropped afterwards (memory)
    _host_t0: float = field(default=0.0, repr=False)
    _dispatch_t: float = field(default=0.0, repr=False)


class RoundExecutor:
    """Bounded-window pipelined driver for ``step(state, batch)`` programs.

    Parameters
    ----------
    step : callable(state, batch) -> (state, metrics)
        The jit'd hybrid round (or any async-dispatching stand-in whose
        metric values support ``float()`` lazily).
    cplane : ControlPlane
        Host planner; its ``plan_round``/``finish_round`` bookkeeping is
        committed at DISPATCH time (host order), never at drain time.
    window : int
        Max dispatched-but-undrained rounds.  1 = synchronous (bit-for-bit
        the old loop), 2 = double buffering.
    profiles : StragglerProfiles | None
        Measured straggler profiles; when given, every plan uses
        ``profiles.produce/reads`` and every drained round feeds the EMA.
    gather / scatter : callables for per-group retention
        ``gather(state, g) -> params`` (host copies) and
        ``scatter(state, g, params) -> state``; see
        ``fedopt_step.gather_group_state`` / ``scatter_group_state``.
    store / gather_slot / scatter_slot : tiered activation store wiring
        ``store`` is a ``repro.memory.ActivationStore`` (host spill
        pool); ``gather_slot(state, s) -> payload`` and
        ``scatter_slot(state, s, payload) -> state`` move one ring
        slot host↔mesh (``fedopt_step.gather_act_slot`` /
        ``scatter_act_slot``).  Planned ``fill``/``spill`` moves run at
        the round boundary, inside the in-flight window.  Fills and the
        host-side bookkeeping stay fully async; a SPILL gathers
        pre-round ring content from the previous round's HANDLE (the
        donation-safe ``jnp.copy`` snapshot taken at dispatch) when one
        exists, so deep windows never synchronize on the live ring —
        the live-state ``np.asarray`` sync remains only as the
        window=1 / unwired fallback.  Fills run before spills, so the
        pool never transiently exceeds its cap; a slot filled and
        re-spilled at the same boundary spills the fill payload itself
        (the handle predates the fill).
    registry : ElasticRegistry | None
        Optional roster mirror: drops/rejoins are recorded with the round
        index as the timestamp.
    faults : repro.faults.PodFaultInjector | None
        Chaos plane for pod-mode runs.  At each round head the injector
        may raise ``InjectedCrash`` (server crash at a round boundary —
        the driver persists the fired-crash set and resumes from the
        checkpoint store), mask timed-out groups out of ``active`` (their
        slots are reclaimed by the normal plan_round retire path and the
        retained state rejoins at the recorded α), and veto poisoned
        activation production via the update-validation gate.  ``None``
        (the default) is a strict no-op: no branch of the round loop
        changes.
    """

    def __init__(self, step, cplane, *, window: int = 1, profiles=None,
                 gather=None, scatter=None, registry=None,
                 store=None, gather_slot=None, scatter_slot=None,
                 faults=None, metrics=None):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.step = step
        self.cplane = cplane
        self.window = window
        self.profiles = profiles
        self.gather = gather
        self.scatter = scatter
        self.registry = registry
        self.store = store
        self.gather_slot = gather_slot
        self.scatter_slot = scatter_slot
        self.faults = faults
        self.stats: list[RoundStats] = []
        # -- instruments (pure bookkeeping; legacy names are properties) --
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._g_in_flight = self.metrics.gauge("exec.in_flight")
        self._c_host_s = self.metrics.counter("exec.host_s")
        self._c_hidden_s = self.metrics.counter("exec.hidden_host_s")
        self._c_ckpt_flush = self.metrics.counter("exec.ckpt_flush")
        self._c_ckpt_noflush = self.metrics.counter("exec.ckpt_noflush")
        self._g_handle_bytes = self.metrics.gauge("exec.handle_bytes")
        self._h_plan = self.metrics.histogram("exec.plan_s")
        self._h_build = self.metrics.histogram("exec.build_s")
        self._h_wall = self.metrics.histogram("exec.round_wall_s")
        self._pending: deque = deque()     # (RoundStats, metrics futures)
        self._last_drain_t: float | None = None
        self._last_completion_t: float | None = None
        # -- donation-safe per-round handle ring --------------------------
        # With window > 1 the donated step invalidates older rounds' state
        # references, so every leaf a LATER boundary may need (retention
        # gathers read dev/aux; spill gathers read act_buf) is snapshotted
        # into the ring at dispatch (one fused on-device copy; D2H happens
        # lazily per consumed slice).  Capture is ADAPTIVE so workloads
        # that never consume a handle never pay for one: act_buf is
        # captured only while a spill pool is active, and dev/aux only
        # once churn has been observed — the first churned boundary falls
        # back to the live-state gather (value-identical: the live state
        # at a boundary IS the previous round's output, not yet donated).
        # window=1 consumers always read the live state synchronously.
        self._churn_seen = False
        self.handles = HandleRing(depth=window + 1)
        self._deferred: deque[RoundHandle] = deque()   # no-flush saves

    # legacy counter names, read-only over the registry instruments
    @property
    def peak_in_flight(self) -> int:
        return int(self._g_in_flight.peak)

    @property
    def total_host_s(self) -> float:
        return self._c_host_s.value

    @property
    def hidden_host_s(self) -> float:
        return self._c_hidden_s.value

    @property
    def n_ckpt_flush(self) -> int:
        return int(self._c_ckpt_flush.value)

    @property
    def n_ckpt_noflush(self) -> int:
        return int(self._c_ckpt_noflush.value)

    @property
    def handle_bytes_peak(self) -> int:
        return int(self._g_handle_bytes.peak)

    # ------------------------------------------------------------------
    def run(self, state, start_round: int, end_round: int, *, active_fn,
            batch_fn, on_metrics=None, checkpoint_every: int = 0,
            checkpoint_fn=None, capture_fn=None, checkpoint_flush=None):
        """Drive rounds [start_round, end_round).

        active_fn(r) -> (G,) bool roster for round r (host RNG lives with
        the caller, consumed in dispatch order — window-invariant).
        batch_fn(r, plan) -> jit batch for round r.
        on_metrics(r, metrics, stats) fires at drain, in round order.

        Checkpointing comes in two shapes:

        * **legacy flush** (``capture_fn=None``): the pipeline is fully
          drained at the due boundary and ``checkpoint_fn(r, state)`` is
          called with the live post-round-r state — the synchronous
          loop's save point exactly.
        * **checkpoint-without-flush** (``capture_fn`` given): at the due
          boundary a donation-safe :class:`RoundHandle` of the full state
          is captured at DISPATCH (on-device copies + async D2H), with
          ``capture_fn(r)`` providing the dispatch-time host metadata
          (ControlPlane snapshot, RNG state, extras) so arrays and
          bookkeeping describe the same round.  ``checkpoint_fn(r,
          handle)`` then runs once the handle's copies are ready — rounds
          r+1..r+window stay in flight the whole time, and the save never
          lags more than ``window`` rounds behind (forced at the end of
          the run).  Pass ``checkpoint_flush=True`` to keep the drain
          while still receiving handles (the flush-vs-no-flush A/B).
        """
        flush = (capture_fn is None) if checkpoint_flush is None \
            else bool(checkpoint_flush)
        history: list[dict] = []
        for r in range(start_round, end_round):
            t0 = _now()
            active = np.asarray(active_fn(r), bool)
            H = self.cplane.H
            produce = self.profiles.produce(H) if self.profiles is not None \
                else None
            reads = self.profiles.reads(H) if self.profiles is not None \
                else None
            if self.faults is not None:
                # crash faults raise BEFORE any round-r bookkeeping, so a
                # resumed run replans round r from identical state
                self.faults.on_round_start(r)
                active = self.faults.mask_active(r, active)
                if produce is None:
                    produce = np.ones((H, self.cplane.G), bool)
                produce = self.faults.mask_produce(r, produce, active)
            plan = self.cplane.plan_round(
                active=active, produce=produce, reads=reads,
                lookahead=self.window if self.store is not None else 0)
            state = self._apply_retention(state, plan, r)
            state = self._apply_memory(state, plan, r)
            t1 = _now()
            batch = batch_fn(r, plan)
            t2 = _now()
            if _tr.TRACING:
                _tr.emit_span("host/plan", "plan_round", t0, t1, round=int(r))
                _tr.emit_span("host/build", "build_batch", t1, t2,
                              round=int(r))
            st = RoundStats(round=r, plan_s=t1 - t0, build_s=t2 - t1,
                            in_flight_at_dispatch=len(self._pending),
                            plan=plan, _host_t0=t0, _dispatch_t=t2)
            state, metrics = self.step(state, batch)
            self.cplane.finish_round(active=active)
            self._check_cap(r)
            if _san.TRACING:
                _san.emit("exec.round", cp=self.cplane, store=self.store,
                          round=int(r), in_flight=len(self._pending))
            self._pending.append((st, metrics))
            self._g_in_flight.set(len(self._pending))
            due = checkpoint_fn is not None and checkpoint_every and \
                (r + 1) % checkpoint_every == 0
            self._capture_round(r, state, due and not flush, capture_fn)
            while len(self._pending) >= self.window:
                self._drain_one(history, on_metrics)
            if due and flush:
                while self._pending:          # flush: state == round r
                    self._drain_one(history, on_metrics)
                tc0 = _now() if _tr.TRACING else 0.0
                if capture_fn is None:
                    checkpoint_fn(r, state)   # legacy (r, state) contract
                else:
                    # drained pipe: the live tree is stable until the next
                    # dispatch, so the handle wraps it without copying
                    checkpoint_fn(r, RoundHandle.capture(
                        r, state, meta=capture_fn(r), copy=False))
                if _tr.TRACING:
                    _tr.emit_span("host/ckpt", "ckpt_flush", tc0, _now(),
                                  round=int(r))
                self._c_ckpt_flush.inc()
            self._service_deferred(checkpoint_fn, now=r)
        while self._pending:
            self._drain_one(history, on_metrics)
        self._service_deferred(checkpoint_fn, force=True)
        if self.faults is not None:
            self.faults.finalize(end_round)
        return state, history

    # ------------------------------------------------------------------
    def _light_keys(self) -> tuple:
        """Leaves the NEXT boundary's consumers may slice from this
        round's handle.  Adaptive: no spill pool and no churn so far
        means no keys — and no per-round copy cost."""
        if self.window <= 1:
            return ()
        keys = []
        if self.gather is not None and self._churn_seen:
            keys += ["dev", "aux"]
        if self.store is not None and \
                getattr(self.cplane, "pool_cap", 0) > 0:
            keys += ["act_buf"]
        return tuple(keys)

    def _capture_round(self, r: int, state, ckpt_due: bool, capture_fn):
        """Dispatch-time handle capture: the light per-round snapshot of
        retention-/spill-referenced leaves into the ring, plus (when a
        no-flush checkpoint is due) a full-state handle with async D2H
        staging queued for the deferred saver."""
        keys = self._light_keys()
        light = keys and isinstance(state, dict)
        if not (light or ckpt_due):
            return
        tc0 = _now() if _tr.TRACING else 0.0
        if ckpt_due:
            meta = capture_fn(r) if capture_fn is not None else None
            h = RoundHandle.capture(r, state, meta=meta, to_host=True)
            self._deferred.append(h)
        if light:
            self.handles.push(RoundHandle.capture(r, state, keys=keys))
        if _tr.TRACING:
            _tr.emit_span("host/capture", "capture_handle", tc0, _now(),
                          round=int(r))
        self._g_handle_bytes.set(
            self.handles.nbytes + sum(h.nbytes for h in self._deferred))

    def _service_deferred(self, checkpoint_fn, *, now=None,
                          force: bool = False):
        """Run deferred no-flush saves whose device copies completed.
        A save is forced once its round falls a full window behind (or
        at the end of the run), bounding checkpoint lag — in-order
        execution means the copy is all but certainly done by then, so
        the force is a consistency backstop, not a stall in practice."""
        while self._deferred:
            h = self._deferred[0]
            if not (force or h.ready()
                    or (now is not None and now - h.round >= self.window)):
                break
            self._deferred.popleft()
            tc0 = _now() if _tr.TRACING else 0.0
            checkpoint_fn(h.round, h)
            if _tr.TRACING:
                _tr.emit_span("host/ckpt", "ckpt_deferred", tc0, _now(),
                              round=int(h.round))
            self._c_ckpt_noflush.inc()

    # ------------------------------------------------------------------
    def _apply_retention(self, state, plan, r: int):
        # the plan's bcast_mask already excludes dropped groups from the
        # aggregation broadcast, so running churn WITHOUT retention wiring
        # would hand a rejoining group phantom-trained params — refuse
        # loudly rather than silently skip the transfers
        cp = self.cplane
        if plan.retire and self.gather is None:
            raise RuntimeError(
                f"round {r} drops groups {plan.retire} but this executor "
                "has no gather fn — per-group retention must be wired "
                "(fedopt_step.gather_group_state/scatter_group_state) for "
                "runs with churn")
        if plan.restore and self.scatter is None:
            raise RuntimeError(
                f"round {r} restores groups {plan.restore} but this "
                "executor has no scatter fn — per-group retention must be "
                "wired for runs with churn")
        if plan.retire or plan.restore:
            # from here on, dev/aux ride the handle ring (this boundary's
            # gathers use the ring when a handle exists, else the live
            # state — the same values either way)
            self._churn_seen = True
        h = self.handles.get(r - 1) if plan.retire else None
        for g in plan.retire:
            if h is not None and h.has("dev"):
                # donation-safe: slice the previous round's handle (its
                # post-step dev/aux copies ARE this boundary's pre-round
                # values) instead of syncing the live, soon-donated state
                cp.retain_group(g, h.group_state(g))
            else:
                cp.retain_group(g, self.gather(state, g))
            if self.registry is not None:
                self.registry.leave(g, t=float(r))
        for g in plan.restore:
            # validate before popping: the error path must not destroy the
            # retained metadata (a fixed-up rerun still needs the entry)
            if cp.retention.params_of(g) is None:
                raise RuntimeError(
                    f"group {g} rejoins but its retained params are "
                    "missing — a resumed run must restore the checkpoint's "
                    "extras into ControlPlane.retention.load_arrays first")
            entry = cp.release_group(g)
            state = self.scatter(state, g, entry["params"])
            if self.registry is not None:
                self.registry.rejoin(g, t=float(r))
        return state

    def _apply_memory(self, state, plan, r: int):
        """Perform the plan's tiered-store moves (host↔mesh ring-slot
        transfers) before dispatch.  Fills first — a fill frees the pool
        entry a same-boundary spill may need — then spills of pre-round
        ring content into the host pool, then plan-neutral prefetch
        staging of lookahead pool entries."""
        if not (plan.fill or plan.spill or plan.prefetch):
            return state
        tm0 = _now() if _tr.TRACING else 0.0
        if self.store is None or self.gather_slot is None or \
                self.scatter_slot is None:
            raise RuntimeError(
                f"round {r} plans spill/fill moves "
                f"(fill={plan.fill}, spill={plan.spill}) but this executor "
                "has no ActivationStore wiring — pass store=/gather_slot=/"
                "scatter_slot= (fedopt_step.gather_act_slot/"
                "scatter_act_slot) for runs with pool_cap > 0")
        filled: dict[int, dict] = {}
        for key, s in plan.fill:
            payload = self.store.fill(key)
            filled[s] = payload
            state = self.scatter_slot(state, s, payload)
        h = self.handles.get(r - 1) if plan.spill else None
        for s, key in plan.spill:
            if s in filled:
                # fill-then-spill of the same slot at one boundary: the
                # handle predates the fill, so the ring content being
                # spilled IS the fill payload just scattered — reuse it
                # (bit-identical to a live gather-after-scatter)
                self.store.spill(key, filled[s])
            elif h is not None and h.has("act_buf"):
                # donation-safe: slice the previous round's ring handle
                # instead of syncing the live (about-to-donate) ring
                self.store.spill(key, h.act_slot(s))
            else:
                self.store.spill(key, self.gather_slot(state, s))
        for key in plan.prefetch:
            self.store.prefetch(key)
        if _tr.TRACING:
            _tr.emit_span("host/memory", "fill_spill", tm0, _now(),
                          round=int(r), fills=len(plan.fill),
                          spills=len(plan.spill),
                          prefetch=len(plan.prefetch))
        return state

    def _check_cap(self, r: int):
        cp = self.cplane
        if not cp.within_cap:
            raise RuntimeError(
                f"activation cap ω={cp.omega}+pool={cp.pool_cap} violated "
                f"after round {r}: {cp.live_slots}/{cp.omega} live ring "
                f"slots (occupancy={cp.slot_occupancy}), "
                f"{cp.pool_live}/{cp.pool_cap} pool entries, flow "
                f"promised={cp.flow.promised} of cap={cp.flow.cap} "
                f"(buffered={cp.flow.buffered}, "
                f"inflight={cp.flow.inflight}, "
                f"tokens={cp.flow.active_tokens})")

    def _drain_one(self, history, on_metrics):
        st, metrics = self._pending.popleft()
        t_fetch = _now()
        m = {k: float(v) for k, v in metrics.items()}   # blocks here only
        t = _now()
        # device-completion estimate: a blocking fetch pins the completion
        # at its return; a non-blocking fetch means the round finished at
        # some unobservable earlier point — fall back to its dispatch time
        # so overlap is only ever credited on evidence (a lower bound:
        # hidden time is never overstated)
        completion = t if (t - t_fetch) > 1e-4 else st._dispatch_t
        # hidden host time for THIS round's plan+build: it overlapped the
        # mesh only while the previously-dispatched round was still
        # executing — clamp by that round's observed completion (a host
        # interval outlasting the device work is exposed, not hidden)
        if st.in_flight_at_dispatch and self._last_completion_t is not None:
            st.hidden_host_s = max(
                0.0, min(st._dispatch_t, self._last_completion_t)
                - st._host_t0)
        self._last_completion_t = completion
        # device wall estimate: dispatch→done is exact when nothing was
        # queued ahead; under pipelining the completion-to-completion gap
        # is the steady-state round time — take the tighter of the two
        wall = t - st._dispatch_t
        if self._last_drain_t is not None:
            wall = min(wall, max(t - self._last_drain_t, 1e-9))
        self._last_drain_t = t
        st.round_wall_s = wall
        if self.profiles is not None:
            self.profiles.observe_round(wall, self.cplane.H)
        self._c_host_s.inc(st.plan_s + st.build_s)
        self._c_hidden_s.inc(st.hidden_host_s)
        self._h_plan.observe(st.plan_s)
        self._h_build.observe(st.build_s)
        self._h_wall.observe(wall)
        if _tr.TRACING:
            # mesh busy: dispatch → observed completion (clipped so
            # pipelined rounds tile the lane instead of overlapping);
            # device lanes mirror it for the groups the plan broadcast to
            _tr.emit_span("host/drain", "drain", t_fetch, t,
                          round=int(st.round))
            end = completion if completion > st._dispatch_t \
                else st._dispatch_t + wall
            _tr.emit_span("mesh", "round", st._dispatch_t, end,
                          clip=True, round=int(st.round))
            if st.plan is not None and \
                    getattr(st.plan, "bcast_mask", None) is not None:
                for g in np.nonzero(
                        np.asarray(st.plan.bcast_mask) > 0.5)[0]:
                    _tr.emit_span(f"dev/{int(g)}", "round",
                                  st._dispatch_t, end, clip=True,
                                  round=int(st.round))
        self.stats.append(st)
        history.append(m)
        if on_metrics is not None:
            on_metrics(st.round, m, st)
        # the full RoundPlan (H×G schedule arrays) is only needed through
        # the drain hook; keep the per-round stats list O(scalars) so long
        # runs don't accumulate plans
        st.plan = None

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        """JSON-able overlap accounting for logs / benchmarks.

        Besides whole-run totals, reports STEADY-STATE exposure excluding
        the first ``window`` dispatches: those warmup rounds have no (or
        a partial) in-flight round to hide behind, so including them
        biases deep-window comparisons against exactly the windows they
        are meant to evaluate."""
        n = len(self.stats)
        warmup = min(n, self.window)
        steady = self.stats[warmup:]
        host_steady = sum(s.plan_s + s.build_s for s in steady)
        hidden_steady = sum(s.hidden_host_s for s in steady)
        out = {
            "rounds": n,
            "window": self.window,
            "peak_in_flight": self.peak_in_flight,
            "host_s_total": self.total_host_s,
            "host_s_hidden": self.hidden_host_s,
            "host_s_exposed": self.total_host_s - self.hidden_host_s,
            "host_ms_hidden_per_round":
                1e3 * self.hidden_host_s / max(n, 1),
            "device_s_per_round":
                float(np.mean([s.round_wall_s for s in self.stats]))
                if n else 0.0,
            "warmup_rounds_excluded": warmup,
            "host_s_exposed_steady": host_steady - hidden_steady,
            "hidden_host_frac_steady":
                hidden_steady / host_steady if host_steady > 0 else 0.0,
            "handles": self.handles.summary(),
            "handle_bytes_peak": int(self.handle_bytes_peak),
            "checkpoints": {"flush_saves": self.n_ckpt_flush,
                            "noflush_saves": self.n_ckpt_noflush},
        }
        if self.profiles is not None:
            out["profiles"] = self.profiles.summary()
        if self.store is not None:
            out["memory"] = {**self.cplane.memory_summary(),
                             **self.store.summary()}
        if self.faults is not None:
            out["faults"] = self.faults.report()
        return out
