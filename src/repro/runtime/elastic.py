"""Elastic scaling: the number of participating devices K changes mid-run.

FedOptima's design makes this nearly free (paper §3.4.2): the server holds
ONE model + a global activation cap ω, so admission of a new device is just
(1) registering an activation queue, (2) sending it the current global
device-side model, and (3) flow control naturally throttles the new
sender.  Departure is queue removal; in-flight activations still train.

`ElasticRegistry` is the control-plane bookkeeping used by both the event
simulator and the training drivers.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class DeviceInfo:
    device_id: int
    flops_per_s: float
    bandwidth: float            # bytes/s
    joined_at: float = 0.0
    active: bool = True
    left_at: float | None = None   # time of the last departure (None: never
                                   # left, or currently active)
    absences: int = 0              # departures so far (churn accounting)


@dataclass
class ElasticRegistry:
    devices: dict = field(default_factory=dict)
    _next_id: int = 0

    def join(self, flops_per_s: float, bandwidth: float, t: float = 0.0) -> int:
        did = self._next_id
        self._next_id += 1
        self.devices[did] = DeviceInfo(did, flops_per_s, bandwidth, t, True)
        return did

    def leave(self, device_id: int, t: float | None = None):
        if device_id in self.devices:
            info = self.devices[device_id]
            if info.active:
                # only the first leave of an absence records the timestamp:
                # a repeated (defensive) leave must not reset or erase it
                info.absences += 1
                info.left_at = t
            info.active = False

    def rejoin(self, device_id: int, t: float = 0.0):
        if device_id in self.devices:
            self.devices[device_id].active = True
            self.devices[device_id].joined_at = t
            self.devices[device_id].left_at = None

    def absence(self, device_id: int, t: float) -> float | None:
        """How long device_id has been gone as of time t (None if active
        or its departure was recorded without a timestamp)."""
        info = self.devices[device_id]
        if info.active or info.left_at is None:
            return None
        return t - info.left_at

    @property
    def active_ids(self) -> list[int]:
        return [d for d, i in self.devices.items() if i.active]

    def set_bandwidth(self, device_id: int, bw: float):
        self.devices[device_id].bandwidth = bw
