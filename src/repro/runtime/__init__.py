from .fault_tolerance import ChurnModel, CheckpointPolicy, resume_or_init
from .elastic import DeviceInfo, ElasticRegistry
