"""Fault tolerance policy: checkpoint/restart + device churn handling.

Two layers of resilience:

1. **FL-native elasticity** (paper §3.4.2): device groups joining/leaving
   never block training — the simulator and the hybrid step both tolerate
   any subset of devices being active.  `ChurnModel` reproduces the paper's
   unstable-environment protocol (§6.4): every `interval` sim-seconds each
   device drops with probability p and rejoins at the next boundary;
   bandwidth is re-drawn uniformly from [bw_lo, bw_hi].

2. **Checkpoint/restart** for the server job itself: `CheckpointPolicy`
   decides when to snapshot (step cadence + wall-clock cadence), and
   `resume_or_init` restores the newest committed snapshot that passes
   integrity verification (``store.verify_snapshot``) after a crash —
   a snapshot torn after commit is skipped and reported, never
   half-loaded.
"""
from __future__ import annotations

import math
import sys
from dataclasses import dataclass, field

import numpy as np

from repro.checkpoint import store
from repro.obs.clock import now as _now


@dataclass
class ChurnModel:
    n_devices: int
    p_drop: float = 0.0
    interval: float = 600.0          # re-draw every 10 simulated minutes (§6.4)
    bw_lo: float = 25e6 / 8          # bytes/s (25 Mbps)
    bw_hi: float = 50e6 / 8
    seed: int = 0

    def draw(self, t: float):
        """State for the interval containing time t: (active mask, bw).

        The draw is a pure function of ``(seed, interval_index)`` — NOT of
        how many times / in what order ``draw`` was called — so the
        availability at time t is the same whether a consumer replays the
        whole grid (``FleetTrace.from_churn``), queries one boundary, or
        re-queries after a crash/resume mid-run.
        """
        idx = int(math.floor(t / self.interval + 1e-9))
        rng = np.random.default_rng([self.seed, idx])
        active = rng.random(self.n_devices) >= self.p_drop
        bw = rng.uniform(self.bw_lo, self.bw_hi, size=self.n_devices)
        return active, bw


@dataclass
class CheckpointPolicy:
    directory: str
    every_steps: int = 100
    every_seconds: float = 600.0
    retain: int = 3
    _last_step: int = 0
    _last_time: float = field(default_factory=_now)

    def should_save(self, step: int) -> bool:
        now = _now()
        due = (step - self._last_step >= self.every_steps or
               now - self._last_time >= self.every_seconds)
        return due

    def note_resume(self, step: int):
        """Seed the cadence from a resumed step so the first
        ``should_save`` after restart measures from the restored snapshot,
        not from the dataclass defaults (``_last_step=0`` would otherwise
        make a resume at step 5000 save again immediately)."""
        self._last_step = int(step)
        self._last_time = _now()

    def save(self, step: int, tree, metadata=None, extras=None):
        path = store.save(self.directory, step, tree, metadata, self.retain,
                          extras=extras)
        self._last_step = step
        self._last_time = _now()
        return path


def resume_or_init(directory: str, init_fn, like=None, policy=None):
    """Restore the newest *verified* snapshot, else build fresh state.

    init_fn() -> state pytree; `like` defaults to init_fn()'s structure.
    Snapshots that fail integrity verification (torn payload, checksum
    mismatch, unreadable manifest) are skipped with a warning — the next
    older retained snapshot is tried, so a tear can cost at most the
    retention window, never a half-loaded state.  When ``policy`` (a
    :class:`CheckpointPolicy`) is given, its save cadence is seeded from
    the resumed step.  Returns (state, start_step).
    """
    step, skipped = store.latest_verified_step(directory)
    for bad_step, reason in skipped:
        print(f"resume_or_init: skipping torn snapshot step {bad_step}: "
              f"{reason}", file=sys.stderr)
    template = like if like is not None else init_fn()
    if step is None:
        return template, 0
    state = store.restore(directory, step, template)
    if policy is not None:
        policy.note_resume(step)
    return state, step
