"""Fault tolerance policy: checkpoint/restart + device churn handling.

Two layers of resilience:

1. **FL-native elasticity** (paper §3.4.2): device groups joining/leaving
   never block training — the simulator and the hybrid step both tolerate
   any subset of devices being active.  `ChurnModel` reproduces the paper's
   unstable-environment protocol (§6.4): every `interval` sim-seconds each
   device drops with probability p and rejoins at the next boundary;
   bandwidth is re-drawn uniformly from [bw_lo, bw_hi].

2. **Checkpoint/restart** for the server job itself: `CheckpointPolicy`
   decides when to snapshot (step cadence + wall-clock cadence), and
   `resume_or_init` restores the latest committed snapshot after a crash.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.checkpoint import store


@dataclass
class ChurnModel:
    n_devices: int
    p_drop: float = 0.0
    interval: float = 600.0          # re-draw every 10 simulated minutes (§6.4)
    bw_lo: float = 25e6 / 8          # bytes/s (25 Mbps)
    bw_hi: float = 50e6 / 8
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def draw(self, t: float):
        """State for interval starting at time t: (active mask, bandwidths)."""
        active = self._rng.random(self.n_devices) >= self.p_drop
        bw = self._rng.uniform(self.bw_lo, self.bw_hi, size=self.n_devices)
        return active, bw


@dataclass
class CheckpointPolicy:
    directory: str
    every_steps: int = 100
    every_seconds: float = 600.0
    retain: int = 3
    _last_step: int = 0
    _last_time: float = field(default_factory=time.monotonic)

    def should_save(self, step: int) -> bool:
        now = time.monotonic()
        due = (step - self._last_step >= self.every_steps or
               now - self._last_time >= self.every_seconds)
        return due

    def save(self, step: int, tree, metadata=None):
        path = store.save(self.directory, step, tree, metadata, self.retain)
        self._last_step = step
        self._last_time = time.monotonic()
        return path


def resume_or_init(directory: str, init_fn, like=None):
    """Restore latest committed snapshot, else build fresh state.

    init_fn() -> state pytree; `like` defaults to init_fn()'s structure.
    Returns (state, start_step).
    """
    step = store.latest_step(directory)
    template = like if like is not None else init_fn()
    if step is None:
        return template, 0
    state = store.restore(directory, step, template)
    return state, step
