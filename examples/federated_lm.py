"""End-to-end driver: train the ~135M-param smollm architecture (reduced
depth for CPU wall-clock, full d_model/vocab optional) with FedOptima for
a few hundred rounds on non-IID synthetic LM shards, with checkpointing.

This is the (b) deliverable's "train a ~100M model for a few hundred
steps" driver: on a TPU pod you'd pass --full and a real mesh; on CPU the
same code path runs the smoke reduction by default.

Run:  PYTHONPATH=src python examples/federated_lm.py [--rounds 200] [--full]
"""
import argparse

from repro.launch.train import run_pod


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--rounds", type=int, default=200)
    p.add_argument("--full", action="store_true",
                   help="full smollm-135m config (slow on CPU)")
    p.add_argument("--ckpt-dir", default="/tmp/fedoptima_lm_ckpt")
    args = p.parse_args()

    ns = argparse.Namespace(
        arch="smollm-135m", full=args.full, rounds=args.rounds,
        seq_len=128 if not args.full else 1024, batch=8, H=4, l_split=0,
        lr_d=0.08, lr_s=0.08, server_opt="adamw", mesh_data=1, mesh_model=1,
        groups_per_shard=4, p_drop=0.05,         # light churn, §3.4.2
        ckpt_dir=args.ckpt_dir, ckpt_every=25, log_every=10, seed=0)
    out = run_pod(ns)
    h = out["history"]
    print(f"\ntrained {len(h)} rounds; server loss "
          f"{h[0]['s_loss']:.3f} -> {h[-1]['s_loss']:.3f}, device aux loss "
          f"{h[0]['d_loss']:.3f} -> {h[-1]['d_loss']:.3f}")
    assert h[-1]["s_loss"] < h[0]["s_loss"], "server did not learn"


if __name__ == "__main__":
    main()
