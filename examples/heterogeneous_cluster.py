"""Scenario: heterogeneous edge cluster with churn (paper §6.2-§6.4).

Simulates the paper's Testbed-B-style cluster (16 devices, 4 speed groups)
running FedOptima vs all six baselines, prints the idle-time/throughput
table, then repeats under churn (p=0.3) to show the retention gap.

Run:  PYTHONPATH=src python examples/heterogeneous_cluster.py
"""
from repro.core.baselines import REGISTRY
from repro.core.simulation import (SimModel, heterogeneous_cluster,
                                   simulate_fedoptima)
from repro.runtime.fault_tolerance import ChurnModel

MODEL = SimModel(dev_fwd_flops=2.5e9, dev_bwd_flops=5.0e9,
                 full_fwd_flops=1.4e10, srv_flops_per_batch=2.6e10,
                 act_bytes=3.2e6, dev_model_bytes=1.2e6,
                 full_model_bytes=2.2e7, batch_size=32)
CLUSTER = heterogeneous_cluster(16, base_flops=8e9,
                                speed_groups=(1.0, 1.33, 2.67, 3.84),
                                bw=100e6 / 8, srv_ratio=50.0)
DUR = 1200.0


def table(churn=None, tag=""):
    print(f"\n=== {tag} ===")
    print(f"{'method':12s} {'srv idle':>9s} {'dev idle':>9s} "
          f"{'samples/s':>10s}")
    rows = {}
    m = simulate_fedoptima(MODEL, CLUSTER, duration=DUR, omega=8,
                           churn=churn)
    rows["fedoptima"] = m
    for name, fn in REGISTRY.items():
        rows[name] = fn(MODEL, CLUSTER, duration=DUR, churn=churn)
    for name, m in rows.items():
        print(f"{name:12s} {m.srv_idle_frac:9.1%} {m.dev_idle_frac:9.1%} "
              f"{m.throughput:10.1f}")
    return rows


stable = table(tag="stable environment (Fig. 8/10)")
churny = table(churn=ChurnModel(n_devices=16, p_drop=0.3, interval=600.0,
                                bw_lo=50e6 / 8, bw_hi=100e6 / 8, seed=0),
               tag="unstable: p_drop=0.3, bandwidth re-drawn / 10 min (Fig. 12)")

print("\nretention R(0.3) = T(p)/T(0):")
for name in stable:
    r = churny[name].throughput / max(stable[name].throughput, 1e-9)
    print(f"  {name:12s} {r:.2f}")
