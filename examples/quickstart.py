"""Quickstart: the FedOptima pipeline in ~60 lines.

1. Pick an architecture (any of the 10 assigned ids) at smoke scale.
2. Split it at a period boundary (paper Eq. 8 picks the split from device
   profiles; here we take the default).
3. Run a few hybrid rounds: device groups train their block with the
   auxiliary-network local loss; the server trains the rest centrally on
   the activation stream; async aggregation merges device blocks.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import fedopt_step as F
from repro.launch.mesh import make_debug_mesh

ARCH = "smollm-135m"

arch = registry.smoke_config(ARCH)
mesh = make_debug_mesh(1, 1)                   # CPU: a 1x1 debug mesh
cfg = F.FedStepConfig(
    arch=arch,
    l_split=F.default_l_split(arch),           # device-side periods
    n_groups=4,                                # FL device groups
    seq_len=64, per_group_batch=4, H=4,        # 4 local iters per round
    lr_d=0.1, lr_s=0.1)

step, _, state_shardings, _ = F.jit_train_step(cfg, mesh)
state = jax.jit(lambda: F.init_train_state(jax.random.PRNGKey(0), cfg),
                out_shardings=state_shardings)()

print(f"{ARCH}: {arch.n_periods} periods, split at {cfg.l_split} "
      f"(device) / {arch.n_periods - cfg.l_split} (server), "
      f"{cfg.n_groups} groups x H={cfg.H}")

for r in range(8):
    batch = F.concrete_train_batch(jax.random.PRNGKey(100 + r), cfg)
    state, metrics = step(state, batch)
    print(f"round {r+1}: device aux loss {float(metrics['d_loss']):.4f}  "
          f"server loss {float(metrics['s_loss']):.4f}  "
          f"global version {int(state['version'])}")

print("done — devices never waited for the server (activation buffer is "
      "one step stale), and no gradient ever crossed server->device.")
