"""Scenario: batched serving with the merged global model.

After FedOptima training, device + server halves merge into one model
(``merge_params``); serving is standard prefill + KV-cache decode — the
same code paths the decode_32k / long_500k dry-run cells lower at pod
scale.  Demonstrates a hybrid arch (jamba: mamba states + attention KV +
MoE routing in one cache pytree).

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch jamba-1.5-large-398b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import fedopt_step as F
from repro.launch.serve import generate
from repro.models import transformer as tfm


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="jamba-1.5-large-398b")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--new-tokens", type=int, default=12)
    args = p.parse_args()

    arch = registry.smoke_config(args.arch)
    rng = jax.random.PRNGKey(0)

    # train one hybrid round, then merge the halves for serving
    mesh_cfg = F.FedStepConfig(arch=arch, l_split=1, n_groups=2, seq_len=32,
                               per_group_batch=2, H=2)
    from repro.launch.mesh import make_debug_mesh
    step, _, s_spec, _ = F.jit_train_step(mesh_cfg, make_debug_mesh(1, 1))
    state = jax.jit(lambda: F.init_train_state(rng, mesh_cfg),
                    out_shardings=s_spec)()
    state, _ = step(state, F.concrete_train_batch(rng, mesh_cfg))
    dev0 = jax.tree.map(lambda x: x[0], state["dev"])   # any group (merged)
    params = tfm.merge_params(dev0, state["srv"], arch)

    prompts = jax.random.randint(rng, (args.batch, 16), 0, arch.vocab,
                                 jnp.int32)
    frontend = None
    if arch.frontend_len:
        frontend = jax.random.normal(
            rng, (args.batch, arch.frontend_len, arch.d_model))
    t0 = time.time()
    out = generate(params, arch, prompts, new_tokens=args.new_tokens,
                   max_len=16 + args.new_tokens, frontend=frontend)
    dt = time.time() - t0
    assert bool(jnp.isfinite(out).all())
    print(f"[{arch.name}] served {args.batch} requests x "
          f"{args.new_tokens} tokens in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s on CPU smoke)")
    print("sample:", out[0].tolist())


if __name__ == "__main__":
    main()
