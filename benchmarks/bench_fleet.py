"""Fleet emulation: FedOptima vs the baselines under ONE shared trace.

A K=32 capability-sampled fleet (repro.fleet.devices tier mix) runs a
shared diurnal availability trace (repro.fleet.traces): FedOptima under
each participant-selection policy (random / REFL-style refl /
Apodotiko-style score, half-fraction cohorts) plus full participation,
and the baseline protocols under the identical trace.  Per row: device/
server idle, throughput, and the per-device contribution-balance metric
(Gini/CV of consumed counts — Alg. 3's fairness objective measured
fleet-wide).  Results are written to ``BENCH_fleet.json``.
"""
from __future__ import annotations

import os

from repro.core.baselines import REGISTRY
from repro.core.simulation import simulate_fedoptima
from repro.fleet import diurnal_trace, sample_cluster

from . import common
from .common import (MOBILENET_SPLIT, OMEGA, Row, bench_duration,
                     fedoptima_control, timed)

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_fleet.json")

K = 32
TIERS = "low:2,mid:3,high:2,premium:1"
POLICIES = ("random:0.5", "refl:0.5", "score:0.5")
BASELINES = ("fl", "fedasync", "fedbuff", "oafl")


def _shared_scenario(dur):
    cluster = sample_cluster(K, TIERS, seed=11)
    # two diurnal cycles over the run so every policy sees both ramps;
    # ~60% aggregate availability with per-device phase spread, link
    # bandwidths jittering around the tier-sampled per-device medians
    trace = diurnal_trace(K, horizon=dur, interval=dur / 24.0, day=dur / 2.0,
                          on_frac=0.6, bw=cluster.dev_bw, bw_jitter=0.3,
                          seed=7)
    return cluster, trace


def _entry(m, extra=None):
    bal = m.contribution_balance()
    out = {"srv_idle": m.srv_idle_frac, "dev_idle": m.dev_idle_frac,
           "throughput": m.throughput, "balance": bal}
    out.update(extra or {})
    return out


def _derived(m):
    bal = m.contribution_balance()
    return (f"srv_idle={m.srv_idle_frac:.3f};dev_idle={m.dev_idle_frac:.3f}"
            f";tput={m.throughput:.1f};gini={bal['gini']:.3f}"
            f";cv={bal['cv']:.3f}")


def main() -> list[Row]:
    dur = bench_duration(3600.0, smoke=120.0)
    cluster, trace = _shared_scenario(dur)
    rows = []
    record = {"K": K, "duration": dur, "tiers": TIERS,
              "trace": trace.meta,
              "availability": [float(a) for a in trace.availability()],
              "fedoptima": {}, "baselines": {}}

    for spec in ("all",) + POLICIES:
        sel = None if spec == "all" else spec
        cp = fedoptima_control(cluster)
        m, us = timed(simulate_fedoptima, MOBILENET_SPLIT, cluster,
                      duration=dur, omega=OMEGA, fleet=trace,
                      selection=sel, control=cp)
        assert cp.flow.within_cap, "tiered cap violated under the trace"
        rows.append(Row(f"fleet/fedoptima/{spec}", us, _derived(m)))
        record["fedoptima"][spec] = _entry(
            m, {"peak_buffered": cp.peak_buffered,
                "accepted": cp.n_accepted, "rejected": cp.n_rejected})

    for name in BASELINES:
        m, us = timed(REGISTRY[name], MOBILENET_SPLIT, cluster,
                      duration=dur, fleet=trace)
        rows.append(Row(f"fleet/{name}", us, _derived(m)))
        record["baselines"][name] = _entry(m)

    common.write_record(OUT_PATH, record)
    rows.append(Row("fleet/json", 0.0, f"wrote={os.path.basename(OUT_PATH)}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r.csv())
