"""Fig. 15: counter-based vs FIFO task scheduling under heterogeneity —
per-device consumption balance and end accuracy."""
from __future__ import annotations

import numpy as np

from repro.core.learning import FedOptimaLearner, ModelAdapter
from repro.core.simulation import heterogeneous_cluster, simulate_fedoptima
from repro.data.partitioner import dirichlet_partition
from repro.data.pipeline import DeviceDataset
from repro.data.synthetic import classification_dataset
from repro.models import cnn

from .common import Row, VGG5_SPLIT, timed

K = 8
DUR = 30.0


def main() -> list[Row]:
    data = classification_dataset(2048, 10, img_size=8, seed=1, noise=2.5)
    parts = dirichlet_partition(data.y, K, alpha=0.5, seed=1)
    cfg = cnn.vgg5_config(n_classes=10, img_size=8)
    adapter = ModelAdapter(cnn, cfg)
    xe, ye = data.x[:512], data.y[:512]
    cluster = heterogeneous_cluster(K)   # 4x speed spread -> FIFO skews

    rows = []
    for policy in ("counter", "fifo"):
        datasets = [DeviceDataset(data.x[ix], data.y[ix], batch=32, seed=g)
                    for g, ix in enumerate(parts)]
        learner = FedOptimaLearner(adapter, datasets, l_split=1,
                                   lr_d=0.05, lr_s=0.05)
        m, us = timed(simulate_fedoptima, VGG5_SPLIT, cluster, duration=DUR,
                      omega=4, policy=policy, hooks=learner)
        acc = learner.eval_accuracy(xe, ye)
        rows.append(Row(f"ablation_sched/{policy}", us,
                        f"acc={acc:.3f};srv_batches={m.srv_batches}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r.csv())
