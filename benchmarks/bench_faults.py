"""Chaos plane: goodput under faults, with and without recovery armor.

A K=32 capability-sampled fleet on the shared diurnal trace (same
scenario as ``bench_fleet``) runs FedOptima three times against ONE
seeded dense fault schedule (``repro.faults``):

* **clean** — no faults: the goodput ceiling for this trace.
* **faulted** — faults injected, recovery DISARMED (``fault_gate=False``):
  poisoned activation batches flow through and the server spends compute
  on them (badput); delayed/duplicate arrivals are still absorbed by the
  protocol itself (staleness weighting / dedup are structural, not
  optional).
* **faulted+recovery** — the default :class:`repro.faults.UpdateGate`
  quarantines poison at arrival (flow token withdrawn, strike counters,
  re-admission backoff), so every injected fault class is matched by a
  recovery disposition.

Per leg: server batches consumed, **goodput** (batches minus poisoned
ones the server consumed), badput fraction, and the injector's full
accounting report.  Results land in ``BENCH_faults.json``; the headline
comparison is goodput_clean >= goodput_recovered >> goodput_unarmored's
*useful* share even when raw srv_batches look similar.
"""
from __future__ import annotations

import os

from repro.core.simulation import simulate_fedoptima
from repro.faults import make_fault_schedule
from repro.fleet import diurnal_trace, sample_cluster

from . import common
from .common import (MOBILENET_SPLIT, OMEGA, Row, bench_duration,
                     fedoptima_control, timed)

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_faults.json")

K = 32
TIERS = "low:2,mid:3,high:2,premium:1"
#: ceil(density * K / 4) events per fault class — dense: ~2 per device
#: across the schedule's classes
DENSITY = 2.0

#: activation dispositions that mean the server consumed a poisoned batch
_BADPUT_KEYS = ("admitted_poisoned_act", "gate_missed_act")


def _shared_scenario(dur):
    cluster = sample_cluster(K, TIERS, seed=11)
    trace = diurnal_trace(K, horizon=dur, interval=dur / 24.0, day=dur / 2.0,
                          on_frac=0.6, bw=cluster.dev_bw, bw_jitter=0.3,
                          seed=7)
    return cluster, trace


def _badput(report) -> int:
    if report is None:
        return 0
    disp = report.get("disposition", {})
    return sum(int(disp.get(k, 0)) for k in _BADPUT_KEYS)


def _entry(m):
    report = m.faults
    bad = _badput(report)
    good = max(int(m.srv_batches) - bad, 0)
    out = {"srv_batches": int(m.srv_batches), "goodput_batches": good,
           "badput_batches": bad,
           "badput_frac": bad / max(int(m.srv_batches), 1),
           "throughput": m.throughput, "srv_idle": m.srv_idle_frac,
           "dev_idle": m.dev_idle_frac}
    if report is not None:
        out["faults"] = report
    return out


def _derived(m):
    e = _entry(m)
    matched = "" if m.faults is None else f";matched={m.faults['matched']}"
    return (f"goodput={e['goodput_batches']};badput={e['badput_batches']}"
            f";srv_batches={e['srv_batches']};tput={m.throughput:.1f}"
            f"{matched}")


def main() -> list[Row]:
    dur = bench_duration(3600.0, smoke=120.0)
    cluster, trace = _shared_scenario(dur)
    sched = make_fault_schedule(K, dur, seed=5, density=DENSITY)
    rows = []
    record = {"K": K, "duration": dur, "tiers": TIERS, "density": DENSITY,
              "schedule": sched.counts(), "trace": trace.meta, "legs": {}}

    legs = (("clean", {}),
            ("faulted", {"faults": sched, "fault_gate": False}),
            ("faulted_recovery", {"faults": sched}))
    for name, kw in legs:
        cp = fedoptima_control(cluster)
        m, us = timed(simulate_fedoptima, MOBILENET_SPLIT, cluster,
                      duration=dur, omega=OMEGA, fleet=trace, control=cp,
                      **kw)
        if not cp.flow.within_cap:
            raise RuntimeError(f"faults/{name}: flow cap violated — "
                               "quarantine leaked a token")
        rows.append(Row(f"faults/{name}", us, _derived(m)))
        record["legs"][name] = _entry(m)

    rec = record["legs"]["faulted_recovery"].get("faults")
    if rec is not None and not rec["matched"]:
        raise RuntimeError("faults/faulted_recovery: injected faults were "
                           f"not all matched by recovery: {rec}")

    common.write_record(OUT_PATH, record)
    rows.append(Row("faults/json", 0.0, f"wrote={os.path.basename(OUT_PATH)}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r.csv())
