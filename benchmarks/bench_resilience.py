"""Fig. 12/13: throughput retention R(p) = T(p)/T(0) under churn +
bandwidth variation (§6.4 protocol: re-draw every 10 sim-minutes)."""
from __future__ import annotations

from repro.core.baselines import simulate_fedasync, simulate_pipar
from repro.core.simulation import simulate_fedoptima
from repro.runtime.fault_tolerance import ChurnModel

from .common import (MOBILENET_SPLIT, Row, TRANSFORMER12_SPLIT, testbed_b,
                     timed)

DUR = 3600.0
PS = (0.0, 0.1, 0.3, 0.5)


def retention(sim_fn, model, cluster, tag):
    rows = []
    base = None
    for p in PS:
        churn = (None if p == 0.0 else
                 ChurnModel(n_devices=cluster.K, p_drop=p, interval=600.0,
                            bw_lo=50e6 / 8, bw_hi=100e6 / 8, seed=int(p * 10)))
        m, us = timed(sim_fn, model, cluster, duration=DUR, churn=churn)
        if p == 0.0:
            base = m.throughput
        r = m.throughput / max(base, 1e-9)
        rows.append(Row(f"resilience/{tag}/p={p}", us,
                        f"throughput={m.throughput:.1f};R={r:.3f}"))
    return rows


def main() -> list[Row]:
    cluster = testbed_b()
    rows = []
    rows += retention(lambda m, c, **kw: simulate_fedoptima(m, c, omega=8, **kw),
                      MOBILENET_SPLIT, cluster, "B_image/fedoptima")
    rows += retention(simulate_fedasync, MOBILENET_SPLIT, cluster,
                      "B_image/fedasync")
    rows += retention(lambda m, c, **kw: simulate_fedoptima(m, c, omega=8, **kw),
                      TRANSFORMER12_SPLIT, cluster, "B_text/fedoptima")
    rows += retention(simulate_pipar, TRANSFORMER12_SPLIT, cluster,
                      "B_text/pipar")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r.csv())
