"""Pallas kernel micro-benchmarks: fused flash-attention / SSD vs the
pure-JAX references, forward and forward+backward, at a few training-shaped
sizes.  Emits the usual CSV rows AND writes ``BENCH_kernels.json`` at the
repo root so the kernel-path perf trajectory is tracked across PRs.

On CPU the kernels run in interpret mode (the Pallas grid executed by a
Python interpreter), so absolute numbers measure program *logic*, not TPU
performance — the JSON records backend + mode so trajectories only compare
like with like.  On a TPU backend the same harness times the Mosaic
kernels.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.models.attention import sdpa_chunked

from . import common
from .common import Row, timed

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_kernels.json")

# (B, S, H, Hkv, hd) — GQA training shapes, small enough for interpret mode
ATTN_SHAPES = [(1, 256, 8, 2, 64), (2, 512, 8, 2, 64)]
# (B, T, H, P, G, N, chunk)
SSD_SHAPES = [(1, 256, 8, 64, 1, 32, 64), (2, 512, 8, 64, 1, 32, 128)]

REPEAT = 3


def _block(x):
    jax.block_until_ready(x)
    return x


def _time_pair(fwd_fn, args):
    """(fwd_us, fwd+bwd_us) for a scalar-loss wrapper of fwd_fn, both
    jit-compiled and warmed before timing."""
    f = jax.jit(lambda *a: fwd_fn(*a))
    g = jax.jit(jax.value_and_grad(lambda *a: jnp.sum(fwd_fn(*a)) ** 2,
                                   argnums=tuple(range(len(args)))))
    _block(f(*args))                       # compile
    _block(g(*args))
    _, fwd_us = timed(lambda: _block(f(*args)), repeat=REPEAT)
    _, bwd_us = timed(lambda: _block(g(*args)), repeat=REPEAT)
    return fwd_us, bwd_us


def _bench_attention(record):
    rows = []
    for B, S, H, Hkv, hd in ATTN_SHAPES:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, S, H, hd))
        k = jax.random.normal(ks[1], (B, S, Hkv, hd))
        v = jax.random.normal(ks[2], (B, S, Hkv, hd))
        name = f"attn_b{B}_s{S}_h{H}kv{Hkv}_d{hd}"
        kf, kb = _time_pair(
            lambda q, k, v: ops.flash_attention(q, k, v, causal=True),
            (q, k, v))
        rf, rb = _time_pair(
            lambda q, k, v: sdpa_chunked(q, k, v, causal=True, window=None,
                                         logit_cap=None, chunk_q=128),
            (q, k, v))
        record[name] = {"kernel_fwd_us": kf, "kernel_fwd_bwd_us": kb,
                        "ref_fwd_us": rf, "ref_fwd_bwd_us": rb}
        rows.append(Row(f"kernels/{name}/fwd", kf, f"ref_us={rf:.1f}"))
        rows.append(Row(f"kernels/{name}/fwd_bwd", kb, f"ref_us={rb:.1f}"))
    return rows


def _bench_ssd(record):
    rows = []
    for B, T, H, P, G, N, chunk in SSD_SHAPES:
        ks = jax.random.split(jax.random.PRNGKey(1), 4)
        x = jax.random.normal(ks[0], (B, T, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)) - 1.0)
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
        Bm = jax.random.normal(ks[3], (B, T, G, N)) * 0.5
        Cm = jax.random.normal(jax.random.fold_in(ks[3], 1),
                               (B, T, G, N)) * 0.5
        name = f"ssd_b{B}_t{T}_h{H}p{P}_n{N}_q{chunk}"
        kf, kb = _time_pair(
            lambda *a: ops.ssd(*a, chunk=chunk), (x, dt, A, Bm, Cm))
        rf, rb = _time_pair(
            lambda *a: ref.ssd_reference(*a)[0], (x, dt, A, Bm, Cm))
        record[name] = {"kernel_fwd_us": kf, "kernel_fwd_bwd_us": kb,
                        "ref_fwd_us": rf, "ref_fwd_bwd_us": rb}
        rows.append(Row(f"kernels/{name}/fwd", kf, f"ref_us={rf:.1f}"))
        rows.append(Row(f"kernels/{name}/fwd_bwd", kb, f"ref_us={rb:.1f}"))
    return rows


def main() -> list[Row]:
    record: dict = {"backend": jax.default_backend(),
                    "interpret": ops._interpret(), "repeat": REPEAT}
    rows = _bench_attention(record) + _bench_ssd(record)
    common.write_record(OUT_PATH, record)
    rows.append(Row("kernels/json", 0.0,
                    f"wrote={os.path.basename(OUT_PATH)}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r.csv())
