"""Fig. 14: auxiliary-network design ablation (default / no-aux / only-
classifier / deep) — convergence of the local loss + end accuracy with
everything else fixed."""
from __future__ import annotations

from repro.core.learning import FedOptimaLearner, ModelAdapter, SplitLearner
from repro.core.simulation import heterogeneous_cluster, simulate_fedoptima
from repro.core.baselines import simulate_oafl
from repro.data.partitioner import dirichlet_partition
from repro.data.pipeline import DeviceDataset
from repro.data.synthetic import classification_dataset
from repro.models import cnn

from .common import Row, VGG5_SPLIT, timed

K = 4
DUR = 45.0


def main() -> list[Row]:
    data = classification_dataset(2048, 10, img_size=8, seed=0, noise=2.5)
    parts = dirichlet_partition(data.y, K, alpha=0.5, seed=0)
    cfg = cnn.vgg5_config(n_classes=10, img_size=8)
    adapter = ModelAdapter(cnn, cfg)
    xe, ye = data.x[:512], data.y[:512]
    cluster = heterogeneous_cluster(K)

    rows = []
    for variant in ("default", "classifier_only", "deep"):
        datasets = [DeviceDataset(data.x[ix], data.y[ix], batch=32, seed=g)
                    for g, ix in enumerate(parts)]
        learner = FedOptimaLearner(adapter, datasets, l_split=1,
                                   aux_variant=variant, lr_d=0.05, lr_s=0.05)
        _, us = timed(simulate_fedoptima, VGG5_SPLIT, cluster, duration=DUR,
                      omega=4, hooks=learner)
        acc = learner.eval_accuracy(xe, ye)
        rows.append(Row(f"ablation_aux/{variant}", us, f"acc={acc:.3f}"))

    # "no aux network" == gradients from the server (SplitFed-style wire)
    datasets = [DeviceDataset(data.x[ix], data.y[ix], batch=32, seed=g)
                for g, ix in enumerate(parts)]
    no_aux = SplitLearner(adapter, datasets, l_split=1, lr=0.05)
    _, us = timed(simulate_oafl, VGG5_SPLIT, cluster, duration=DUR,
                  hooks=no_aux)
    rows.append(Row("ablation_aux/no_aux(grad_return)", us,
                    f"acc={no_aux.eval_accuracy(xe, ye):.3f}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r.csv())
