"""Shared benchmark harness: the paper's testbeds as simulator configs.

Testbed A: 8 devices (Raspberry Pi classes, 4 speed groups), CPU server,
50 Mbps links.  Testbed B: 16 devices (Jetson classes), GPU server,
100 Mbps links.  Speed ratios follow Table 3; absolute scales are nominal
(the figures reproduce *relative* orderings — see DESIGN.md §7)."""
from __future__ import annotations

import time
from dataclasses import dataclass

from repro.core.control_plane import ControlPlane
from repro.core.simulation import SimModel, SimCluster, heterogeneous_cluster

#: The paper's default global activation cap (Eq. 3) used across benchmarks.
OMEGA = 8


def fedoptima_control(cluster: SimCluster, omega: int = OMEGA,
                      **kw) -> ControlPlane:
    """The integrated host control plane for a FedOptima simulation run:
    per-device flow units so Σ_k |Q_k^act| ≤ ω is the strict Eq. 3 cap.
    Pass as ``simulate_fedoptima(..., control=...)`` and inspect
    ``peak_buffered`` / ``consumption`` afterwards."""
    return ControlPlane.for_sim(cluster.K, omega, **kw)

# device-side / server-side per-batch costs for a VGG-5-like split (batch 32)
VGG5_SPLIT = SimModel(
    dev_fwd_flops=1.2e9, dev_bwd_flops=2.4e9, full_fwd_flops=7.5e9,
    srv_flops_per_batch=1.9e10, act_bytes=2.1e6, dev_model_bytes=0.5e6,
    full_model_bytes=8e6, batch_size=32)

# MobileNetV3-Large-ish on Tiny ImageNet (batch 32)
MOBILENET_SPLIT = SimModel(
    dev_fwd_flops=2.5e9, dev_bwd_flops=5.0e9, full_fwd_flops=1.4e10,
    srv_flops_per_batch=2.6e10, act_bytes=3.2e6, dev_model_bytes=1.2e6,
    full_model_bytes=2.2e7, batch_size=32)

# Transformer-6 on SST-2 (batch 32, seq 64)
TRANSFORMER6_SPLIT = SimModel(
    dev_fwd_flops=0.8e9, dev_bwd_flops=1.6e9, full_fwd_flops=4.6e9,
    srv_flops_per_batch=1.2e10, act_bytes=0.82e6, dev_model_bytes=0.7e6,
    full_model_bytes=4.5e6, batch_size=32)

# Transformer-12 on IMDB (batch 32, seq 128)
TRANSFORMER12_SPLIT = SimModel(
    dev_fwd_flops=1.6e9, dev_bwd_flops=3.2e9, full_fwd_flops=1.05e10,
    srv_flops_per_batch=2.6e10, act_bytes=1.64e6, dev_model_bytes=0.8e6,
    full_model_bytes=9e6, batch_size=32)


def testbed_a() -> SimCluster:
    return heterogeneous_cluster(8, base_flops=3e9,
                                 speed_groups=(1.0, 2.0, 2.0, 3.0),
                                 bw=50e6 / 8, srv_ratio=20.0)


def testbed_b() -> SimCluster:
    return heterogeneous_cluster(16, base_flops=8e9,
                                 speed_groups=(1.0, 1.33, 2.67, 3.84),
                                 bw=100e6 / 8, srv_ratio=50.0)


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6
