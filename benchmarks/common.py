"""Shared benchmark harness: the paper's testbeds as simulator configs.

Testbed A: 8 devices (Raspberry Pi classes, 4 speed groups), CPU server,
50 Mbps links.  Testbed B: 16 devices (Jetson classes), GPU server,
100 Mbps links.  Speed ratios follow Table 3; absolute scales are nominal
(the figures reproduce *relative* orderings — see DESIGN.md §7).

Every ``BENCH_*.json`` record should be written through
:func:`write_record`, which stamps an ``env`` block (backend, device
kind, jax/numpy versions, interpret-mode flag, smoke flag) so numbers
like the kernel suite's cpu-interpret timings are self-describing
instead of relying on out-of-band knowledge of where they ran."""
from __future__ import annotations

import json
import os
import platform
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass

import numpy as np

from repro.core.control_plane import ControlPlane
from repro.core.simulation import SimModel, SimCluster, heterogeneous_cluster

#: The paper's default global activation cap (Eq. 3) used across benchmarks.
OMEGA = 8

#: Smoke mode (CI): tiny simulated durations / fewer rounds so the full
#: benchmark path runs in seconds.  Set by ``run.py --smoke`` or the
#: BENCH_SMOKE env var; results are for wiring checks, not trajectories.
SMOKE = os.environ.get("BENCH_SMOKE", "") not in ("", "0")


def bench_duration(default: float, smoke: float = 30.0) -> float:
    if SMOKE:
        return smoke
    return float(os.environ.get("BENCH_DUR", default))


def env_meta() -> dict:
    """Execution-environment stamp for benchmark records: which backend
    produced the numbers (cpu ⇒ Pallas kernels ran in interpret mode —
    shape/semantics checks, not device performance), under which jax."""
    import jax
    dev = jax.devices()[0]
    return {"jax_version": jax.__version__,
            "numpy_version": np.__version__,
            "backend": dev.platform,
            "device_kind": dev.device_kind,
            "n_devices": jax.device_count(),
            "pallas_interpret": dev.platform == "cpu",
            "python": platform.python_version(),
            "platform": platform.platform(),
            "smoke": SMOKE}


def write_record(path: str, record: dict, registry=None) -> None:
    """Write one BENCH_*.json record, stamped with :func:`env_meta`
    (callers may pre-set ``env`` to override).  ``registry`` (a
    :class:`repro.obs.metrics.MetricsRegistry`) merges its snapshot under
    a ``"metrics"`` key — the benchmark's own instruments ride the
    record instead of a second ad-hoc accounting block."""
    record.setdefault("env", env_meta())
    if registry is not None:
        record.setdefault("metrics", registry.snapshot())
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {path}")


def fedoptima_control(cluster: SimCluster, omega: int = OMEGA,
                      **kw) -> ControlPlane:
    """The integrated host control plane for a FedOptima simulation run:
    per-device flow units so Σ_k |Q_k^act| ≤ ω is the strict Eq. 3 cap
    (pass ``pool_cap=`` to admit against the tiered ω + pool budget
    instead — the server memory manager's spill tier).  Pass as
    ``simulate_fedoptima(..., control=...)`` and inspect
    ``peak_buffered`` / ``consumption`` / ``memory_summary`` afterwards."""
    return ControlPlane.for_sim(cluster.K, omega, **kw)

# device-side / server-side per-batch costs for a VGG-5-like split (batch 32)
VGG5_SPLIT = SimModel(
    dev_fwd_flops=1.2e9, dev_bwd_flops=2.4e9, full_fwd_flops=7.5e9,
    srv_flops_per_batch=1.9e10, act_bytes=2.1e6, dev_model_bytes=0.5e6,
    full_model_bytes=8e6, batch_size=32)

# MobileNetV3-Large-ish on Tiny ImageNet (batch 32)
MOBILENET_SPLIT = SimModel(
    dev_fwd_flops=2.5e9, dev_bwd_flops=5.0e9, full_fwd_flops=1.4e10,
    srv_flops_per_batch=2.6e10, act_bytes=3.2e6, dev_model_bytes=1.2e6,
    full_model_bytes=2.2e7, batch_size=32)

# Transformer-6 on SST-2 (batch 32, seq 64)
TRANSFORMER6_SPLIT = SimModel(
    dev_fwd_flops=0.8e9, dev_bwd_flops=1.6e9, full_fwd_flops=4.6e9,
    srv_flops_per_batch=1.2e10, act_bytes=0.82e6, dev_model_bytes=0.7e6,
    full_model_bytes=4.5e6, batch_size=32)

# Transformer-12 on IMDB (batch 32, seq 128)
TRANSFORMER12_SPLIT = SimModel(
    dev_fwd_flops=1.6e9, dev_bwd_flops=3.2e9, full_fwd_flops=1.05e10,
    srv_flops_per_batch=2.6e10, act_bytes=1.64e6, dev_model_bytes=0.8e6,
    full_model_bytes=9e6, batch_size=32)


def run_protocol_grid(model: SimModel, cluster: SimCluster, *,
                      duration: float, omega: int = OMEGA,
                      registry=None, trace: bool = False,
                      control_kw: dict | None = None):
    """Run FedOptima + every registered baseline once on (model, cluster).

    The shared per-protocol loop behind ``bench_idle`` and
    ``bench_throughput``: one FedOptima run through the integrated
    :class:`ControlPlane` plus each :data:`repro.core.baselines.REGISTRY`
    entry, each wall-timed through the unified metrics registry
    (``bench.us.<protocol>`` histograms — a re-run of the same grid
    accumulates instead of overwriting).

    ``trace=True`` attaches a fresh sim-domain ``Tracer`` per protocol so
    callers can feed :func:`repro.obs.idle.attribute_idle` (the tracer is
    detached between protocols: each trace covers exactly one run).

    Returns ``(results, registry, cp)``: ``results`` maps protocol name
    -> ``{"metrics", "us", "tracer"}`` (tracer ``None`` when off), and
    ``cp`` is FedOptima's control plane for ω-cap assertions.
    """
    from repro.core.baselines import REGISTRY
    from repro.core.simulation import simulate_fedoptima
    from repro.obs.metrics import MetricsRegistry
    from repro.obs.trace import Tracer, traced

    reg = registry if registry is not None else MetricsRegistry()
    cp = fedoptima_control(cluster, omega, **(control_kw or {}))
    results: dict = {}

    def one(name, fn, *args, **kw):
        tracer = Tracer(domain="sim") if trace else None
        if tracer is not None:
            with traced(tracer):
                m, us = timed(fn, *args, **kw)
        else:
            m, us = timed(fn, *args, **kw)
        # benchmark wall times span µs..minutes; widen the bucket range
        reg.histogram(f"bench.us.{name}", lo=1.0, hi=1e9).observe(us)
        results[name] = {"metrics": m, "us": us, "tracer": tracer}

    one("fedoptima", simulate_fedoptima, model, cluster,
        duration=duration, omega=omega, control=cp)
    for name, fn in REGISTRY.items():
        one(name, fn, model, cluster, duration=duration)
    return results, reg, cp


def testbed_a() -> SimCluster:
    return heterogeneous_cluster(8, base_flops=3e9,
                                 speed_groups=(1.0, 2.0, 2.0, 3.0),
                                 bw=50e6 / 8, srv_ratio=20.0)


def testbed_b() -> SimCluster:
    return heterogeneous_cluster(16, base_flops=8e9,
                                 speed_groups=(1.0, 1.33, 2.67, 3.84),
                                 bw=100e6 / 8, srv_ratio=50.0)


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


# ---------------------------------------------------------------------------
# Executor-overlap harness: a future-backed device stand-in
# ---------------------------------------------------------------------------

class StubDevice:
    """Async-dispatch stand-in for the jit'd hybrid step.

    ``step(state, batch)`` queues a round of duration ``round_s`` on a
    single worker thread (a serialized device queue, like one mesh) and
    returns immediately; the metrics are futures whose ``float()`` blocks
    until that round completes — exactly the contract ``RoundExecutor``
    drains against.  Use as a context manager (or call ``close``) so the
    worker thread doesn't outlive the measurement.
    """

    class _Lazy:
        def __init__(self, fut):
            self._fut = fut

        def __float__(self):
            return float(self._fut.result())

    def __init__(self, round_s: float):
        self.round_s = round_s
        self._pool = ThreadPoolExecutor(max_workers=1)

    def _run(self):
        time.sleep(self.round_s)
        return 0.0

    def step(self, state, batch):
        fut = self._pool.submit(self._run)
        return state, {"d_loss": self._Lazy(fut), "s_loss": self._Lazy(fut)}

    def close(self):
        self._pool.shutdown(wait=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def executor_overlap(model: SimModel, cluster: SimCluster, *, H: int = 8,
                     rounds: int = 20, window: int = 2,
                     sim_time_scale: float = 0.004,
                     host_frac: float = 0.4,
                     host_burst_every: int = 0,
                     host_burst_frac: float = 1.0,
                     checkpoint_every: int = 0,
                     checkpoint_flush: bool = False,
                     ckpt_save_s: float | None = None,
                     state_bytes: int = 0) -> dict:
    """Measure RoundExecutor overlap on a modeled workload.

    The stub device round is the testbed's lockstep cost — H × the
    slowest device's per-iteration time from the SimModel/cluster cost
    accounting — compressed by ``sim_time_scale`` (simulated seconds →
    benchmark wall seconds, clamped to [10 ms, 100 ms] so every testbed
    finishes quickly but still dwarfs scheduler noise).  Host batch
    assembly is modeled at ``host_frac`` of the device round (the pod
    driver's Python-side shard packing); every ``host_burst_every``-th
    round costs ``host_burst_frac`` × that (periodic host spikes — re-
    partitioning, logging, GC — the load deep windows exist to amortize:
    a window shallower than the burst cadence exposes each spike).

    ``checkpoint_every`` > 0 models the save path: ``ckpt_save_s``
    (default 1.5 × the device round — np.savez of a real state dwarfs
    one round) is slept per save, after a full pipeline drain when
    ``checkpoint_flush`` else via the deferred no-flush handle path.
    ``state_bytes`` sizes a real numpy state dict so handle-ring/
    checkpoint byte accounting is measured, not modeled.

    Returns wall/round for the given window plus the executor's own
    overlap accounting (incl. steady-state exposure excluding the
    ``window`` warmup rounds, handle-ring peaks, and save counters).
    """
    from repro.core.executor import RoundExecutor

    t_iter = (model.dev_fwd_flops + model.dev_bwd_flops) / \
        np.asarray(cluster.dev_flops, float)
    round_sim_s = H * float(t_iter.max())
    round_s = float(np.clip(round_sim_s * sim_time_scale, 0.01, 0.1))
    host_s = host_frac * round_s
    save_s = 1.5 * round_s if ckpt_save_s is None else float(ckpt_save_s)
    cp = ControlPlane(cluster.K, OMEGA, H)
    state = {"params": np.zeros(max(state_bytes, 4) // 4, np.float32)} \
        if state_bytes else 0

    def batch_fn(r, plan):
        mult = host_burst_frac if host_burst_every and \
            r % host_burst_every == 0 else 1.0
        time.sleep(host_s * mult)   # modeled host batch-assembly cost
        return {}

    def checkpoint_fn(r, handle):
        time.sleep(save_s)          # modeled np.savez + fsync

    ckpt_kw = {}
    if checkpoint_every:
        ckpt_kw = dict(checkpoint_every=checkpoint_every,
                       checkpoint_fn=checkpoint_fn,
                       capture_fn=lambda r: None,
                       checkpoint_flush=checkpoint_flush)

    with StubDevice(round_s) as dev:
        ex = RoundExecutor(dev.step, cp, window=window)
        t0 = time.perf_counter()
        _, hist = ex.run(state, 0, rounds,
                         active_fn=lambda r: np.ones(cluster.K, bool),
                         batch_fn=batch_fn, **ckpt_kw)
        wall = time.perf_counter() - t0
    out = ex.summary()
    out.update(wall_s=wall, wall_s_per_round=wall / max(rounds, 1),
               rounds_per_s=max(rounds, 1) / wall,
               round_sim_s=round_sim_s, stub_round_s=round_s,
               host_s_modeled=host_s, rounds_completed=len(hist),
               plan_us=1e6 * float(np.mean([s.plan_s for s in ex.stats]))
               if ex.stats else 0.0)
    return out


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6
