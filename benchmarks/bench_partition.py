"""Eq. 6-8: split-point selection cost curves for the paper models and the
assigned LM architectures under the two testbed profiles."""
from __future__ import annotations

import numpy as np

from repro.configs import registry
from repro.core.partition import (cnn_profile, select_split, split_costs,
                                  transformer_profile)
from repro.models.cnn import mobilenetv3ish_config, vgg5_config

from .common import Row, testbed_a, testbed_b, timed


def main() -> list[Row]:
    rows = []
    for tag, prof, cluster in (
            ("vgg5/A", cnn_profile(vgg5_config()), testbed_a()),
            ("mobilenet/B", cnn_profile(mobilenetv3ish_config()), testbed_b())):
        l, us = timed(select_split, prof, cluster.dev_flops, cluster.dev_bw)
        c = split_costs(prof, cluster.dev_flops, cluster.dev_bw)
        rows.append(Row(f"partition/{tag}", us,
                        f"l_star={l};cost_s={c[l-1]:.4f};units={prof.n_units}"))
    for name in ("smollm-135m", "qwen3-32b", "jamba-1.5-large-398b",
                 "qwen3-moe-235b-a22b"):
        cfg = registry.get(name)
        prof = transformer_profile(cfg, seq=4096)
        cluster = testbed_b()
        l, us = timed(select_split, prof, cluster.dev_flops, cluster.dev_bw)
        rows.append(Row(f"partition/{name}/B", us,
                        f"l_star={l};periods={prof.n_units}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r.csv())
