"""Fig. 2: communication volume per training round (OFL vs OAFL vs
FedOptima).  A round = training over the full distributed dataset."""
from __future__ import annotations

from repro.core.baselines import simulate_oafl, simulate_splitfed
from repro.core.simulation import simulate_fedoptima

from .common import MOBILENET_SPLIT, Row, testbed_b, timed

DUR = 600.0
TOTAL = 16 * 6250      # nominal Tiny ImageNet split across 16 devices


def main() -> list[Row]:
    cluster = testbed_b()
    rows = []
    ofl, us1 = timed(simulate_splitfed, MOBILENET_SPLIT, cluster, duration=DUR)
    oafl, us2 = timed(simulate_oafl, MOBILENET_SPLIT, cluster, duration=DUR)
    fo, us3 = timed(simulate_fedoptima, MOBILENET_SPLIT, cluster,
                    duration=DUR, omega=8)
    c_ofl = ofl.comm_per_round(TOTAL)
    c_oafl = oafl.comm_per_round(TOTAL)
    c_fo = fo.comm_per_round(TOTAL)
    rows.append(Row("comm/ofl(splitfed)", us1, f"MB_per_round={c_ofl/1e6:.1f}"))
    rows.append(Row("comm/oafl", us2, f"MB_per_round={c_oafl/1e6:.1f}"))
    rows.append(Row("comm/fedoptima", us3, f"MB_per_round={c_fo/1e6:.1f}"))
    rows.append(Row("comm/oafl_increase_over_ofl", 0.0,
                    f"pct={(c_oafl/c_ofl - 1):.1%}"))
    rows.append(Row("comm/fedoptima_reduction_vs_oafl", 0.0,
                    f"pct={(1 - c_fo/c_oafl):.1%}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r.csv())
