"""Fig. 3 / Eq. 2-3: server memory vs number of devices.

OAFL: μ = (K+1)·μ_model + K·μ_act (a server-side model per device).
FedOptima: μ = μ_model + ω·μ_act (one model + a global activation cap) —
verified against the integrated ControlPlane's actual peak buffer
occupancy (the simulator asserts the flow-control cap on every enqueue,
so Σ|Q_act| ≤ ω holds *during* the run, not just at the end)."""
from __future__ import annotations

from repro.core.simulation import simulate_fedoptima

from .common import MOBILENET_SPLIT, OMEGA, Row, fedoptima_control, \
    testbed_b, timed
from repro.core.simulation import SimCluster
import numpy as np

MU_MODEL = 22e6       # server-side MobileNetV3 block bytes
MU_ACT = 3.2e6        # one activation batch


def main() -> list[Row]:
    rows = []
    for K in (8, 16, 32, 64, 128, 256):
        oafl = (K + 1) * MU_MODEL + K * MU_ACT
        fed = MU_MODEL + OMEGA * MU_ACT
        rows.append(Row(f"memory/K={K}/oafl_eq2", 0.0,
                        f"GB={oafl/1e9:.3f}"))
        rows.append(Row(f"memory/K={K}/fedoptima_eq3", 0.0,
                        f"GB={fed/1e9:.3f}"))
    # verify the cap empirically: peak buffered activations ≤ ω for any K
    for K in (8, 32, 128):
        cluster = SimCluster(dev_flops=np.full(K, 5e9),
                             dev_bw=np.full(K, 100e6 / 8), srv_flops=4e11)
        cp = fedoptima_control(cluster)
        m, us = timed(simulate_fedoptima, MOBILENET_SPLIT, cluster,
                      duration=120.0, omega=OMEGA, control=cp)
        rows.append(Row(f"memory/K={K}/sim_peak_buffer", us,
                        f"max_buffered={m.max_buffered};omega={OMEGA}"
                        f";cp_peak={cp.peak_buffered}"))
        assert m.max_buffered <= OMEGA
        assert cp.peak_buffered <= OMEGA and cp.flow.within_cap
    # 8 GB server bound (paper: OAFL caps out at 26 devices)
    k_max_oafl = int((8e9 - MU_MODEL) / (MU_MODEL + MU_ACT))
    rows.append(Row("memory/oafl_max_devices_8GB", 0.0, f"K={k_max_oafl}"))
    rows.append(Row("memory/fedoptima_max_devices_8GB", 0.0, "K=unbounded"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r.csv())
